"""Fault-tolerant checkpointing (np-backed, reshard-on-load).

Design (1000+-node posture, scaled to this container):

  * checkpoints store *logical* arrays (flattened pytree -> .npy entries),
    never device tiles — restoring onto a different mesh (elastic
    downsize/upsize) is just ``device_put`` with the new shardings;
  * atomic commit: write to ``step_N.tmp`` then ``os.replace`` — a crash
    mid-write never corrupts the latest checkpoint;
  * async: the array->host gather happens on the caller thread (cheap),
    the file write is handed to a background thread so the train loop
    isn't blocked;
  * retention: keep the last ``max_to_keep`` steps;
  * metadata (step, data position, rng) rides along, so resume is exact.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_checkpoint(directory: str, step: int, tree, *, meta: Optional[dict] = None,
                    max_to_keep: int = 3, async_write: bool = True) -> threading.Thread:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")

    def write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        _retain(directory, max_to_keep)

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if not async_write:
        t.join()
    return t


def _retain(directory: str, max_to_keep: int):
    steps = sorted(_list_steps(directory))
    for s in steps[:-max_to_keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def _list_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree, *,
                       shardings=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    placed (and re-tiled) onto the *current* mesh, so restoring a
    checkpoint written on a 512-chip mesh onto a 256-chip mesh (or a
    1-CPU test) just works (elastic reshard-on-load).
    """
    path = os.path.join(directory, f"step_{step}")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    flat_target, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    flat_shardings = jax.tree.leaves(shardings) if shardings is not None \
        else [None] * len(flat_target)
    leaves = []
    for (pth, leaf), shd in zip(flat_target, flat_shardings):
        key = "/".join(_path_str(p) for p in pth)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree.structure(target_tree), leaves)
    return tree, meta


class CheckpointManager:
    """Train-loop-facing wrapper: periodic async saves + exact resume."""

    def __init__(self, directory: str, save_every: int = 100,
                 max_to_keep: int = 3):
        self.directory = directory
        self.save_every = save_every
        self.max_to_keep = max_to_keep
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, meta: Optional[dict] = None,
                   force: bool = False):
        if not force and (step % self.save_every):
            return False
        self.wait()
        self._pending = save_checkpoint(
            self.directory, step, tree, meta=meta, max_to_keep=self.max_to_keep)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, target_tree, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        tree, meta = restore_checkpoint(self.directory, step, target_tree,
                                        shardings=shardings)
        return tree, meta
