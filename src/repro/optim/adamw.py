"""Optimizers (pure-JAX, optax-style (init, update) pairs).

  * ``adamw``          — standard AdamW, fp32 moments.
  * ``scalable_adamw`` — the ≥10B-parameter variant used at multi-pod
    scale: bf16 first moment + *factored* second moment (Adafactor-style
    row/col statistics for matrices).  For grok-1 (314B params) this cuts
    optimizer state from 8 bytes/param to ~2 bytes/param, which is what
    lets train_4k fit 16 GB/chip on the production mesh (EXPERIMENTS.md
    §Dry-run).

Optimizer state inherits each parameter's PartitionSpec (factored leaves
drop the factored-out axis), so state is ZeRO-sharded wherever params are
FSDP-sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any, Any]]  # (grads, state, params, step)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    """Clip in fp32 math but KEEP each leaf's dtype — upcasting here would
    materialize a second full-parameter-sized fp32 tree (observed +2.5
    GB/device on grok-1)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Standard AdamW
# ---------------------------------------------------------------------------

def adamw(lr: Callable[[jax.Array], jax.Array] | float, *, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.1, max_grad_norm: Optional[float] = 1.0
          ) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gnorm = global_norm(grads)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps)
            if p.ndim >= 2 and weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        treedef = jax.tree.structure(params)
        out = [upd(p, g, m, v) for p, g, m, v in zip(
            jax.tree.leaves(params), jax.tree.leaves(grads),
            jax.tree.leaves(state["m"]), jax.tree.leaves(state["v"]))]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr_t}
        return new_params, {"m": new_m, "v": new_v}, metrics

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Scalable AdamW: bf16 m + factored v
# ---------------------------------------------------------------------------

_FACTOR_MIN_SIZE = 128  # factor v only for matrices with both dims >= this


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= _FACTOR_MIN_SIZE \
        and p.shape[-2] >= _FACTOR_MIN_SIZE


def scalable_adamw(lr, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                   max_grad_norm: Optional[float] = 1.0,
                   use_momentum: bool = True) -> Optimizer:
    """AdamW with bf16 first moment and factored second moment.

    v ≈ r ⊗ c / mean(r): r/c are row/col means of g² (Adafactor, Shazeer &
    Stern 2018), kept per leading batch dims (scan-stacked layers factor
    only the trailing two dims).

    ``use_momentum=False`` drops the first moment entirely — true
    Adafactor, the T5/PaLM ≥100B recipe: optimizer state goes to
    O(sqrt(params)), which is what lets grok-1 (314B) train on a single
    256-chip v5e pod (fp32 params 4.9 GB/chip + v ≈ 0).
    """
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        def init_m(p):
            return jnp.zeros_like(p, jnp.bfloat16)

        def init_v(p):
            if _factored(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return jnp.zeros_like(p, jnp.float32)

        state = {"v": jax.tree.map(init_v, params)}
        if use_momentum:
            state["m"] = jax.tree.map(init_m, params)
        return state

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        else:
            gnorm = global_norm(grads)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)  # per-leaf upcast (not whole-tree)
            g2 = jnp.square(g) + 1e-30
            if _factored(p):
                r = b2 * v["r"] + (1 - b2) * jnp.mean(g2, axis=-1)
                c = b2 * v["c"] + (1 - b2) * jnp.mean(g2, axis=-2)
                rm = jnp.mean(r, axis=-1, keepdims=True)
                vh = (r[..., None] * c[..., None, :]) / (rm[..., None] + 1e-30)
                new_v = {"r": r, "c": c}
            else:
                vh = b2 * v + (1 - b2) * g2
                new_v = vh
            if use_momentum:
                m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
                num = m32 / bc1
                new_m = m32.astype(jnp.bfloat16)
            else:
                num = g
                new_m = None
            delta = num / (jnp.sqrt(vh / bc2) + eps)
            if p.ndim >= 2 and weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
            return newp, new_m, new_v

        treedef = jax.tree.structure(params)
        p_l = jax.tree.leaves(params)
        g_l = jax.tree.leaves(grads)
        m_l = jax.tree.leaves(state["m"]) if use_momentum else [None] * len(p_l)
        v_l = jax.tree.leaves(state["v"],
                              is_leaf=lambda x: isinstance(x, dict) and "r" in x)
        out = [upd(p, g, m, v) for p, g, m, v in zip(p_l, g_l, m_l, v_l)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_state = {"v": jax.tree.unflatten(treedef, [o[2] for o in out])}
        if use_momentum:
            new_state["m"] = jax.tree.unflatten(treedef, [o[1] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr_t}
        return new_params, new_state, metrics

    return Optimizer(init, update)


def is_factored_leaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"r", "c"}
