from repro.optim.adamw import adamw, scalable_adamw  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.compression import error_feedback_compress, compressed_psum  # noqa: F401
