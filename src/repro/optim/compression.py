"""Quantization: weight-only storage formats and gradient compression.

Three pieces (DESIGN.md §13):

  * The **quant-axis codec** — :func:`quantize` / :func:`dequantize` /
    :class:`QuantizedTensor` implement the scale schemes of
    :class:`repro.core.descriptor.QuantSpec` (per_tensor / per_channel /
    per_tile) plus the dispatch-time helpers (:func:`expand_scale`,
    :func:`quantize_operand`) the GEMM entry points use to build the
    kernel-facing f32 scale vectors.  :func:`quantize_model` is the
    quantize-once-at-load path for W8A16 serving: every 2-D ``"w"``
    projection leaf becomes a :class:`QuantizedTensor`; embeddings
    (``"table"``), norm vectors and 3-D grouped MoE banks stay wide.

  * :func:`error_feedback_compress` — int8 block-quantization with error
    feedback (the residual of each quantization step is carried into the
    next step), applied to gradients before the cross-pod reduction.
    Error feedback keeps SGD/Adam convergence (Karimireddy et al. 2019)
    while cutting DCN bytes 4x vs fp32 / 2x vs bf16.

  * :func:`compressed_psum` — a shard_map-level all-reduce that quantizes
    per-shard partials to int8, reduces, and dequantizes.  On the
    production mesh this is applied to the "pod" axis only — ICI
    reductions stay full-precision; the slow DCN hop carries int8.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.descriptor import QuantSpec, resolve_quant
from repro.core.machine import FP8_DTYPE, HAS_FP8
from repro.core.schedule import QUANT_TILE

_BLOCK = 256

# Largest representable magnitude per wire dtype: symmetric int8 uses the
# [-127, 127] range (keeping -128 unused preserves negation symmetry);
# fp8-e4m3 saturates at 448.
_QMAX = {"int8": 127.0, "float8_e4m3": 448.0}


def _wire_dtype(spec: QuantSpec):
    if spec.dtype == "int8":
        return jnp.int8
    if not HAS_FP8:  # pragma: no cover - build-dependent
        raise ValueError("float8_e4m3 is unavailable in this jax build "
                         "(repro.core.machine.HAS_FP8 is False)")
    return FP8_DTYPE


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """A quantized array plus the scale metadata to reconstruct it.

    The storage format of the weight-only path (DESIGN.md §13): ``q``
    holds the narrow wire values, ``scale`` the f32 scale(s) whose shape
    depends on ``spec.scheme`` (scalar / per-channel vector / per-tile
    vector along ``axis``).  Registered as a pytree whose *children* are
    the arrays and whose aux data is the (hashable) spec — so a
    quantized param tree jits, donates, and shards like a wide one.
    ``dtype`` reports the *logical* (pre-quantization) dtype so shape/
    dtype-inspecting model code keeps working.
    """

    def __init__(self, q, scale, spec: QuantSpec, axis: int = -1,
                 orig_dtype=jnp.float32):
        self.q = q
        self.scale = scale
        self.spec = spec
        self.axis = axis
        self.orig_dtype = jnp.dtype(orig_dtype)

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def dtype(self):
        return self.orig_dtype

    def dequantize(self, dtype=None):
        return dequantize(self, dtype=dtype)

    def tree_flatten(self):
        return (self.q, self.scale), (self.spec, self.axis, self.orig_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, aux[0], axis=aux[1], orig_dtype=aux[2])

    def __repr__(self):
        return (f"QuantizedTensor(shape={tuple(self.q.shape)}, "
                f"spec={self.spec!r}, axis={self.axis})")


def _scale_for(x32, spec: QuantSpec, axis: int):
    """f32 scale array for ``x32`` under ``spec.scheme`` along ``axis``.

    per_tensor -> (); per_channel -> (x.shape[axis],); per_tile ->
    (ceil(x.shape[axis] / QUANT_TILE),) — fixed 128-wide blocks along the
    channel axis, trailing tail block allowed to be short.
    """
    qmax = _QMAX[spec.dtype]
    if spec.scheme == "per_tensor":
        amax = jnp.max(jnp.abs(x32)) if x32.size else jnp.zeros((), jnp.float32)
        return amax / qmax + 1e-12
    axis = axis % max(x32.ndim, 1)
    reduce_axes = tuple(i for i in range(x32.ndim) if i != axis)
    if spec.scheme == "per_channel":
        amax = jnp.max(jnp.abs(x32), axis=reduce_axes) if x32.size else \
            jnp.zeros((x32.shape[axis],), jnp.float32)
        return amax / qmax + 1e-12
    # per_tile: pad the channel axis to a QUANT_TILE multiple, reduce per
    # block.  The pad is zeros, which never win the max.
    n = x32.shape[axis]
    tiles = max(-(-n // QUANT_TILE), 1) if n else 0
    if n == 0:
        return jnp.zeros((0,), jnp.float32)
    moved = jnp.moveaxis(x32, axis, -1).reshape(-1, n)
    pad = tiles * QUANT_TILE - n
    moved = jnp.pad(moved, ((0, 0), (0, pad)))
    amax = jnp.max(jnp.abs(moved.reshape(moved.shape[0], tiles, QUANT_TILE)),
                   axis=(0, 2))
    return amax / qmax + 1e-12


def expand_scale(scale, spec: QuantSpec, length: int):
    """Expand a scheme-shaped scale to a dense (length,) f32 vector.

    This is the dispatch-time form the kernels consume: per_tensor
    broadcasts the scalar, per_channel is already dense, per_tile repeats
    each block scale QUANT_TILE times and truncates the tail.
    """
    scale = jnp.asarray(scale, jnp.float32)
    if spec.scheme == "per_tensor":
        return jnp.full((length,), scale, jnp.float32)
    if spec.scheme == "per_channel":
        return scale.reshape(length)
    return jnp.repeat(scale, QUANT_TILE)[:length]


def quantize(x, spec, *, axis: int = -1) -> QuantizedTensor:
    """Quantize ``x`` to ``spec``'s wire dtype along channel ``axis``.

    Symmetric scaling: ``q = round(x / scale)`` clipped to the wire
    range, ``scale = amax / qmax`` per channel group.  ``axis`` is the
    channel axis for per_channel / per_tile (the output-feature axis of
    a weight, the row axis of an activation).
    """
    spec = resolve_quant(spec)
    x = jnp.asarray(x)
    x32 = x.astype(jnp.float32)
    scale = _scale_for(x32, spec, axis)
    if spec.scheme == "per_tensor":
        dense = scale
    else:
        dense = expand_scale(scale, spec, x.shape[axis % max(x.ndim, 1)]) \
            if x.size else scale
        if x.size:
            shape = [1] * x.ndim
            shape[axis % x.ndim] = x.shape[axis % x.ndim]
            dense = dense.reshape(shape)
    scaled = x32 / dense if x.size else x32
    if spec.dtype == "int8":
        q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(scaled, -_QMAX["float8_e4m3"],
                     _QMAX["float8_e4m3"]).astype(_wire_dtype(spec))
    return QuantizedTensor(q, scale, spec, axis=axis, orig_dtype=x.dtype)


def dequantize(qt: QuantizedTensor, dtype=None):
    """Reconstruct the wide tensor: ``q.astype(f32) * scale`` per group."""
    dtype = qt.orig_dtype if dtype is None else dtype
    x32 = qt.q.astype(jnp.float32)
    if qt.spec.scheme == "per_tensor" or x32.size == 0:
        return (x32 * qt.scale).astype(dtype)
    axis = qt.axis % x32.ndim
    dense = expand_scale(qt.scale, qt.spec, x32.shape[axis])
    shape = [1] * x32.ndim
    shape[axis] = x32.shape[axis]
    return (x32 * dense.reshape(shape)).astype(dtype)


def quantize_operand(x, spec: QuantSpec, *, axis: int):
    """Quantize a GEMM operand at dispatch, returning kernel-ready parts.

    Returns ``(q, dense_scale)`` where ``dense_scale`` is the full
    (x.shape[axis],) f32 dequant vector the fused epilogue consumes
    (DESIGN.md §13) — per_tensor/per_tile already expanded.
    """
    qt = quantize(x, spec, axis=axis)
    n = x.shape[axis % max(x.ndim, 1)]
    return qt.q, expand_scale(qt.scale, spec, n)


def quantize_model(params, spec="w8a16", *, min_size: int = 0):
    """Quantize-once-at-load for W8A16 serving (DESIGN.md §13).

    Walks the param tree and replaces every 2-D ``"w"`` projection leaf
    with a :class:`QuantizedTensor` (per-output-channel by default,
    ``axis=-1``).  Embedding tables (``"table"``), norm vectors, biases
    and the 3-D grouped-MoE weight banks are left wide — those either
    feed gathers (no GEMM to fuse into) or the grouped path, which
    quantizes activations at dispatch instead.  ``min_size`` skips
    leaves smaller than the threshold (tiny projections gain nothing).
    """
    spec = resolve_quant(spec)
    if spec is None:
        return params

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if (k == "w" and hasattr(v, "ndim") and v.ndim == 2
                        and not isinstance(v, QuantizedTensor)
                        and v.size >= min_size):
                    out[k] = quantize(v, spec, axis=-1)
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def _quantize_int8(x32: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8 quantization over the trailing axis."""
    flat = x32.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale, shape):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    return deq[:_numel(shape)].reshape(shape)


def _numel(shape):
    n = 1
    for s in shape:
        n *= int(s)
    return n


def error_feedback_compress(grads, residual):
    """Quantize grads to int8 (simulated wire format) with error feedback.

    Returns (dequantized grads actually applied, new residual).  The
    returned grads are exactly what the receiving end of a compressed
    all-reduce would see; the residual carries this step's quantization
    error into the next step.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + (r.astype(jnp.float32) if r is not None else 0.0)
        q, scale = _quantize_int8(g32)
        deq = _dequantize_int8(q, scale, g32.shape)
        return deq, (g32 - deq)

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    out = jax.tree.map(one, grads, residual)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_r


def compressed_psum(x: jax.Array, axis_name: str):
    """int8-compressed all-reduce over ``axis_name`` (use inside shard_map).

    Quantize local partial -> sum int32 partials (exact) -> dequantize with
    the max scale.  One extra small psum carries the scales.
    """
    q, scale = _quantize_int8(x.astype(jnp.float32))
    scale_max = jax.lax.pmax(scale, axis_name)
    # renormalize local quants to the shared scale so the int sum is aligned
    q_aligned = jnp.clip(jnp.round(q.astype(jnp.float32) * (scale / scale_max)),
                         -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q_aligned, axis_name)
    deq = (total.astype(jnp.float32) * scale_max).reshape(-1)
    return deq[:_numel(x.shape)].reshape(x.shape).astype(x.dtype)
