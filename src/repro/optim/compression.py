"""Gradient compression for cross-pod (DCN) reduction.

Two pieces:

  * :func:`error_feedback_compress` — int8 block-quantization with error
    feedback (the residual of each quantization step is carried into the
    next step), applied to gradients before the cross-pod reduction.
    Error feedback keeps SGD/Adam convergence (Karimireddy et al. 2019)
    while cutting DCN bytes 4x vs fp32 / 2x vs bf16.

  * :func:`compressed_psum` — a shard_map-level all-reduce that quantizes
    per-shard partials to int8, reduces, and dequantizes.  On the
    production mesh this is applied to the "pod" axis only — ICI
    reductions stay full-precision; the slow DCN hop carries int8.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_BLOCK = 256


def _quantize_int8(x32: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8 quantization over the trailing axis."""
    flat = x32.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale, shape):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    return deq[:_numel(shape)].reshape(shape)


def _numel(shape):
    n = 1
    for s in shape:
        n *= int(s)
    return n


def error_feedback_compress(grads, residual):
    """Quantize grads to int8 (simulated wire format) with error feedback.

    Returns (dequantized grads actually applied, new residual).  The
    returned grads are exactly what the receiving end of a compressed
    all-reduce would see; the residual carries this step's quantization
    error into the next step.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + (r.astype(jnp.float32) if r is not None else 0.0)
        q, scale = _quantize_int8(g32)
        deq = _dequantize_int8(q, scale, g32.shape)
        return deq, (g32 - deq)

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    out = jax.tree.map(one, grads, residual)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_r


def compressed_psum(x: jax.Array, axis_name: str):
    """int8-compressed all-reduce over ``axis_name`` (use inside shard_map).

    Quantize local partial -> sum int32 partials (exact) -> dequantize with
    the max scale.  One extra small psum carries the scales.
    """
    q, scale = _quantize_int8(x.astype(jnp.float32))
    scale_max = jax.lax.pmax(scale, axis_name)
    # renormalize local quants to the shared scale so the int sum is aligned
    q_aligned = jnp.clip(jnp.round(q.astype(jnp.float32) * (scale / scale_max)),
                         -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q_aligned, axis_name)
    deq = (total.astype(jnp.float32) * scale_max).reshape(-1)
    return deq[:_numel(x.shape)].reshape(x.shape).astype(x.dtype)
