"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module does not touch jax device state — smoke tests see 1 device; only
``dryrun.py`` forces 512 host devices.

Axis semantics (DESIGN.md §5):
  * "pod"   — cross-pod data parallelism (DCN; gradient all-reduce only)
  * "data"  — in-pod data parallel + FSDP storage axis
  * "model" — tensor/expert parallel (ICI)
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# ``AxisType`` (and make_mesh's ``axis_types=``) only exist on newer jax;
# older releases default every axis to Auto semantics anyway.
try:
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Mesh over however many (CPU) devices exist — used by unit tests."""
    return _make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return mesh.devices.size
