import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, on the single-pod (16,16)
mesh AND the multi-pod (2,16,16) mesh:

    jax.jit(step, in_shardings=..., out_shardings=...)
        .lower(**input_specs).compile()

must succeed; we record ``memory_analysis()`` (fits-per-chip proof),
``cost_analysis()`` (per-device FLOPs/bytes) and the collective schedule
parsed from the optimized HLO — the roofline analysis
(``repro.launch.roofline``) reads these JSON records.

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all            # every cell, resumable
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum per-device result bytes of every collective op in optimized HLO."""
    out = {c: {"count": 0, "bytes": 0.0} for c in _COLLECTIVES}
    array_re = re.compile(r"(bf16|f64|f32|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
                          r"\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(.+?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(",
                      line)
        if not m or (m.group(3) == "-done"):
            continue  # -done carries the same type as -start; count once
        result_type, op = m.group(1), m.group(2)
        nbytes = 0.0
        for dm in array_re.finditer(result_type):
            dt, dims = dm.group(1), dm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
    return out


def pick_optimizer(cfg):
    from repro.optim import adamw, scalable_adamw, warmup_cosine
    sched = warmup_cosine(3e-4, 1000, 100000)
    if cfg.param_count() > 100e9:
        # ≥100B: true Adafactor (no momentum, factored v) — the T5/PaLM
        # recipe; optimizer state is O(sqrt(params)).
        return scalable_adamw(sched, use_momentum=False)
    if cfg.param_count() > 10e9:
        return scalable_adamw(sched)
    return adamw(sched)


def pick_microbatches(cfg, suite) -> int:
    """Gradient-accumulation factor per arch (activation-memory knob).

    Chosen so peak per-device memory fits 16 GB HBM on the single-pod
    mesh (see EXPERIMENTS.md §Dry-run memory table)."""
    if suite.kind != "train":
        return 1
    act_cost = cfg.d_model * cfg.num_layers
    if cfg.num_experts:
        act_cost *= 2  # dispatch buffers
    if act_cost > 500_000:   # grok-1 class
        return 4
    if act_cost > 150_000:   # starcoder2 / phi3.5-moe / recurrentgemma class
        return 2
    return 1


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, shape_for, input_specs
    from repro.configs.shapes import cell_applicable
    from repro.launch.mesh import make_production_mesh, mesh_chips
    from repro.runtime import sharding as shd
    from repro.runtime.shardlib import use_mesh
    from repro.runtime import steps as steps_lib

    cfg = get_config(arch)
    suite = shape_for(shape_name)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "kind": suite.kind, "params": cfg.param_count(),
              "active_params": cfg.active_param_count()}

    skip = cell_applicable(cfg, suite)
    if skip:
        record.update(status="skip", reason=skip)
        return _finish(record, save)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh_chips(mesh)
    record["chips"] = chips
    t0 = time.time()

    with use_mesh(mesh):
        pshapes = steps_lib.param_shapes(cfg)
        fsdp = True
        if suite.kind != "train":
            # Serving holds bf16 weights (no optimizer): half the bytes,
            # half the FSDP-gather traffic per decode step.
            pshapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                if s.dtype == jnp.float32 else s, pshapes)
            # Serve-mode weight residency: when TP-sharded bf16 weights fit
            # per-chip, skip FSDP entirely — weights stay resident and the
            # per-step all-gathers disappear (EXPERIMENTS.md §Perf).
            msize = mesh.shape.get("model", 1)
            tp_resident_gb = 2.0 * cfg.param_count() / msize / 2**30
            fsdp = tp_resident_gb > 8.0
        pspecs = shd.param_pspecs(pshapes, cfg, mesh, fsdp=fsdp)
        p_shardings = shd.to_named(mesh, pspecs)
        ispecs = input_specs(cfg, suite)
        bspecs = shd.batch_pspecs(ispecs, mesh)
        b_shardings = shd.to_named(mesh, bspecs)

        if suite.kind == "train":
            optimizer = pick_optimizer(cfg)
            oshapes = steps_lib.opt_state_shapes(cfg, optimizer, pshapes)
            ospecs = shd.opt_pspecs(oshapes, pshapes, cfg, mesh)
            o_shardings = shd.to_named(mesh, ospecs)
            step_fn = steps_lib.make_train_step(
                cfg, optimizer, microbatches=pick_microbatches(cfg, suite))
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shardings, o_shardings, b_shardings,
                              NamedSharding(mesh, P())),
                out_shardings=(p_shardings, o_shardings, None),
                donate_argnums=(0, 1),
            )
            args = (pshapes, oshapes, ispecs,
                    jax.ShapeDtypeStruct((), jnp.int32))
        elif suite.kind == "prefill":
            cshapes = steps_lib.cache_shapes(cfg, suite.global_batch,
                                             suite.seq_len)
            cspecs = shd.cache_pspecs(cshapes, cfg, mesh)
            c_shardings = shd.to_named(mesh, cspecs)
            step_fn = steps_lib.make_prefill_step(cfg, capacity=suite.seq_len)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_shardings, b_shardings),
                             out_shardings=(None, c_shardings))
            args = (pshapes, ispecs)
        else:  # decode
            cshapes = steps_lib.cache_shapes(cfg, suite.global_batch,
                                             suite.seq_len)
            cspecs = shd.cache_pspecs(cshapes, cfg, mesh)
            c_shardings = shd.to_named(mesh, cspecs)
            step_fn = steps_lib.make_serve_step(cfg)
            in_sh = [p_shardings, c_shardings,
                     b_shardings["tokens"], b_shardings["pos"]]
            args = [pshapes, cshapes, ispecs["tokens"], ispecs["pos"]]
            if cfg.encoder_decoder:
                in_sh.append(b_shardings["enc_out"])
                args.append(ispecs["enc_out"])
            jitted = jax.jit(step_fn,
                             in_shardings=tuple(in_sh),
                             out_shardings=(None, c_shardings, None),
                             donate_argnums=(1,))
            args = tuple(args)

        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import analyze as hlo_analyze
    walk = hlo_analyze(hlo)
    # Stash compressed HLO so cost-model refinements re-analyze without
    # recompiling the cell.
    import gzip
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with gzip.open(os.path.join(
            RESULTS_DIR, f"{arch}__{shape_name}__{mesh_kind}.hlo.gz"),
            "wt") as f:
        f.write(hlo)
    record.update(
        status="ok",
        compile_seconds=round(time.time() - t0, 1),
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device": ma.argument_size_in_bytes +
                ma.temp_size_in_bytes + ma.output_size_in_bytes -
                ma.alias_size_in_bytes,
        },
        # trip-count-aware walker (repro.launch.hlo_cost): XLA's module
        # cost_analysis counts while bodies once, undercounting scans.
        cost={
            "flops_per_device": walk["flops"],
            "bytes_per_device": walk["bytes"],
            "xla_flops_unscaled": ca.get("flops", 0.0),
            "xla_bytes_unscaled": ca.get("bytes accessed", 0.0),
        },
        collectives=walk["collectives"],
        collective_bytes_per_device=walk["collective_bytes"],
    )
    # memory_analysis() proves it fits; the walker feeds §Roofline.
    print(f"[{arch} x {shape_name} x {mesh_kind}] compiled in "
          f"{record['compile_seconds']}s")
    print("  memory_analysis:", record["memory"])
    print("  cost_analysis:", record["cost"])
    print("  collectives:", {k: v for k, v in walk["collectives"].items()
                             if v["count"]})
    return _finish(record, save)


def _finish(record: dict, save: bool) -> dict:
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
        with open(os.path.join(RESULTS_DIR, name), "w") as f:
            json.dump(record, f, indent=2)
    return record


def all_cells():
    from repro.configs import list_configs, SHAPES
    for arch in list_configs():
        for shape in SHAPES:
            for mesh in ("pod", "multipod"):
                yield arch, shape, mesh


def run_all(resume: bool = True, subprocess_mode: bool = True):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    failures = []
    for arch, shape, mesh in all_cells():
        name = f"{arch}__{shape}__{mesh}.json"
        path = os.path.join(RESULTS_DIR, name)
        if resume and os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") in ("ok", "skip"):
                continue
        if subprocess_mode:
            ret = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape, "--mesh", mesh],
                env=dict(os.environ),
                capture_output=True, text=True, timeout=3600)
            if ret.returncode != 0:
                failures.append((arch, shape, mesh))
                _finish({"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "error",
                         "error": (ret.stderr or "")[-4000:]}, save=True)
                print(f"FAIL [{arch} x {shape} x {mesh}]:\n{ret.stderr[-2000:]}")
            else:
                print(ret.stdout.strip().splitlines()[0]
                      if ret.stdout.strip() else f"ok {arch} {shape} {mesh}")
        else:
            try:
                run_cell(arch, shape, mesh)
            except Exception:
                failures.append((arch, shape, mesh))
                _finish({"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "error",
                         "error": traceback.format_exc()[-4000:]}, save=True)
    print(f"\ndry-run sweep done; {len(failures)} failures: {failures}")
    return failures


def reanalyze_all():
    """Re-walk stashed HLO with the current cost model (no recompiles)."""
    import gzip
    from repro.launch.hlo_cost import analyze as hlo_analyze
    n = 0
    for arch, shape, mesh in all_cells():
        base = f"{arch}__{shape}__{mesh}"
        jpath = os.path.join(RESULTS_DIR, base + ".json")
        hpath = os.path.join(RESULTS_DIR, base + ".hlo.gz")
        if not (os.path.exists(jpath) and os.path.exists(hpath)):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        with gzip.open(hpath, "rt") as f:
            walk = hlo_analyze(f.read())
        rec["cost"]["flops_per_device"] = walk["flops"]
        rec["cost"]["bytes_per_device"] = walk["bytes"]
        rec["collectives"] = walk["collectives"]
        rec["collective_bytes_per_device"] = walk["collective_bytes"]
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
    print(f"re-analyzed {n} cells")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("pod", "multipod"), default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="re-walk stashed HLO with the current cost model")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze_all()
        return
    if args.all:
        failures = run_all(resume=not args.no_resume)
        sys.exit(1 if failures else 0)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    rec = run_cell(args.arch, args.shape, args.mesh)
    sys.exit(0 if rec["status"] in ("ok", "skip") else 1)


if __name__ == "__main__":
    main()
