"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop *body* once — but our
models run their layer stack (and microbatch accumulation, and attention
q-chunking) as ``lax.scan``, so module-level numbers undercount FLOPs,
bytes and collectives by the trip counts.  This walker parses the
optimized HLO, reconstructs the computation call graph (while bodies,
conditions, fusions), extracts each loop's trip count from its condition,
and accumulates:

  * ``flops``            — 2·(result elems)·(contracted elems) per dot,
                            multiplied along the enclosing-loop path;
  * ``bytes``            — operand + result bytes of every top-level
                            instruction (fusion boundaries ≈ HBM traffic);
  * ``collectives``      — per-op count and result bytes (per device).

Shapes are resolved through a module-wide symbol table (operands are
referenced by name in optimized HLO).  Trip counts follow XLA's canonical
``i = 0; while (i < N)`` form; the largest integer constant in the
condition computation is used as N (validated against known loop
structures in tests/test_hlo_cost.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str  # raw text of result type (may be a tuple)
    op: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def _type_bytes(type_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _shape_dims(type_text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*?)\)(.*)$")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    current: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        head = _COMP_HEAD_RE.match(line.strip())
        if head and line.strip().endswith("{"):
            name = head.group(2)
            current = Computation(name, [])
            comps[name] = current
            if head.group(1):
                entry = name
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, name, rtype, op, operands, attrs = m.groups()
        ops = [o.strip().lstrip("%") for o in _split_operands(operands)]
        current.instrs.append(Instr(name, rtype, op, ops, attrs, line))
    return comps, entry or ""


def _split_operands(text: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in text:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            depth += ch in "([{"
            depth -= ch in ")]}"
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [o for o in (s.strip() for s in out) if o]


def _symbol_table(comps: Dict[str, Computation]) -> Dict[str, str]:
    table = {}
    for comp in comps.values():
        for ins in comp.instrs:
            table[ins.name] = ins.result_type
    return table


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition ≈ trip count."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = {entry: 1.0}
    # iterate to fixpoint (call graph is a DAG; a few passes suffice)
    for _ in range(12):
        changed = False
        for cname, comp in comps.items():
            if cname not in mult:
                continue
            base = mult[cname]
            for ins in comp.instrs:
                if ins.op == "while":
                    body = _attr_ref(ins.attrs, "body")
                    cond = _attr_ref(ins.attrs, "condition")
                    trips = _trip_count(comps[cond]) if cond in comps else 1
                    for target, m in ((body, base * trips), (cond, base * (trips + 1))):
                        if target in comps and mult.get(target, 0) < m:
                            mult[target] = m
                            changed = True
                elif ins.op in ("fusion", "call", "custom-call", "conditional",
                                "async-start", "reduce", "map", "sort",
                                "scatter", "select-and-scatter"):
                    for ref in re.findall(r"(?:calls|to_apply|branch_computations|"
                                          r"called_computations)=\{?%?([\w.\-]+)",
                                          ins.attrs):
                        if ref in comps and mult.get(ref, 0) < base:
                            mult[ref] = base
                            changed = True
        if not changed:
            break
    return mult


def _attr_ref(attrs: str, key: str) -> str:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else ""


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota", "while", "fusion-kind"}


def analyze(hlo: str) -> dict:
    comps, entry = parse_module(hlo)
    if not entry:
        raise ValueError("no ENTRY computation found")
    table = _symbol_table(comps)
    mult = _multipliers(comps, entry)

    flops = 0.0
    byte_traffic = 0.0
    colls = {c: {"count": 0.0, "bytes": 0.0} for c in COLLECTIVE_OPS}

    # "parameter-like" names: loop/computation parameters and their tuple
    # elements — reads of these are genuine HBM traffic every iteration
    # (weights re-streamed per layer in a scan: the FSDP/scan reality).
    param_like = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "parameter":
                param_like.add(ins.name)
            elif ins.op == "get-tuple-element" and ins.operands:
                ref = ins.operands[0].split(" ")[-1].lstrip("%")
                if ref in param_like:
                    param_like.add(ins.name)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                res = _shape_dims(ins.result_type)
                lhs_type = table.get(ins.operands[0].split(" ")[-1].lstrip("%"), "")
                lhs = _shape_dims(lhs_type)
                if res is None or lhs is None:
                    continue
                _, rdims = res
                _, ldims = lhs
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
                contracted = 1
                if cdims and cdims.group(1):
                    for d in cdims.group(1).split(","):
                        contracted *= ldims[int(d)]
                relems = 1
                for d in rdims:
                    relems *= d
                flops += m * 2.0 * relems * contracted
            base_op = ins.op.replace("-start", "").replace("-done", "")
            if base_op in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                colls[base_op]["count"] += m
                colls[base_op]["bytes"] += m * _type_bytes(ins.result_type)
            if ins.op in _SKIP_BYTES_OPS:
                continue
            # HBM traffic model: every materialized result is written once
            # and read once by its consumer (2x result bytes); operands
            # that are loop/computation parameters (weights, carried
            # state) are charged per read — intermediate operands are NOT
            # re-charged (they were counted at their producer; charging
            # full operand sizes per consumer overcounts ~100x vs fusion
            # reality).  In-place slice updates only move the slice.
            if ins.op == "dynamic-update-slice":
                upd = ins.operands[1].split(" ")[-1].lstrip("%") \
                    if len(ins.operands) > 1 else ""
                nbytes = 2 * _type_bytes(table.get(upd, ""))
            elif ins.op == "dynamic-slice":
                nbytes = 2 * _type_bytes(ins.result_type)
            else:
                nbytes = 2 * _type_bytes(ins.result_type)
                for opnd in ins.operands:
                    ref = opnd.split(" ")[-1].lstrip("%")
                    if ref in param_like and ref in table:
                        nbytes += _type_bytes(table[ref])
            byte_traffic += m * nbytes

    return {
        "flops": flops,
        "bytes": byte_traffic,
        "collectives": colls,
        "collective_bytes": sum(c["bytes"] for c in colls.values()),
        "num_computations": len(comps),
    }


def descriptor_cost(desc) -> dict:
    """Cost record for one engine kernel descriptor, in :func:`analyze`'s
    schema — lets dry-run tooling merge engine-dispatched kernels (any
    family, not just GEMMs) with HLO-derived module costs."""
    return {
        "flops": float(desc.flops),
        "bytes": float(desc.in_bytes + desc.out_bytes),
        "collectives": {c: {"count": 0.0, "bytes": 0.0}
                        for c in COLLECTIVE_OPS},
        "collective_bytes": 0.0,
        "num_computations": 1,
    }
