"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Single-host it runs a real (reduced or full) config on the local devices;
with ``--dryrun-mesh`` it only verifies lowering (see dryrun.py for the
full matrix).  Fault tolerance: checkpoint/restart supervisor + straggler
accounting from repro.runtime.train_loop.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data import SyntheticLMDataset
from repro.optim import adamw, warmup_cosine
from repro.runtime.steps import make_train_step, model_for
from repro.runtime.train_loop import TrainLoopConfig, run_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--scale", type=int, default=1,
                    help="multiplier on the reduced config width/depth")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--backend", choices=("xla", "pallas"), default=None,
                    help="engine backend: pallas routes the kernel families"
                         " (and their scheduled backward walks) through the"
                         " engine; default keeps the process config")
    ap.add_argument("--fused", choices=("auto", "on", "off"), default=None,
                    help="fused-lowering policy for engine dispatches,"
                         " forward and backward (DESIGN.md §10-11)")
    args = ap.parse_args()

    if args.backend is not None or args.fused is not None:
        from repro.core.config import configure
        overrides = {}
        if args.backend is not None:
            overrides["backend"] = args.backend
            if args.backend == "pallas":
                overrides["interpret"] = True  # container has no TPU
        if args.fused is not None:
            overrides["fused"] = args.fused
        configure(**overrides)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(
            cfg,
            d_model=64 * args.scale,
            d_ff=128 * args.scale,
            num_layers=max(2, 2 * len(cfg.block_pattern)) * args.scale,
        )
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,}")

    opt = adamw(warmup_cosine(args.lr, args.steps // 10, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch)

    def batch_fn(step):
        hb = ds.host_batch(step)
        return {k: jnp.asarray(v) for k, v in hb.items()}

    loop = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                           save_every=args.save_every)

    def log(step, m):
        print(f"step {step:5d} loss={m['loss']:.4f} nll={m['nll']:.4f} "
              f"gnorm={m['grad_norm']:.2f} dt={m['step_seconds']*1e3:.0f}ms")

    out = run_with_restarts(lambda: (params, opt_state), step_fn, batch_fn,
                            loop, log_fn=log)
    first = out["metrics"][0]["nll"]
    last = out["metrics"][-1]["nll"]
    floor = ds.unigram_floor_nats()
    print(f"nll: {first:.3f} -> {last:.3f} (structure floor ~{floor:.3f}, "
          f"uniform {jnp.log(cfg.vocab_size):.3f}); "
          f"stragglers={out['stragglers']} restarts={out['restarts']}")
    # Engine provenance: which families dispatched, and whether gradients
    # flowed through the scheduled backward walks (DESIGN.md §11).
    for fam, s in sorted(out.get("engine_stats", {}).items()):
        if s["launches"] or s["launches_bwd"]:
            print(f"engine[{fam}]: launches={s['launches']} "
                  f"launches_bwd={s['launches_bwd']} "
                  f"plan_hits={s['plan_hits']} "
                  f"plan_hits_bwd={s['plan_hits_bwd']}")


if __name__ == "__main__":
    main()
