"""Roofline analysis over the dry-run records (deliverable g).

For every (arch x shape) cell on the single-pod mesh, convert the
compiled artifact's per-device FLOPs / HBM bytes / collective bytes into
the three roofline terms (seconds), identify the dominant bottleneck, and
compare against analytic MODEL_FLOPS (6·N_active·D train / 2·N_active·D
inference) — the ratio exposes remat recompute, causal-masking waste and
one-hot dispatch phantoms.

    python -m repro.launch.roofline [--mesh pod] [--write experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.core.machine import TPU_V5E

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

HBM_BUDGET = 16 * 1024**3


def kernel_roofline(desc, machine=TPU_V5E, chips: int = 1) -> dict:
    """Roofline terms for ONE engine kernel descriptor — any family.

    Every :class:`repro.core.descriptor.KernelDescriptor` carries
    flops/bytes accounting, so a flash-attention, grouped-GEMM, SSD or
    transpose request costs through the same machinery as a GEMM.
    """
    compute_s = machine.compute_seconds(desc.flops, desc.dtype
                                        if hasattr(desc, "dtype")
                                        else desc.in_dtype, chips)
    memory_s = machine.memory_seconds(desc.in_bytes + desc.out_bytes, chips)
    dominant = "compute" if compute_s >= memory_s else "memory"
    return {
        "family": desc.family,
        "flops": desc.flops,
        "bytes": desc.in_bytes + desc.out_bytes,
        "arithmetic_intensity": desc.arithmetic_intensity,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "dominant": dominant,
    }


def model_flops(rec: dict, cfg, suite) -> float:
    """Analytic useful FLOPs per step, global."""
    n_active = cfg.active_param_count()
    tokens = suite.global_batch * suite.seq_len
    if suite.kind == "train":
        return 6.0 * n_active * tokens
    if suite.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * suite.global_batch


def analyze_record(rec: dict) -> Optional[dict]:
    from repro.configs import get_config, shape_for
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    suite = shape_for(rec["shape"])
    chips = rec["chips"]
    m = TPU_V5E
    flops_dev = rec["cost"]["flops_per_device"]
    bytes_dev = rec["cost"]["bytes_per_device"]
    coll_dev = rec["collective_bytes_per_device"]

    compute_s = flops_dev / m.peak("bfloat16")
    memory_s = bytes_dev / m.hbm_bw
    collective_s = coll_dev / m.ici_bw_per_link
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec, cfg, suite) / chips
    ratio = mf / max(flops_dev, 1.0)
    bound = max(terms.values())
    useful_s = mf / m.peak("bfloat16")
    roofline_frac = useful_s / max(bound, 1e-12)

    hints = {
        "compute": "cut recompute (remat policy) and masked-block waste "
                   "(causal upper-triangle, one-hot dispatch)",
        "memory": "raise arithmetic intensity: larger per-step tiles, "
                  "fuse epilogues, bf16 end-to-end",
        "collective": "reshard to cut per-layer gathers (FSDP prefetch, "
                      "sequence-parallel boundaries, EP vs TP-f choice)",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops_dev,
        "useful_ratio": ratio,
        "roofline_frac": roofline_frac,
        "peak_mem_gb": rec["memory"]["peak_per_device"] / 2**30,
        "fits_16gb": rec["memory"]["peak_per_device"] <= HBM_BUDGET,
        "hint": hints[dominant],
    }


def load_records(mesh: str = "pod") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        out.append(rec)
    return out


def render_table(rows: List[dict], skips: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | peak GB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['peak_mem_gb']:.1f} | "
            f"{'yes' if r['fits_16gb'] else 'NO'} |")
    for s in skips:
        lines.append(f"| {s['arch']} | {s['shape']} | — | — | — | skip | — "
                     f"| — | — | — |")
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--write", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows, skips = [], []
    for rec in load_records(args.mesh):
        if rec.get("status") == "skip":
            skips.append(rec)
            continue
        r = analyze_record(rec)
        if r:
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    table = render_table(rows, skips)
    print(table)
    for r in rows:
        print(f"{r['arch']} x {r['shape']}: {r['dominant']}-bound -> "
              f"{r['hint']}")
    if args.write:
        with open(args.write, "w") as f:
            f.write("# Roofline (single-pod 16x16, per-device terms)\n\n")
            f.write(table)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
