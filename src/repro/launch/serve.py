"""Serving driver: batched prefill + decode with KV/state caches.

``python -m repro.launch.serve --arch <id> --batch 8 --prompt-len 64
--gen 32`` runs reduced-config batched generation on local devices and
reports prefill/decode throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.runtime.steps import make_prefill_step, make_serve_step, model_for


def generate(cfg, params, prompts, gen_steps: int, *, capacity=None):
    """Greedy batched generation. prompts: (b, s) int32."""
    b, s = prompts.shape
    capacity = capacity or (s + gen_steps)
    model = model_for(cfg)
    prefill = jax.jit(make_prefill_step(cfg, capacity))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(gen_steps - 1):
        logits, cache = serve(params, cache, tok, jnp.asarray(s + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    return jnp.concatenate(out, axis=1), t_prefill, t_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    tokens, t_p, t_d = generate(cfg, params, prompts, args.gen)
    ptput = args.batch * args.prompt_len / t_p
    dtput = args.batch * (args.gen - 1) / max(t_d, 1e-9)
    print(f"arch={cfg.name} generated {tokens.shape} "
          f"prefill={ptput:.0f} tok/s decode={dtput:.0f} tok/s")


if __name__ == "__main__":
    main()
