"""Serving driver: batched prefill + decode with KV/state caches.

Two modes (DESIGN.md §12):

  * static batch (default): ``python -m repro.launch.serve --arch <id>
    --batch 8 --prompt-len 64 --gen 32`` — prefill once, decode the
    whole batch in lock-step.
  * continuous batching: ``python -m repro.launch.serve --arch <id>
    --continuous`` — a Poisson-style request trace runs through the
    paged serving runtime (``repro.runtime.batching``); per-decode-step
    launch counts stay flat in ``engine.stats()`` while the batch
    churns, and greedy outputs are checked token-identical against the
    static path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import engine
from repro.runtime.steps import make_prefill_step, make_serve_step, model_for


def generate(cfg, params, prompts, gen_steps: int, *, capacity=None):
    """Greedy batched generation.  prompts: (b, s) int32.

    Returns a dict: ``tokens`` (b, gen_steps), ``prefill_seconds``,
    ``decode_seconds``, and an ``engine_stats`` snapshot (the
    launch-count provenance, mirroring ``launch.train``).  The decode
    position is carried *inside* the jitted step — the loop never
    rebuilds a host-side position scalar per token.
    """
    b, s = prompts.shape
    capacity = capacity or (s + gen_steps)
    prefill = jax.jit(make_prefill_step(cfg, capacity))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.asarray(s, jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen_steps - 1):
        logits, cache, pos = serve(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    return {
        "tokens": jnp.concatenate(out, axis=1),
        "prefill_seconds": t_prefill,
        "decode_seconds": t_decode,
        "engine_stats": engine.stats(),
    }


def run_continuous(cfg, params, *, num_slots=4, num_pages=64, page_size=16,
                   max_blocks=8, num_requests=6, rate=0.5, prompt_len=12,
                   max_new=8, seed=0):
    """Drive the continuous-batching runtime on a Poisson trace and check
    it against the static-batch path.  Returns the engine's run result
    with a ``token_identical`` flag added."""
    from repro.models.attention import PageSpec
    from repro.runtime.batching import (ContinuousBatchingEngine,
                                        poisson_trace)

    spec = PageSpec(num_pages, page_size, max_blocks)
    reqs = poisson_trace(num_requests=num_requests, rate=rate,
                         prompt_lens=prompt_len, max_new=max_new,
                         vocab_size=cfg.vocab_size, seed=seed)
    serving = ContinuousBatchingEngine(cfg, params, num_slots=num_slots,
                                       spec=spec)
    result = serving.run(reqs)

    # Oracle: each request decoded alone on the static path must emit the
    # same greedy tokens the churning batch produced.
    identical = True
    for r in reqs:
        static = generate(cfg, params, jnp.asarray(r.prompt)[None, :],
                          r.max_new)
        want = np.asarray(static["tokens"][0])
        got = result["outputs"][r.rid]
        identical &= bool(np.array_equal(want, got))
    result["token_identical"] = identical
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching mode over a Poisson trace")
    ap.add_argument("--backend", choices=["xla", "pallas"], default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    if args.backend:
        from repro.core import configure
        configure(backend=args.backend)

    if args.continuous:
        res = run_continuous(cfg, params, prompt_len=args.prompt_len // 4
                             or 8, max_new=args.gen // 4 or 4,
                             seed=args.seed)
        m = res["metrics"]
        print(f"arch={cfg.name} continuous: requests={m['requests']} "
              f"tokens={m['total_tokens']} decode_steps={m['decode_steps']} "
              f"evictions={m['evictions']} "
              f"tok/s={m['tokens_per_s']:.0f} "
              f"p50={m['p50_token_latency_s']*1e3:.1f}ms "
              f"p99={m['p99_token_latency_s']*1e3:.1f}ms "
              f"token_identical={res['token_identical']}")
        fam = res["engine_stats"].get("flash_decode", {})
        if fam.get("launches"):
            per_step = m["flash_decode_launches"] / max(m["decode_steps"], 1)
            print(f"engine[flash_decode]: launches={fam['launches']} "
                  f"({per_step:.2f}/decode step — flat while the batch "
                  f"churned)")
        return

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    res = generate(cfg, params, prompts, args.gen)
    ptput = args.batch * args.prompt_len / res["prefill_seconds"]
    dtput = args.batch * (args.gen - 1) / max(res["decode_seconds"], 1e-9)
    print(f"arch={cfg.name} generated {res['tokens'].shape} "
          f"prefill={ptput:.0f} tok/s decode={dtput:.0f} tok/s")


if __name__ == "__main__":
    main()
