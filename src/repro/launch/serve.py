"""Serving driver: batched prefill + decode with KV/state caches.

Two modes (DESIGN.md §12):

  * static batch (default): ``python -m repro.launch.serve --arch <id>
    --batch 8 --prompt-len 64 --gen 32`` — prefill once, decode the
    whole batch in lock-step.
  * continuous batching: ``python -m repro.launch.serve --arch <id>
    --continuous`` — a Poisson-style request trace runs through the
    paged serving runtime (``repro.runtime.batching``); per-decode-step
    launch counts stay flat in ``engine.stats()`` while the batch
    churns, and greedy outputs are checked token-identical against the
    static path.

Zero-stall startup (DESIGN.md §15): ``--warm-start manifest.json``
records the dispatched descriptor population on a cold run and replays
it through ``ContinuousBatchingEngine.warmup`` on the next — combined
with ``--tuning-cache-preload`` (fleet cache) and ``--refit-model``
(fleet-fitted cost coefficients), serving then starts with every plan
resolved and every kernel built before the first request arrives.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import engine
from repro.runtime.steps import make_prefill_step, make_serve_step, model_for


def generate(cfg, params, prompts, gen_steps: int, *, capacity=None):
    """Greedy batched generation.  prompts: (b, s) int32.

    Returns a dict: ``tokens`` (b, gen_steps), ``prefill_seconds``,
    ``decode_seconds``, and an ``engine_stats`` snapshot (the
    launch-count provenance, mirroring ``launch.train``).  The decode
    position is carried *inside* the jitted step — the loop never
    rebuilds a host-side position scalar per token.
    """
    b, s = prompts.shape
    capacity = capacity or (s + gen_steps)
    prefill = jax.jit(make_prefill_step(cfg, capacity))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.asarray(s, jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen_steps - 1):
        logits, cache, pos = serve(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    return {
        "tokens": jnp.concatenate(out, axis=1),
        "prefill_seconds": t_prefill,
        "decode_seconds": t_decode,
        "engine_stats": engine.stats(),
    }


def run_continuous(cfg, params, *, num_slots=4, num_pages=64, page_size=16,
                   max_blocks=8, num_requests=6, rate=0.5, prompt_len=12,
                   max_new=8, seed=0, warm_start=None):
    """Drive the continuous-batching runtime on a Poisson trace and check
    it against the static-batch path.  Returns the engine's run result
    with a ``token_identical`` flag added.

    ``warm_start`` names a descriptor manifest (DESIGN.md §15): when the
    file exists, every kernel is plan-resolved and built — and the
    prefill/decode steps traced — *before* the first request, and the
    result gains a ``warmup`` summary proving the serving phase ran with
    zero autotune timings and zero plan-cache misses.  When it does not
    exist yet, the run records one (``engine.save_manifest``) so the
    next start is warm."""
    import os

    from repro.models.attention import PageSpec
    from repro.runtime.batching import (ContinuousBatchingEngine,
                                        poisson_trace)

    spec = PageSpec(num_pages, page_size, max_blocks)
    reqs = poisson_trace(num_requests=num_requests, rate=rate,
                         prompt_lens=prompt_len, max_new=max_new,
                         vocab_size=cfg.vocab_size, seed=seed)
    serving = ContinuousBatchingEngine(cfg, params, num_slots=num_slots,
                                       spec=spec)
    warmup = None
    if warm_start and os.path.exists(warm_start):
        # Prompt lengths the scheduler will prefill: fresh admissions use
        # the full prompt; re-admissions replay context-minus-one, which
        # traces lazily (rare, eviction-dependent).
        warmup = serving.warmup(
            prompt_lens={len(r.prompt) for r in reqs},
            manifest=warm_start)
        # Counters reset so the serving phase's stats stand alone; plan /
        # kernel / trace caches all stay hot.
        engine.reset_stats(entries=False)
    result = serving.run(reqs)
    if warmup is not None:
        stats = result["engine_stats"]
        warmup["post_autotune_timings"] = sum(
            v for b in stats.values() for k, v in b.items()
            if k.startswith("autotune_timings"))
        warmup["post_plan_misses"] = sum(
            v for b in stats.values() for k, v in b.items()
            if k.startswith("plan_misses"))
        result["warmup"] = warmup
    elif warm_start:
        engine.save_manifest(warm_start)

    # Oracle: each request decoded alone on the static path must emit the
    # same greedy tokens the churning batch produced.
    identical = True
    for r in reqs:
        static = generate(cfg, params, jnp.asarray(r.prompt)[None, :],
                          r.max_new)
        want = np.asarray(static["tokens"][0])
        got = result["outputs"][r.rid]
        identical &= bool(np.array_equal(want, got))
    result["token_identical"] = identical
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching mode over a Poisson trace")
    ap.add_argument("--backend", choices=["xla", "pallas"], default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tuning-cache", default=None,
                    help="read/write autotune timing cache (JSON path)")
    ap.add_argument("--tuning-cache-preload", default=None,
                    help="read-only fleet-merged cache (tools/tune.py)")
    ap.add_argument("--refit-model", default=None,
                    help="refit-model JSON overlaying fleet-fitted cost "
                         "coefficients (tools/tune.py refit)")
    ap.add_argument("--warm-start", default=None,
                    help="descriptor manifest for AOT warm-start; created "
                         "on first (cold) run, consumed on the next")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    engine_kw = {}
    if args.backend:
        engine_kw["backend"] = args.backend
    if args.tuning_cache is not None:
        engine_kw["tuning_cache"] = args.tuning_cache
    if args.tuning_cache_preload is not None:
        engine_kw["tuning_cache_preload"] = args.tuning_cache_preload
    if args.refit_model:
        from repro.core.config import get_config as get_engine_config
        from repro.core.machine import load_refit_model
        engine_kw["machine"] = load_refit_model(
            args.refit_model, base=get_engine_config().machine)
    if engine_kw:
        from repro.core import configure
        configure(**engine_kw)

    if args.continuous:
        res = run_continuous(cfg, params, prompt_len=args.prompt_len // 4
                             or 8, max_new=args.gen // 4 or 4,
                             seed=args.seed, warm_start=args.warm_start)
        m = res["metrics"]
        print(f"arch={cfg.name} continuous: requests={m['requests']} "
              f"tokens={m['total_tokens']} decode_steps={m['decode_steps']} "
              f"evictions={m['evictions']} "
              f"tok/s={m['tokens_per_s']:.0f} "
              f"p50={m['p50_token_latency_s']*1e3:.1f}ms "
              f"p99={m['p99_token_latency_s']*1e3:.1f}ms "
              f"token_identical={res['token_identical']}")
        fam = res["engine_stats"].get("flash_decode", {})
        if fam.get("launches"):
            per_step = m["flash_decode_launches"] / max(m["decode_steps"], 1)
            print(f"engine[flash_decode]: launches={fam['launches']} "
                  f"({per_step:.2f}/decode step — flat while the batch "
                  f"churned)")
        ph = m.get("phase_seconds", {})
        if ph:
            print("phases: " + " ".join(
                f"{k}={ph[k]*1e3:.1f}ms" for k in sorted(ph)))
        w = res.get("warmup")
        if w is not None:
            print(f"warm-start: warmed {sum(w['kernels'].values())} "
                  f"kernels + {len(w['prefill_lengths'])} prefill traces "
                  f"in {w['seconds']:.2f}s; serving phase: "
                  f"autotune_timings={w['post_autotune_timings']} "
                  f"plan_misses={w['post_plan_misses']}")
        elif args.warm_start:
            print(f"warm-start: recorded manifest -> {args.warm_start} "
                  f"(next start is warm)")
        return

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    res = generate(cfg, params, prompts, args.gen)
    ptput = args.batch * args.prompt_len / res["prefill_seconds"]
    dtput = args.batch * (args.gen - 1) / max(res["decode_seconds"], 1e-9)
    print(f"arch={cfg.name} generated {res['tokens'].shape} "
          f"prefill={ptput:.0f} tok/s decode={dtput:.0f} tok/s")


if __name__ == "__main__":
    main()
