"""Deterministic, shardable synthetic data pipeline.

Production posture without shipping a corpus: every (step, sample) is a
pure function of the dataset seed, so

  * resume-after-failure is exact (skip-to-step is free — no iterator
    state to checkpoint beyond the step counter),
  * each data shard materializes only its slice (``make_global_batch``
    builds a global jax.Array from per-shard callbacks — no host ever
    holds the global batch),
  * the token stream follows a fixed random bigram (Markov) table, so
    cross-entropy has learnable structure and training loss demonstrably
    falls below the unigram floor (used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    branching: int = 8  # candidate successors per token (entropy knob)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v, k = self.vocab_size, self.branching
        self._succ = rng.integers(0, v, size=(v, k), dtype=np.int64)

    def _sample_rows(self, step: int, row0: int, rows: int) -> np.ndarray:
        """Rows [row0, row0+rows) of the global batch at ``step``."""
        out = np.empty((rows, self.seq_len + 1), dtype=np.int32)
        for i in range(rows):
            r = np.random.default_rng(
                (self.seed, step, row0 + i))  # counter-based: O(1) skip
            tok = r.integers(0, self.vocab_size)
            choices = r.integers(0, self.branching, size=self.seq_len + 1)
            for t in range(self.seq_len + 1):
                out[i, t] = tok
                tok = self._succ[tok, choices[t]]
        return out

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        """Full batch on one host (examples / tests)."""
        toks = self._sample_rows(step, 0, self.global_batch)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def unigram_floor_nats(self) -> float:
        """Entropy of the stationary next-token distribution ≈ log(branching)."""
        return float(np.log(self.branching))


def make_global_batch(ds: SyntheticLMDataset, step: int, mesh: Mesh,
                      batch_axes=("pod", "data")) -> Dict[str, jax.Array]:
    """Build the sharded global batch; each device materializes its rows."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    sharding = NamedSharding(mesh, P(axes, None))

    def build(key):
        def cb(index):
            rowsel = index[0]
            row0 = rowsel.start or 0
            rows = (rowsel.stop or ds.global_batch) - row0
            toks = ds._sample_rows(step, row0, rows)
            return toks[:, :-1] if key == "tokens" else toks[:, 1:]

        shape = (ds.global_batch, ds.seq_len)
        return jax.make_array_from_callback(shape, sharding, cb)

    return {"tokens": build("tokens"), "labels": build("labels")}
