"""Modality frontend STUBS (per assignment: the transformer backbone is
real; vision/audio feature extractors provide *precomputed* embeddings).

  * vision (internvl2): ``input_specs`` supplies ViT patch embeddings
    (b, n_img, vit_dim); a learned MLP projector maps them into the LM
    width and they are prepended to the token embeddings.
  * audio (seamless): ``input_specs`` supplies fbank frame embeddings
    (b, s_enc, frame_dim); a learned adapter maps them into the encoder
    width.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import common


def frontend_init(rng, cfg):
    if cfg.modality is None:
        return None
    r1, r2 = common.split_rngs(rng, 2)
    if cfg.modality == "vision":
        return {
            "proj1": common.linear_init(r1, cfg.modality_dim, cfg.d_model, bias=True),
            "proj2": common.linear_init(r2, cfg.d_model, cfg.d_model, bias=True),
        }
    if cfg.modality == "audio":
        return {"adapter": common.linear_init(r1, cfg.modality_dim, cfg.d_model, bias=True)}
    raise ValueError(cfg.modality)


def frontend_apply(params, cfg, feats):
    dt = jnp.dtype(cfg.dtype)
    if cfg.modality == "vision":
        h = common.linear(params["proj1"], feats.astype(dt),
                          epilogue="gelu", compute_dtype=dt)
        return common.linear(params["proj2"], h, compute_dtype=dt)
    if cfg.modality == "audio":
        return common.linear(params["adapter"], feats.astype(dt), compute_dtype=dt)
    raise ValueError(cfg.modality)
