"""Composable model substrate.

All dense compute routes through ``repro.core.matmul`` — the paper's JIT
GEMM engine is the matmul layer of every architecture.  Layers are plain
``init(rng, cfg) -> params`` / ``apply(params, x, ...)`` function pairs
operating on nested-dict pytrees; layer stacks are ``lax.scan`` over
stacked parameters (compile-time O(1) in depth).
"""
from repro.models.lm import LanguageModel  # noqa: F401
from repro.models.encdec import EncoderDecoderModel  # noqa: F401
