"""Decoder-only language model (with optional modality prefix).

API (pure functions over nested-dict pytrees):

  * ``LanguageModel.init(rng, cfg) -> params``
  * ``LanguageModel.apply(params, cfg, tokens, ...) -> (logits, cache, aux)``
  * ``LanguageModel.init_cache(cfg, batch, capacity) -> cache``

Decode is ``apply`` with a 1-token input and a cache; caches for "local"
blocks are ring buffers of size ``attn_window`` and for "rec"/"ssm" blocks
are O(1) states — so 500k-context decode carries no 500k-sized buffers for
sub-quadratic architectures.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.blocks import stack_apply, stack_cache, stack_init
from repro.models.frontends import frontend_apply, frontend_init
from repro.runtime.shardlib import shard_activation


class LanguageModel:
    @staticmethod
    def init(rng, cfg):
        r_embed, r_stack, r_norm, r_head, r_front = common.split_rngs(rng, 5)
        params = {
            "embed": common.embedding_init(r_embed, cfg.vocab_size, cfg.d_model),
            "blocks": stack_init(r_stack, cfg),
            "final_norm": common.norm_init(cfg.norm_type, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = common.linear_init(r_head, cfg.d_model,
                                                   cfg.vocab_size)
        if cfg.modality is not None:
            params["frontend"] = frontend_init(r_front, cfg)
        return params

    @staticmethod
    def apply(params, cfg, tokens, *, positions=None, cache=None,
              modality_feats=None, logits_mode="all"):
        """tokens: (b, s) int32.  modality_feats: (b, n_mod, modality_dim)
        prepended before the text tokens (positions account for the
        prefix).  ``logits_mode="last"`` unembeds only the final position
        (prefill: skips a (b,s,V)-sized matmul + HBM round-trip).
        Returns (logits, new_cache, aux_loss)."""
        dt = jnp.dtype(cfg.dtype)
        b, s = tokens.shape
        x = common.embed(params["embed"], tokens, dt)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt)

        n_mod = 0
        if modality_feats is not None:
            prefix = frontend_apply(params["frontend"], cfg, modality_feats)
            n_mod = prefix.shape[1]
            x = jnp.concatenate([prefix, x], axis=1)

        if positions is None:
            positions = jnp.arange(s + n_mod, dtype=jnp.int32)
        x = shard_activation(x, (("pod", "data"), "model", None))

        x, new_cache, aux = stack_apply(params["blocks"], cfg, x, positions,
                                        cache=cache)
        x = common.norm_apply(cfg.norm_type, params["final_norm"], x,
                              cfg.norm_eps)
        if logits_mode == "last":
            x = x[:, -1:]
        ldt = jnp.dtype(cfg.logits_dtype)
        if cfg.tie_embeddings:
            logits = common.unembed(params["embed"], x, dt, out_dtype=ldt)
        else:
            w = common.cast_param(params["lm_head"]["w"], dt)
            from repro.core import matmul
            logits = matmul(x, w, out_dtype=ldt)
        if cfg.final_logit_softcap:
            cap = cfg.final_logit_softcap
            logits = jnp.tanh(logits / cap) * cap
        logits = shard_activation(logits, (("pod", "data"), "model", None))
        return logits, new_cache, aux

    @staticmethod
    def init_cache(cfg, batch, capacity, paged=None):
        """``paged`` (a :class:`repro.models.attention.PageSpec`) builds
        the continuous-batching serving cache: "attn" blocks become paged
        pools + block tables, everything else stays slot-major dense
        (DESIGN.md §12)."""
        return stack_cache(batch, cfg, capacity, paged)
