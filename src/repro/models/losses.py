"""Losses: next-token cross-entropy with z-loss, memory-optimal backward.

Forward reductions run in fp32 over the (possibly sharded) vocab dim; the
custom VJP emits the d(logits) cotangent directly in the logits dtype
(bf16 in production) — the default autodiff path materializes 2-3
logits-sized fp32 buffers, which at a 256k vocab is ~6 GiB/device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.custom_vjp
def _nll_and_lse(logits, labels):
    """Returns (nll, lse) per position; logits (..., V), labels (...)."""
    l32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(l32, axis=-1)
    gold = jnp.take_along_axis(l32, labels[..., None], axis=-1)[..., 0]
    return lse - gold, lse


def _nll_fwd(logits, labels):
    out = _nll_and_lse(logits, labels)
    return out, (logits, labels, out[1])


def _nll_bwd(res, g):
    logits, labels, lse = res
    g_nll, g_lse = g
    # softmax recomputed from the saved (tiny) lse; everything fuses —
    # the only logits-sized buffer is the bf16 cotangent itself.  The
    # label indicator is a fused iota-compare (a materialized fp32
    # one_hot + s32 iota costs ~4.5 GiB at a 256k vocab).
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    is_gold = jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1) == labels[..., None]
    coeff = (g_nll + g_lse)[..., None]
    dlogits = coeff * p - jnp.where(is_gold, g_nll[..., None], 0.0)
    return dlogits.astype(logits.dtype), None


_nll_and_lse.defvjp(_nll_fwd, _nll_bwd)


def softmax_cross_entropy(logits, labels, *, z_loss: float = 0.0,
                          mask=None):
    """logits: (..., V); labels: (...) int.  Returns (loss, metrics)."""
    nll, lse = _nll_and_lse(logits, labels)
    total = nll
    if z_loss:
        total = total + z_loss * jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(total * mask) / denom
        nll_mean = jnp.sum(nll * mask) / denom
    else:
        loss = jnp.mean(total)
        nll_mean = jnp.mean(nll)
    metrics = {
        "nll": nll_mean,
        "ppl_proxy": jnp.exp(jnp.clip(nll_mean, 0.0, 20.0)),
    }
    return loss, metrics
