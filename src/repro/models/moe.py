"""Mixture-of-Experts with top-k routing (GShard/T5X-style grouped dispatch).

The expert FFNs are batches of *small, ragged* GEMMs — exactly the
population the paper's engine targets; on TPU hardware the expert compute
routes through ``repro.kernels.grouped_gemm`` (see kernels/).  The
dispatch/combine here uses the capacity-factor one-hot formulation (dense
einsums) because it partitions deterministically under SPMD: tokens are
processed in groups of ``cfg.moe_group`` so dispatch stays O(g·E·C) per
group instead of O(T·E·C).

Routing priority is k-major (all top-1 assignments beat any top-2), the
T5X convention.  Dropped tokens pass through the residual stream only.
Returns the GShard auxiliary load-balancing loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import get_config
from repro.models import common


_MAX_BATCH_SHARDS = 32  # pod x data on the largest production mesh


def _expert_gemm_grouped(x4, w, epilogue=None):
    """(n, e, cap, k) x (e, k, f) -> (n, e, cap, f) via the engine's
    ragged grouped-GEMM family.

    The capacity slots are uniform, so the "ragged" split degenerates to
    E equal groups of n*cap rows — rows sorted by expert after a
    transpose, exactly the layout the kernel's scalar-prefetch dispatch
    expects.  ``epilogue`` fuses the activation into the kernel's store
    (DESIGN.md §9) instead of a follow-up elementwise pass.
    Differentiable: training pulls gradients through the family's custom
    VJP, whose backward is ONE scheduled dX/dW walk over the same
    runtime tile tables — never the pad/scatter path (DESIGN.md §11).
    """
    from repro.kernels.grouped_gemm import grouped_gemm
    n, e, cap, k = x4.shape
    xt = x4.transpose(1, 0, 2, 3).reshape(e * n * cap, k)
    sizes = jnp.full((e,), n * cap, jnp.int32)
    out = grouped_gemm(xt, w, sizes, epilogue=epilogue)
    return out.reshape(e, n, cap, -1).transpose(1, 0, 2, 3)


def moe_init(rng, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    rr, rg, ru, rd = common.split_rngs(rng, 4)
    p = {
        "router": common.linear_init(rr, d, e, bias=False),
        # Experts stacked on a leading E dim.
        "w_up": {"w": common.scaled_init(ru, (e, d, f), d)},
        "w_down": {"w": common.scaled_init(rd, (e, f, d), f)},
    }
    if cfg.mlp_gated:
        p["w_gate"] = {"w": common.scaled_init(rg, (e, d, f), d)}
    return p


def _act(x, kind):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": lambda v: jnp.maximum(v, 0)}[kind](x)


def moe_apply(params, cfg, x):
    """x: (b, s, d) -> (y, aux_loss)."""
    dt = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    # Keep >= MAX_BATCH_SHARDS groups so the group dim stays batch-sharded
    # on the production mesh even at decode shapes (t small).
    g = min(cfg.moe_group, max(1, t // _MAX_BATCH_SHARDS))
    while t % g:
        g -= 1
    n = t // g
    cap = int(cfg.capacity_factor * g * k / e)
    cap = max(8, -(-cap // 8) * 8)

    from repro.runtime.shardlib import current_mesh, shard_activation
    mesh = current_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    ep = msize > 1 and e % msize == 0  # expert parallelism when E divides

    xg = x.reshape(n, g, d).astype(dt)
    xg = shard_activation(xg, (("pod", "data"), None, None))

    # --- routing (fp32) ---------------------------------------------------
    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (n, g, e)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (n, g, k)
    if cfg.moe_renormalize:
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # GShard aux loss.
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce)

    # --- capacity assignment (k-major priority) ----------------------------
    mask = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (n, g, k, e)
    mask_flat = mask.transpose(0, 2, 1, 3).reshape(n, k * g, e)
    pos_flat = jnp.cumsum(mask_flat, axis=1) - 1.0
    pos = pos_flat.reshape(n, k, g, e).transpose(0, 2, 1, 3)  # (n, g, k, e)
    keep = mask * (pos < cap)  # (n, g, k, e)
    slot = jnp.sum(pos * keep, axis=-1).astype(jnp.int32)  # (n, g, k)
    slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32) * \
        jnp.sum(keep, axis=-1, keepdims=True)  # (n, g, k, cap)

    dispatch = jnp.einsum("ngke,ngkc->ngec", keep, slot_oh).astype(dt)
    combine = jnp.einsum("ngke,ngkc->ngec", keep * gate_vals[..., None],
                         slot_oh).astype(dt)

    # Two SPMD layouts (DESIGN.md §5):
    #   * EP  (E % model == 0, e.g. phi3.5-moe): experts live on "model";
    #     dispatch produces e-sharded slot buffers — an all-to-all moves
    #     tokens to their experts, weights never move.
    #   * TP-f fallback (grok-1: E=8 < 16): tokens stay data-sharded, the
    #     expert FFN dim f is model-sharded (Megatron inside each expert).
    bd = ("pod", "data")
    # Mesh-aware engine dispatch (DESIGN.md §14): under the pallas
    # backend, EP expert compute enters the engine as a MESH descriptor
    # — the comm-charged planner arbitrates gathered vs distributed
    # (all_to_all) dispatch per shape, keeping the fused single-launch
    # property per shard.  Needs the token-group dim divisible too.
    ep_mesh = ep and get_config().backend == "pallas" and n % msize == 0
    if ep:
        dispatch = shard_activation(dispatch, (bd, None, "model", None))
        combine = shard_activation(combine, (bd, None, "model", None))
        if ep_mesh:
            # shard_map shards the token-group dim over "model"; matching
            # constraints avoid reshard ping-pong between the three GEMMs.
            xin_spec = ("model", None, None, None)
            h_spec = ("model", None, None, None)
        else:
            xin_spec = (bd, "model", None, None)
            h_spec = (bd, "model", None, None)
    elif t <= 2048:
        # Decode-scale token counts: replicate the (tiny) token block so
        # the 2D-sharded expert weights never move — XLA partial-contracts
        # the data-sharded d dim and all-reduces the small activations
        # instead of all-gathering GBs of weights per step.
        xin_spec = (None, None, None, None)
        h_spec = (None, None, None, "model")
    else:
        xin_spec = (bd, None, None, None)
        h_spec = (bd, None, None, "model")

    # --- expert compute (batched small GEMMs over the E dim) --------------
    # Under the pallas backend the three expert GEMMs route through the
    # engine's grouped-GEMM family (descriptor-planned tiles), with the
    # activation fused into the kernel epilogue (DESIGN.md §9); the XLA
    # default keeps the einsum formulation, which partitions under SPMD.
    if get_config().backend == "pallas":
        if ep_mesh:
            from repro.kernels.grouped_gemm import expert_parallel_grouped_gemm

            def mm(x4, w, epilogue=None):
                return expert_parallel_grouped_gemm(x4, w, axis="model",
                                                    epilogue=epilogue)
        else:
            mm = _expert_gemm_grouped
    else:
        def mm(x4, w, epilogue=None):
            out = jnp.einsum("neck,ekf->necf", x4, w)
            return _act(out, epilogue) if epilogue else out
    xin = jnp.einsum("ngec,ngd->necd", dispatch, xg)  # (n, e, cap, d)
    xin = shard_activation(xin, xin_spec)
    w_up = common.cast_param(params["w_up"]["w"], dt)
    w_down = common.cast_param(params["w_down"]["w"], dt)
    if cfg.mlp_gated:
        up = shard_activation(mm(xin, w_up), h_spec)
        w_gate = common.cast_param(params["w_gate"]["w"], dt)
        gate = shard_activation(mm(xin, w_gate, epilogue=cfg.mlp_act), h_spec)
        h = gate * up
    else:
        h = shard_activation(mm(xin, w_up, epilogue=cfg.mlp_act), h_spec)
    y_slots = mm(h, w_down)
    y_slots = shard_activation(y_slots, xin_spec)
    y = jnp.einsum("ngec,necd->ngd", combine, y_slots)
    return y.reshape(b, s, d), aux_loss
