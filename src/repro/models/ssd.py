"""Mamba-2 SSD (state-space duality) layer, chunked algorithm
(arXiv:2405.21060 §6).

The chunked SSD forward is a ladder of *small batched GEMMs* —
(Q×n)·(n×Q), (Q×n)·(n×p), (n×Q)·(Q×p) with Q=chunk, n=state, p=headdim all
in the 64–256 range — i.e. exactly the small-GEMM population the paper's
engine targets (DESIGN.md §4).  On TPU the inner contractions route through
the engine; here they are einsums so the XLA dry-run path shards cleanly.

Layer structure (Mamba-2 block):

    in_proj -> [z | x | B | C | dt];  conv1d+silu over [x|B|C];
    SSD(x, dt, A, B, C) + D·x;  RMSNorm(y ⊙ silu(z));  out_proj

Decode carries (conv tail, S[h,p,n]) — O(1) state in sequence length,
which is what makes mamba2 a legal ``long_500k`` architecture.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.config import get_config
from repro.models import common


class SSMState(NamedTuple):
    conv: jax.Array  # (b, cw-1, conv_dim)
    s: jax.Array     # (b, h, p, n) fp32


def ssd_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    return d_in, h, cfg.ssm_ngroups, cfg.ssm_state


def ssd_init(rng, cfg):
    d = cfg.d_model
    d_in, h, g, n = ssd_dims(cfg)
    conv_dim = d_in + 2 * g * n
    proj_dim = 2 * d_in + 2 * g * n + h
    ri, ro, rc, rd = common.split_rngs(rng, 4)
    dt = jnp.exp(jax.random.uniform(rd, (h,), jnp.float32,
                                    jnp.log(0.001), jnp.log(0.1)))
    return {
        "in_proj": common.linear_init(ri, d, proj_dim),
        "out_proj": common.linear_init(ro, d_in, d),
        "conv_w": common.normal_init(rc, (cfg.conv1d_width, conv_dim), 0.02),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse-softplus init
        "norm": common.rmsnorm_init(d_in),
    }


def _segsum(x):
    """log-decay lower-triangular matrix: out[..., i, j] = sum_{j<k<=i} x[k]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(x, dt, a, b_mat, c_mat, chunk, s0=None):
    """Chunked SSD scan.

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); a: (h,) negative;
    b_mat/c_mat: (b, s, g, n); s0: optional initial state (b, h, p, n).
    Returns y: (b, s, h, p), final state (b, h, p, n).
    """
    bsz, s_orig, h, p = x.shape
    g, n = b_mat.shape[-2], b_mat.shape[-1]
    pad = (-s_orig) % chunk
    if pad:
        # dt = 0 on padded steps => decay 1 and zero input: state-exact.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    rep = h // g

    # reshape into chunks
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, g, n)
    cc = c_mat.reshape(bsz, nc, chunk, g, n)

    da = dtc * a[None, None, None, :]              # (b, nc, Q, h) log-decay
    da_cs = jnp.cumsum(da, axis=2)                  # within-chunk cumsum
    da_tot = da_cs[:, :, -1]                        # (b, nc, h)

    # ---- intra-chunk (quadratic within chunk: small GEMM ladder) --------
    L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (b, nc, h, Q, Q)
    xdt = xc * dtc[..., None]                        # (b, nc, Q, h, p)
    if get_config().backend == "pallas":
        # Engine routing (DESIGN.md §10): the whole chunked scan — the
        # intra-chunk ladder AND the inter-chunk recurrence — is ONE
        # dispatch of the ssd_chunk family's scan form, with each
        # (batch, head) pair a group and the (p, n) state carried across
        # the chunk walk inside the kernel; the associative-scan +
        # einsum composition below never materializes on this path.
        # Differentiable: training pulls gradients through the family's
        # custom VJP, whose backward is ONE reverse-walk launch carrying
        # the state cotangent in scratch (DESIGN.md §11).
        from repro.kernels.ssd_chunk import ssd_chunk_scan
        gdim = bsz * h
        cg = jnp.repeat(cc, rep, axis=3).transpose(0, 3, 1, 2, 4) \
            .reshape(gdim, nc, chunk, n)
        bg = jnp.repeat(bc, rep, axis=3).transpose(0, 3, 1, 2, 4) \
            .reshape(gdim, nc, chunk, n)
        lg = L.transpose(0, 2, 1, 3, 4).reshape(gdim, nc, chunk, chunk)
        xg = xdt.transpose(0, 3, 1, 2, 4).reshape(gdim, nc, chunk, p)
        di = jnp.exp(da_cs).transpose(0, 3, 1, 2).reshape(gdim, nc, chunk)
        do = jnp.exp(da_tot[:, :, None] - da_cs) \
            .transpose(0, 3, 1, 2).reshape(gdim, nc, chunk)
        s0g = (jnp.zeros((gdim, p, n), jnp.float32) if s0 is None
               else s0.astype(jnp.float32).reshape(gdim, p, n))
        yg, s_fin = ssd_chunk_scan(cg, bg, lg, xg, di, do, s0g)
        y = yg.reshape(bsz, h, nc, chunk, p).transpose(0, 2, 3, 1, 4) \
            .reshape(bsz, s, h, p)
        return y[:, :s_orig], s_fin.reshape(bsz, h, p, n)
    # scores: C_i · B_j over state dim, broadcast groups->heads
    cb = jnp.einsum("bnqgd,bnkgd->bngqk", cc, bc)   # (b, nc, g, Q, Q)
    cb = jnp.repeat(cb, rep, axis=2)                 # (b, nc, h, Q, Q)
    w = cb * L
    y_diag = jnp.einsum("bnhqk,bnkhp->bnqhp", w.astype(x.dtype), xdt)

    # ---- chunk states ----------------------------------------------------
    decay_out = jnp.exp(da_tot[..., None] - da_cs.transpose(0, 1, 3, 2))  # (b,nc,h,Q)
    bfull = jnp.repeat(bc, rep, axis=3)  # (b, nc, Q, h, n) groups -> heads
    bx = jnp.einsum("bnqhd,bnqhp->bnhpd", bfull,
                    (xdt * decay_out.transpose(0, 1, 3, 2)[..., None]).astype(x.dtype))

    # ---- inter-chunk recurrence (associative over chunks) ----------------
    dec = jnp.exp(da_tot)  # (b, nc, h) decay applied across each chunk

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, sl * dr[..., None, None] + sr

    dcum, s_incl = jax.lax.associative_scan(combine, (dec.astype(jnp.float32),
                                                      bx.astype(jnp.float32)), axis=1)
    if s0 is not None:
        # fold the initial state into every chunk's inclusive state
        s_incl = s_incl + dcum[..., None, None] * s0[:, None]
    # state *entering* chunk i = inclusive state of chunk i-1
    first = jnp.zeros_like(s_incl[:, :1]) if s0 is None else s0[:, None]
    s_prev = jnp.concatenate([first, s_incl[:, :-1]], axis=1)  # (b,nc,h,p,n)

    # ---- inter-chunk contribution ----------------------------------------
    decay_in = jnp.exp(da_cs)  # (b, nc, Q, h)
    cfull = jnp.repeat(cc, rep, axis=3)  # (b, nc, Q, h, n)
    y_off = jnp.einsum("bnqhd,bnhpd->bnqhp", cfull,
                       s_prev.astype(x.dtype)) * decay_in[..., None].astype(x.dtype)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y[:, :s_orig], s_incl[:, -1]  # final state (b, h, p, n)


def ssd_apply(params, cfg, x, *, state: Optional[SSMState] = None):
    """x: (b, s, d) -> (y, new_state)."""
    dt_ = jnp.dtype(cfg.dtype)
    bsz, s, _ = x.shape
    d_in, h, g, n = ssd_dims(cfg)
    p = cfg.ssm_head_dim

    zxbcdt = common.linear(params["in_proj"], x, compute_dtype=dt_)
    z, xs, bb, cc, dt_raw = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + g * n, 2 * d_in + 2 * g * n], axis=-1)

    conv_in = jnp.concatenate([xs, bb, cc], axis=-1)
    tail = state.conv if state is not None else None
    cw = params["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((bsz, cw - 1, conv_in.shape[-1]), conv_in.dtype)
    xp = jnp.concatenate([tail.astype(conv_in.dtype), conv_in], axis=1)
    conv_out = sum(xp[:, i:i + s] * params["conv_w"][i].astype(conv_in.dtype)
                   for i in range(cw))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(conv_in.dtype))
    new_tail = xp[:, -(cw - 1):]

    xs, bb, cc = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)
    xs = xs.reshape(bsz, s, h, p)
    bb = bb.reshape(bsz, s, g, n)
    cc = cc.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    dt = jnp.clip(dt, 0.0, 10.0)
    a = -jnp.exp(params["A_log"])  # (h,) negative

    if s == 1 and state is not None:
        # ---- decode: single recurrent step -------------------------------
        da = jnp.exp(dt[:, 0] * a[None, :])  # (b, h)
        bx = jnp.einsum("bgd,bhp->bhpd",
                        bb[:, 0].astype(jnp.float32),
                        (xs[:, 0] * dt[:, 0, :, None].astype(xs.dtype)).astype(jnp.float32))
        s_new = state.s * da[..., None, None] + bx
        cfull = jnp.repeat(cc[:, 0], h // g, axis=1)  # (b, h, n)
        y = jnp.einsum("bhd,bhpd->bhp", cfull.astype(jnp.float32), s_new)
        y = y[:, None].astype(dt_)  # (b, 1, h, p)
        final_state = s_new
    else:
        s0 = state.s if state is not None else None
        y, final_state = _ssd_chunked(xs, dt, a, bb, cc, cfg.ssm_chunk, s0)

    y = y + xs * params["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(bsz, s, d_in)
    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = common.linear(params["out_proj"], y, compute_dtype=dt_)
    new_state = SSMState(conv=new_tail, s=final_state.astype(jnp.float32))
    return out, new_state


def init_ssm_state(batch, cfg) -> SSMState:
    d_in, h, g, n = ssd_dims(cfg)
    conv_dim = d_in + 2 * g * n
    return SSMState(
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, conv_dim), jnp.bfloat16),
        s=jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
    )
