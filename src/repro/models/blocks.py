"""Residual blocks and layer stacks.

A *block* = (norm → mixer → residual) [+ (norm → mlp|moe → residual)].
Mixer kinds: "attn" (global attention), "local" (sliding-window attention),
"rec" (RG-LRU), "ssm" (Mamba-2 SSD).  An architecture is a repeating
``block_pattern`` (e.g. ("rec","rec","local") for recurrentgemma); the
stack scans over pattern *groups* with stacked params so compile time is
O(1) in depth, with any non-multiple remainder applied unscanned.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import common
from repro.models.attention import (attention_apply, attention_init,
                                    init_kv_cache, init_paged_kv_cache)
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import init_recurrent_state, rglru_apply, rglru_init
from repro.models.ssd import init_ssm_state, ssd_apply, ssd_init

from repro.runtime.shardlib import shard_activation


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def block_init(rng, cfg, kind: str, cross: bool = False):
    r_mix, r_ff, r_cross = common.split_rngs(rng, 3)
    p: Dict[str, Any] = {"norm_mix": common.norm_init(cfg.norm_type, cfg.d_model)}
    if kind in ("attn", "local"):
        p["mixer"] = attention_init(r_mix, cfg)
    elif kind == "rec":
        p["mixer"] = rglru_init(r_mix, cfg)
    elif kind == "ssm":
        p["mixer"] = ssd_init(r_mix, cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    if cross:
        p["norm_cross"] = common.norm_init(cfg.norm_type, cfg.d_model)
        p["cross"] = attention_init(r_cross, cfg, cross=True)
    if cfg.block_has_mlp:
        p["norm_ff"] = common.norm_init(cfg.norm_type, cfg.d_model)
        p["ff"] = moe_init(r_ff, cfg) if cfg.num_experts else mlp_init(r_ff, cfg)
    return p


def block_cache(batch, cfg, kind: str, capacity: int, paged=None):
    """Initial decode-state for one block (None for stateless train).

    ``paged`` (a :class:`repro.models.attention.PageSpec`) switches "attn"
    blocks to the paged pool layout of the continuous-batching serving
    runtime (DESIGN.md §12); "local"/"rec"/"ssm" states are already
    O(window)/O(1) per slot, so they stay slot-major dense."""
    if kind == "attn":
        if paged is not None:
            return init_paged_kv_cache(batch, paged, cfg.num_kv_heads,
                                       cfg.head_dim,
                                       jnp.dtype(cfg.kv_cache_dtype))
        return init_kv_cache(batch, capacity, cfg.num_kv_heads, cfg.head_dim,
                             jnp.dtype(cfg.kv_cache_dtype))
    if kind == "local":
        cap = min(capacity, cfg.attn_window)
        return init_kv_cache(batch, cap, cfg.num_kv_heads, cfg.head_dim,
                             jnp.dtype(cfg.kv_cache_dtype))
    if kind == "rec":
        return init_recurrent_state(batch, cfg)
    if kind == "ssm":
        return init_ssm_state(batch, cfg)
    raise ValueError(kind)


def block_apply(params, cfg, kind: str, x, positions, *, cache=None,
                enc_out=None) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = common.norm_apply(cfg.norm_type, params["norm_mix"], x, cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.attn_window if kind == "local" else None
        y, new_cache = attention_apply(params["mixer"], cfg, h, positions,
                                       cache=cache, window=window)
    elif kind == "rec":
        y, new_cache = rglru_apply(params["mixer"], cfg, h, state=cache)
    elif kind == "ssm":
        y, new_cache = ssd_apply(params["mixer"], cfg, h, state=cache)
    else:
        raise ValueError(kind)
    x = x + y
    x = shard_activation(x, (("pod", "data"), "model", None))

    if enc_out is not None and "cross" in params:
        h = common.norm_apply(cfg.norm_type, params["norm_cross"], x, cfg.norm_eps)
        y, _ = attention_apply(params["cross"], cfg, h, positions,
                               kv_override=enc_out)
        x = x + y

    if cfg.block_has_mlp:
        h = common.norm_apply(cfg.norm_type, params["norm_ff"], x, cfg.norm_eps)
        if cfg.num_experts:
            y, aux = moe_apply(params["ff"], cfg, h)
        else:
            y = mlp_apply(params["ff"], cfg, h)
        x = x + y
        x = shard_activation(x, (("pod", "data"), "model", None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack: scan over pattern groups
# ---------------------------------------------------------------------------

def stack_layout(cfg) -> Tuple[int, Tuple[str, ...]]:
    """(num_scanned_groups, remainder_kinds)."""
    pat = cfg.block_pattern
    groups = cfg.num_layers // len(pat)
    rem = cfg.num_layers - groups * len(pat)
    return groups, tuple(pat[:rem])


def stack_init(rng, cfg, cross: bool = False):
    pat = cfg.block_pattern
    groups, rem = stack_layout(cfg)
    r_groups, r_rem = jax.random.split(rng)

    def one_group(r):
        rs = common.split_rngs(r, len(pat))
        return {f"b{i}": block_init(rs[i], cfg, kind, cross)
                for i, kind in enumerate(pat)}

    stacked = jax.vmap(one_group)(jax.random.split(r_groups, groups)) \
        if groups else None
    rem_params = [block_init(r, cfg, kind, cross)
                  for r, kind in zip(common.split_rngs(r_rem, max(1, len(rem))), rem)]
    return {"groups": stacked, "rem": rem_params}


def stack_cache(batch, cfg, capacity: int, paged=None):
    pat = cfg.block_pattern
    groups, rem = stack_layout(cfg)

    def one_group(_):
        return {f"b{i}": block_cache(batch, cfg, kind, capacity, paged)
                for i, kind in enumerate(pat)}

    stacked = None
    if groups:
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one_group(g) for g in range(groups)]) \
            if groups > 1 else jax.tree.map(lambda x: x[None], one_group(0))
    rem_caches = [block_cache(batch, cfg, kind, capacity, paged)
                  for kind in rem]
    return {"groups": stacked, "rem": rem_caches}


def _group_apply(group_params, cfg, x, positions, group_cache, enc_out):
    pat = cfg.block_pattern
    new_cache = {} if group_cache is not None else None
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(pat):
        c = group_cache[f"b{i}"] if group_cache is not None else None
        x, nc, a = block_apply(group_params[f"b{i}"], cfg, kind, x, positions,
                               cache=c, enc_out=enc_out)
        aux = aux + a
        if new_cache is not None:
            new_cache[f"b{i}"] = nc
    return x, new_cache, aux


def stack_apply(params, cfg, x, positions, *, cache=None, enc_out=None):
    """Returns (x, new_cache, aux_loss_sum)."""
    groups, rem = stack_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_group_cache = None

    if groups:
        def body(carry, xs):
            h, aux = carry
            if cache is not None:
                gp, gc = xs
            else:
                gp, gc = xs, None
            # Name the (bf16) carry so the remat policy saves exactly this
            # tensor per layer group — without the name, XLA is free to
            # save an fp32-converted copy of the whole stack (observed:
            # +3.8 GiB/device on starcoder2, EXPERIMENTS.md §Perf).
            h = checkpoint_name(h, "block_carry")
            h, nc, a = _group_apply(gp, cfg, h, positions, gc, enc_out)
            return (h, aux + a), nc

        if cfg.remat:
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "block_carry"),
                prevent_cse=False)
        xs = (params["groups"], cache["groups"]) if cache is not None \
            else params["groups"]
        (x, aux_total), new_group_cache = jax.lax.scan(body, (x, aux_total), xs)

    new_rem = []
    for i, kind in enumerate(rem):
        c = cache["rem"][i] if cache is not None else None
        x, nc, a = block_apply(params["rem"][i], cfg, kind, x, positions,
                               cache=c, enc_out=enc_out)
        aux_total = aux_total + a
        new_rem.append(nc)

    new_cache = None
    if cache is not None:
        new_cache = {"groups": new_group_cache, "rem": new_rem}
    return x, new_cache, aux_total
