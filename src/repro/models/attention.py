"""Multi-head attention: GQA / MQA, RoPE, qk-norm, QKV-bias, logit softcap,
local (sliding-window) attention, chunked long-context attention, and
ring-buffer KV caches for decode.

Layout: heads are kept *flattened* (b, s, h, hd) with K/V repeated to the
full head count for GQA — the standard tensor-parallel formulation: the
head dim shards on "model" when divisible; otherwise the score matrix
shards over the query dim instead (context-parallel fallback, used by e.g.
internvl2's 14-head backbone).  All projections route through
``repro.core.matmul``.

For sequences above ``Q_CHUNK`` the score matrix is never fully
materialized: a ``lax.scan`` over query chunks attends against the full
(or windowed) KV — linear activation memory in sequence length (the
XLA-path analogue of the Pallas flash-attention kernel in
``repro.kernels.flash_attention``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.config import get_config
from repro.models import common
from repro.models.rotary import apply_rope
from repro.runtime.shardlib import current_mesh, shard_activation

Q_CHUNK = 512

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (b, S, h_kv, hd)
    v: jax.Array  # (b, S, h_kv, hd)
    pos: jax.Array  # (b, S) absolute position of each slot, -1 = empty


def init_kv_cache(batch, capacity, n_kv, head_dim, dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
    )


class PageSpec(NamedTuple):
    """Static paged-cache geometry (the serving runtime's pool shape).

    Threaded through ``block_cache``/``stack_cache``/``init_cache``: when
    present, "attn" blocks get a :class:`PagedKVCache` pool instead of a
    dense per-slot ring (DESIGN.md §12).  ``max_blocks * page_size`` caps
    the per-sequence context length the block tables can map.
    ``kv_quant="int8"`` stores the pools in int8 with per-token f32
    dequant scales (DESIGN.md §13) — half the KV bytes per token, scales
    folded into the decode kernel's score/PV algebra."""
    num_pages: int
    page_size: int
    max_blocks: int
    kv_quant: Optional[str] = None


class PagedKVCache(NamedTuple):
    """Paged KV pool + per-slot block tables (continuous batching).

    Unlike :class:`KVCache`, storage is not per-slot: ``k``/``v`` pool
    pages are allocated to sequences by the host-side free-list allocator
    (``repro.runtime.pages.PagePool``) and mapped by ``tables`` — so a
    slot's KV footprint tracks its actual length, and admitting/evicting
    a sequence moves page *indices*, never KV bytes.  Position ``p`` of
    slot ``i`` lives at ``(tables[i, p // P], p % P)``.

    ``k_scale``/``v_scale`` (int8 pools only, else None): per-token f32
    dequant scales, same page layout as the pools with the head/feature
    dims reduced away — ``(num_pages, page_size)``."""
    k: jax.Array       # (num_pages, page_size, h_kv, hd)
    v: jax.Array       # (num_pages, page_size, h_kv, hd)
    tables: jax.Array  # (num_slots, max_blocks) int32 page ids
    k_scale: Optional[jax.Array] = None  # (num_pages, page_size) f32
    v_scale: Optional[jax.Array] = None


def init_paged_kv_cache(num_slots, spec: PageSpec, n_kv, head_dim,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    kv_quant = getattr(spec, "kv_quant", None)
    pool_dtype = jnp.int8 if kv_quant == "int8" else dtype
    scale = (jnp.zeros((spec.num_pages, spec.page_size), jnp.float32)
             if kv_quant == "int8" else None)
    return PagedKVCache(
        k=jnp.zeros((spec.num_pages, spec.page_size, n_kv, head_dim),
                    pool_dtype),
        v=jnp.zeros((spec.num_pages, spec.page_size, n_kv, head_dim),
                    pool_dtype),
        tables=jnp.zeros((num_slots, spec.max_blocks), jnp.int32),
        k_scale=scale, v_scale=scale,
    )


def attention_init(rng, cfg, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rq, rk, rv, ro, rn = common.split_rngs(rng, 5)
    p = {
        "wq": common.linear_init(rq, d, hq * hd, bias=cfg.qkv_bias),
        "wk": common.linear_init(rk, d, hkv * hd, bias=cfg.qkv_bias),
        "wv": common.linear_init(rv, d, hkv * hd, bias=cfg.qkv_bias),
        "wo": common.linear_init(ro, hq * hd, d, bias=False),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.rmsnorm_init(hd)
        p["k_norm"] = common.rmsnorm_init(hd)
    return p


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _head_axes(n_heads: int):
    """Sharding specs for (b, s|q, h, hd) and (b, h, q, k) tensors.

    Heads shard on "model" when divisible; otherwise the query/sequence
    dim takes the model axis (context-parallel fallback).
    """
    mesh = current_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    heads_ok = msize <= 1 or n_heads % msize == 0
    if heads_ok:
        return (("pod", "data"), None, "model", None), \
               (("pod", "data"), "model", None, None)
    return (("pod", "data"), "model", None, None), \
           (("pod", "data"), None, "model", None)


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _repeat_kv(x, n_rep: int):
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=2)


def _attend(q, k, v, mask, softcap: Optional[float], *,
            kv_seq_sharded: bool = False):
    """q: (b, sq, h, hd); k/v: (b, sk, h, hd); mask broadcast (b,h,sq,sk).

    Inputs stay bf16 (fp32 *accumulation* via preferred_element_type —
    upcasting the inputs would double every gather/buffer); scores/softmax
    run in fp32.

    ``kv_seq_sharded``: decode against a sequence-sharded KV cache (GQA
    head counts that don't divide the model axis).  Scores stay sharded
    over the KV-sequence dim; XLA turns the softmax/weighted-sum into
    partial reductions + tiny all-reduces — SPMD FlashDecoding split-K —
    instead of all-gathering the whole cache every step.
    """
    h = q.shape[2]
    if kv_seq_sharded:
        qspec = (("pod", "data"), None, None, None)
        sspec = (("pod", "data"), None, None, "model")
    else:
        qspec, sspec = _head_axes(h)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, NEG_INF)
    scores = shard_activation(scores, sspec)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return shard_activation(out.astype(v.dtype), qspec)


def _causal_mask(q_pos, k_pos, window: Optional[int]):
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    m &= (k_pos >= 0)[None, :]
    return m[None, None]  # (1, 1, sq, sk)


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _attention_seq(q, k, v, q_pos, k_pos, window, softcap):
    """Chunked causal attention, linear activation memory in sq."""
    b, sq, h, hd = q.shape
    # Engine routing: under the pallas backend the plain-causal full-seq
    # case lowers to the flash-attention kernel family (descriptor-planned
    # block sizes, engine-cached build; fused plans walk the causal-aware
    # tile table in one launch — DESIGN.md §10).  The routed call is
    # differentiable: training pulls gradients through the family's
    # custom VJP, whose backward is ONE scheduled dQ/dK/dV walk over the
    # same causal-pruned tile table (DESIGN.md §11) — not XLA autodiff of
    # the kernel.  Windowing, softcap and shifted q/k stay on the XLA
    # formulation; positions are assumed contiguous ascending here (true
    # for the train/prefill callers).
    if (get_config().backend == "pallas" and window is None
            and not softcap and sq == k.shape[1]):
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=True)
    if sq <= Q_CHUNK:
        return _attend(q, k, v, _causal_mask(q_pos, k_pos, window), softcap)

    assert sq % Q_CHUNK == 0, f"seq {sq} not divisible by q-chunk {Q_CHUNK}"
    nc = sq // Q_CHUNK
    qs = q.reshape(b, nc, Q_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(nc, Q_CHUNK)

    if window is not None and k.shape[1] > window + Q_CHUNK:
        # Sliding window: each q chunk touches a static-size KV slice.
        pad = ((0, 0), (window, 0), (0, 0), (0, 0))
        kp_pad = jnp.pad(k_pos, (window, 0), constant_values=-1)
        k_pad, v_pad = jnp.pad(k, pad), jnp.pad(v, pad)

        def body(_, args):
            qc, qpc, start = args
            ks = jax.lax.dynamic_slice_in_dim(k_pad, start, window + Q_CHUNK, 1)
            vs = jax.lax.dynamic_slice_in_dim(v_pad, start, window + Q_CHUNK, 1)
            kps = jax.lax.dynamic_slice_in_dim(kp_pad, start, window + Q_CHUNK, 0)
            return None, _attend(qc, ks, vs, _causal_mask(qpc, kps, window),
                                 softcap)

        starts = jnp.arange(nc) * Q_CHUNK
        # remat: without it the backward keeps every chunk's fp32 score
        # matrix alive at once — the flash-attention memory argument.
        _, out = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                              None, (qs, qp, starts))
    else:
        def body(_, args):
            qc, qpc = args
            return None, _attend(qc, k, v, _causal_mask(qpc, k_pos, window),
                                 softcap)

        _, out = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                              None, (qs, qp))

    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# Paged decode (continuous batching, DESIGN.md §12)
# ---------------------------------------------------------------------------

def _paged_decode(cfg, cache: PagedKVCache, q, k, v, pos2d, dt, g):
    """One decode step against the paged KV pool.

    q/k/v: (S, 1, h|hkv, hd); ``pos2d``: (S, 1) per-slot positions (the
    slot's current length; -1 = inactive).  The new token's KV scatters
    into page ``tables[i, pos // P]`` at offset ``pos % P`` — inactive
    rows scatter to an out-of-bounds page id, which ``mode="drop"``
    discards, so dead slots never touch the pool (their *output* rows
    are garbage the step-level merge masks).  Attention runs either through the engine's
    ``flash_decode`` family (pallas backend: ONE launch walking the
    runtime :class:`~repro.core.schedule.DecodeTileSchedule`) or the XLA
    gather formulation (``ref_paged_decode_attention``'s math)."""
    S = q.shape[0]
    pages, P = cache.k.shape[0], cache.k.shape[1]
    B = cache.tables.shape[1]
    hkv, hd = cache.k.shape[2], cache.k.shape[3]
    pos = pos2d[:, 0] if pos2d.shape[0] == S else \
        jnp.broadcast_to(pos2d[:, 0], (S,))
    active = pos >= 0
    safe = jnp.clip(pos, 0)
    blk = jnp.take_along_axis(cache.tables, (safe // P)[:, None], axis=1)[:, 0]
    # Inactive rows scatter to page id == pages: out of bounds, which
    # mode="drop" discards (NOT -1 — negative indices wrap in jnp).
    pid = jnp.where(active, blk, pages)
    off = safe % P
    ks_new = vs_new = None
    if cache.k_scale is not None:
        # int8 pools (DESIGN.md §13): symmetric per-token quantization at
        # write time — one f32 scale per (page, offset) row, the row's
        # absmax over heads x features divided by the int8 range.
        def _qrow(row):  # (S, hkv, hd) wide -> int8 values + (S,) scales
            r32 = row.astype(jnp.float32)
            s = jnp.max(jnp.abs(r32), axis=(1, 2)) / 127.0 + 1e-12
            qv = jnp.clip(jnp.round(r32 / s[:, None, None]), -127, 127)
            return qv.astype(jnp.int8), s.astype(jnp.float32)
        kq, ks = _qrow(k[:, 0])
        vq, vs = _qrow(v[:, 0])
        k_new = cache.k.at[pid, off].set(kq, mode="drop")
        v_new = cache.v.at[pid, off].set(vq, mode="drop")
        ks_new = cache.k_scale.at[pid, off].set(ks, mode="drop")
        vs_new = cache.v_scale.at[pid, off].set(vs, mode="drop")
    else:
        k_new = cache.k.at[pid, off].set(k[:, 0].astype(cache.k.dtype),
                                         mode="drop")
        v_new = cache.v.at[pid, off].set(v[:, 0].astype(cache.v.dtype),
                                         mode="drop")
    new_cache = PagedKVCache(k_new, v_new, cache.tables, ks_new, vs_new)
    lengths = jnp.where(active, pos + 1, 0)

    if get_config().backend == "pallas" and not cfg.attn_logit_softcap:
        from repro.kernels.flash_attention import paged_decode_attention
        out = paged_decode_attention(q[:, 0], k_new, v_new, cache.tables,
                                     lengths, k_scale=ks_new,
                                     v_scale=vs_new)[:, None]
        return new_cache, out
    # XLA fallback: gather the block-table pages into a contiguous view
    # (gathered column j holds absolute position j) and mask j >= length
    # — identical math to ref_paged_decode_attention, expressed through
    # the shared _attend so float ops match the dense decode path.
    gidx = jnp.clip(cache.tables, 0, pages - 1)
    gk = k_new[gidx]  # (S, B, P, hkv, hd)
    gv = v_new[gidx]
    if ks_new is not None:
        # dequant in f32 before entering the shared attention math
        gk = gk.astype(jnp.float32) * ks_new[gidx][..., None, None]
        gv = gv.astype(jnp.float32) * vs_new[gidx][..., None, None]
    gk = _repeat_kv(gk.reshape(S, B * P, hkv, hd).astype(dt), g)
    gv = _repeat_kv(gv.reshape(S, B * P, hkv, hd).astype(dt), g)
    live = jnp.arange(B * P)[None, :] < lengths[:, None]  # (S, B*P)
    out = _attend(q, gk, gv, live[:, None, None, :], cfg.attn_logit_softcap)
    return new_cache, out


# ---------------------------------------------------------------------------
# Public apply
# ---------------------------------------------------------------------------

def attention_apply(params, cfg, x, positions, *, cache: Optional[KVCache] = None,
                    window: Optional[int] = None, kv_override=None):
    """Self-attention (or cross-attention when ``kv_override`` is given).

    positions: (s,) absolute positions of the ``s`` tokens in ``x``, or
    (b, s) *per-row* positions (the continuous-batching decode step: each
    slot sits at its own length; -1 marks an inactive slot whose row is
    garbage the step-level merge discards — DESIGN.md §12).
    Returns (y, new_cache).  With a cache and s==1 this is one decode step.
    """
    dt = jnp.dtype(cfg.dtype)
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = hq // hkv
    qspec, _ = _head_axes(hq)
    pos2d = positions if positions.ndim == 2 else positions[None, :]

    q = _split_heads(common.linear(params["wq"], x, compute_dtype=dt), hq, hd)
    kv_src = x if kv_override is None else kv_override
    k = _split_heads(common.linear(params["wk"], kv_src, compute_dtype=dt), hkv, hd)
    v = _split_heads(common.linear(params["wv"], kv_src, compute_dtype=dt), hkv, hd)

    if cfg.qk_norm:
        q = common.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = common.rmsnorm(params["k_norm"], k, cfg.norm_eps)

    if cfg.rope:
        q = apply_rope(q, pos2d, cfg.rope_theta)
        if kv_override is None:
            k = apply_rope(k, pos2d, cfg.rope_theta)

    q = shard_activation(q, qspec)

    new_cache = None
    if isinstance(cache, PagedKVCache) and kv_override is None:
        new_cache, out = _paged_decode(cfg, cache, q, k, v, pos2d, dt, g)
    elif cache is not None and kv_override is None:
        # Ring-buffer write: slot = pos % capacity (windowed caches stay
        # O(window) even at 500k-token contexts).
        cap = cache.k.shape[1]
        slots = pos2d % cap  # (1|b, s); broadcasts against bidx
        bidx = jnp.arange(b)[:, None]
        k_new = cache.k.at[bidx, slots].set(k.astype(cache.k.dtype))
        v_new = cache.v.at[bidx, slots].set(v.astype(cache.v.dtype))
        pos_new = cache.pos.at[bidx, slots].set(
            jnp.broadcast_to(pos2d, (b, s)))
        new_cache = KVCache(k_new, v_new, pos_new)
        if s == 1:
            # Decode: attend over the cache with per-slot positions.
            mesh = current_mesh()
            msize = mesh.shape.get("model", 1) if mesh is not None else 1
            seq_sharded = msize > 1 and hkv % msize != 0 \
                and cache.k.shape[1] % msize == 0
            kf = _repeat_kv(new_cache.k.astype(dt), g)
            vf = _repeat_kv(new_cache.v.astype(dt), g)
            if seq_sharded:
                kv_spec = (("pod", "data"), "model", None, None)
                kf = shard_activation(kf, kv_spec)
                vf = shard_activation(vf, kv_spec)
            qpos = pos2d[:, -1].reshape(-1, 1, 1, 1)  # (1|b, 1, 1, 1)
            mask = (new_cache.pos[:, None, None, :] <= qpos)
            if window is not None:
                mask &= new_cache.pos[:, None, None, :] > qpos - window
            mask &= new_cache.pos[:, None, None, :] >= 0
            out = _attend(q, kf, vf, mask, cfg.attn_logit_softcap,
                          kv_seq_sharded=seq_sharded)
        else:
            out = _attention_seq(q, _repeat_kv(k, g), _repeat_kv(v, g),
                                 positions, positions, window,
                                 cfg.attn_logit_softcap)
    elif kv_override is not None:
        # Cross-attention: all encoder positions visible.  Under the
        # pallas backend this is the non-causal flash case — the schedule
        # layer's ragged sq/sk handling (DESIGN.md §10) covers decoder
        # and encoder lengths that disagree, so no mask tensor is built.
        if get_config().backend == "pallas" and not cfg.attn_logit_softcap:
            from repro.kernels.flash_attention import flash_attention
            out = flash_attention(q, _repeat_kv(k, g), _repeat_kv(v, g),
                                  causal=False)
        else:
            sk = k.shape[1]
            mask = jnp.ones((1, 1, s, sk), bool)
            out = _attend(q, _repeat_kv(k, g), _repeat_kv(v, g), mask,
                          cfg.attn_logit_softcap)
    else:
        out = _attention_seq(q, _repeat_kv(k, g), _repeat_kv(v, g),
                             positions, positions, window,
                             cfg.attn_logit_softcap)

    out = out.reshape(b, s, hq * hd)
    y = common.linear(params["wo"], out, compute_dtype=dt)
    return y, new_cache
