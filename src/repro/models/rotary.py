"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., s, h, hd); positions: broadcastable to (..., s).

    Angles in fp32; the rotation multiplies stay in ``x.dtype`` so no
    activation-sized fp32 buffers materialize (sin/cos precision is what
    matters; the product rounds to bf16 anyway).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., s, hd/2)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
