"""Dense MLP blocks: gated (SwiGLU/GeGLU) and classic 2-layer.

The gated path issues two column-parallel GEMMs with a fused activation
epilogue — on the Pallas backend the activation runs inside the kernel's
store phase (§IV epilogue fusion); on the XLA backend it fuses identically.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import common


def mlp_init(rng, cfg):
    d, f = cfg.d_model, cfg.d_ff
    r1, r2, r3 = common.split_rngs(rng, 3)
    p = {"w_down": common.linear_init(r2, f, d, bias=cfg.mlp_bias)}
    if cfg.mlp_gated:
        p["w_gate"] = common.linear_init(r1, d, f, bias=cfg.mlp_bias)
        p["w_up"] = common.linear_init(r3, d, f, bias=cfg.mlp_bias)
    else:
        p["w_up"] = common.linear_init(r1, d, f, bias=cfg.mlp_bias)
    return p


def mlp_apply(params, cfg, x):
    dt = jnp.dtype(cfg.dtype)
    act = cfg.mlp_act  # "silu" | "gelu" | "relu"
    if cfg.mlp_gated:
        gate = common.linear(params["w_gate"], x, epilogue=act, compute_dtype=dt)
        up = common.linear(params["w_up"], x, compute_dtype=dt)
        h = gate * up
    else:
        h = common.linear(params["w_up"], x, epilogue=act, compute_dtype=dt)
    return common.linear(params["w_down"], h, compute_dtype=dt)
