"""Shared layer primitives: norms, embeddings, initializers, dtype policy."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import matmul


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(rng, shape, scale=0.02, dtype=jnp.float32):
    return scale * jax.random.normal(rng, shape, dtype)


def scaled_init(rng, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) / jnp.sqrt(jnp.asarray(fan_in, dtype))


def split_rngs(rng, n):
    return list(jax.random.split(rng, n))


# ---------------------------------------------------------------------------
# Dtype policy: params stored fp32 (optimizer-friendly), compute in
# cfg.dtype (bf16 default).  The cast happens at point of use so FSDP
# all-gathers move bf16 bytes, not fp32 (see DESIGN.md §5).
# ---------------------------------------------------------------------------

def cast_param(p, dtype):
    from repro.optim.compression import QuantizedTensor
    if isinstance(p, QuantizedTensor):
        # W8A16 weights (DESIGN.md §13): the wire format and its f32
        # scales are the storage policy — never cast through here (the
        # kernel dequantizes in its epilogue at the logical dtype).
        return p
    if p.dtype == jnp.dtype(dtype) or not jnp.issubdtype(p.dtype, jnp.floating):
        return p
    return p.astype(dtype)


def tree_cast(params, dtype):
    from repro.optim.compression import QuantizedTensor
    return jax.tree.map(lambda p: cast_param(p, dtype), params,
                        is_leaf=lambda p: isinstance(p, QuantizedTensor))


# ---------------------------------------------------------------------------
# Linear (routes through the paper's engine)
# ---------------------------------------------------------------------------

def linear_init(rng, d_in, d_out, bias=False, dtype=jnp.float32):
    p = {"w": scaled_init(rng, (d_in, d_out), d_in, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params, x, *, epilogue: Optional[str] = None, compute_dtype=None):
    """y = x @ W (+ b) with optional fused activation epilogue."""
    w = params["w"]
    if compute_dtype is not None:
        w = cast_param(w, compute_dtype)
        x = x.astype(compute_dtype)
    b = params.get("b")
    if b is not None and compute_dtype is not None:
        b = cast_param(b, compute_dtype)
    if b is not None:
        epi = {"gelu": "bias_gelu", "silu": "bias_silu", None: "bias"}.get(epilogue, epilogue)
        return matmul(x, w, epilogue=epi, bias=b)
    return matmul(x, w, epilogue=epilogue)


# ---------------------------------------------------------------------------
# Norms (fp32 compute regardless of activation dtype)
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def norm_init(kind: str, d, dtype=jnp.float32):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, params, x, eps):
    return rmsnorm(params, x, eps) if kind == "rmsnorm" else layernorm(params, x, eps)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embedding_init(rng, vocab, d, dtype=jnp.float32):
    return {"table": normal_init(rng, (vocab, d), 0.02, dtype)}


def embed(params, ids, compute_dtype):
    return cast_param(params["table"], compute_dtype)[ids]


def unembed(params, x, compute_dtype, out_dtype=jnp.float32):
    """Tied read-out: logits = x @ tableᵀ (an NT-layout GEMM, §IV-C)."""
    table = cast_param(params["table"], compute_dtype)
    return matmul(x, table, layout="nt", out_dtype=out_dtype)
