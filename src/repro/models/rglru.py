"""Real-Gated Linear Recurrent Unit (RG-LRU) block from Griffin
(arXiv:2402.19427), used by recurrentgemma.

Block structure (one "recurrent block"):

    x ─ linear_y ─ gelu ──────────────────┐
    x ─ linear_x ─ conv1d(4) ─ RG-LRU ─ ⊙ ┴─ linear_out

RG-LRU recurrence (per channel):
    r_t = σ(W_a x_t + b_a)          recurrence gate
    i_t = σ(W_x x_t + b_x)          input gate
    a_t = a^(c·r_t),  a = σ(Λ)      (c = 8)
    h_t = a_t · h_{t-1} + √(1 − a_t²) · (i_t ⊙ x_t)

Training/prefill uses ``lax.associative_scan`` (log-depth); decode is a
single fused step on the carried state.  The state is O(width) — this is
what makes recurrentgemma a legal ``long_500k`` architecture.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.runtime.shardlib import shard_activation

_C = 8.0
_MIN_LOG = -8.0


class RecurrentState(NamedTuple):
    h: jax.Array  # (b, width) fp32 recurrent state
    conv: jax.Array  # (b, conv_width - 1, width) conv tail


def rglru_init(rng, cfg):
    d, w = cfg.d_model, cfg.rglru_width
    ry, rx, ro, ra, rg, rc = common.split_rngs(rng, 6)
    # Λ init so that a = σ(Λ)^c is in ~[0.9, 0.999] (Griffin appendix).
    u = jax.random.uniform(ra, (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(u ** (1.0 / _C) / (1 - u ** (1.0 / _C)))
    return {
        "lin_y": common.linear_init(ry, d, w, bias=True),
        "lin_x": common.linear_init(rx, d, w, bias=True),
        "lin_out": common.linear_init(ro, w, d, bias=True),
        "conv_w": common.normal_init(rc, (cfg.conv1d_width, w), 0.02),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "gate_a": common.linear_init(ra, w, w, bias=True),
        "gate_x": common.linear_init(rg, w, w, bias=True),
        "lambda": lam,
    }


def _causal_conv1d(x, w, b, tail: Optional[jax.Array]):
    """Depthwise causal conv. x: (b, s, w); w: (cw, w); tail: (b, cw-1, w)."""
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(cw))
    new_tail = xp[:, -(cw - 1):] if cw > 1 else tail
    return out + b.astype(x.dtype), new_tail


def _rglru_scan(xs, a_log_t, h0):
    """Associative scan of h_t = a_t h_{t-1} + b_t over time axis 1.

    xs/b: (b, s, w) fp32; a_log_t: log(a_t) (for numerics); h0: (b, w).
    """
    a_t = jnp.exp(a_log_t)
    b_t = xs
    if h0 is not None:
        b_t = b_t.at[:, 0].add(a_t[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
    return h


def rglru_apply(params, cfg, x, *, state: Optional[RecurrentState] = None):
    """x: (b, s, d) -> (y, new_state)."""
    dt = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    y_branch = common.linear(params["lin_y"], x, epilogue="gelu", compute_dtype=dt)
    xb = common.linear(params["lin_x"], x, compute_dtype=dt)
    # Width-parallel region: the recurrence is elementwise over the LRU
    # width, so post-gate activations shard on "model" along w (and the
    # time scan stays shard-local — no cross-device permute chains).  xb
    # itself stays width-full: the gate projections contract over w.
    wspec = (("pod", "data"), None, "model")
    y_branch = shard_activation(y_branch, wspec)

    tail = state.conv if state is not None else None
    xb, new_tail = _causal_conv1d(xb, params["conv_w"], params["conv_b"], tail)

    # Gate projections contract the full width (like attention qkv), so
    # their INPUT stays bf16 (an fp32 xb here forces fp32 full-width
    # gathers: +0.5 GiB x hundreds of buffers on recurrentgemma-9b); only
    # the width-sharded gate outputs are upcast for the recurrence math.
    r = jax.nn.sigmoid(common.linear(params["gate_a"], xb,
                                     compute_dtype=dt).astype(jnp.float32))
    i = jax.nn.sigmoid(common.linear(params["gate_x"], xb,
                                     compute_dtype=dt).astype(jnp.float32))
    r = shard_activation(r, wspec)
    i = shard_activation(i, wspec)
    log_a1 = -jax.nn.softplus(-params["lambda"])  # log σ(Λ)
    log_at = jnp.maximum(_C * r * log_a1[None, None, :], _MIN_LOG)
    gated = i * xb.astype(jnp.float32)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12))
    bt = shard_activation(mult * gated, wspec)

    h0 = state.h if state is not None else None
    if s == 1 and h0 is not None:
        h = (jnp.exp(log_at[:, 0]) * h0 + bt[:, 0])[:, None]
    else:
        h = _rglru_scan(bt, log_at, h0)

    new_state = RecurrentState(h=h[:, -1].astype(jnp.float32), conv=new_tail)
    out = (h.astype(dt) * y_branch)
    return common.linear(params["lin_out"], out, compute_dtype=dt), new_state


def init_recurrent_state(batch, cfg) -> RecurrentState:
    return RecurrentState(
        h=jnp.zeros((batch, cfg.rglru_width), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, cfg.rglru_width), jnp.bfloat16),
    )
