"""Encoder–decoder model (seamless-m4t backbone stub).

Encoder: bidirectional attention over precomputed audio-frame embeddings
(the modality frontend is a stub per the assignment).  Decoder: causal
self-attention + cross-attention into the encoder output, sharing the
block machinery of the decoder-only stack.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.attention import attention_apply, attention_init
from repro.models.blocks import stack_apply, stack_cache, stack_init
from repro.models.frontends import frontend_apply, frontend_init
from repro.models.mlp import mlp_apply, mlp_init
from repro.runtime.shardlib import shard_activation


def _encoder_block_init(rng, cfg):
    r1, r2 = common.split_rngs(rng, 2)
    return {
        "norm_attn": common.norm_init(cfg.norm_type, cfg.d_model),
        "attn": attention_init(r1, cfg),
        "norm_ff": common.norm_init(cfg.norm_type, cfg.d_model),
        "ff": mlp_init(r2, cfg),
    }


def _encoder_block_apply(params, cfg, x, positions):
    h = common.norm_apply(cfg.norm_type, params["norm_attn"], x, cfg.norm_eps)
    # bidirectional: kv_override = the sequence itself (no causal mask)
    y, _ = attention_apply(params["attn"], cfg, h, positions, kv_override=h)
    x = x + y
    h = common.norm_apply(cfg.norm_type, params["norm_ff"], x, cfg.norm_eps)
    x = x + mlp_apply(params["ff"], cfg, h)
    return shard_activation(x, (("pod", "data"), "model", None))


class EncoderDecoderModel:
    @staticmethod
    def init(rng, cfg):
        r_f, r_enc, r_dec, r_emb, r_norm_e, r_head = common.split_rngs(rng, 6)
        enc_rngs = common.split_rngs(r_enc, cfg.num_encoder_layers)

        def one(r):
            return _encoder_block_init(r, cfg)

        enc_stacked = jax.vmap(one)(jnp.stack(enc_rngs))
        return {
            "frontend": frontend_init(r_f, cfg),
            "encoder": enc_stacked,
            "enc_norm": common.norm_init(cfg.norm_type, cfg.d_model),
            "embed": common.embedding_init(r_emb, cfg.vocab_size, cfg.d_model),
            "decoder": stack_init(r_dec, cfg, cross=True),
            "final_norm": common.norm_init(cfg.norm_type, cfg.d_model),
            "lm_head": common.linear_init(r_head, cfg.d_model, cfg.vocab_size),
        }

    @staticmethod
    def encode(params, cfg, feats):
        """feats: (b, s_enc, modality_dim) -> (b, s_enc, d)."""
        x = frontend_apply(params["frontend"], cfg, feats)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = shard_activation(x, (("pod", "data"), "model", None))

        def body(h, blk_params):
            return _encoder_block_apply(blk_params, cfg, h, positions), None

        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                                  prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return common.norm_apply(cfg.norm_type, params["enc_norm"], x, cfg.norm_eps)

    @staticmethod
    def apply(params, cfg, tokens, feats=None, *, enc_out=None, positions=None,
              cache=None, logits_mode="all"):
        """Teacher-forced decode over ``tokens`` given encoder input."""
        dt = jnp.dtype(cfg.dtype)
        if enc_out is None:
            enc_out = EncoderDecoderModel.encode(params, cfg, feats)
        b, s = tokens.shape
        x = common.embed(params["embed"], tokens, dt)
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)
        x = shard_activation(x, (("pod", "data"), "model", None))
        x, new_cache, aux = stack_apply(params["decoder"], cfg, x, positions,
                                        cache=cache, enc_out=enc_out)
        x = common.norm_apply(cfg.norm_type, params["final_norm"], x, cfg.norm_eps)
        if logits_mode == "last":
            x = x[:, -1:]
        w = common.cast_param(params["lm_head"]["w"], dt)
        from repro.core import matmul
        logits = matmul(x, w, out_dtype=jnp.dtype(cfg.logits_dtype))
        logits = shard_activation(logits, (("pod", "data"), "model", None))
        return logits, new_cache, aux

    @staticmethod
    def init_cache(cfg, batch, capacity):
        return stack_cache(batch, cfg, capacity)
