"""GEMM descriptors — the LIBXSMM ``libxsmm_gemm_descriptor`` analogue.

The paper's JIT code generator "hardwires matrix sizes, datatypes, and
leading dimensions when generating a matrix kernel" (§IV).  A
``GemmDescriptor`` carries exactly that metadata; it is the hashable key of
the JIT cache (``repro.core.jit_cache``) and the input of the blocking
planner (``repro.core.blocking``).

Layout semantics.  JAX arrays are logically row-major.  We express the
paper's two studied layouts as contraction forms:

  * ``"nn"`` — ``C[M,N] += A[M,K] @ B[K,N]``: the contraction dim of B is
    its *major* dim.  This corresponds to the paper's row-major-B case
    (§IV-A): B's N-slice for one k is contiguous, outer-product friendly.
  * ``"nt"`` — ``C[M,N] += A[M,K] @ B[N,K]^T``: B stores N major / K minor.
    This is the paper's "transposing B" case (§IV-C): the contraction dim
    is strided, so the kernel must either transpose panels through scratch
    (the ZA horizontal/vertical trick) or fuse a block transpose.

(The paper's column-major `A/C`, row-major `B` maps onto "nn" under a
global transpose of the problem; what matters — and what we preserve — is
whether B's contraction dim is contiguous.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .machine import canonical_dtype

LAYOUTS = ("nn", "nt")
EPILOGUES = (None, "bias", "gelu", "silu", "relu", "bias_gelu", "bias_silu")


@dataclasses.dataclass(frozen=True)
class GemmDescriptor:
    """Hashable metadata fully specifying one generated GEMM kernel."""

    m: int
    n: int
    k: int
    layout: str = "nn"  # "nn": B is (K,N); "nt": B is (N,K)
    in_dtype: str = "float32"
    acc_dtype: str = "float32"
    out_dtype: str = "float32"
    accumulate: bool = False  # True => C += A@B (beta=1), else C = A@B
    epilogue: Optional[str] = None
    # Edge-handling strategy: "mask" (predication analogue) or "pad"
    # (copy-based).  §IV-B uses predicates; we support both to benchmark.
    edge: str = "mask"
    # batch dims (leading, shared by A/B/C); 0 => unbatched 2-D GEMM
    batch: int = 0

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, got {self.layout}")
        if self.epilogue not in EPILOGUES:
            raise ValueError(f"epilogue must be one of {EPILOGUES}")
        if self.edge not in ("mask", "pad"):
            raise ValueError("edge must be 'mask' or 'pad'")
        for d in (self.m, self.n, self.k):
            if d <= 0:
                raise ValueError(f"GEMM dims must be positive, got {self}")

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_operands(cls, a, b, layout="nn", accumulate=False, epilogue=None,
                      acc_dtype="float32", out_dtype=None, edge="mask"):
        if a.ndim != b.ndim:
            raise ValueError(f"rank mismatch: A{a.shape} vs B{b.shape}")
        batch = 0
        if a.ndim == 3:
            if a.shape[0] != b.shape[0]:
                raise ValueError(f"batch mismatch: A{a.shape} vs B{b.shape}")
            batch = a.shape[0]
        elif a.ndim != 2:
            raise ValueError(f"GEMM operands must be rank 2 or 3, got {a.ndim}")
        m, k = a.shape[-2], a.shape[-1]
        if layout == "nn":
            kb, n = b.shape[-2], b.shape[-1]
        else:
            n, kb = b.shape[-2], b.shape[-1]
        if kb != k:
            raise ValueError(f"contraction mismatch: A{a.shape} {layout} B{b.shape}")
        in_dtype = canonical_dtype(a.dtype)
        if canonical_dtype(b.dtype) != in_dtype:
            raise ValueError(f"A/B dtype mismatch: {a.dtype} vs {b.dtype}")
        return cls(
            m=m, n=n, k=k, layout=layout, in_dtype=in_dtype,
            acc_dtype=canonical_dtype(acc_dtype),
            out_dtype=canonical_dtype(out_dtype or acc_dtype),
            accumulate=accumulate, epilogue=epilogue, edge=edge, batch=batch,
        )

    # -- properties ----------------------------------------------------------
    @property
    def flops(self) -> int:
        nb = max(1, self.batch)
        return 2 * nb * self.m * self.n * self.k

    @property
    def in_bytes(self) -> int:
        nb = max(1, self.batch)
        isz = jnp.dtype(self.in_dtype).itemsize
        return nb * (self.m * self.k + self.k * self.n) * isz

    @property
    def out_bytes(self) -> int:
        nb = max(1, self.batch)
        return nb * self.m * self.n * jnp.dtype(self.out_dtype).itemsize

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1, self.in_bytes + self.out_bytes)

    def b_shape(self) -> tuple:
        core = (self.k, self.n) if self.layout == "nn" else (self.n, self.k)
        return (self.batch, *core) if self.batch else core

    def a_shape(self) -> tuple:
        core = (self.m, self.k)
        return (self.batch, *core) if self.batch else core

    def c_shape(self) -> tuple:
        core = (self.m, self.n)
        return (self.batch, *core) if self.batch else core
