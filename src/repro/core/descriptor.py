"""Kernel descriptors — the LIBXSMM ``libxsmm_gemm_descriptor`` analogue.

The paper's JIT code generator "hardwires matrix sizes, datatypes, and
leading dimensions when generating a matrix kernel" (§IV).  A descriptor
carries exactly that metadata; it is the hashable key of both engine
caches (plan + kernel, see ``repro.core.engine``) and the input of the
blocking planners (``repro.core.blocking``).

Every kernel family the engine dispatches — dense GEMM, flash attention,
ragged grouped GEMM, the SSD intra-chunk ladder, and tile transpose — has
one frozen-dataclass descriptor here, all deriving from
:class:`KernelDescriptor`.  Each carries flops/bytes accounting so
``launch/roofline.py`` and ``launch/hlo_cost.py`` can cost any kernel in
the system, not just GEMMs (DESIGN.md §2).

Layout semantics.  JAX arrays are logically row-major.  We express the
paper's two studied layouts as contraction forms:

  * ``"nn"`` — ``C[M,N] += A[M,K] @ B[K,N]``: the contraction dim of B is
    its *major* dim.  This corresponds to the paper's row-major-B case
    (§IV-A): B's N-slice for one k is contiguous, outer-product friendly.
  * ``"nt"`` — ``C[M,N] += A[M,K] @ B[N,K]^T``: B stores N major / K minor.
    This is the paper's "transposing B" case (§IV-C): the contraction dim
    is strided, so the kernel must either transpose panels through scratch
    (the ZA horizontal/vertical trick) or fuse a block transpose.

(The paper's column-major `A/C`, row-major `B` maps onto "nn" under a
global transpose of the problem; what matters — and what we preserve — is
whether B's contraction dim is contiguous.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .machine import canonical_dtype

LAYOUTS = ("nn", "nt")
EPILOGUES = (None, "bias", "gelu", "silu", "relu", "bias_gelu", "bias_silu")
BIAS_EPILOGUES = tuple(e for e in EPILOGUES if e and e.startswith("bias"))

QUANT_DTYPES = ("int8", "float8_e4m3")
QUANT_SCHEMES = ("per_tensor", "per_channel", "per_tile")

# String shorthands accepted anywhere a quant spec is (config knob,
# REPRO_QUANT, gemm(quant=...)).
_QUANT_ALIASES = {
    "int8": ("int8", False),
    "w8a16": ("int8", True),
    "fp8": ("float8_e4m3", False),
    "float8_e4m3": ("float8_e4m3", False),
}


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Low-precision execution spec carried by GEMM-family descriptors
    (DESIGN.md §13).

    ``dtype`` is the *wire* dtype the quantized operand(s) are stored and
    staged in; accumulation always happens wide (int32 for int8 inputs,
    f32 otherwise) and dequantization fuses into the shared epilogue.
    ``scheme`` fixes how scales partition operand channels — per-tensor
    (scalar), per-channel (one scale per A row / B output column), or
    per-tile (one scale per ``QUANT_TILE``-sized channel block; see
    ``repro.core.schedule.QUANT_TILE``).  All three are row/col-separable,
    which is what lets the dequant commute through the contraction and
    live in the epilogue.  ``weight_only`` quantizes only the B operand
    (W8A16): A stays in ``in_dtype``, B is dequantized in-kernel before
    the MXU dot, and the column scales still apply in the epilogue.
    """

    dtype: str = "int8"
    scheme: str = "per_channel"
    weight_only: bool = False

    def __post_init__(self):
        if self.dtype not in QUANT_DTYPES:
            raise ValueError(
                f"quant dtype must be one of {QUANT_DTYPES}, got {self.dtype}")
        if self.scheme not in QUANT_SCHEMES:
            raise ValueError(
                f"quant scheme must be one of {QUANT_SCHEMES}, "
                f"got {self.scheme}")
        if self.dtype == "float8_e4m3" and not hasattr(jnp, "float8_e4m3fn"):
            raise ValueError(
                "float8_e4m3 quantization needs a jax build with "
                "jnp.float8_e4m3fn (gate callers on "
                "repro.core.machine.HAS_FP8)")

    @property
    def wire_itemsize(self) -> int:
        """Bytes per element of the quantized wire format (1 for both
        int8 and fp8)."""
        return 1


def resolve_quant(quant) -> Optional[QuantSpec]:
    """Normalize a quant argument: None/False → None, a string shorthand
    (``"int8"``/``"w8a16"``/``"fp8"``) → the matching :class:`QuantSpec`,
    a spec → itself."""
    if quant is None or quant is False:
        return None
    if isinstance(quant, QuantSpec):
        return quant
    if isinstance(quant, str):
        if quant not in _QUANT_ALIASES:
            raise ValueError(
                f"unknown quant shorthand {quant!r}; expected one of "
                f"{sorted(_QUANT_ALIASES)} or a QuantSpec")
        dtype, weight_only = _QUANT_ALIASES[quant]
        return QuantSpec(dtype=dtype, weight_only=weight_only)
    raise ValueError(f"quant must be None, a str or a QuantSpec, got "
                     f"{type(quant).__name__}")


def check_bias(epilogue, bias) -> None:
    """Shared precondition: a bias-consuming epilogue needs a bias operand."""
    if epilogue in BIAS_EPILOGUES and bias is None:
        raise ValueError(
            f"epilogue {epilogue!r} requires a bias operand, got bias=None")


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Mesh placement carried by GEMM-family descriptors (DESIGN.md §14).

    ``axis`` names the mesh axis the weight operand is sharded over
    (expert dim for grouped GEMM, output-column dim for dense GEMM) and
    ``size`` is that axis's extent.  ``None`` mesh on a descriptor means
    the single-chip problem the planner always handled; a ``MeshSpec``
    makes the *global* problem the descriptor's subject, and the planner
    charges communication (all-gather vs. all_to_all) to pick between a
    *gathered* and a *distributed* execution.  Frozen + hashable, so it
    participates in every cache key via ``KernelDescriptor.cache_key``.
    """

    axis: str = "model"
    size: int = 1

    def __post_init__(self):
        if not self.axis:
            raise ValueError("mesh axis name must be non-empty")
        if self.size < 1:
            raise ValueError(f"mesh size must be >= 1, got {self.size}")


@dataclasses.dataclass(frozen=True)
class KernelDescriptor:
    """Base of every per-family descriptor.

    Subclasses are frozen dataclasses — hashable and equality-comparable by
    value — and set ``family`` to the engine registry name.  The engine
    derives both cache keys (plan and kernel) from :meth:`cache_key`, so no
    family hand-writes a key tuple.
    """

    family = "abstract"

    def cache_key(self) -> tuple:
        return (self.family,) + dataclasses.astuple(self)

    # flops/bytes accounting — subclasses override; base gives the shared
    # derived metric.
    @property
    def flops(self) -> int:
        raise NotImplementedError

    @property
    def in_bytes(self) -> int:
        raise NotImplementedError

    @property
    def out_bytes(self) -> int:
        raise NotImplementedError

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1, self.in_bytes + self.out_bytes)


@dataclasses.dataclass(frozen=True)
class GemmDescriptor(KernelDescriptor):
    """Hashable metadata fully specifying one generated GEMM kernel."""

    family = "gemm"

    m: int
    n: int
    k: int
    layout: str = "nn"  # "nn": B is (K,N); "nt": B is (N,K)
    in_dtype: str = "float32"
    acc_dtype: str = "float32"
    out_dtype: str = "float32"
    accumulate: bool = False  # True => C += A@B (beta=1), else C = A@B
    epilogue: Optional[str] = None
    # Edge-handling strategy: "mask" (predication analogue) or "pad"
    # (copy-based).  §IV-B uses predicates; we support both to benchmark.
    edge: str = "mask"
    # batch dims (leading, shared by A/B/C); 0 => unbatched 2-D GEMM
    batch: int = 0
    # Low-precision execution axis (DESIGN.md §13); None = wide GEMM.
    quant: Optional[QuantSpec] = None
    # Mesh placement (DESIGN.md §14): B's output-column (n) dim sharded
    # over mesh.axis; None = the single-chip problem.
    mesh: Optional[MeshSpec] = None

    def __post_init__(self):
        if self.mesh is not None:
            if not isinstance(self.mesh, MeshSpec):
                raise ValueError(f"mesh must be a MeshSpec, got {self.mesh!r}")
            if self.n % self.mesh.size:
                raise ValueError(f"mesh size {self.mesh.size} must divide "
                                 f"n={self.n}")
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, got {self.layout}")
        if self.epilogue not in EPILOGUES:
            raise ValueError(f"epilogue must be one of {EPILOGUES}")
        if self.edge not in ("mask", "pad"):
            raise ValueError("edge must be 'mask' or 'pad'")
        for d in (self.m, self.n, self.k):
            if d <= 0:
                raise ValueError(f"GEMM dims must be positive, got {self}")
        if self.quant is not None:
            if not isinstance(self.quant, QuantSpec):
                raise ValueError(f"quant must be a QuantSpec, got {self.quant!r}")
            if self.accumulate:
                raise ValueError("quantized GEMM does not support accumulate "
                                 "(C += A@B); dequant owns the epilogue")
            if self.batch:
                raise ValueError("quantized GEMM is unbatched (scale vectors "
                                 "are per-row/per-column of one problem)")
            if self.edge != "mask":
                raise ValueError("quantized GEMM requires edge='mask'")

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_operands(cls, a, b, layout="nn", accumulate=False, epilogue=None,
                      acc_dtype="float32", out_dtype=None, edge="mask",
                      quant=None):
        if a.ndim != b.ndim:
            raise ValueError(f"rank mismatch: A{a.shape} vs B{b.shape}")
        batch = 0
        if a.ndim == 3:
            if a.shape[0] != b.shape[0]:
                raise ValueError(f"batch mismatch: A{a.shape} vs B{b.shape}")
            batch = a.shape[0]
        elif a.ndim != 2:
            raise ValueError(f"GEMM operands must be rank 2 or 3, got {a.ndim}")
        m, k = a.shape[-2], a.shape[-1]
        if layout == "nn":
            kb, n = b.shape[-2], b.shape[-1]
        else:
            n, kb = b.shape[-2], b.shape[-1]
        if kb != k:
            raise ValueError(f"contraction mismatch: A{a.shape} {layout} B{b.shape}")
        quant = resolve_quant(quant)
        in_dtype = canonical_dtype(a.dtype)
        if quant is not None and quant.weight_only:
            # W8A16: B arrives in (or will be quantized to) the wire
            # dtype while A stays wide — the equality check is the wide
            # path's invariant, not this one's.
            pass
        elif canonical_dtype(b.dtype) != in_dtype:
            raise ValueError(f"A/B dtype mismatch: {a.dtype} vs {b.dtype}")
        return cls(
            m=m, n=n, k=k, layout=layout, in_dtype=in_dtype,
            acc_dtype=canonical_dtype(acc_dtype),
            out_dtype=canonical_dtype(out_dtype or acc_dtype),
            accumulate=accumulate, epilogue=epilogue, edge=edge, batch=batch,
            quant=quant,
        )

    # -- properties ----------------------------------------------------------
    @property
    def flops(self) -> int:
        nb = max(1, self.batch)
        return 2 * nb * self.m * self.n * self.k

    @property
    def a_wire_itemsize(self) -> int:
        """Bytes per staged A element: the quant wire format for a fully
        quantized GEMM, ``in_dtype`` otherwise (W8A16 keeps A wide)."""
        if self.quant is not None and not self.quant.weight_only:
            return self.quant.wire_itemsize
        return jnp.dtype(self.in_dtype).itemsize

    @property
    def b_wire_itemsize(self) -> int:
        """Bytes per staged B element (any quant spec narrows B)."""
        if self.quant is not None:
            return self.quant.wire_itemsize
        return jnp.dtype(self.in_dtype).itemsize

    @property
    def compute_dtype(self) -> str:
        """The dtype whose machine peak prices the MXU work: the quant
        wire dtype for fully quantized GEMMs (int8 MACs), ``in_dtype``
        for wide and weight-only GEMMs (W8A16 dequantizes B before the
        dot)."""
        if self.quant is not None and not self.quant.weight_only:
            return self.quant.dtype
        return self.in_dtype

    @property
    def in_bytes(self) -> int:
        nb = max(1, self.batch)
        total = nb * (self.m * self.k * self.a_wire_itemsize
                      + self.k * self.n * self.b_wire_itemsize)
        if self.quant is not None:
            # f32 dequant scale vectors staged alongside the operands.
            total += (self.m + self.n) * 4
        return total

    @property
    def out_bytes(self) -> int:
        nb = max(1, self.batch)
        return nb * self.m * self.n * jnp.dtype(self.out_dtype).itemsize

    def b_shape(self) -> tuple:
        core = (self.k, self.n) if self.layout == "nn" else (self.n, self.k)
        return (self.batch, *core) if self.batch else core

    def a_shape(self) -> tuple:
        core = (self.m, self.k)
        return (self.batch, *core) if self.batch else core

    def c_shape(self) -> tuple:
        core = (self.m, self.n)
        return (self.batch, *core) if self.batch else core


# ---------------------------------------------------------------------------
# Non-GEMM families
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlashDescriptor(KernelDescriptor):
    """Flash-attention forward: (BH, sq, d) x (BH, sk, d)^2 -> (BH, sq, d)."""

    family = "flash_attention"

    batch_heads: int
    sq: int
    sk: int
    d: int
    causal: bool = True
    dtype: str = "float32"

    def __post_init__(self):
        for v in (self.batch_heads, self.sq, self.sk, self.d):
            if v <= 0:
                raise ValueError(f"flash dims must be positive, got {self}")

    @classmethod
    def from_operands(cls, q, k, *, causal=True):
        b, sq, h, d = q.shape
        return cls(batch_heads=b * h, sq=sq, sk=k.shape[1], d=d,
                   causal=causal, dtype=canonical_dtype(q.dtype))

    @property
    def flops(self) -> int:
        # QK^T and PV are each 2*sq*sk*d MACs; causal masking halves the
        # useful score area (the kernel skips fully-masked tiles).
        full = 4 * self.batch_heads * self.sq * self.sk * self.d
        return full // 2 if self.causal else full

    @property
    def in_bytes(self) -> int:
        isz = jnp.dtype(self.dtype).itemsize
        return self.batch_heads * (self.sq + 2 * self.sk) * self.d * isz

    @property
    def out_bytes(self) -> int:
        return self.batch_heads * self.sq * self.d * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class FlashDecodeDescriptor(KernelDescriptor):
    """Paged decode attention (continuous batching, DESIGN.md §12):
    one query row per slot against that slot's live KV pages.

    ``(q: (S, h, hd))`` x ``(k/v pool: (pages, page_size, hkv, hd))``
    -> ``(S, h, hd)``, mapped by runtime ``(block_tables, lengths)``
    operands.  Like the grouped-GEMM family, the *ragged part is data*:
    the descriptor carries only the static pool geometry, so the kernel
    is built once per (pool, heads) shape and the churning batch rides
    through as scalar-prefetch tables (no retrace on admission/eviction).
    """

    family = "flash_decode"

    num_seqs: int     # decode slots
    pages: int        # pool size in pages
    page_size: int    # KV slots per page
    max_blocks: int   # block-table width
    num_heads: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "float32"

    def __post_init__(self):
        for v in (self.num_seqs, self.pages, self.page_size,
                  self.max_blocks, self.num_heads, self.num_kv_heads,
                  self.head_dim):
            if v <= 0:
                raise ValueError(f"decode dims must be positive, got {self}")
        if self.num_heads % self.num_kv_heads:
            raise ValueError(f"GQA group must divide heads, got {self}")

    @classmethod
    def from_operands(cls, q, k_pool, block_tables):
        s, h, hd = q.shape
        pages, page_size, hkv, _ = k_pool.shape
        return cls(num_seqs=s, pages=pages, page_size=page_size,
                   max_blocks=block_tables.shape[1], num_heads=h,
                   num_kv_heads=hkv, head_dim=hd,
                   dtype=canonical_dtype(q.dtype))

    @property
    def flops(self) -> int:
        # QK^T and PV over every pool page (the worst case: all pages
        # live); actual walked tiles are bounded by the same number since
        # live pages are exclusively owned.
        return 4 * self.num_heads * self.head_dim * self.pages \
            * self.page_size

    @property
    def in_bytes(self) -> int:
        isz = jnp.dtype(self.dtype).itemsize
        q = self.num_seqs * self.num_heads * self.head_dim * isz
        kv = 2 * self.pages * self.page_size * self.num_kv_heads \
            * self.head_dim * isz
        tables = self.num_seqs * (self.max_blocks + 1) * 4
        return q + kv + tables

    @property
    def out_bytes(self) -> int:
        return self.num_seqs * self.num_heads * self.head_dim \
            * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class GroupedGemmDescriptor(KernelDescriptor):
    """Ragged grouped GEMM (MoE expert compute): (T, K) x (E, K, N) -> (T, N).

    ``t`` is the static row count; the per-group split (``group_sizes``) is
    a runtime operand and deliberately NOT part of the descriptor — the
    kernel is shape-specialized, the routing is data (DESIGN.md §2).
    ``epilogue`` mirrors the GEMM vocabulary; the ``bias`` operand is
    per-expert, shape (E, N).
    """

    family = "grouped_gemm"

    t: int
    k: int
    n: int
    num_experts: int
    dtype: str = "float32"
    epilogue: Optional[str] = None
    # Low-precision execution axis (DESIGN.md §13); None = wide GEMM.
    quant: Optional[QuantSpec] = None
    # Mesh placement (DESIGN.md §14): the expert dim sharded over
    # mesh.axis; ``t``/``num_experts`` describe the GLOBAL problem and
    # the planner derives the per-shard sub-problems it costs.
    mesh: Optional[MeshSpec] = None

    def __post_init__(self):
        for v in (self.t, self.k, self.n, self.num_experts):
            if v <= 0:
                raise ValueError(f"grouped-GEMM dims must be positive, got {self}")
        if self.epilogue not in EPILOGUES:
            raise ValueError(f"epilogue must be one of {EPILOGUES}")
        if self.quant is not None and not isinstance(self.quant, QuantSpec):
            raise ValueError(f"quant must be a QuantSpec, got {self.quant!r}")
        if self.mesh is not None:
            if not isinstance(self.mesh, MeshSpec):
                raise ValueError(f"mesh must be a MeshSpec, got {self.mesh!r}")
            if self.num_experts % self.mesh.size or self.t % self.mesh.size:
                raise ValueError(
                    f"mesh size {self.mesh.size} must divide both "
                    f"num_experts={self.num_experts} and t={self.t}")

    @classmethod
    def from_operands(cls, x, w, epilogue=None, quant=None, mesh=None):
        t, k = x.shape
        e, kw, n = w.shape
        if kw != k:
            raise ValueError(f"contraction mismatch: x{x.shape} vs w{w.shape}")
        return cls(t=t, k=k, n=n, num_experts=e,
                   dtype=canonical_dtype(x.dtype), epilogue=epilogue,
                   quant=resolve_quant(quant), mesh=mesh)

    @property
    def x_wire_itemsize(self) -> int:
        """Bytes per staged activation-row element (narrow only for a
        fully quantized grouped GEMM)."""
        if self.quant is not None and not self.quant.weight_only:
            return self.quant.wire_itemsize
        return jnp.dtype(self.dtype).itemsize

    @property
    def w_wire_itemsize(self) -> int:
        """Bytes per staged expert-panel element (narrow under any quant
        spec)."""
        if self.quant is not None:
            return self.quant.wire_itemsize
        return jnp.dtype(self.dtype).itemsize

    @property
    def compute_dtype(self) -> str:
        """Dtype pricing the MXU work (see GemmDescriptor.compute_dtype)."""
        if self.quant is not None and not self.quant.weight_only:
            return self.quant.dtype
        return self.dtype

    @property
    def flops(self) -> int:
        # Each row contracts against exactly one expert's (K, N) panel.
        return 2 * self.t * self.k * self.n

    @property
    def in_bytes(self) -> int:
        total = (self.t * self.k * self.x_wire_itemsize
                 + self.num_experts * self.k * self.n * self.w_wire_itemsize)
        if self.quant is not None:
            # per-expert column scales (+ per-row activation scales).
            total += (self.num_experts * self.n + self.t) * 4
        return total

    @property
    def out_bytes(self) -> int:
        return self.t * self.n * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class SsdChunkDescriptor(KernelDescriptor):
    """SSD (Mamba-2) chunked-scan family, two forms (DESIGN.md §4/§10).

    ``chunks == 0`` — the intra-chunk ladder only (the pre-schedule
    surface): ``(G,Q,n) x2, (G,Q,Q), (G,Q,p) -> (G,Q,p)`` where ``G``
    flattens batch x chunk x head.

    ``chunks >= 1`` — the whole chunked scan: per group (batch x head)
    the kernel walks ``chunks`` sequentially with the inter-chunk state
    ``(p, n)`` carried as accumulator state, consuming
    ``(G, C, Q, n) x2, (G, C, Q, Q), (G, C, Q, p), (G, C, Q) x2`` decay
    vectors and an initial state ``(G, p, n)``, and producing
    ``y: (G, C, Q, p)`` plus the final state ``(G, p, n)``.
    """

    family = "ssd_chunk"

    groups: int
    q: int
    n: int
    p: int
    dtype: str = "float32"
    # number of chunks walked per group with carried state; 0 selects the
    # intra-chunk (diagonal-block) form with no inter-chunk recurrence
    chunks: int = 0

    def __post_init__(self):
        for v in (self.groups, self.q, self.n, self.p):
            if v <= 0:
                raise ValueError(f"SSD dims must be positive, got {self}")
        if self.chunks < 0:
            raise ValueError(f"SSD chunks must be >= 0, got {self}")

    @classmethod
    def from_operands(cls, c_mat, xdt):
        """Descriptor of the intra-chunk form from ``(G,Q,n)``/``(G,Q,p)``
        operands."""
        g, q, n = c_mat.shape
        return cls(groups=g, q=q, n=n, p=xdt.shape[-1],
                   dtype=canonical_dtype(xdt.dtype))

    @classmethod
    def from_scan_operands(cls, c_mat, xdt):
        """Descriptor of the carried-state scan form from
        ``(G,C,Q,n)``/``(G,C,Q,p)`` operands."""
        g, chunks, q, n = c_mat.shape
        return cls(groups=g, q=q, n=n, p=xdt.shape[-1],
                   dtype=canonical_dtype(xdt.dtype), chunks=chunks)

    @property
    def cells(self) -> int:
        """(group, chunk) cells walked: ``G`` for the intra-chunk form,
        ``G * chunks`` for the scan form."""
        return self.groups * max(1, self.chunks)

    @property
    def flops(self) -> int:
        # Intra-chunk ladder per cell: GEMM 1 (Q,n)x(n,Q) + GEMM 2
        # (Q,Q)x(Q,p); the scan form adds the inter-chunk terms y_off
        # (Q,n)x(n,p) and the state outer product (p,Q)x(Q,n).
        intra = 2 * self.q * self.q * (self.n + self.p)
        inter = 4 * self.q * self.n * self.p if self.chunks else 0
        return self.cells * (intra + inter)

    @property
    def in_bytes(self) -> int:
        isz = jnp.dtype(self.dtype).itemsize
        per_cell = 2 * self.q * self.n + self.q * self.q + self.q * self.p
        if self.chunks:
            per_cell += 2 * self.q  # decay_in / decay_out vectors
        total = self.cells * per_cell * isz
        if self.chunks:
            total += self.groups * self.p * self.n * 4  # initial state, fp32
        return total

    @property
    def out_bytes(self) -> int:
        total = self.cells * self.q * self.p * jnp.dtype(self.dtype).itemsize
        if self.chunks:
            total += self.groups * self.p * self.n * 4  # final state, fp32
        return total


@dataclasses.dataclass(frozen=True)
class FlashBwdDescriptor(FlashDescriptor):
    """Flash-attention backward: dO, O, LSE, Q, K, V -> dQ, dK, dV.

    Same geometry fields as :class:`FlashDescriptor` (the backward walk
    reuses the forward ``FlashTileSchedule``), but a distinct ``family`` so
    the engine caches/autotunes/counts backward plans separately
    (DESIGN.md §11).
    """

    family = "flash_attention_bwd"

    @classmethod
    def from_forward(cls, desc: FlashDescriptor) -> "FlashBwdDescriptor":
        """Backward descriptor sharing a forward descriptor's geometry."""
        return cls(**dataclasses.asdict(desc))

    @property
    def flops(self) -> int:
        # Five tile GEMMs per visited (q,k) tile (dV, dP, dQ, dK plus the
        # recomputed P) vs the forward's two — charge 2.5x forward.
        return (5 * super().flops) // 2

    @property
    def in_bytes(self) -> int:
        isz = jnp.dtype(self.dtype).itemsize
        # q/k/v/o/do operand panels plus the staged fp32 LSE rows.
        return (self.batch_heads * (3 * self.sq + 2 * self.sk) * self.d * isz
                + self.batch_heads * self.sq * 4)

    @property
    def out_bytes(self) -> int:
        # dQ (operand dtype) + dK/dV accumulated in fp32.
        isz = jnp.dtype(self.dtype).itemsize
        return self.batch_heads * (self.sq * self.d * isz
                                   + 2 * self.sk * self.d * 4)


@dataclasses.dataclass(frozen=True)
class GroupedGemmBwdDescriptor(GroupedGemmDescriptor):
    """Grouped-GEMM backward: dY, X, W, group_sizes -> dX, dW, (dB).

    Inherits the forward geometry so ``GroupedGemmPlan.tile_schedule()``
    applies unchanged; the distinct ``family`` keys separate plan/kernel
    cache rows and launch counters (DESIGN.md §11).
    """

    family = "grouped_gemm_bwd"

    @classmethod
    def from_forward(cls, desc: GroupedGemmDescriptor
                     ) -> "GroupedGemmBwdDescriptor":
        """Backward descriptor sharing a forward descriptor's geometry.

        The quant spec is deliberately dropped: quantization is a
        forward/inference axis (DESIGN.md §13) — backward walks run in
        the wide dtype on the saved wide residuals.  The mesh spec is
        dropped too: the distributed path runs the *local* grouped GEMM
        (whose VJP this descriptor keys) under ``shard_map``, so the
        backward geometry is always the meshless per-shard problem
        (DESIGN.md §14).
        """
        fields = dataclasses.asdict(desc)
        fields["quant"] = None  # asdict flattens QuantSpec to a dict anyway
        fields["mesh"] = None   # same for MeshSpec
        return cls(**fields)

    @property
    def flops(self) -> int:
        # dX = dY @ W^T and dW = X^T @ dY: two contractions of forward cost.
        return 2 * super().flops

    @property
    def in_bytes(self) -> int:
        isz = jnp.dtype(self.dtype).itemsize
        return (self.t * (self.k + self.n)
                + self.num_experts * self.k * self.n) * isz

    @property
    def out_bytes(self) -> int:
        isz = jnp.dtype(self.dtype).itemsize
        # dX in operand dtype; dW (and db when biased) staged in fp32.
        total = self.t * self.k * isz + self.num_experts * self.k * self.n * 4
        if self.epilogue in BIAS_EPILOGUES:
            total += self.num_experts * self.n * 4
        return total


@dataclasses.dataclass(frozen=True)
class SsdChunkBwdDescriptor(SsdChunkDescriptor):
    """SSD chunked-scan backward: reverse walk with carried (p,n) cotangent.

    Geometry matches the forward :class:`SsdChunkDescriptor` (scan form,
    ``chunks >= 1``); the distinct ``family`` gives backward plans their
    own cache/autotune/launch accounting (DESIGN.md §11).
    """

    family = "ssd_chunk_bwd"

    @classmethod
    def from_forward(cls, desc: SsdChunkDescriptor) -> "SsdChunkBwdDescriptor":
        """Backward descriptor sharing a forward descriptor's geometry."""
        return cls(**dataclasses.asdict(desc))

    @property
    def flops(self) -> int:
        # Each forward GEMM spawns two cotangent GEMMs in reverse.
        return 2 * super().flops

    @property
    def in_bytes(self) -> int:
        # Forward operands + dY/dSf cotangents + the saved per-chunk fp32
        # carried states the reverse walk consumes.
        extra = (self.cells * self.q * self.p
                 * jnp.dtype(self.dtype).itemsize          # dY
                 + 2 * self.groups * self.p * self.n * 4   # dSf + s0
                 + self.cells * self.p * self.n * 4)       # saved states
        return super().in_bytes + extra

    @property
    def out_bytes(self) -> int:
        isz = jnp.dtype(self.dtype).itemsize
        per_cell = (2 * self.q * self.n + self.q * self.q  # dc, db, dl
                    + self.q * self.p)                     # dx
        return (self.cells * per_cell * isz
                + self.cells * 2 * self.q * 4              # ddi/ddo, fp32
                + self.groups * self.p * self.n * 4)       # ds0


@dataclasses.dataclass(frozen=True)
class TransposeDescriptor(KernelDescriptor):
    """Blocked (batched) 2-D transpose: (..., rows, cols) -> (..., cols, rows).

    ``batch`` is a leading grid dimension of the generated kernel, not a
    ``vmap`` — a batched transpose is ONE launch (DESIGN.md §9).
    """

    family = "transpose"

    rows: int
    cols: int
    dtype: str = "float32"
    # leading batch dim shared by in/out; 0 => unbatched 2-D transpose
    batch: int = 0

    def __post_init__(self):
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError(f"transpose dims must be positive, got {self}")

    @classmethod
    def from_operands(cls, x):
        batch = 0
        if x.ndim == 3:
            batch = x.shape[0]
        elif x.ndim != 2:
            raise ValueError(f"transpose operand must be rank 2 or 3, "
                             f"got {x.ndim}")
        rows, cols = x.shape[-2], x.shape[-1]
        return cls(rows=rows, cols=cols, dtype=canonical_dtype(x.dtype),
                   batch=batch)

    @property
    def flops(self) -> int:
        return 0  # pure data movement

    @property
    def in_bytes(self) -> int:
        nb = max(1, self.batch)
        return nb * self.rows * self.cols * jnp.dtype(self.dtype).itemsize

    @property
    def out_bytes(self) -> int:
        return self.in_bytes


# ---------------------------------------------------------------------------
# Cache-key round trip (DESIGN.md §15)
# ---------------------------------------------------------------------------

# Family name -> descriptor class, for rebuilding a descriptor from its
# engine cache key.  Kept next to the classes so adding a family here is
# part of adding the family.
_FAMILY_DESCRIPTORS = {
    cls.family: cls for cls in (
        GemmDescriptor, FlashDescriptor, FlashBwdDescriptor,
        FlashDecodeDescriptor, GroupedGemmDescriptor,
        GroupedGemmBwdDescriptor, SsdChunkDescriptor, SsdChunkBwdDescriptor,
        TransposeDescriptor)
}


def descriptor_from_cache_key(key) -> KernelDescriptor:
    """Rebuild the descriptor a ``cache_key()`` tuple names.

    ``cache_key()`` is ``(family,) + dataclasses.astuple(desc)``, with
    nested :class:`QuantSpec` / :class:`MeshSpec` recursed into plain
    tuples — so the key is fully invertible.  This is what lets the
    offline refit pipeline and the warm-start manifest reconstruct the
    exact descriptor population from TuningCache entry keys and recorded
    manifests (DESIGN.md §15).  Raises ``ValueError`` on an unknown
    family or a field-count mismatch (a key written by a different
    descriptor schema must not silently half-apply).
    """
    key = tuple(key)
    if not key:
        raise ValueError("empty cache key")
    family, values = key[0], key[1:]
    cls = _FAMILY_DESCRIPTORS.get(family)
    if cls is None:
        raise ValueError(f"unknown descriptor family {family!r}; "
                         f"known: {sorted(_FAMILY_DESCRIPTORS)}")
    fields = dataclasses.fields(cls)
    if len(values) != len(fields):
        raise ValueError(
            f"{family} cache key carries {len(values)} fields, the "
            f"descriptor schema has {len(fields)} — written by a "
            f"different version?")
    kwargs = {}
    for f, v in zip(fields, values):
        if v is not None:
            if f.name == "quant":
                v = QuantSpec(*v)
            elif f.name == "mesh":
                v = MeshSpec(*v)
            elif isinstance(v, list):
                v = tuple(v)
        kwargs[f.name] = v
    return cls(**kwargs)
