"""JIT kernel cache — the LIBXSMM dispatch analogue.

LIBXSMM generates a kernel per ``libxsmm_gemm_descriptor`` and serves later
requests from a code registry.  Here, "code generation" is building the
shape-specialized ``pallas_call`` executors for every region of a
:class:`BlockingPlan`; this registry memoizes (descriptor, plan-knobs) ->
built executor so models with thousands of identical small GEMMs pay the
planning/build cost once per shape.

(``jax.jit`` separately caches *compiled* artifacts by aval; this cache
avoids re-running the planner and re-tracing kernel builds, and gives us
the hit/miss observability the paper's dispatch layer has.)
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Hashable, Tuple


class KernelCache:
    def __init__(self, max_entries: int = 4096):
        self._lock = threading.Lock()
        self._store: Dict[Hashable, Any] = {}
        self._max = max_entries
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._store:
                self.hits += 1
                return self._store[key]
        # Build outside the lock (builders trace JAX code and can be slow).
        value = builder()
        with self._lock:
            if key not in self._store:
                if len(self._store) >= self._max:
                    # Simple FIFO eviction; shape populations in one model
                    # are tiny compared to max_entries.
                    self._store.pop(next(iter(self._store)))
                self._store[key] = value
                self.misses += 1
            else:
                self.hits += 1
            return self._store[key]

    def stats(self) -> Tuple[int, int, int]:
        with self._lock:
            return self.hits, self.misses, len(self._store)

    def clear(self):
        with self._lock:
            self._store.clear()
            self.hits = self.misses = 0


GLOBAL_KERNEL_CACHE = KernelCache()
