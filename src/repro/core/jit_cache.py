"""JIT kernel cache — the LIBXSMM dispatch analogue.

LIBXSMM generates a kernel per ``libxsmm_gemm_descriptor`` and serves later
requests from a code registry.  Here, "code generation" is building the
shape-specialized ``pallas_call`` executors for every region of a plan;
this registry memoizes (descriptor-derived key) -> built executor so models
with thousands of identical small GEMMs pay the planning/build cost once
per shape.

The cache is a true LRU (hits refresh recency; eviction removes the
least-recently-used entry) and keeps per-family hit/miss/eviction counters:
every key is a tuple whose first element is the kernel-family name (the
engine derives keys from ``KernelDescriptor.cache_key()``), which is also
how the stats are bucketed.

(``jax.jit`` separately caches *compiled* artifacts by aval; this cache
avoids re-running the planner and re-tracing kernel builds, and gives us
the hit/miss observability the paper's dispatch layer has.)
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Dict, Hashable, Tuple


def _family_of(key: Hashable) -> str:
    if isinstance(key, tuple) and key and isinstance(key[0], str):
        return key[0]
    return "other"


class LruCache:
    """Thread-safe LRU mapping with per-family hit/miss/eviction stats.

    Shared by the engine's two layers: plan cache (descriptor -> plan) and
    kernel cache (descriptor+plan knobs -> built executor).
    """

    def __init__(self, max_entries: int = 4096):
        self._lock = threading.Lock()
        self._store: "collections.OrderedDict[Hashable, Any]" = \
            collections.OrderedDict()
        self._max = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._by_family: Dict[str, Dict[str, int]] = {}

    def _bucket(self, family: str) -> Dict[str, int]:
        return self._by_family.setdefault(
            family, {"hits": 0, "misses": 0, "evictions": 0})

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._store:
                self._store.move_to_end(key)  # refresh recency
                self.hits += 1
                self._bucket(_family_of(key))["hits"] += 1
                return self._store[key]
        # Build outside the lock (builders trace JAX code and can be slow).
        value = builder()
        with self._lock:
            if key not in self._store:
                while len(self._store) >= self._max:
                    evicted_key, _ = self._store.popitem(last=False)
                    self.evictions += 1
                    self._bucket(_family_of(evicted_key))["evictions"] += 1
                self._store[key] = value
                self.misses += 1
                self._bucket(_family_of(key))["misses"] += 1
            else:
                # Raced with another builder thread; theirs won.
                self._store.move_to_end(key)
                self.hits += 1
                self._bucket(_family_of(key))["hits"] += 1
            return self._store[key]

    def put(self, key: Hashable, value: Any) -> None:
        """Insert or overwrite ``key`` (refreshing recency), no counters.

        The engine uses this to propagate a freshly autotuned winner onto
        the tuned-tier plan key, overwriting any model plan a jit trace
        cached there before the tuning-cache file was populated.
        """
        with self._lock:
            self._store[key] = value
            self._store.move_to_end(key)
            while len(self._store) > self._max:
                evicted_key, _ = self._store.popitem(last=False)
                self.evictions += 1
                self._bucket(_family_of(evicted_key))["evictions"] += 1

    def stats(self) -> Tuple[int, int, int]:
        with self._lock:
            return self.hits, self.misses, len(self._store)

    def family_stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {fam: dict(c) for fam, c in self._by_family.items()}

    def keys(self) -> list:
        """Current keys in LRU order (least-recently-used first)."""
        with self._lock:
            return list(self._store)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def _reset_counters_locked(self):
        self.hits = self.misses = self.evictions = 0
        self._by_family.clear()

    def clear(self):
        with self._lock:
            self._store.clear()
            self._reset_counters_locked()

    def reset_stats(self):
        """Zero the counters but keep the entries (and their recency).

        Benchmark phase boundaries use this: the next phase's table starts
        from zero without forcing every kernel to rebuild —
        ``engine.reset_stats(entries=False)`` fans out to both caches.
        """
        with self._lock:
            self._reset_counters_locked()


# Back-compat name: pre-engine code imported ``KernelCache``.
KernelCache = LruCache

GLOBAL_KERNEL_CACHE = LruCache()
