"""The schedule layer — family-generic fused-execution machinery (DESIGN.md §9).

The paper's generator emits ONE kernel per problem: main tiles and edge
tiles are covered inside it by predication and a two-step load/store path,
so raggedness never costs extra dispatches or operand copies (§IV, Fig 7).
PR 3 built that machinery for dense GEMM only, inlined into
``core/blocking.py`` + ``kernels/gemm/kernel.py``.  This module hoists it
into a family-generic subsystem so every ragged family can flatten its
work-list into tile tables walked by a single ``pallas_call``:

  * :class:`TileSchedule` — the trace-time flattening of a dense region
    cover (GEMM): per-tile ownership rectangles + clamped window origins;
  * :class:`GroupedTileSchedule` — the *runtime* flattening of a ragged
    expert row partition (grouped GEMM / MoE): the geometry is static,
    the tables are data, computed from ``group_sizes`` with jnp ops and
    shipped to the kernel as a scalar-prefetch operand;
  * :class:`FlashTileSchedule` — the trace-time flattening of the flash
    attention (q-block, k-block) walk (DESIGN.md §10): fully-masked
    causal k-blocks are dropped at plan time instead of skipped at run
    time, and the online-softmax carry (m/l/acc) threads through the
    flat tile walk as accumulator state;
  * scalar-prefetch table packing (``pack_table`` — int32, the SMEM
    currency);
  * in-kernel predication helpers shared by every fused kernel body:
    clamped K windows + tail masks (the predicate-register analogue) and
    ownership-masked read-modify-write stores (the two-step store path);
  * launch accounting (:func:`plan_launches`) — the per-plan
    ``pallas_call`` count that executors report via
    ``engine.count_launches`` and cost models charge at
    ``machine.launch_overhead_s``.

``repro.core.blocking`` builds schedules from plans; ``repro.kernels.*``
consume them.  This module imports neither — it is the seam between the
planning layer and the generated kernels.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def ceil_div(a: int, b: int) -> int:
    """Ceiling division on Python ints: ``ceil(a / b)`` without floats."""
    return -(-a // b)


# Channel-block width of the ``per_tile`` quantization scheme (DESIGN.md
# §13): scale tables hold one f32 scale per QUANT_TILE-wide block of the
# channel axis, and each tile row's ``scale_idx`` column names the block
# its clamped window origin falls in.  Pinned to the lane count (128) so a
# scale block never straddles a native register tile.
QUANT_TILE = 128


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the nearest multiple of ``b``."""
    return ceil_div(a, b) * b


# ---------------------------------------------------------------------------
# Dense (GEMM) tile schedules — trace-time tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """Flattened tile schedule of one dense region cover (DESIGN.md §9).

    The fused single-launch GEMM kernel walks this instead of launching one
    ``pallas_call`` per region: every region's grid is unrolled into a flat
    tuple of tiles, all trace-time constants, which the kernel receives as
    a scalar-prefetch table and indexes by ``pl.program_id``.

    ``blocks`` are the distinct effective block geometries (region blocks
    clamped to the matrix so a clamped load window always fits the operand
    buffers); each tile row is

        (row0, col0, row_end, col_end, row_start, col_start, block_id,
         scale_idx)

    where ``[row0, row_end) x [col0, col_end)`` is the set of C elements
    the tile owns (the predicate mask) and ``(row_start, col_start)`` is
    the clamped origin of its fixed-shape load/store window — the paper's
    two-step load/store path: edge windows slide inward and the mask keeps
    each element owned by exactly one tile.  ``scale_idx`` is the quant
    axis's scale-table coordinate (DESIGN.md §13): the ``per_tile`` scale
    block (:data:`QUANT_TILE`-wide) the window origin's row falls in —
    carried on every tile so quantized and wide plans share one table
    layout; wide kernels simply never read the column.
    """

    m: int
    n: int
    k: int
    bk: int
    k_steps: int
    blocks: Tuple[Tuple[int, int], ...]
    tiles: Tuple[Tuple[int, int, int, int, int, int, int, int], ...]

    @property
    def num_tiles(self) -> int:
        return len(self.tiles)

    def validate(self):
        """Every C element owned by exactly one tile mask."""
        owned = 0
        for row0, col0, row_end, col_end, rs, cs, bid, sidx in self.tiles:
            bm_e, bn_e = self.blocks[bid]
            assert 0 <= rs and rs + bm_e <= self.m, (rs, bm_e, self.m)
            assert 0 <= cs and cs + bn_e <= self.n, (cs, bn_e, self.n)
            assert rs <= row0 and row_end <= rs + bm_e
            assert cs <= col0 and col_end <= cs + bn_e
            assert sidx == rs // QUANT_TILE, (sidx, rs)
            owned += (row_end - row0) * (col_end - col0)
        assert owned == self.m * self.n, (owned, self.m * self.n)
        return True


def flatten_regions(m: int, n: int, k: int, bk: int,
                    regions: Sequence) -> TileSchedule:
    """Flatten a region cover into the fused kernel's tile tables.

    ``regions`` is any sequence of objects with ``row0/col0/rows/cols``
    ownership rectangles and ``bm/bn`` block geometry (the
    :class:`repro.core.blocking.Region` shape).  Region blocks are clamped
    to the matrix (``bm_e = min(bm, m)``) so every fixed-shape window fits
    the real operand buffers; a clamped block walks its region with the
    *effective* stride, so raggedness is absorbed by the per-tile
    ownership mask, never by the shapes.
    """
    bk = max(1, min(bk, k))
    blocks: List[Tuple[int, int]] = []
    ids = {}
    tiles = []
    for r in regions:
        bm_e, bn_e = min(r.bm, m), min(r.bn, n)
        bid = ids.get((bm_e, bn_e))
        if bid is None:
            bid = ids[(bm_e, bn_e)] = len(blocks)
            blocks.append((bm_e, bn_e))
        for i in range(ceil_div(r.rows, bm_e)):
            row0 = r.row0 + i * bm_e
            row_end = min(row0 + bm_e, r.row0 + r.rows)
            for j in range(ceil_div(r.cols, bn_e)):
                col0 = r.col0 + j * bn_e
                col_end = min(col0 + bn_e, r.col0 + r.cols)
                rs = min(row0, m - bm_e)
                tiles.append((row0, col0, row_end, col_end,
                              rs, min(col0, n - bn_e),
                              bid, rs // QUANT_TILE))
    return TileSchedule(m=m, n=n, k=k, bk=bk, k_steps=ceil_div(k, bk),
                        blocks=tuple(blocks), tiles=tuple(tiles))


def pack_table(rows: Sequence[Sequence[int]]) -> np.ndarray:
    """Pack tile rows into the int32 scalar-prefetch table the kernels ride.

    numpy, not jnp: trace-time tables are baked into the kernel closure,
    and a traced constant must not leak into the kernel cache (runtime
    tables — :meth:`GroupedTileSchedule.tables` — are jnp by construction
    and travel as operands instead).
    """
    table = np.asarray(rows, dtype=np.int32)
    assert table.ndim == 2, table.shape
    return table


# ---------------------------------------------------------------------------
# Ragged (grouped) tile schedules — runtime tables, static geometry
# ---------------------------------------------------------------------------

# Tile states in the grouped table's ``state`` column.
TILE_SKIP = 0     # beyond the active tile count: no work
TILE_COMPUTE = 1  # owns rows of one expert: accumulate + store
TILE_ZERO = 2     # owns rows past sum(group_sizes): store zeros


@dataclasses.dataclass(frozen=True)
class GroupedTileSchedule:
    """Schedule of a ragged row partition (grouped GEMM, DESIGN.md §9).

    The *geometry* is trace-time (effective blocks, grid extents, the
    static ``max_tiles`` bound) but the *tables* are runtime data: the
    router decides ``group_sizes`` per call, so each expert's row blocks
    are computed with jnp ops (:meth:`tables`) and ride to the kernel as
    a scalar-prefetch operand — no host-side pad/scatter, no padded
    intermediate, no gather-back.

    Each table row is ``(row0, row_end, row_start, expert, state)``:
    ``[row0, row_end)`` are the x/out rows the tile owns, ``row_start``
    is the clamped origin of its fixed ``bm``-row window, ``expert``
    selects the weight (and bias) panel, and ``state`` marks the tile as
    compute / zero-fill (rows past ``sum(group_sizes)``) / skip.
    """

    t: int
    k: int
    n: int
    num_experts: int
    bm: int
    bk: int
    bn: int

    def __post_init__(self):
        assert self.bm <= self.t and self.bn <= self.n and self.bk <= self.k

    @property
    def max_tiles(self) -> int:
        """Static row-tile bound: every expert may add one partial block,
        plus the zero-fill tail region."""
        return ceil_div(self.t, self.bm) + self.num_experts + 1

    @property
    def k_steps(self) -> int:
        return ceil_div(self.k, self.bk)

    @property
    def n_steps(self) -> int:
        return ceil_div(self.n, self.bn)

    def tables(self, group_sizes: jax.Array) -> jax.Array:
        """Runtime tile table: ``(max_tiles, 5)`` int32 from the router's
        ``group_sizes``.  All shapes static, values dynamic — traceable
        under ``jit``.  Rows past ``sum(group_sizes)`` form a zero-fill
        pseudo-group so the kernel covers every output row exactly once.
        """
        bm, t, e = self.bm, self.t, self.num_experts
        sizes = group_sizes.astype(jnp.int32)
        tail = t - jnp.sum(sizes)
        all_sizes = jnp.concatenate([sizes, tail[None]])          # (E+1,)
        all_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(all_sizes)])        # (E+2,)
        nblocks = (all_sizes + bm - 1) // bm                      # (E+1,)
        bstart = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(nblocks)])           # (E+2,)
        g = jnp.arange(self.max_tiles, dtype=jnp.int32)
        # Which (pseudo-)group owns tile g; empty groups contribute no
        # tiles (their bstart span is empty, searchsorted skips them).
        owner = jnp.clip(
            jnp.searchsorted(bstart, g, side="right") - 1, 0, e)
        local = g - bstart[owner]
        row0 = all_off[owner] + local * bm
        row_end = jnp.minimum(row0 + bm, all_off[owner] + all_sizes[owner])
        active = g < bstart[-1]
        row0 = jnp.where(active, row0, t)
        row_end = jnp.where(active, row_end, t)
        rs = jnp.clip(jnp.minimum(row0, t - bm), 0)
        expert = jnp.minimum(owner, e - 1)  # always a legal panel index
        state = jnp.where(
            active & (row_end > row0),
            jnp.where(owner < e, TILE_COMPUTE, TILE_ZERO), TILE_SKIP)
        return jnp.stack([row0, row_end, rs, expert, state],
                         axis=1).astype(jnp.int32)

    def validate_tables(self, table, group_sizes) -> bool:
        """Property check on one concrete table (tests): every output row
        owned by exactly one tile, windows in bounds, experts consistent.
        """
        table = np.asarray(table)
        sizes = np.asarray(group_sizes, dtype=np.int64)
        offsets = np.concatenate([[0], np.cumsum(sizes)])
        assert table.shape == (self.max_tiles, 5), table.shape
        assert table.dtype == np.int32, table.dtype
        owner_of = np.full(self.t, -1, dtype=np.int64)
        for row0, row_end, rs, expert, state in table:
            if state == TILE_SKIP:
                assert row0 == row_end, (row0, row_end)
                continue
            assert 0 <= rs and rs + self.bm <= self.t, (rs, self.bm, self.t)
            assert rs <= row0 and row_end <= rs + self.bm
            assert 0 <= expert < self.num_experts
            assert (owner_of[row0:row_end] == -1).all(), "row owned twice"
            owner_of[row0:row_end] = expert if state == TILE_COMPUTE else -2
            if state == TILE_COMPUTE:
                # owned rows really belong to that expert
                assert offsets[expert] <= row0
                assert row_end <= offsets[expert + 1]
            else:  # TILE_ZERO: rows past the ragged total
                assert row0 >= offsets[-1]
        assert (owner_of != -1).all(), "uncovered output rows"
        return True


# ---------------------------------------------------------------------------
# Paged decode tile schedules — runtime tables over live KV pages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeTileSchedule:
    """Schedule of one continuous-batching decode step over a paged KV
    cache (DESIGN.md §12).

    The serving runtime stores each sequence's KV in fixed-size *pages*
    of a shared pool, mapped by per-sequence block tables
    (``runtime/pages.py``).  A decode step attends each sequence's single
    query row against exactly its live pages — a ragged walk whose
    raggedness is *runtime data* (sequence lengths change every step, the
    batch churns with admissions/evictions), so it gets the
    :class:`GroupedTileSchedule` treatment, not the trace-time
    :class:`FlashTileSchedule` one: the geometry (pool size, page size,
    slot count, the static ``max_tiles`` bound) is trace-time, the tables
    are jnp data computed from ``(block_tables, lengths)`` each step and
    shipped to the kernel as a scalar-prefetch operand.  Batch churn
    never retraces — the kernel is shape-specialized, the batch
    composition is data.

    Each table row is ``(seq, page, k_len, first, last)``: the decode
    kernel's grid step ``t`` attends query row ``seq`` against pool page
    ``page``, of which the first ``k_len`` slots are live (the tail
    predicate), with ``first``/``last`` bracketing the sequence's
    contiguous page walk for the online-softmax carry exactly as in the
    flash schedule.  A sequence always owns at least one table row — an
    empty (length-0 / inactive) slot gets a single fully-masked row so
    its carry still initializes and drains (to zeros) without branching.
    """

    num_seqs: int    # decode slots (pool block-table rows)
    pages: int       # pool size in pages
    page_size: int   # KV slots per page
    max_blocks: int  # block-table width: max pages one sequence may own

    def __post_init__(self):
        assert self.num_seqs > 0 and self.pages > 0
        assert self.page_size > 0 and self.max_blocks > 0

    @property
    def max_tiles(self) -> int:
        """Static tile bound: live pages are exclusively owned so at most
        ``pages`` compute tiles exist pool-wide (never more than
        ``num_seqs * max_blocks``), plus one dummy tile per sequence for
        the ≥1-row floor."""
        return min(self.num_seqs * self.max_blocks, self.pages) \
            + self.num_seqs

    @property
    def max_len(self) -> int:
        """Longest sequence the block tables can map."""
        return self.max_blocks * self.page_size

    def tables(self, block_tables: jax.Array,
               lengths: jax.Array) -> jax.Array:
        """Runtime tile table: ``(max_tiles, 5)`` int32 from this step's
        ``block_tables`` (num_seqs, max_blocks) and ``lengths``
        (num_seqs,).  All shapes static, values dynamic — traceable under
        ``jit``, so admissions/evictions/growth never recompile."""
        P, S = self.page_size, self.num_seqs
        lengths = lengths.astype(jnp.int32)
        # ceil(len/P) live pages per sequence, floored at one (dummy) tile
        # so every slot's carry initializes and drains.
        nblocks = jnp.maximum((lengths + P - 1) // P, 1)       # (S,)
        bstart = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(nblocks)])        # (S+1,)
        g = jnp.arange(self.max_tiles, dtype=jnp.int32)
        seq = jnp.clip(jnp.searchsorted(bstart, g, side="right") - 1,
                       0, S - 1)
        local = g - bstart[seq]
        active = g < bstart[-1]
        lcl = jnp.clip(local, 0, self.max_blocks - 1)
        page = jnp.clip(block_tables[seq, lcl], 0, self.pages - 1)
        k_len = jnp.clip(lengths[seq] - local * P, 0, P)
        first = active & (local == 0)
        last = active & (local == nblocks[seq] - 1)
        page = jnp.where(active, page, 0)
        k_len = jnp.where(active, k_len, 0)
        return jnp.stack([seq, page, k_len,
                          first.astype(jnp.int32), last.astype(jnp.int32)],
                         axis=1).astype(jnp.int32)

    def validate_tables(self, table, block_tables, lengths) -> bool:
        """Property check on one concrete table (tests): every sequence's
        live pages visited exactly once, in block-table order, with
        correct tail lengths and carry flags; inactive tail rows inert."""
        table = np.asarray(table)
        bt = np.asarray(block_tables)
        lengths = np.asarray(lengths, dtype=np.int64)
        P = self.page_size
        assert table.shape == (self.max_tiles, 5), table.shape
        assert table.dtype == np.int32, table.dtype
        nblocks = np.maximum(-(-lengths // P), 1)
        total = int(nblocks.sum())
        assert total <= self.max_tiles, (total, self.max_tiles)
        visited = {}  # seq -> list of (page, k_len)
        open_seq = None
        for i, (seq, page, k_len, first, last) in enumerate(table):
            if i >= total:  # inactive tail: inert rows, legal indices only
                assert first == 0 and last == 0 and k_len == 0, table[i]
                assert 0 <= seq < self.num_seqs and 0 <= page < self.pages
                continue
            assert 0 <= seq < self.num_seqs and 0 <= page < self.pages
            if first:
                assert open_seq is None, "carry re-opened before drain"
                open_seq = seq
                visited.setdefault(int(seq), [])
            assert open_seq == seq, "row outside the open carry"
            visited[int(seq)].append((int(page), int(k_len)))
            if last:
                open_seq = None
        assert open_seq is None, "carry never drained"
        for s in range(self.num_seqs):
            walk = visited.get(s, [])
            n, length = int(nblocks[s]), int(lengths[s])
            assert len(walk) == n, (s, walk, n)
            # pages follow the block table; each live page exactly once
            pages_seen = [p for p, _ in walk]
            if length > 0:
                expect = [int(bt[s, j]) for j in range(n)]
                assert pages_seen == expect, (s, pages_seen, expect)
                assert len(set(pages_seen)) == n, "page visited twice"
            # k_len: P per full page, the ragged tail on the last one
            assert sum(kl for _, kl in walk) == length, (s, walk, length)
            for j, (_, kl) in enumerate(walk):
                want = min(max(length - j * P, 0), P)
                assert kl == want, (s, j, kl, want)
        return True


# ---------------------------------------------------------------------------
# Flash-attention tile schedules — trace-time tables, causal-aware
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlashTileSchedule:
    """Flattened (q-block, k-block) walk of one flash attention problem
    (DESIGN.md §10).

    The causal mask is a *cover* problem, not a runtime branch: a k-block
    strictly above a q-block's diagonal contributes nothing, so it is
    dropped when the tile table is built — at long causal sequences
    roughly half the dense (q, k) grid never reaches the kernel.  The
    surviving tiles are ordered q-block-major with each q-block's
    k-blocks contiguous and ascending, so the online-softmax carry
    (running max / denominator / output accumulator) threads through the
    flat walk as VMEM accumulator state, reset at ``first`` and drained
    at ``last``.

    Each tile row is ``(q0, q_end, qs, k0, k_end, ks, first, last)``:
    ``[q0, q_end)`` are the query rows the tile's q-block *owns*, ``qs``
    / ``ks`` are the clamped origins of the fixed ``(bq, d)`` / ``(bk,
    d)`` windows (the two-step load path: ragged edge windows slide
    inward instead of shrinking), ``[k0, k_end)`` are the key columns
    this tile contributes (the predicate on the clamped-window overlap
    and the sk tail), and ``first``/``last`` flag the q-block's carry
    boundaries.
    """

    sq: int
    sk: int
    bq: int
    bk: int
    causal: bool
    tiles: Tuple[Tuple[int, int, int, int, int, int, int, int], ...]

    @property
    def num_tiles(self) -> int:
        """Tiles actually walked (per batch x head slice)."""
        return len(self.tiles)

    @property
    def dense_tiles(self) -> int:
        """Tile count of the dense (q, k) grid the causal drop beats."""
        return ceil_div(self.sq, self.bq) * ceil_div(self.sk, self.bk)

    def validate(self):
        """Every query row drained exactly once; every kept tile's k
        range in bounds, non-empty and causal-reachable; carry flags
        bracket each q-block's contiguous k walk."""
        drained = np.zeros(self.sq, dtype=np.int64)
        open_q = None  # ownership of the q-block currently being walked
        prev_k_end = 0
        for q0, q_end, qs, k0, k_end, ks, first, last in self.tiles:
            assert 0 <= qs and qs + self.bq <= self.sq, (qs, self.bq, self.sq)
            assert 0 <= ks and ks + self.bk <= self.sk, (ks, self.bk, self.sk)
            assert qs <= q0 and q_end <= qs + self.bq
            assert ks <= k0 and k_end <= ks + self.bk
            assert k0 < k_end <= self.sk
            if self.causal:
                # at least one owned (q, k) pair is visible
                assert k0 <= q_end - 1, (k0, q_end)
            if first:
                assert open_q is None, "carry re-opened before drain"
                open_q, prev_k_end = (q0, q_end), 0
            assert open_q == (q0, q_end), "tile outside the open carry"
            assert k0 == prev_k_end, "k walk not contiguous ascending"
            prev_k_end = k_end
            if last:
                drained[q0:q_end] += 1
                open_q = None
        assert open_q is None, "carry never drained"
        assert (drained == 1).all(), "query rows not drained exactly once"
        if self.causal and self.sq == self.sk and self.sq > self.bq + self.bk:
            assert self.num_tiles < self.dense_tiles
        return True


def flash_tile_schedule(sq: int, sk: int, bq: int, bk: int,
                        causal: bool) -> FlashTileSchedule:
    """Build the flattened causal-aware (q, k) tile walk.

    Block edges are clamped to the problem so every fixed-shape window
    fits the operands; for ``causal=True`` a k-block whose first column
    ``k0`` exceeds the q-block's last *owned* row is fully masked and
    never enters the table (the heterogeneous-cover idea applied to the
    causal triangle — at plan time, not as a run-time branch).
    """
    bq = max(1, min(bq, sq))
    bk = max(1, min(bk, sk))
    ck = ceil_div(sk, bk)
    tiles: List[Tuple[int, ...]] = []
    for qi in range(ceil_div(sq, bq)):
        q0 = qi * bq
        q_end = min(q0 + bq, sq)
        qs = min(q0, sq - bq)
        # k-blocks with any visible column for the owned rows [q0, q_end)
        k_hi = min(ck, ceil_div(q_end, bk)) if causal else ck
        row = []
        for ki in range(k_hi):
            k0 = ki * bk
            row.append([q0, q_end, qs, k0, min(k0 + bk, sk),
                        min(k0, sk - bk), 0, 0])
        row[0][6] = 1
        row[-1][7] = 1
        tiles.extend(tuple(r) for r in row)
    return FlashTileSchedule(sq=sq, sk=sk, bq=bq, bk=bk, causal=causal,
                             tiles=tuple(tiles))


# ---------------------------------------------------------------------------
# In-kernel predication helpers (shared by every fused kernel body)
# ---------------------------------------------------------------------------

def clamped_k_window(ks, bk: int, k: int):
    """Two-step K load: ``(k0, kstart)`` for K-panel ``ks``.

    ``k0`` is the nominal panel start; ``kstart`` the clamped origin of
    the fixed-``bk`` window (the last panel slides inward instead of
    shrinking).  When they differ the window revisits lanes the previous
    panel already summed — mask with :func:`k_tail_mask`.
    """
    k0 = ks * bk
    return k0, jnp.minimum(k0, k - bk)


def k_tail_mask(x, axis: int, k0, kstart):
    """Predicate the clamped-K overlap: keep only lanes at/after the
    nominal panel start.  ``where`` (not multiply) because the overlap may
    hold non-finite user data."""
    kk = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis) + kstart
    return jnp.where(kk >= k0, x, 0)


def ownership_mask(shape: Tuple[int, int], rs, cs, row0, row_end,
                   col0, col_end):
    """Boolean mask of the window elements this tile *owns* (the predicate
    that keeps every output element owned by exactly one tile)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0) + rs
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1) + cs
    return ((rows >= row0) & (rows < row_end)
            & (cols >= col0) & (cols < col_end))


def predicated_store(ref, idx, values, own):
    """Predicated two-step RMW store: write only owned elements of the
    clamped window, preserving neighbours written by other tiles."""
    old = ref[idx]
    ref[idx] = jnp.where(own, values, old)


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------

def plan_launches(plan, fused: bool) -> int:
    """``pallas_call`` count one plan's lowering emits.

    Fused lowerings are single-launch by construction; a multi-launch
    dense plan pays one dispatch per region.  Executors report this via
    ``engine.count_launches`` and cost models charge it at
    ``machine.launch_overhead_s``.
    """
    if fused:
        return 1
    regions = getattr(plan, "regions", None)
    return len(regions) if regions is not None else 1
