"""Machine-characterization harness — the paper's §III in library form.

The paper microbenchmarks M4 (instruction throughput per dtype, ZA
load/store strategies, multi-core scaling) and feeds the findings into
the code generator.  This module provides the same probes for whatever
device JAX is running on, and — closing the paper's measure→generate
loop — :func:`calibrate` folds the probe results into a
:class:`~repro.core.machine.MachineModel` via
:meth:`~repro.core.machine.MachineModel.from_probes`, so every planner
cost model in ``repro.core.blocking`` ranks candidate tilings against the
*measured* host instead of pinned Table-I constants (DESIGN.md §7).
benchmarks/table1_throughput.py, fig23_bandwidth.py and fig1_scaling.py
are the reporting front-ends.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .machine import CPU_HOST, MachineModel, TPU_V5E


@dataclasses.dataclass
class ProbeResult:
    """One measured characterization probe: name, value, unit."""

    name: str
    value: float
    unit: str


def _timeit(fn, *args, iters=5, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def probe_matmul_flops(dtype="float32", size=512, iters=5) -> ProbeResult:
    """Peak-ish matmul throughput on the host (Table I analogue).

    Covers the quant axis too (DESIGN.md §13): ``dtype="int8"`` times an
    integer contraction with an int32 accumulator — a plain ``a @ b``
    would overflow and measure nothing — and ``"float8_e4m3"`` (gated on
    :data:`~repro.core.machine.HAS_FP8`) an fp8 one with f32 accumulate,
    exactly the MACs the quantized kernels issue.
    """
    rng = np.random.default_rng(0)
    if dtype == "int8":
        a = jnp.asarray(rng.integers(-127, 128, (size, size)), jnp.int8)
        b = jnp.asarray(rng.integers(-127, 128, (size, size)), jnp.int8)
        f = jax.jit(lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32))
    elif dtype in ("float8_e4m3", "float8_e4m3fn"):
        from .machine import FP8_DTYPE, HAS_FP8
        if not HAS_FP8:
            raise ValueError("float8_e4m3 unavailable in this jax build")
        a = jnp.asarray(rng.standard_normal((size, size)), FP8_DTYPE)
        b = jnp.asarray(rng.standard_normal((size, size)), FP8_DTYPE)
        f = jax.jit(lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))
    else:
        a = jnp.asarray(rng.standard_normal((size, size)), dtype)
        b = jnp.asarray(rng.standard_normal((size, size)), dtype)
        f = jax.jit(lambda a, b: a @ b)
    s = _timeit(f, a, b, iters=iters)
    return ProbeResult(f"matmul_{dtype}", 2 * size**3 / s / 1e9, "GFLOP/s")


def probe_copy_bandwidth(mbytes=64) -> ProbeResult:
    """Streaming copy bandwidth (Fig 2/3 baseline analogue)."""
    n = mbytes * 2**20 // 4
    x = jnp.zeros((n,), jnp.float32)
    f = jax.jit(lambda x: x + 1.0)
    s = _timeit(f, x)
    return ProbeResult("copy_bw", 2 * n * 4 / s / 1e9, "GB/s")


def probe_elementwise_latency() -> ProbeResult:
    """Small-op dispatch latency (grid-step overhead calibration)."""
    x = jnp.zeros((8,), jnp.float32)
    f = jax.jit(lambda x: x * 2.0)
    s = _timeit(f, x, iters=20, warmup=5)
    return ProbeResult("dispatch_latency", s * 1e6, "us")


# --- interconnect probes (DESIGN.md §14) ---------------------------------
# Each measures one collective over a 1-D mesh spanning every visible
# device (a real TPU slice, or a host-count-forced CPU mesh under
# ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  Below 2
# devices there is no interconnect to measure: the probes return an
# explicit 0.0 "(uncalibrated)" result — never silently skipped — and
# ``MachineModel.from_probes`` maps that to ``None`` network fields, so
# the machine fingerprint / tuning key carry the uncalibrated provenance.

_LANES = 128


def _probe_mesh():
    devs = jax.devices()
    if len(devs) < 2:
        return None
    from jax.sharding import Mesh
    return Mesh(np.array(devs), ("probe",))


def _shmap_collective(mesh, body, out_spec):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P("probe"),
                             out_specs=out_spec, check_rep=False))


def probe_all_gather(mbytes: int = 4, iters: int = 5) -> ProbeResult:
    """Per-device ``all_gather`` receive bandwidth over the device mesh."""
    mesh = _probe_mesh()
    if mesh is None:
        return ProbeResult("all_gather_bw", 0.0, "GB/s (uncalibrated)")
    from jax.sharding import PartitionSpec as P
    s = mesh.devices.size
    rows = max(s, mbytes * 2**20 // (4 * _LANES * s)) * s
    x = jnp.zeros((rows, _LANES), jnp.float32)
    f = _shmap_collective(
        mesh, lambda x: jax.lax.all_gather(x, "probe", tiled=True), P(None))
    t = _timeit(f, x, iters=iters)
    recv = (s - 1) * (rows // s) * _LANES * 4  # bytes received per device
    return ProbeResult("all_gather_bw", recv / t / 1e9, "GB/s")


def probe_all_to_all(mbytes: int = 4, iters: int = 5) -> ProbeResult:
    """Per-device ``all_to_all`` exchange bandwidth over the device mesh."""
    mesh = _probe_mesh()
    if mesh is None:
        return ProbeResult("all_to_all_bw", 0.0, "GB/s (uncalibrated)")
    from jax.sharding import PartitionSpec as P
    s = mesh.devices.size
    rows = max(s, mbytes * 2**20 // (4 * _LANES * s)) * s * s
    x = jnp.zeros((rows, _LANES), jnp.float32)
    f = _shmap_collective(
        mesh,
        lambda x: jax.lax.all_to_all(
            x.reshape(s, rows // s // s, _LANES), "probe",
            split_axis=0, concat_axis=0).reshape(rows // s, _LANES),
        P("probe"))
    t = _timeit(f, x, iters=iters)
    moved = (s - 1) * (rows // s // s) * _LANES * 4  # bytes sent per device
    return ProbeResult("all_to_all_bw", moved / t / 1e9, "GB/s")


def probe_psum(mbytes: int = 4, iters: int = 5) -> ProbeResult:
    """Per-device ``psum`` (all-reduce) bandwidth over the device mesh."""
    mesh = _probe_mesh()
    if mesh is None:
        return ProbeResult("psum_bw", 0.0, "GB/s (uncalibrated)")
    from jax.sharding import PartitionSpec as P
    s = mesh.devices.size
    rows = max(s, mbytes * 2**20 // (4 * _LANES * s)) * s
    x = jnp.zeros((rows, _LANES), jnp.float32)
    f = _shmap_collective(
        mesh, lambda x: jax.lax.psum(x, "probe"), P(None))
    t = _timeit(f, x, iters=iters)
    # ring all-reduce moves ~2*(s-1)/s of the per-device payload
    moved = 2 * (s - 1) * (rows // s) * _LANES * 4 / s
    return ProbeResult("psum_bw", moved / t / 1e9, "GB/s")


def probe_collective_latency(iters: int = 20) -> ProbeResult:
    """Launch latency of a tiny collective (the per-collective fixed cost
    the mesh cost model charges on top of bandwidth)."""
    mesh = _probe_mesh()
    if mesh is None:
        return ProbeResult("collective_latency", 0.0, "us (uncalibrated)")
    from jax.sharding import PartitionSpec as P
    s = mesh.devices.size
    x = jnp.zeros((8 * s,), jnp.float32)
    f = _shmap_collective(
        mesh, lambda x: jax.lax.psum(x, "probe"), P(None))
    t = _timeit(f, x, iters=iters, warmup=5)
    return ProbeResult("collective_latency", t * 1e6, "us")


def characterize(machine: MachineModel = TPU_V5E, *,
                 size: int = 512, mbytes: int = 64) -> Dict[str, ProbeResult]:
    """Run all probes; pair host measurements with target-model constants."""
    from .machine import HAS_FP8
    out = {}
    dtypes = ["float32", "bfloat16", "int8"]
    if HAS_FP8:
        dtypes.append("float8_e4m3")
    for dtype in dtypes:
        r = probe_matmul_flops(dtype, size=size)
        out[r.name] = r
        out[f"target_peak_{dtype}"] = ProbeResult(
            f"target_peak_{dtype}", machine.peak(dtype) / 1e9, "GFLOP/s")
    r = probe_copy_bandwidth(mbytes=mbytes)
    out[r.name] = r
    out["target_hbm_bw"] = ProbeResult("target_hbm_bw",
                                       machine.hbm_bw / 1e9, "GB/s")
    out[probe_elementwise_latency().name] = probe_elementwise_latency()
    # Interconnect probes (DESIGN.md §14) — always present, value 0.0
    # "(uncalibrated)" on 1-device hosts rather than silently absent.
    net_mb = min(mbytes, 4)
    for r in (probe_all_gather(mbytes=net_mb), probe_all_to_all(mbytes=net_mb),
              probe_psum(mbytes=net_mb), probe_collective_latency()):
        out[r.name] = r
    out["target_ici_bw"] = ProbeResult(
        "target_ici_bw", machine.ici_bw_per_link / 1e9, "GB/s")
    return out


def calibrate(base: Optional[MachineModel] = None, *, size: int = 512,
              mbytes: int = 64, name: str = "calibrated_host",
              refit: Optional[str] = None) -> MachineModel:
    """Probe the host and return the calibrated machine model.

    The measure→generate loop in one call: §III probes in,
    planner-parameterizing model out.  ``size``/``mbytes`` shrink the
    probe problem for fast smoke runs; ``base`` supplies the constants
    the probes don't measure (memory capacities, tile geometry).

    ``refit`` optionally overlays a fleet-fitted refit-model JSON
    (``tools/tune.py refit``, DESIGN.md §15) on the probed model: the
    probes measure this host's rooflines, the refit supplies dispatch
    coefficients regressed from real kernel timings.  A bad refit file
    warns and leaves the probed model unchanged.
    """
    probes = characterize(base if base is not None else CPU_HOST,
                          size=size, mbytes=mbytes)
    model = MachineModel.from_probes(probes, base=base, name=name)
    if refit:
        from .machine import load_refit_model
        model = load_refit_model(refit, base=model)
    return model


if __name__ == "__main__":
    for name, r in characterize().items():
        print(f"{r.name:24s} {r.value:12.2f} {r.unit}")
    m = calibrate()
    print(f"calibrated: peak_f32={m.peak('float32')/1e9:.1f} GFLOP/s "
          f"bw={m.hbm_bw/1e9:.1f} GB/s overhead={m.step_overhead_s*1e6:.2f} us")
