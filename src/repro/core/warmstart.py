"""AOT warm-start support: descriptor manifests + operand synthesis.

A serving process pays its kernel builds and plan resolutions at the
first request — the cold stall the paper's dispatch-cache architecture
exists to avoid.  ``engine.warmup`` (DESIGN.md §15) eliminates it by
replaying a *descriptor population* before traffic arrives: resolve each
plan through the tuned tier and execute the family once on synthesized
zero operands so the kernel cache is hot.

This module owns the two supporting pieces:

  * the **manifest** — a versioned JSON recording of descriptor cache
    keys (``engine.seen_descriptors()`` captures what a process actually
    dispatched; ``save_manifest`` / ``load_manifest`` round-trip it via
    :func:`repro.core.descriptor.descriptor_from_cache_key`), and
  * **operand synthesis** — ``synth_operands`` builds the smallest legal
    zero-filled operand set for any descriptor, enough to drive one real
    ``execute()`` through kernel build + caching.

Degradation mirrors the tuning cache: a corrupt or stale manifest warns
and yields an empty population (cold start, never a crash).
"""
from __future__ import annotations

import ast
import json
import os
import tempfile
import warnings
from typing import Iterable, List, Optional, Tuple

import jax.numpy as jnp

from .descriptor import (BIAS_EPILOGUES, FlashBwdDescriptor,
                         FlashDecodeDescriptor, FlashDescriptor,
                         GemmDescriptor, GroupedGemmBwdDescriptor,
                         GroupedGemmDescriptor, KernelDescriptor,
                         SsdChunkBwdDescriptor, SsdChunkDescriptor,
                         TransposeDescriptor, descriptor_from_cache_key)
from .machine import FP8_DTYPE

MANIFEST_VERSION = 1

# Wire dtypes of the quantized formats (DESIGN.md §13).
_WIRE_DTYPES = {"int8": jnp.int8, "float8_e4m3": FP8_DTYPE}


def _dt(name):
    """jnp dtype for a canonical descriptor dtype name (fp8-aware)."""
    if name == "float8_e4m3":
        return FP8_DTYPE
    return jnp.dtype(name)


def save_manifest(path: str,
                  descriptors: Iterable[KernelDescriptor]) -> int:
    """Write a descriptor manifest (atomic); returns the entry count.

    Entries are the ``repr`` of each descriptor's ``cache_key()`` — the
    same invertible encoding the tuning cache uses, so a manifest is
    human-greppable and stable across processes.
    """
    keys = sorted({repr(d.cache_key()) for d in descriptors})
    payload = {"version": MANIFEST_VERSION, "descriptors": keys}
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".manifest.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return len(keys)


def load_manifest(path: str) -> List[KernelDescriptor]:
    """Descriptors recorded in a manifest file.

    Missing / corrupt / stale-version files warn and return ``[]`` (a
    cold start, never a crash); individually unparsable entries are
    skipped with a warning so one bad line cannot void the manifest.
    """
    try:
        with open(path) as f:
            data = json.load(f)
        if (not isinstance(data, dict)
                or data.get("version") != MANIFEST_VERSION
                or not isinstance(data.get("descriptors"), list)):
            raise ValueError("not a descriptor manifest (or stale version)")
    except (OSError, json.JSONDecodeError, ValueError) as e:
        warnings.warn(f"ignoring warm-start manifest {path}: {e}")
        return []
    out: List[KernelDescriptor] = []
    for entry in data["descriptors"]:
        try:
            out.append(descriptor_from_cache_key(ast.literal_eval(entry)))
        except (ValueError, SyntaxError, TypeError) as e:
            warnings.warn(f"skipping manifest entry {entry!r}: {e}")
    return out


def _zeros(shape, dtype):
    return jnp.zeros(shape, dtype=dtype)


def _gemm_operands(desc: GemmDescriptor) -> Tuple[tuple, dict]:
    a_dtype = _dt(desc.in_dtype)
    b_dtype = _dt(desc.in_dtype)
    kw = {}
    if desc.quant is not None:
        wire = _WIRE_DTYPES[desc.quant.dtype]
        b_dtype = wire
        kw["sb"] = jnp.ones((desc.n,), jnp.float32)
        if not desc.quant.weight_only:
            a_dtype = wire
            kw["sa"] = jnp.ones((desc.m,), jnp.float32)
    if desc.epilogue in BIAS_EPILOGUES:
        kw["bias"] = _zeros((desc.n,), jnp.float32)
    if desc.accumulate:
        kw["c"] = _zeros(desc.c_shape(), _dt(desc.out_dtype))
    return (_zeros(desc.a_shape(), a_dtype),
            _zeros(desc.b_shape(), b_dtype)), kw


def _flash_operands(desc: FlashDescriptor) -> Tuple[tuple, dict]:
    dt = _dt(desc.dtype)
    q = _zeros((desc.batch_heads, desc.sq, desc.d), dt)
    k = _zeros((desc.batch_heads, desc.sk, desc.d), dt)
    v = _zeros((desc.batch_heads, desc.sk, desc.d), dt)
    if isinstance(desc, FlashBwdDescriptor):
        o = _zeros((desc.batch_heads, desc.sq, desc.d), dt)
        lse = _zeros((desc.batch_heads, desc.sq), jnp.float32)
        return (q, k, v, o, o, lse), {}
    return (q, k, v), {}


def _decode_operands(desc: FlashDecodeDescriptor) -> Tuple[tuple, dict]:
    dt = _dt(desc.dtype)
    q = _zeros((desc.num_seqs, desc.num_heads, desc.head_dim), dt)
    pool = _zeros((desc.pages, desc.page_size, desc.num_kv_heads,
                   desc.head_dim), dt)
    tables = _zeros((desc.num_seqs, desc.max_blocks), jnp.int32)
    lengths = _zeros((desc.num_seqs,), jnp.int32)
    return (q, pool, pool, tables, lengths), {}


def _grouped_operands(desc: GroupedGemmDescriptor) -> Tuple[tuple, dict]:
    dt = _dt(desc.dtype)
    x_dtype = w_dtype = dt
    kw = {}
    quant = getattr(desc, "quant", None)
    if quant is not None:
        wire = _WIRE_DTYPES[quant.dtype]
        w_dtype = wire
        kw["sw"] = jnp.ones((desc.num_experts, desc.n), jnp.float32)
        if not quant.weight_only:
            x_dtype = wire
            kw["sx"] = jnp.ones((desc.t,), jnp.float32)
    if desc.epilogue in BIAS_EPILOGUES:
        kw["bias"] = _zeros((desc.num_experts, desc.n), jnp.float32)
    x = _zeros((desc.t, desc.k), x_dtype)
    w = _zeros((desc.num_experts, desc.k, desc.n), w_dtype)
    sizes = [desc.t // desc.num_experts] * desc.num_experts
    sizes[0] += desc.t - sum(sizes)
    group_sizes = jnp.asarray(sizes, jnp.int32)
    if isinstance(desc, GroupedGemmBwdDescriptor):
        dy = _zeros((desc.t, desc.n), dt)
        return (x, dy, w, group_sizes), {}
    return (x, w, group_sizes), kw


def _ssd_operands(desc: SsdChunkDescriptor) -> Tuple[tuple, dict]:
    dt = _dt(desc.dtype)
    g, q, n, p = desc.groups, desc.q, desc.n, desc.p
    if not desc.chunks:
        return (_zeros((g, q, n), dt), _zeros((g, q, n), dt),
                _zeros((g, q, q), dt), _zeros((g, q, p), dt)), {}
    nc = desc.chunks
    c = _zeros((g, nc, q, n), dt)
    l = _zeros((g, nc, q, q), dt)
    xdt = _zeros((g, nc, q, p), dt)
    decay = _zeros((g, nc, q), jnp.float32)
    s0 = _zeros((g, p, n), jnp.float32)
    if isinstance(desc, SsdChunkBwdDescriptor):
        states = _zeros((g, nc, p, n), jnp.float32)
        dy = _zeros((g, nc, q, p), jnp.float32)
        dsf = _zeros((g, p, n), jnp.float32)
        return (c, c, l, xdt, decay, decay, states, dy, dsf), {}
    return (c, c, l, xdt, decay, decay, s0), {}


def synth_operands(
        desc: KernelDescriptor) -> Optional[Tuple[tuple, dict]]:
    """Zero-filled operands + keywords driving one ``execute()``.

    Returns ``None`` for descriptors warmup cannot synthesize operands
    for (mesh descriptors need the shard_map capacity-slot layout and a
    live device mesh) — the caller then warms the plan tier only.
    """
    if getattr(desc, "mesh", None) is not None:
        return None
    if isinstance(desc, GemmDescriptor):
        return _gemm_operands(desc)
    if isinstance(desc, FlashDescriptor):
        return _flash_operands(desc)
    if isinstance(desc, FlashDecodeDescriptor):
        return _decode_operands(desc)
    if isinstance(desc, GroupedGemmDescriptor):
        return _grouped_operands(desc)
    if isinstance(desc, SsdChunkDescriptor):
        return _ssd_operands(desc)
    if isinstance(desc, TransposeDescriptor):
        shape = ((desc.batch, desc.rows, desc.cols) if desc.batch
                 else (desc.rows, desc.cols))
        return (_zeros(shape, _dt(desc.dtype)),), {}
    return None
