"""Register/VMEM blocking planner — the paper's §IV-B adapted to TPU.

The paper's code generator owns a *palette* of accumulator register
blockings for the 4 KiB ZA array — 32x32, 16x64, 64x16 — and covers a
ragged output matrix C with a *heterogeneous* mix of them so that the
number of microkernel executions is minimized (Fig 7: 7 executions instead
of 10 for an 80x80 C), with predicate-masked edges.

On TPU the accumulator lives in VMEM and is fed by the 128x128 MXU, so the
palette is a set of (bm, bn) VMEM accumulator blocks under a fixed element
budget (the ZA-capacity analogue), aligned to the native register tiling
(sublane x 128 lanes) and ideally to the MXU edge (128).  The cost model is
the paper's, re-derived for a systolic unit:

  * every accumulator update of a (bm, bn) block with a K-panel of depth bk
    loads (bm + bn) * bk input elements — maximizing bm*bn/(bm+bn) is the
    paper's argument for square blocks (32x32 loads 64 values/update,
    16x64 loads 80);
  * masked (edge) blocks issue bm*bn MACs but only use rows*cols of them —
    utilization of the systolic array replaces predicated-lane occupancy;
  * each block execution has a fixed grid-step overhead (the analogue of
    the paper's per-microkernel-invocation cost that motivates Fig 7).

``plan_gemm`` returns a :class:`BlockingPlan`: a list of :class:`Region`
covers (interior / bottom strip / right strip / corner), each of which maps
onto one shape-specialized ``pallas_call`` in ``repro.kernels.gemm``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp

from .descriptor import (BIAS_EPILOGUES, FlashBwdDescriptor,
                         FlashDecodeDescriptor, FlashDescriptor,
                         GemmDescriptor, GroupedGemmBwdDescriptor,
                         GroupedGemmDescriptor, SsdChunkBwdDescriptor,
                         SsdChunkDescriptor, TransposeDescriptor)
from . import machine as machine_mod
from .machine import MachineModel, DEFAULT_MACHINE
# The flattening/predication machinery lives in the schedule layer
# (DESIGN.md §9); re-exported here for compatibility — plans *produce*
# schedules, so blocking is the schedule layer's only upstream.
from .schedule import (DecodeTileSchedule, FlashTileSchedule,  # noqa: F401
                       GroupedTileSchedule, TileSchedule, ceil_div,
                       flash_tile_schedule, flatten_regions, plan_launches,
                       round_up)

# ---------------------------------------------------------------------------
# Palette
# ---------------------------------------------------------------------------

# Accumulator element budget per kernel instance.  ZA analogue: M4 has
# 1024 fp32 accumulator elements; v5e's VMEM comfortably holds 64k fp32
# accumulator elements (256 KiB) next to double-buffered input blocks.
ACC_BUDGET_ELEMS = 256 * 256

# Candidate block edge lengths.  bn must be lane-aligned (128); bm is
# sublane-aligned with MXU-aligned values preferred.
_BM_CANDIDATES = (8, 16, 32, 64, 128, 256, 512)
_BN_CANDIDATES = (128, 256, 512, 1024)

# Per-microkernel/grid-step launch cost now lives on the machine model
# (``machine.step_overhead_s``) so calibration can replace the pinned
# default with the measured dispatch latency (DESIGN.md §7).


def palette(budget: int = ACC_BUDGET_ELEMS,
            machine: MachineModel = DEFAULT_MACHINE,
            dtype: str = "float32") -> List[Tuple[int, int]]:
    """All legal (bm, bn) accumulator blockings under ``budget`` elements.

    Mirrors the paper's {32x32, 16x64, 64x16}: the full-budget shapes here
    are {256x256, 128x512, 512x128} plus sub-budget shapes used for small
    or ragged problems (where the paper would mask most of a tile).
    """
    sub, lane = machine.reg_tile(dtype)
    shapes = []
    for bm in _BM_CANDIDATES:
        if bm % sub:
            continue
        for bn in _BN_CANDIDATES:
            if bn % lane:
                continue
            if bm * bn > budget:
                continue
            shapes.append((bm, bn))
    return shapes


# ---------------------------------------------------------------------------
# Plan datatypes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Region:
    """A rectangular sub-block of C covered with a single blocking."""

    row0: int
    col0: int
    rows: int
    cols: int
    bm: int
    bn: int

    @property
    def grid(self) -> Tuple[int, int]:
        return (ceil_div(self.rows, self.bm), ceil_div(self.cols, self.bn))

    @property
    def num_microkernels(self) -> int:
        gm, gn = self.grid
        return gm * gn

    def issued_macs(self, k: int) -> int:
        gm, gn = self.grid
        return gm * self.bm * gn * self.bn * k

    def useful_macs(self, k: int) -> int:
        return self.rows * self.cols * k

    def input_elems(self, k: int) -> int:
        """Input traffic: paper's loads-per-update metric summed over blocks."""
        gm, gn = self.grid
        return (gm * gn) * (self.bm + self.bn) * k


@dataclasses.dataclass(frozen=True)
class BlockingPlan:
    """Planned heterogeneous region cover of one GEMM descriptor (§IV-B,
    Fig 7): the regions, the uniform K-panel depth ``bk``, and the
    ``fused`` execution-path bit (DESIGN.md §8)."""

    desc: GemmDescriptor
    regions: Tuple[Region, ...]
    bk: int
    heterogeneous: bool
    # Execute the whole plan (regions + batch) in ONE pallas_call via the
    # flattened tile schedule (DESIGN.md §8) instead of one launch per
    # region stitched with dynamic_slice / dynamic_update_slice.
    fused: bool = False
    # Provenance: "model" (analytical planner) or "autotuned" (empirically
    # timed winner, fresh or replayed from the tuning cache — DESIGN.md §7).
    plan_source: str = "model"
    # Mesh strategy (DESIGN.md §14), set only when desc.mesh is: "gathered"
    # (all-gather the sharded weights, compute the whole problem locally)
    # or "distributed" (keep weight shards, move activations/outputs).
    # The regions/bk knobs then describe the per-shard local sub-problem
    # (``mesh_local_desc``), not the global descriptor.
    comm: Optional[str] = None

    # ---- aggregate stats (paper Fig 7 metrics) -------------------------
    @property
    def num_microkernels(self) -> int:
        return sum(r.num_microkernels for r in self.regions)

    @property
    def utilization(self) -> float:
        k = self.desc.k
        issued = sum(r.issued_macs(k) for r in self.regions)
        useful = sum(r.useful_macs(k) for r in self.regions)
        return useful / max(1, issued)

    @property
    def input_elems(self) -> int:
        return sum(r.input_elems(self.desc.k) for r in self.regions)

    def predicted_seconds(self, machine: MachineModel = DEFAULT_MACHINE) -> float:
        local, comm_s = self.desc, 0.0
        if self.desc.mesh is not None and self.comm is not None:
            local = mesh_local_desc(self.desc, self.comm)
            comm_s = mesh_comm_seconds(self.desc, machine, self.comm)
        return _predict_seconds(self.regions, local, self.bk, machine,
                                fused=self.fused) + comm_s

    def tile_schedule(self) -> TileSchedule:
        """Flatten the region cover into the fused kernel's tile tables
        (delegates to the schedule layer, DESIGN.md §9).  For a mesh plan
        the schedule covers the per-shard local sub-problem — execution
        happens per shard (DESIGN.md §14)."""
        desc = self.desc
        if desc.mesh is not None and self.comm is not None:
            desc = mesh_local_desc(desc, self.comm)
        return flatten_regions(desc.m, desc.n, desc.k, self.bk, self.regions)

    def validate(self):
        """Every C element covered exactly once (tested by hypothesis)."""
        cover = {}
        for ri, r in enumerate(self.regions):
            for i in (r.row0, r.row0 + r.rows - 1):
                for j in (r.col0, r.col0 + r.cols - 1):
                    assert 0 <= i < self.desc.m and 0 <= j < self.desc.n, (r, self.desc)
        total = sum(r.rows * r.cols for r in self.regions)
        assert total == self.desc.m * self.desc.n, (
            f"cover mismatch: {total} vs {self.desc.m * self.desc.n}")
        # overlap check on region rectangles
        rects = [(r.row0, r.col0, r.row0 + r.rows, r.col0 + r.cols) for r in self.regions]
        for i in range(len(rects)):
            for j in range(i + 1, len(rects)):
                a, b = rects[i], rects[j]
                if not (a[2] <= b[0] or b[2] <= a[0] or a[3] <= b[1] or b[3] <= a[1]):
                    raise AssertionError(f"regions overlap: {a} {b}")
        return True


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

# Calibration against BENCH_gemm_fused.json (measured fused/multi deltas).
# The bench showed the previous model over-charged the multi-launch path
# (fused vs multi predicted identically for single-region plans, yet fused
# measured 0.79x at nn_128 and 0.82x at hetero_640): fused execution is
# not free — every grid step decodes a tile-table row and the accumulator
# read-modify-writes its output window — while the measured multi-launch
# dispatch + stitch overhead is ~4x smaller than the model charged.
# These coefficients now live on :class:`MachineModel` so the offline
# refit pipeline (``tools/tune.py refit``, DESIGN.md §15) can replace the
# hand calibration with a least-squares fit of TuningCache timings; the
# module aliases keep the seed values importable.
FUSED_TILE_DECODE_S = machine_mod.DEFAULT_FUSED_TILE_DECODE_S
EXTRA_LAUNCH_FACTOR = machine_mod.DEFAULT_EXTRA_LAUNCH_FACTOR
STITCH_DISCOUNT = machine_mod.DEFAULT_STITCH_DISCOUNT


def _predict_seconds(regions: Sequence[Region], desc: GemmDescriptor, bk: int,
                     machine: MachineModel, fused: bool = False) -> float:
    """Napkin-math time model used to rank candidate plans.

    Four terms, mirroring the roofline decomposition used throughout the
    system: systolic compute on *issued* MACs (masked lanes still occupy
    the MXU — the SME predicate analogue), HBM traffic for inputs + C,
    per-grid-step overhead, and per-``pallas_call`` dispatch overhead.
    The fused path (DESIGN.md §8) pays dispatch once but adds per-step
    tile-table decode plus the accumulator's output-window re-read
    (read-modify-write); the multi-launch path pays dispatch per region
    plus the inter-region stitching traffic (``dynamic_slice`` operand
    copies and the ``zeros`` + ``dynamic_update_slice`` assembly of C).
    Both extras are calibrated against BENCH_gemm_fused.json.
    """
    k = desc.k
    # Wire itemsizes: under a quant spec (DESIGN.md §13) the staged
    # operands are the narrow dtype — the planner charges the bytes that
    # actually move, which is the whole point of the low-precision axis.
    a_sz = desc.a_wire_itemsize
    b_sz = desc.b_wire_itemsize
    out_sz = jnp.dtype(desc.out_dtype).itemsize
    issued = sum(r.issued_macs(k) for r in regions)
    compute_s = 2.0 * issued / machine.peak(desc.compute_dtype)
    traffic = sum(r.num_microkernels * (r.bm * a_sz + r.bn * b_sz) * k
                  for r in regions)
    out_elems = sum(r.rows * r.cols for r in regions)
    traffic += out_elems * out_sz * (2 if desc.accumulate else 1)
    memory_s = traffic / machine.hbm_bw
    steps = sum(r.num_microkernels for r in regions) * ceil_div(k, bk)
    launches = 1 if fused else len(regions)
    launch_s = machine.launch_overhead_s * (
        1 + (launches - 1) * machine.extra_launch_factor)
    stitch_s = 0.0
    fused_s = 0.0
    if fused:
        # Table decode per step plus the RMW re-read of each output window.
        fused_s = (steps * machine.fused_tile_decode_s
                   + out_elems * out_sz / machine.hbm_bw)
    elif len(regions) > 1:
        # Operand slices are copied in and region outputs copied out again
        # when stitching C — traffic the fused path never generates.
        stitch_bytes = sum((r.rows * a_sz + r.cols * b_sz) * k
                           for r in regions)
        stitch_bytes += 2 * out_elems * out_sz
        stitch_s = machine.stitch_discount * stitch_bytes / machine.hbm_bw
    # compute and memory overlap in the pipelined kernel: take max + overhead
    return (max(compute_s, memory_s) + steps * machine.step_overhead_s
            + launch_s + stitch_s + fused_s)


def _pick_bk(desc: GemmDescriptor, bm: int, bn: int,
             machine: MachineModel) -> int:
    """Largest K-panel depth whose double-buffered blocks fit VMEM.

    VMEM budget: acc (bm*bn fp32) + 2*(bm*bk + bk*bn) inputs.  The paper's
    analogue is the two Z-register pairs feeding FMOPA; on TPU deeper
    panels amortize the systolic pipeline, so we take the largest aligned
    bk <= K subject to VMEM.
    """
    acc_bytes = bm * bn * 4
    budget = machine.vmem_bytes // 2 - acc_bytes  # conservative half-VMEM
    if budget <= 0:
        return machine.lanes
    bk_max = budget // (2 * (desc.a_wire_itemsize * bm
                             + desc.b_wire_itemsize * bn))
    sub, lane = machine.reg_tile(desc.in_dtype)
    bk = max(lane, (bk_max // lane) * lane)
    bk = min(bk, round_up(desc.k, lane), 2048)
    return bk


# ---------------------------------------------------------------------------
# Mesh-aware communication model (DESIGN.md §14)
# ---------------------------------------------------------------------------
# A mesh descriptor (``desc.mesh is not None``) describes the GLOBAL
# problem with the weight operand sharded over ``mesh.axis``.  Each
# execution strategy reduces it to a per-shard local sub-problem plus a
# set of collectives; the planner charges both — compute/launch/stitch
# on the local descriptor via the family cost model, communication via
# ``machine.collective_seconds`` — so gathered-vs-distributed is ranked
# by the same napkin-math discipline as every tiling knob.

MESH_STRATEGIES = ("gathered", "distributed")


def mesh_local_desc(desc, comm: str):
    """The per-shard local sub-problem one strategy actually executes.

    grouped_gemm — activations token-sharded over the axis:
      * gathered: all-gather the expert weights, run the full expert set
        over the local token shard (t/s tokens, all E experts);
      * distributed: keep weight shards, all_to_all tokens to their
        expert's owner (t/s tokens, E/s local experts — capacity-uniform
        routing moves exactly the local rows).
    gemm — B column-sharded over the axis:
      * gathered: all-gather B, compute the full (m, n) locally;
      * distributed: keep the B shard, compute (m, n/s), all-gather the
        output columns.
    """
    if desc.mesh is None:
        return desc
    if comm not in MESH_STRATEGIES:
        raise ValueError(f"unknown mesh strategy {comm!r}")
    s = desc.mesh.size
    if isinstance(desc, GroupedGemmDescriptor):
        if comm == "gathered":
            return dataclasses.replace(desc, t=desc.t // s, mesh=None)
        return dataclasses.replace(desc, t=desc.t // s,
                                   num_experts=desc.num_experts // s,
                                   mesh=None)
    if comm == "gathered":
        return dataclasses.replace(desc, mesh=None)
    return dataclasses.replace(desc, n=desc.n // s, mesh=None)


def mesh_comm_events(desc, comm: str) -> Tuple[Tuple[str, int], ...]:
    """``((collective, per-device payload bytes), ...)`` one strategy
    issues around the local kernel.  Payloads follow the probe accounting
    in ``core.microbench``: bytes each device sends/receives, with the
    ring (s-1)/s factor folded in."""
    if desc.mesh is None or desc.mesh.size == 1:
        return ()
    s = desc.mesh.size
    frac = (s - 1) / s
    if isinstance(desc, GroupedGemmDescriptor):
        isz = jnp.dtype(desc.dtype).itemsize
        if comm == "gathered":
            w_sz = getattr(desc, "w_wire_itemsize", isz)
            return (("all_gather",
                     int(frac * desc.num_experts * desc.k * desc.n * w_sz)),)
        t_loc = desc.t // s
        return (("all_to_all", int(frac * t_loc * desc.k * isz)),
                ("all_to_all", int(frac * t_loc * desc.n * isz)))
    out_sz = jnp.dtype(desc.out_dtype).itemsize
    if comm == "gathered":
        return (("all_gather", int(frac * desc.k * desc.n
                                   * desc.b_wire_itemsize)),)
    return (("all_gather", int(frac * desc.m * desc.n * out_sz)),)


def mesh_comm_seconds(desc, machine: MachineModel, comm: str) -> float:
    """Total modeled communication time of one strategy under ``machine``
    (honest when network-calibrated, link-spec napkin math otherwise)."""
    return sum(machine.collective_seconds(nbytes, collective=c)
               for c, nbytes in mesh_comm_events(desc, comm))


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def fused_legal(desc: GemmDescriptor,
                machine: MachineModel = DEFAULT_MACHINE) -> bool:
    """Can this GEMM run as one fused ``pallas_call`` (DESIGN.md §8)?

    The fused kernel stages the whole per-batch-element operands (plus the
    output and the accumulator scratch) in VMEM and slides tile windows
    over them in-kernel, so it is only legal when they all fit.  Batch is a
    grid dimension — only one batch slice is resident at a time.
    """
    out_sz = jnp.dtype(desc.out_dtype).itemsize
    need = (desc.m * desc.k * desc.a_wire_itemsize
            + desc.k * desc.n * desc.b_wire_itemsize)
    if desc.quant is not None:
        # staged scale operands: sa (m, 1) + sb (1, n), f32
        need += (desc.m + desc.n) * 4
    need += desc.m * desc.n * out_sz * (2 if desc.accumulate else 1)
    need += ACC_BUDGET_ELEMS * 4  # accumulator scratch upper bound
    return need <= machine.vmem_bytes


def plan_gemm(desc: GemmDescriptor,
              machine: MachineModel = DEFAULT_MACHINE,
              budget: int = ACC_BUDGET_ELEMS,
              heterogeneous: bool = True,
              force_block: Optional[Tuple[int, int]] = None) -> BlockingPlan:
    """Produce the blocking plan for one GEMM descriptor.

    ``heterogeneous=False`` reproduces the paper's baseline (Fig 7 left):
    one blocking tiles the whole matrix.  ``force_block`` pins the primary
    blocking (used by benchmarks and the perf hillclimb).  The analytical
    planner takes the paper's stance on dispatch: one kernel per GEMM —
    plans come out ``fused`` whenever the operands fit VMEM
    (:func:`fused_legal`); the autotuner refines that choice empirically.

    A mesh descriptor is planned per strategy (DESIGN.md §14): the local
    sub-problem of each strategy gets its own blocking, and the cheaper
    compute + communication total wins, recorded in ``plan.comm``.
    """
    if desc.mesh is not None:
        best = None
        for comm in MESH_STRATEGIES:
            p = plan_gemm(mesh_local_desc(desc, comm), machine, budget,
                          heterogeneous, force_block)
            p = dataclasses.replace(p, desc=desc, comm=comm)
            if best is None or (p.predicted_seconds(machine)
                                < best.predicted_seconds(machine)):
                best = p
        return best
    m, n = desc.m, desc.n
    shapes = palette(budget, machine, desc.in_dtype)
    fused = fused_legal(desc, machine)

    if force_block is not None:
        primary = force_block
    else:
        primary = _best_homogeneous(m, n, shapes, desc, machine)

    if not heterogeneous:
        regions = (Region(0, 0, m, n, *primary),)
        bk = _pick_bk(desc, *primary, machine)
        plan = BlockingPlan(desc, regions, bk, heterogeneous=False,
                            fused=fused)
        return plan

    regions = _heterogeneous_cover(m, n, primary, shapes, desc, machine)
    # Compare against the best homogeneous plan and keep the cheaper one —
    # for aligned shapes the interior cover *is* the homogeneous plan.
    bk = _pick_bk(desc, *primary, machine)
    plan = BlockingPlan(desc, tuple(regions), bk,
                        heterogeneous=len(regions) > 1, fused=fused)
    homo = BlockingPlan(desc, (Region(0, 0, m, n, *primary),), bk, False,
                        fused=fused)
    if homo.predicted_seconds(machine) < plan.predicted_seconds(machine):
        plan = homo
    # Multi-region covers pay the fused walk's per-step tile decode on
    # every region's tiles; BENCH_gemm_fused.json measured hetero shapes
    # where the stitched multi-launch path wins (hetero_640 at 0.848x).
    # The paper's one-kernel stance holds for single-region plans only —
    # for multi-region winners, compare both lowerings under the model.
    if plan.fused and len(plan.regions) > 1:
        multi = dataclasses.replace(plan, fused=False)
        if multi.predicted_seconds(machine) < plan.predicted_seconds(machine):
            plan = multi
    return plan


def _best_homogeneous(m: int, n: int, shapes, desc, machine) -> Tuple[int, int]:
    best, best_t = None, float("inf")
    for bm, bn in shapes:
        # Skip grossly oversized blocks (all-masked) unless nothing smaller.
        region = Region(0, 0, m, n, bm, bn)
        bk = _pick_bk(desc, bm, bn, machine)
        t = _predict_seconds([region], desc, bk, machine)
        if t < best_t:
            best, best_t = (bm, bn), t
    assert best is not None
    return best


def _strip_block(extent_major: int, extent_minor: int, shapes,
                 major_axis: int) -> Tuple[int, int]:
    """Pick the palette block for an edge strip.

    ``major_axis`` = 0 for the bottom strip (few rows, many cols: paper's
    16x64 analogue) and 1 for the right strip (64x16 analogue).  Choose the
    smallest block edge covering the strip thickness (minimum masking) and
    the largest perpendicular edge (minimum invocations).
    """
    best = None
    # minimal covering thickness
    thick_opts = sorted({s[major_axis] for s in shapes})
    cover = [t for t in thick_opts if t >= extent_major]
    thickness = cover[0] if cover else thick_opts[-1]
    spans = [s[1 - major_axis] for s in shapes if s[major_axis] == thickness]
    span = max(spans)
    best = (thickness, span) if major_axis == 0 else (span, thickness)
    return best


def _heterogeneous_cover(m, n, primary, shapes, desc, machine) -> List[Region]:
    bm0, bn0 = primary
    m_full, n_full = m // bm0, n // bn0
    mi, ni = m_full * bm0, n_full * bn0
    regions: List[Region] = []
    if m_full and n_full:
        regions.append(Region(0, 0, mi, ni, bm0, bn0))
    rem_m, rem_n = m - mi, n - ni
    if rem_m and ni:
        bm_s, bn_s = _strip_block(rem_m, ni, shapes, major_axis=0)
        regions.append(Region(mi, 0, rem_m, ni, bm_s, bn_s))
    if rem_n and mi:
        bm_s, bn_s = _strip_block(rem_n, mi, shapes, major_axis=1)
        regions.append(Region(0, ni, mi, rem_n, bm_s, bn_s))
    if rem_m and rem_n:
        bm_c, bn_c = _corner_block(rem_m, rem_n, shapes)
        regions.append(Region(mi, ni, rem_m, rem_n, bm_c, bn_c))
    if not regions:  # degenerate: matrix smaller than every block
        bm_c, bn_c = _corner_block(m, n, shapes)
        regions.append(Region(0, 0, m, n, bm_c, bn_c))
    return regions


def _corner_block(rows, cols, shapes) -> Tuple[int, int]:
    """Smallest palette block covering the (masked) corner."""
    covering = sorted(shapes, key=lambda s: (ceil_div(rows, s[0]) * ceil_div(cols, s[1]),
                                             s[0] * s[1]))
    return covering[0]


# ---------------------------------------------------------------------------
# Non-GEMM family planners
# ---------------------------------------------------------------------------
# Same discipline as plan_gemm: enumerate machine-legal tilings, rank them
# under the max(compute, memory) + per-step-overhead cost model, return a
# frozen plan.  These replace the hardcoded constants the kernel wrappers
# used to carry (block_q=512, bm=128/bk=512/bn=256, bt=256).

def _tile_candidates(extent: int, align: int, lo: int = 64,
                     hi: int = 1024) -> List[int]:
    """Aligned power-of-two tile edges covering [lo, hi], clipped to extent.

    An edge >= extent collapses to the aligned cover of extent itself, so
    small problems get exactly one full tile instead of a masked giant.
    """
    cands = set()
    t = lo
    while t <= hi:
        cands.add(min(t, round_up(extent, align)) if t >= extent else t)
        t *= 2
    return sorted(c for c in cands if c % align == 0 or c >= extent)


@dataclasses.dataclass(frozen=True)
class FlashPlan:
    """Planned (block_q, block_k) tiling of one flash attention descriptor.

    ``fused`` selects the scheduled single-launch lowering (DESIGN.md
    §10): the causal-aware tile table drops fully-masked k-blocks at
    plan time and ONE ``pallas_call`` walks it; the non-fused fallback is
    the dense-grid kernel that skips masked tiles with a run-time branch.
    """

    desc: FlashDescriptor
    block_q: int
    block_k: int
    # Execute via the flattened causal-aware tile table in ONE pallas_call
    # over staged whole operands (DESIGN.md §10); mirrors BlockingPlan.fused.
    fused: bool = False
    plan_source: str = "model"  # see BlockingPlan.plan_source

    def tile_schedule(self) -> FlashTileSchedule:
        """Flatten the (q, k) walk into the fused kernel's tile table
        (delegates to the schedule layer, DESIGN.md §10)."""
        d = self.desc
        return flash_tile_schedule(d.sq, d.sk, self.block_q, self.block_k,
                                   d.causal)

    def predicted_seconds(self, machine: MachineModel = DEFAULT_MACHINE) -> float:
        """Cost-model estimate under ``machine`` (see
        :func:`_predict_flash_seconds`)."""
        return _predict_flash_seconds(self.desc, self.block_q, self.block_k,
                                      machine, fused=self.fused)


def flash_fused_legal(desc: FlashDescriptor,
                      machine: MachineModel = DEFAULT_MACHINE) -> bool:
    """Can this flash attention run as one scheduled ``pallas_call``?

    The fused kernel stages one batch-head slice of q/k/v and the output
    whole in VMEM (clamped ragged windows need element-granular origins,
    which BlockSpec block indices cannot express) and slides tile windows
    over them in-kernel; legal only when they fit next to the per-tile
    score/carry scratch."""
    isz = jnp.dtype(desc.dtype).itemsize
    need = (2 * desc.sq + 2 * desc.sk) * desc.d * isz  # q + out + k + v
    return need <= machine.vmem_bytes // 2


def _predict_flash_seconds(desc: FlashDescriptor, bq: int, bk: int,
                           machine: MachineModel,
                           fused: bool = False) -> float:
    """Napkin-math time model for one flash tiling (both lowerings).

    Causal skips tiles strictly above the diagonal — the heterogeneous-
    cover idea applied to the triangle.  The fused lowering only *walks*
    active tiles (the table drops the rest at plan time), while the
    dense-grid fallback pays grid-step overhead on every (q, k) pair and
    merely branches the masked ones' compute away; both pay one launch.
    """
    cq, ck = ceil_div(desc.sq, bq), ceil_div(desc.sk, bk)
    if desc.causal:
        active = sum(min(ck, ceil_div((qi + 1) * bq, bk)) for qi in range(cq))
    else:
        active = cq * ck
    steps = desc.batch_heads * (active if fused else cq * ck)
    # Issued MACs: tiles are padded to (bq, bk) — masked lanes still occupy
    # the MXU (the SME predicate analogue).
    issued = 4 * desc.batch_heads * active * bq * bk * desc.d
    compute_s = issued / machine.peak(desc.dtype)
    isz = jnp.dtype(desc.dtype).itemsize
    if fused:
        # Whole q/k/v staged once per batch-head slice; output written once.
        traffic = desc.in_bytes + desc.out_bytes
    else:
        # Each active step streams one K and one V tile; Q tiles stream
        # once per q-row of active tiles; output written once.
        traffic = desc.batch_heads * active * 2 * bk * desc.d * isz
        traffic += desc.batch_heads * cq * bq * desc.d * isz
        traffic += desc.out_bytes
    memory_s = traffic / machine.hbm_bw
    return (max(compute_s, memory_s) + steps * machine.step_overhead_s
            + machine.launch_overhead_s)


def _flash_legal(desc: FlashDescriptor,
                 machine: MachineModel) -> List[Tuple[int, int]]:
    """All VMEM-legal (block_q, block_k) pairs for one flash descriptor."""
    sub, lane = machine.reg_tile(desc.dtype)
    isz = jnp.dtype(desc.dtype).itemsize
    legal = []
    for bq in _tile_candidates(desc.sq, sub):
        for bk in _tile_candidates(desc.sk, lane):
            # VMEM: q tile + k/v tiles (double-buffered) + fp32 scratch
            # (score tile, running max/denom, output accumulator).
            vmem = (bq * desc.d + 2 * 2 * bk * desc.d) * isz
            vmem += (bq * bk + 2 * bq + bq * desc.d) * 4
            if vmem > machine.vmem_bytes // 2:
                continue
            legal.append((bq, bk))
    if not legal:  # head dim so large nothing fits: minimal legal tiles
        legal.append((sub, lane))
    return legal


def plan_flash(desc: FlashDescriptor,
               machine: MachineModel = DEFAULT_MACHINE) -> FlashPlan:
    """Pick (block_q, block_k) from VMEM/MXU constraints + the cost model.

    Like ``plan_gemm``, the analytical planner takes the paper's stance
    on dispatch: plans come out ``fused`` (single scheduled launch over
    the causal-aware tile table) whenever the staged operands fit VMEM
    (:func:`flash_fused_legal`); the autotuner refines empirically.
    """
    fused = flash_fused_legal(desc, machine)
    best = min(_flash_legal(desc, machine),
               key=lambda s: _predict_flash_seconds(desc, *s, machine=machine,
                                                    fused=fused))
    return FlashPlan(desc, *best, fused=fused)


@dataclasses.dataclass(frozen=True)
class FlashDecodePlan:
    """Plan of one paged decode-attention step (DESIGN.md §12).

    The page size *is* the k-block (the pool layout fixed it at cache
    construction), so the only planning freedom is the schedule itself;
    like the grouped family, the plan is always ``fused`` — the ragged
    page walk happens inside ONE ``pallas_call`` riding runtime tables,
    and the non-fused alternative is the model-level XLA gather path
    that never enters the engine."""

    desc: FlashDecodeDescriptor
    fused: bool = True
    plan_source: str = "model"  # see BlockingPlan.plan_source

    def tile_schedule(self) -> DecodeTileSchedule:
        """The runtime-table schedule this step walks (one row per live
        KV page, plus the per-slot dummy floor)."""
        d = self.desc
        return DecodeTileSchedule(num_seqs=d.num_seqs, pages=d.pages,
                                  page_size=d.page_size,
                                  max_blocks=d.max_blocks)

    def predicted_seconds(self, machine: MachineModel = DEFAULT_MACHINE
                          ) -> float:
        """Napkin-math step time: every walked tile issues a full
        (h, page_size, hd) MAC pair; traffic streams each live page once
        plus the q/out rows and the prefetch tables."""
        d = self.desc
        steps = self.tile_schedule().max_tiles
        compute_s = d.flops / machine.peak(d.dtype)
        memory_s = (d.in_bytes + d.out_bytes) / machine.hbm_bw
        return (max(compute_s, memory_s) + steps * machine.step_overhead_s
                + machine.launch_overhead_s)


def plan_flash_decode(desc: FlashDecodeDescriptor,
                      machine: MachineModel = DEFAULT_MACHINE
                      ) -> FlashDecodePlan:
    """Single-lowering planner: the pool geometry fixed every knob at
    cache construction, so the plan only packages the schedule."""
    return FlashDecodePlan(desc)


@dataclasses.dataclass(frozen=True)
class GroupedGemmPlan:
    """Planned (bm, bk, bn) tiling of one ragged grouped GEMM, plus the
    ``fused`` execution-path bit (scheduled single launch vs pad/scatter
    — DESIGN.md §9)."""

    desc: GroupedGemmDescriptor
    bm: int
    bk: int
    bn: int
    # Execute the ragged dispatch as ONE pallas_call walking runtime tile
    # tables (DESIGN.md §9) instead of the host-side pad/scatter +
    # gather-back lowering.  Mirrors BlockingPlan.fused.
    fused: bool = False
    plan_source: str = "model"  # see BlockingPlan.plan_source
    comm: Optional[str] = None  # mesh strategy — see BlockingPlan.comm

    @property
    def local_desc(self) -> GroupedGemmDescriptor:
        """The per-shard sub-problem this plan's knobs describe: the
        descriptor itself off-mesh, ``mesh_local_desc`` under a mesh
        strategy (DESIGN.md §14)."""
        if self.desc.mesh is not None and self.comm is not None:
            return mesh_local_desc(self.desc, self.comm)
        return self.desc

    @property
    def t_padded(self) -> int:
        """Static row bound of the pad/scatter lowering: T rounded up plus
        per-group padding room."""
        d = self.local_desc
        return round_up(d.t, self.bm) + d.num_experts * self.bm

    def tile_schedule(self) -> GroupedTileSchedule:
        """The static geometry of the fused lowering (DESIGN.md §9); the
        tables themselves are runtime data built from ``group_sizes``.
        For a mesh plan this is the per-shard schedule — the fused
        single-launch property holds per shard (DESIGN.md §14)."""
        d = self.local_desc
        return GroupedTileSchedule(
            t=d.t, k=d.k, n=d.n, num_experts=d.num_experts,
            bm=min(self.bm, d.t), bk=min(self.bk, d.k), bn=min(self.bn, d.n))

    def predicted_seconds(self, machine: MachineModel = DEFAULT_MACHINE) -> float:
        comm_s = 0.0
        if self.desc.mesh is not None and self.comm is not None:
            comm_s = mesh_comm_seconds(self.desc, machine, self.comm)
        return _predict_grouped_seconds(self.local_desc, self.bm, self.bk,
                                        self.bn, machine,
                                        fused=self.fused) + comm_s


def grouped_fused_legal(desc: GroupedGemmDescriptor,
                        machine: MachineModel = DEFAULT_MACHINE) -> bool:
    """Can this grouped GEMM run as one scheduled ``pallas_call``?

    The fused kernel stages the whole token block and output in VMEM
    (clamped row windows need element-granular origins, which BlockSpec
    block indices cannot express) plus one double-buffered expert weight
    panel; legal only when they all fit.
    """
    isz = jnp.dtype(desc.dtype).itemsize
    x_sz = getattr(desc, "x_wire_itemsize", isz)
    w_sz = getattr(desc, "w_wire_itemsize", isz)
    need = desc.t * desc.k * x_sz + desc.t * desc.n * isz
    need += 2 * desc.k * desc.n * w_sz  # double-buffered expert panel
    if getattr(desc, "quant", None) is not None:
        # staged scale operands: sx (t, 1) whole + one sw expert row, f32
        need += (desc.t + desc.n) * 4
    need += ACC_BUDGET_ELEMS * 4       # accumulator scratch upper bound
    return need <= machine.vmem_bytes


def _predict_grouped_seconds(desc: GroupedGemmDescriptor, bm: int, bk: int,
                             bn: int, machine: MachineModel,
                             fused: bool = False) -> float:
    isz = jnp.dtype(desc.dtype).itemsize
    # Wire itemsizes / compute dtype (quant axis, DESIGN.md §13): backward
    # descriptors carry no quant spec and fall back to the wide dtype.
    x_sz = getattr(desc, "x_wire_itemsize", isz)
    w_sz = getattr(desc, "w_wire_itemsize", isz)
    compute_dt = getattr(desc, "compute_dtype", desc.dtype)
    gn = ceil_div(desc.n, bn)
    gk = ceil_div(desc.k, bk)
    if fused:
        # Ragged row blocks: each expert may add one partial block, plus
        # the zero-fill tail; no padded intermediate, no gather.
        gm = ceil_div(desc.t, bm) + desc.num_experts + 1
        stitch_s = 0.0
    else:
        # Pad/scatter lowering: padded rows still issue MACs, and the
        # scatter-in + gather-back copies are traffic the fused path
        # never generates.
        t_padded = round_up(desc.t, bm) + desc.num_experts * bm
        gm = ceil_div(t_padded, bm)
        stitch_bytes = 2 * desc.t * desc.k * isz          # scatter x
        stitch_bytes += (gm * bm + desc.t) * desc.n * isz  # gather out
        stitch_s = stitch_bytes / machine.hbm_bw
    steps = gm * gn * gk
    issued = 2 * gm * bm * gn * bn * desc.k
    compute_s = issued / machine.peak(compute_dt)
    traffic = (steps * (bm * bk * x_sz + bk * bn * w_sz)
               + gm * bm * desc.n * isz)
    memory_s = traffic / machine.hbm_bw
    return (max(compute_s, memory_s) + steps * machine.step_overhead_s
            + machine.launch_overhead_s + stitch_s)


def _grouped_legal(desc: GroupedGemmDescriptor,
                   machine: MachineModel) -> List[Tuple[int, int, int]]:
    """All VMEM-legal (bm, bk, bn) triples for one grouped descriptor."""
    sub, lane = machine.reg_tile(desc.dtype)
    isz = jnp.dtype(desc.dtype).itemsize
    legal = []
    for bm in _tile_candidates(desc.t, sub, lo=sub):
        for bn in _tile_candidates(desc.n, lane, lo=lane):
            for bk in _tile_candidates(desc.k, lane, lo=lane):
                vmem = bm * bn * 4 + 2 * (bm * bk + bk * bn) * isz
                if vmem > machine.vmem_bytes // 2:
                    continue
                legal.append((bm, bk, bn))
    if not legal:
        legal.append((sub, lane, lane))
    return legal


def plan_grouped(desc: GroupedGemmDescriptor,
                 machine: MachineModel = DEFAULT_MACHINE) -> GroupedGemmPlan:
    """Pick (bm, bk, bn): bm trades per-group padding against grid size.

    Like ``plan_gemm``, the analytical planner takes the paper's stance on
    dispatch: plans come out ``fused`` (single scheduled launch, no
    pad/scatter) whenever the staged operands fit VMEM
    (:func:`grouped_fused_legal`); the autotuner refines empirically.

    A mesh descriptor is planned per strategy (DESIGN.md §14): gathered
    (all-gather expert weights, full expert set over the local token
    shard) vs distributed (all_to_all tokens, local expert shard); the
    cheaper compute + communication total wins, recorded in ``comm``.
    """
    if desc.mesh is not None:
        cands = [dataclasses.replace(
                     plan_grouped(mesh_local_desc(desc, comm), machine),
                     desc=desc, comm=comm)
                 for comm in MESH_STRATEGIES]
        return min(cands, key=lambda p: p.predicted_seconds(machine))
    fused = grouped_fused_legal(desc, machine)
    best = min(_grouped_legal(desc, machine),
               key=lambda s: _predict_grouped_seconds(desc, *s,
                                                      machine=machine,
                                                      fused=fused))
    return GroupedGemmPlan(desc, *best, fused=fused)


@dataclasses.dataclass(frozen=True)
class TransposePlan:
    """Planned square tile edge ``bt`` of one (batched) blocked
    transpose."""

    desc: TransposeDescriptor
    bt: int
    plan_source: str = "model"  # see BlockingPlan.plan_source

    def predicted_seconds(self, machine: MachineModel = DEFAULT_MACHINE) -> float:
        return _predict_transpose_seconds(self.desc, self.bt, machine)


def _predict_transpose_seconds(desc: TransposeDescriptor, bt: int,
                               machine: MachineModel) -> float:
    # Batch is a grid dimension of the single launch (DESIGN.md §9).
    nb = max(1, desc.batch)
    steps = nb * ceil_div(desc.rows, bt) * ceil_div(desc.cols, bt)
    isz = jnp.dtype(desc.dtype).itemsize
    traffic = 2 * steps * bt * bt * isz  # read + mirrored write, padded
    return (traffic / machine.hbm_bw + steps * machine.step_overhead_s
            + machine.launch_overhead_s)


def _transpose_legal(desc: TransposeDescriptor,
                     machine: MachineModel) -> List[int]:
    """All VMEM-legal square tile edges for one transpose descriptor."""
    sub, lane = machine.reg_tile(desc.dtype)
    isz = jnp.dtype(desc.dtype).itemsize
    extent = max(desc.rows, desc.cols)
    legal = [bt for bt in _tile_candidates(extent, max(sub, 8), lo=32)
             if 2 * bt * bt * isz <= machine.vmem_bytes // 2]
    return legal or [lane]


def plan_transpose(desc: TransposeDescriptor,
                   machine: MachineModel = DEFAULT_MACHINE) -> TransposePlan:
    """Pick the square tile edge: biggest VMEM-legal tile wins on traffic,
    smaller tiles win on ragged edges (masked-write waste)."""
    best = min(_transpose_legal(desc, machine),
               key=lambda bt: _predict_transpose_seconds(desc, bt, machine))
    return TransposePlan(desc, best)


@dataclasses.dataclass(frozen=True)
class SsdChunkPlan:
    """The SSD ladder has no free tiling knobs — the whole (Q, n/p) cell
    lives in VMEM per grid step — but the uniform plan object carries the
    VMEM-fit verdict, the ``fused`` execution-path bit (scan form only)
    and the cost estimate for the engine's accounting."""

    desc: SsdChunkDescriptor
    fits_vmem: bool
    # Scan form (desc.chunks >= 1) only: execute the whole chunked scan —
    # intra-chunk ladder AND inter-chunk recurrence — in ONE pallas_call
    # with the (p, n) state carried as accumulator scratch (DESIGN.md §10)
    # instead of the diag kernel + XLA associative-scan stitch.
    fused: bool = False
    plan_source: str = "model"  # see BlockingPlan.plan_source

    def predicted_seconds(self, machine: MachineModel = DEFAULT_MACHINE) -> float:
        """Cost-model estimate: the non-fused scan pays the XLA
        inter-chunk stitch (per-chunk state tensors written and re-read
        around the associative scan) that the carried accumulator never
        materializes."""
        d = self.desc
        compute_s = d.flops / machine.peak(d.dtype)
        memory_s = (d.in_bytes + d.out_bytes) / machine.hbm_bw
        stitch_s = 0.0
        if d.chunks and not self.fused:
            # bx / s_incl / s_prev per (group, chunk), fp32, written by one
            # XLA op and read back by the next.
            stitch_bytes = 3 * d.groups * d.chunks * d.p * d.n * 4
            stitch_s = stitch_bytes / machine.hbm_bw
        return (max(compute_s, memory_s) + d.cells * machine.step_overhead_s
                + machine.launch_overhead_s + stitch_s)


def ssd_fused_legal(desc: SsdChunkDescriptor,
                    machine: MachineModel = DEFAULT_MACHINE) -> bool:
    """Can this SSD scan run as one carried-state ``pallas_call``?

    Only the scan form has a fused lowering; it needs one chunk's cell
    operands (double-buffered) plus the fp32 carried state and score
    scratch resident in VMEM."""
    if not desc.chunks:
        return False
    isz = jnp.dtype(desc.dtype).itemsize
    per_step = (2 * desc.q * desc.n + desc.q * desc.q
                + 2 * desc.q * desc.p + 2 * desc.q) * isz
    need = 2 * per_step                      # double-buffered chunk cell
    need += (desc.q * desc.q + 2 * desc.p * desc.n) * 4  # score + state
    return need <= machine.vmem_bytes // 2


def plan_ssd(desc: SsdChunkDescriptor,
             machine: MachineModel = DEFAULT_MACHINE) -> SsdChunkPlan:
    """Plan one SSD dispatch: record the VMEM-fit verdict and, for the
    scan form, take the paper's one-kernel stance whenever the carried-
    state lowering is legal (:func:`ssd_fused_legal`)."""
    isz = jnp.dtype(desc.dtype).itemsize
    per_step = (2 * desc.q * desc.n + desc.q * desc.q + 2 * desc.q * desc.p) * isz
    per_step += desc.q * desc.q * 4  # fp32 score scratch
    return SsdChunkPlan(desc, fits_vmem=per_step <= machine.vmem_bytes // 2,
                        fused=ssd_fused_legal(desc, machine))


# ---------------------------------------------------------------------------
# Backward-family planners (DESIGN.md §11)
# ---------------------------------------------------------------------------
# The backward walks reuse the forward plan classes (same tiling knobs,
# same tile schedules) under backward descriptors, so plans are cached /
# autotuned / provenance-counted exactly like forward plans.  The fused
# bit gates dispatch: when a backward lowering is not VMEM-legal the
# custom VJP falls back to reference-path autodiff and never reaches the
# engine.

def flash_bwd_fused_legal(desc: FlashBwdDescriptor,
                          machine: MachineModel = DEFAULT_MACHINE) -> bool:
    """Can this flash backward run as one scheduled ``pallas_call``?

    The backward walk stages one batch-head slice of q/k/v/o/do plus the
    dq/dk/dv outputs (dk/dv accumulated fp32) and the staged LSE row."""
    isz = jnp.dtype(desc.dtype).itemsize
    need = (3 * desc.sq + 2 * desc.sk) * desc.d * isz  # q/o/do + k/v
    need += desc.sq * desc.d * isz                     # dq
    need += 2 * desc.sk * desc.d * 4                   # dk/dv, fp32 RMW
    need += desc.sq * 4                                # lse row
    return need <= machine.vmem_bytes // 2


def plan_flash_bwd(desc: FlashBwdDescriptor,
                   machine: MachineModel = DEFAULT_MACHINE) -> FlashPlan:
    """Plan the flash backward walk: same (block_q, block_k) search as the
    forward — the backward reuses the forward ``FlashTileSchedule`` so the
    dKdV walk skips the same fully-masked causal k-blocks — gated by
    :func:`flash_bwd_fused_legal`."""
    fused = flash_bwd_fused_legal(desc, machine)
    best = min(_flash_legal(desc, machine),
               key=lambda s: _predict_flash_seconds(desc, *s, machine=machine,
                                                    fused=fused))
    return FlashPlan(desc, *best, fused=fused)


def grouped_bwd_fused_legal(desc: GroupedGemmBwdDescriptor,
                            machine: MachineModel = DEFAULT_MACHINE) -> bool:
    """Can this grouped-GEMM backward run as one scheduled ``pallas_call``?

    dgrad and wgrad share one launch: x, dy and dx stage whole, the expert
    panel double-buffers, and dW (plus db for biased epilogues) stages
    whole in fp32 for read-modify-write accumulation."""
    isz = jnp.dtype(desc.dtype).itemsize
    need = desc.t * (2 * desc.k + desc.n) * isz      # x, dx, dy
    need += 2 * desc.k * desc.n * isz                # double-buffered panel
    need += desc.num_experts * desc.k * desc.n * 4   # dW, fp32 RMW
    if desc.epilogue in BIAS_EPILOGUES:
        need += desc.num_experts * desc.n * 4        # db, fp32
    need += ACC_BUDGET_ELEMS * 4
    return need <= machine.vmem_bytes


def plan_grouped_bwd(desc: GroupedGemmBwdDescriptor,
                     machine: MachineModel = DEFAULT_MACHINE
                     ) -> GroupedGemmPlan:
    """Plan the grouped backward: same (bm, bk, bn) search as the forward
    — both gradients walk ``GroupedTileSchedule`` runtime tile tables over
    ``group_sizes`` — gated by :func:`grouped_bwd_fused_legal`."""
    fused = grouped_bwd_fused_legal(desc, machine)
    best = min(_grouped_legal(desc, machine),
               key=lambda s: _predict_grouped_seconds(desc, *s,
                                                      machine=machine,
                                                      fused=fused))
    return GroupedGemmPlan(desc, *best, fused=fused)


def ssd_bwd_fused_legal(desc: SsdChunkBwdDescriptor,
                        machine: MachineModel = DEFAULT_MACHINE) -> bool:
    """Can this SSD-scan backward run as one carried-state ``pallas_call``?

    The reverse walk needs a chunk's forward cell, its dY cotangent and
    saved carried state (double-buffered), the cotangent output cell, and
    the fp32 dS carry + score scratch resident in VMEM."""
    if not desc.chunks:
        return False
    isz = jnp.dtype(desc.dtype).itemsize
    q, n, p = desc.q, desc.n, desc.p
    per_step = (2 * q * n + q * q + 2 * q * p + 2 * q) * isz  # fwd cell
    per_step += q * p * isz                                   # dY cell
    per_step += p * n * 4                                     # saved state
    per_step += (2 * q * n + q * q + q * p) * isz + 2 * q * 4  # cotangents
    need = 2 * per_step + (q * q + 2 * p * n) * 4 + p * n * 4
    return need <= machine.vmem_bytes // 2


def plan_ssd_bwd(desc: SsdChunkBwdDescriptor,
                 machine: MachineModel = DEFAULT_MACHINE) -> SsdChunkPlan:
    """Plan the SSD backward: no free tiling knobs — one reverse-walk
    launch carrying the (p, n) cotangent as accumulator scratch — gated by
    :func:`ssd_bwd_fused_legal`."""
    isz = jnp.dtype(desc.dtype).itemsize
    per_step = (2 * desc.q * desc.n + desc.q * desc.q
                + 2 * desc.q * desc.p) * isz
    per_step += desc.q * desc.q * 4
    return SsdChunkPlan(desc, fits_vmem=per_step <= machine.vmem_bytes // 2,
                        fused=ssd_bwd_fused_legal(desc, machine))


# ---------------------------------------------------------------------------
# Candidate enumeration (the autotuner's search space)
# ---------------------------------------------------------------------------

def candidate_plans(desc, machine: MachineModel = DEFAULT_MACHINE,
                    top_k: int = 8) -> List:
    """Top-``top_k`` machine-legal candidate plans for one descriptor.

    This is the empirical-search half of the measure→generate loop
    (DESIGN.md §7): the same legality constraints and
    ``max(compute, memory) + steps·overhead`` cost model that pick *the*
    plan analytically here rank *all* legal plans, and
    ``repro.core.autotune`` times the top K for real.  Candidates are
    deduplicated by their tiling knobs and sorted cheapest-first, so
    ``candidate_plans(desc, machine, 1)[0]`` always agrees with the
    family planner.
    """
    fam = desc.family
    cands: List = []
    seen = set()

    def add(plan, knob_key):
        if knob_key not in seen:
            seen.add(knob_key)
            cands.append(plan)

    if fam in ("gemm", "grouped_gemm") and desc.mesh is not None:
        # Mesh descriptor (DESIGN.md §14): the search space is the two
        # execution strategies, each carrying its own locally-planned
        # knobs — the autotuner times gathered vs distributed end to end
        # and the tuned cache records which won.
        planner = plan_gemm if fam == "gemm" else plan_grouped
        for comm in MESH_STRATEGIES:
            p = dataclasses.replace(planner(mesh_local_desc(desc, comm),
                                            machine),
                                    desc=desc, comm=comm)
            add(p, (comm,))
    elif fam == "gemm":
        # Fused (single-launch) and multi-launch lowerings of one region
        # cover are distinct candidates: the autotuner times both and the
        # tuned cache records which won (DESIGN.md §8).
        fused_ok = fused_legal(desc, machine)
        for shape in palette(ACC_BUDGET_ELEMS, machine, desc.in_dtype):
            for het in (True, False):
                p = plan_gemm(desc, machine, heterogeneous=het,
                              force_block=shape)
                for fused in ((True, False) if fused_ok else (False,)):
                    q = dataclasses.replace(p, fused=fused)
                    add(q, (q.regions, q.bk, fused))
    elif fam == "flash_attention":
        # Fused (scheduled single-launch) and dense-grid lowerings of one
        # tiling are distinct candidates, exactly as for dense GEMM.
        fused_ok = flash_fused_legal(desc, machine)
        for bq, bk in _flash_legal(desc, machine):
            for fused in ((True, False) if fused_ok else (False,)):
                add(FlashPlan(desc, bq, bk, fused=fused), (bq, bk, fused))
    elif fam == "grouped_gemm":
        # Fused (scheduled single-launch) and pad/scatter lowerings of one
        # tiling are distinct candidates, exactly as for dense GEMM.
        fused_ok = grouped_fused_legal(desc, machine)
        for bm, bk, bn in _grouped_legal(desc, machine):
            for fused in ((True, False) if fused_ok else (False,)):
                add(GroupedGemmPlan(desc, bm, bk, bn, fused=fused),
                    (bm, bk, bn, fused))
    elif fam == "flash_attention_bwd":
        # The backward walk has a single (fused) lowering — the non-fused
        # alternative is reference-path autodiff outside the engine — so
        # only fused variants enter the search when legal.
        fused_ok = flash_bwd_fused_legal(desc, machine)
        for bq, bk in _flash_legal(desc, machine):
            add(FlashPlan(desc, bq, bk, fused=fused_ok), (bq, bk))
    elif fam == "grouped_gemm_bwd":
        # As for flash backward: fused-or-fallback, no pad/scatter variant.
        fused_ok = grouped_bwd_fused_legal(desc, machine)
        for bm, bk, bn in _grouped_legal(desc, machine):
            add(GroupedGemmPlan(desc, bm, bk, bn, fused=fused_ok),
                (bm, bk, bn))
    elif fam == "ssd_chunk_bwd":
        # No free tiling knobs and a single reverse-walk lowering.
        add(plan_ssd_bwd(desc, machine), ())
    elif fam == "flash_decode":
        # No free knobs: the page size is the k-block (fixed at cache
        # construction) and the walk is always the scheduled single launch.
        add(plan_flash_decode(desc, machine), ())
    elif fam == "transpose":
        for bt in _transpose_legal(desc, machine):
            add(TransposePlan(desc, bt), (bt,))
    elif fam == "ssd_chunk":
        # No free tiling knobs; the scan form still has two lowerings
        # (carried-state fused vs diag kernel + XLA scan) to choose from.
        p = plan_ssd(desc, machine)
        if ssd_fused_legal(desc, machine):
            for fused in (True, False):
                q = dataclasses.replace(p, fused=fused)
                add(q, (fused,))
        else:
            add(dataclasses.replace(p, fused=False), ())
    else:
        raise KeyError(f"no candidate enumerator for family {fam!r}")

    cands.sort(key=lambda p: p.predicted_seconds(machine))
    return cands[:max(1, top_k)]
