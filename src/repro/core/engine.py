"""Descriptor-driven kernel engine — registry, planning and dispatch.

The paper's pipeline is descriptor -> blocking plan -> generated kernel ->
dispatch cache (the LIBXSMM architecture, §IV).  This module generalizes
that pipeline from the dense-GEMM family to every kernel family in the
system.  A family is registered with two callables:

  * ``planner(desc, machine) -> plan`` — machine-model-driven tile
    selection (``repro.core.blocking``);
  * ``execute(desc, plan, *operands, interpret=...) -> result`` — runs the
    (cached) shape-specialized kernel build for that plan.

``dispatch(desc, *operands)`` is the single entry point: it resolves the
ambient :mod:`~repro.core.config`, serves the plan from an LRU plan cache
(planning used to re-run on *every* call — only kernel builds were
memoized), and invokes the family executor, which in turn serves kernel
builds from the LRU kernel cache.  Both caches key off
``desc.cache_key()`` — no family hand-writes a cache-key tuple — and both
expose per-family hit/miss/eviction stats (``stats()``).

A plan-cache miss resolves through a three-tier policy (DESIGN.md §7):

  1. **tuned cache** — the on-disk JSON store of previously autotuned
     winners (``config.tuning_cache``); a warm cache means a process
     restart re-plans nothing and times nothing;
  2. **autotune** — when ``config.autotune`` is set and the operands are
     concrete, time the top-K model-ranked candidates for real
     (:mod:`repro.core.autotune`) and persist the winner;
  3. **analytical model** — the family planner ranked by the machine
     model, as before.

Which tier served each resolution is visible per family in ``stats()``
(``plan_source_{tuned_cache,autotuned,model}``, ``autotune_timings``) and
on the plan itself (``plan.plan_source``).

Families self-register at import time; ``dispatch`` lazily imports the
owning ``kernels/<family>/ops`` module on first use, so ``repro.core``
never statically depends on ``repro.kernels`` (DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
import importlib
import threading
import warnings
from typing import Any, Callable, Dict, Iterable, List, Optional

from . import autotune as _autotune
from .config import get_config
from .descriptor import KernelDescriptor
from .jit_cache import GLOBAL_KERNEL_CACHE, LruCache
from .machine import MachineModel


@dataclasses.dataclass(frozen=True)
class Family:
    """One registered kernel family."""

    name: str
    planner: Callable[[KernelDescriptor, MachineModel], Any]
    execute: Callable[..., Any]  # (desc, plan, *operands, interpret=...)


_REGISTRY: Dict[str, Family] = {}
_registry_lock = threading.Lock()

# family name -> module that registers it (imported lazily on first use)
_FAMILY_MODULES = {
    "gemm": "repro.kernels.gemm.ops",
    "flash_attention": "repro.kernels.flash_attention.ops",
    "flash_attention_bwd": "repro.kernels.flash_attention.ops",
    "flash_decode": "repro.kernels.flash_attention.ops",
    "grouped_gemm": "repro.kernels.grouped_gemm.ops",
    "grouped_gemm_bwd": "repro.kernels.grouped_gemm.ops",
    "ssd_chunk": "repro.kernels.ssd_chunk.ops",
    "ssd_chunk_bwd": "repro.kernels.ssd_chunk.ops",
    "transpose": "repro.kernels.transpose.ops",
}

# desc -> plan.  Sized for the shape population of a whole model zoo; a
# plan is a few hundred bytes, so 64k entries is still tiny.
PLAN_CACHE = LruCache(max_entries=65536)

# Planner invocation counter per family (distinct from plan-cache misses
# only when callers bypass the cache with an explicit plan).
_plan_calls: Dict[str, int] = {}
_plan_calls_lock = threading.Lock()

# Three-tier resolution observability (DESIGN.md §7): which tier served
# each plan-cache miss, and how many candidate executions autotuning timed.
PLAN_SOURCES = ("tuned_cache", "autotuned", "model")
_plan_sources: Dict[str, Dict[str, int]] = {}
_autotune_timings: Dict[str, int] = {}

# Traced pallas_call launches per family (DESIGN.md §8): each family
# executor reports how many kernel launches one execute() emits — the
# fused GEMM path reports exactly 1 where the multi-launch path reports
# one per plan region.  Counted at trace/execute time, so a jit-compiled
# repeat call (which never re-enters Python) does not re-count.
_launches: Dict[str, int] = {}

# Explicit collectives issued per family (DESIGN.md §14): the distributed
# mesh strategies report the payload bytes and collective launches they
# emit around the per-shard kernel; the gathered strategy issues none
# (any weight resharding is XLA-implicit), so non-zero counters here mean
# a distributed execution really happened.  Trace-time counts, like
# ``_launches``.
_comm_bytes: Dict[str, int] = {}
_collective_launches: Dict[str, int] = {}

# AOT warm-start (DESIGN.md §15): every dispatch records its descriptor
# (keyed by cache key — one entry per distinct problem) so a serving
# process can save the population it actually served (``save_manifest``)
# and the next start can pre-resolve plans + pre-build kernels for it
# (``warmup``) before the first request arrives.
_seen_descs: Dict[tuple, KernelDescriptor] = {}
_warmups: Dict[str, int] = {}


def _note_source(family: str, source: str):
    with _plan_calls_lock:
        bucket = _plan_sources.setdefault(family,
                                          {s: 0 for s in PLAN_SOURCES})
        bucket[source] += 1


def _note_timings(family: str, n: int):
    with _plan_calls_lock:
        _autotune_timings[family] = _autotune_timings.get(family, 0) + n


def count_launches(family: str, n: int = 1):
    """Family executors call this once per execute() with the number of
    kernel launches they are about to emit (``stats()["…"]["launches"]``)."""
    with _plan_calls_lock:
        _launches[family] = _launches.get(family, 0) + n


def count_comm(family: str, nbytes: int, launches: int = 1):
    """Mesh executors call this with the per-device payload bytes and
    number of explicit collectives one execute() emits
    (``stats()["…"]["comm_bytes"]`` / ``["collective_launches"]``)."""
    with _plan_calls_lock:
        _comm_bytes[family] = _comm_bytes.get(family, 0) + int(nbytes)
        _collective_launches[family] = (
            _collective_launches.get(family, 0) + launches)


def register_family(name: str, planner, execute) -> Family:
    """Register (or replace) a kernel family.  Called at ops-module import."""
    fam = Family(name=name, planner=planner, execute=execute)
    with _registry_lock:
        _REGISTRY[name] = fam
    return fam


def get_family(name: str) -> Family:
    """Resolve a family by name, lazily importing its registering ops
    module on first use (the only core → kernels seam, DESIGN.md §1)."""
    fam = _REGISTRY.get(name)
    if fam is None:
        module = _FAMILY_MODULES.get(name)
        if module is None:
            raise KeyError(f"unknown kernel family {name!r}; "
                           f"known: {sorted(_FAMILY_MODULES)}")
        importlib.import_module(module)  # side effect: register_family()
        fam = _REGISTRY.get(name)
        if fam is None:
            raise RuntimeError(f"module {module} did not register family "
                               f"{name!r}")
    return fam


def families() -> Dict[str, Family]:
    """Snapshot of the currently registered kernel families."""
    with _registry_lock:
        return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------

def _resolve_plan(desc: KernelDescriptor, cfg, *,
                  machine: Optional[MachineModel] = None,
                  operands: Optional[tuple] = None,
                  kw: Optional[dict] = None,
                  interpret: Optional[bool] = None) -> Any:
    """Plan-cache lookup; a miss walks the three tiers (DESIGN.md §7)."""
    fam = get_family(desc.family)
    machine = machine or cfg.machine
    interpret = cfg.interpret if interpret is None else interpret
    kw = kw or {}
    # Timing needs concrete operands: under jit tracing (or from plan_for,
    # which has no operands) the autotune tier is unavailable.
    autotunable = (cfg.autotune and operands is not None
                   and _autotune.can_autotune(operands, kw))
    tier = "autotune" if autotunable else \
        ("tuned" if (cfg.tuning_cache or cfg.tuning_cache_preload)
         else "model")
    # The key names the machine by name AND constants-fingerprint (two
    # calibrations of one host share a name but not plans) and the
    # resolution policy (so e.g. a model-tier plan cached during jit
    # tracing never masks a later concrete-operand autotune).
    key = desc.cache_key() + ("plan", machine.name, machine.fingerprint,
                              tier, cfg.tuning_cache or "",
                              cfg.tuning_cache_preload or "")

    def build_plan():
        # Tier 1: persistent tuned cache — a warm file re-times nothing.
        # Lookups key by ``machine.tuning_key`` (name + network-calibration
        # provenance, DESIGN.md §14) so records from network-calibrated
        # and uncalibrated hosts never serve each other.  The read-only
        # preload file (``configure(tuning_cache_preload=)``, fleet-merged
        # by tools/tune.py) is the fallback behind the writable cache.
        for path in (cfg.tuning_cache, cfg.tuning_cache_preload):
            if not path:
                continue
            cache = _autotune.get_tuning_cache(path)
            record = cache.lookup(machine.tuning_key, desc,
                                  interpret=interpret)
            if record is not None:
                plan = _autotune.plan_from_record(desc, record)
                if plan is not None:
                    _note_source(desc.family, "tuned_cache")
                    return plan
        # Tier 2: budgeted empirical search over the model-ranked top-K.
        if autotunable:
            cache = (_autotune.get_tuning_cache(cfg.tuning_cache)
                     if cfg.tuning_cache else None)
            plan, timed = _autotune.search(
                fam.execute, desc, machine, operands, kw,
                interpret=interpret, budget=cfg.autotune_budget,
                tuning_cache=cache)
            _note_timings(desc.family, timed)
            if plan is not None:
                _note_source(desc.family, "autotuned")
                if cfg.tuning_cache:
                    # Overwrite the tuned-tier entry too: a jit trace that
                    # resolved before the file was populated may have
                    # cached a model plan there, and get_or_build would
                    # keep serving it for the rest of the process.
                    PLAN_CACHE.put(
                        desc.cache_key() + ("plan", machine.name,
                                            machine.fingerprint, "tuned",
                                            cfg.tuning_cache or "",
                                            cfg.tuning_cache_preload or ""),
                        plan)
                return plan
        # Tier 3: analytical machine-model planner.
        with _plan_calls_lock:
            _plan_calls[desc.family] = _plan_calls.get(desc.family, 0) + 1
        _note_source(desc.family, "model")
        return fam.planner(desc, machine)

    return PLAN_CACHE.get_or_build(key, build_plan)


def plan_for(desc: KernelDescriptor,
             machine: Optional[MachineModel] = None) -> Any:
    """Plan cache lookup: (descriptor, machine) -> family plan.

    No operands, so the autotune tier is skipped; the tuned cache (when
    configured) and the analytical model still apply.
    """
    return _resolve_plan(desc, get_config(), machine=machine)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def dispatch(desc: KernelDescriptor, *operands, plan: Any = None,
             interpret: Optional[bool] = None, **kw) -> Any:
    """Run one kernel request through the engine.

    ``plan=None`` resolves via tuned-cache → autotune → analytical-model
    (DESIGN.md §7), behind the plan cache; an explicit plan (benchmark
    sweeps, tests pinning tile sizes) bypasses all of it.  ``interpret``
    defaults from the ambient config — no per-call plumbing.
    """
    fam = get_family(desc.family)
    cfg = get_config()
    _seen_descs.setdefault(desc.cache_key(), desc)
    if interpret is None:
        interpret = cfg.interpret
    if plan is None:
        plan = _resolve_plan(desc, cfg, operands=operands, kw=kw,
                             interpret=interpret)
    return fam.execute(desc, plan, *operands, interpret=interpret, **kw)


# ---------------------------------------------------------------------------
# AOT warm-start (DESIGN.md §15)
# ---------------------------------------------------------------------------

def seen_descriptors() -> List[KernelDescriptor]:
    """Every distinct descriptor dispatched since the last full reset,
    in deterministic (cache-key) order — the recordable population a
    warm-start manifest captures."""
    return [_seen_descs[k] for k in sorted(_seen_descs, key=repr)]


def save_manifest(path: str,
                  descriptors: Optional[Iterable[KernelDescriptor]] = None
                  ) -> int:
    """Record a descriptor manifest for ``warmup`` (default: everything
    this process dispatched, :func:`seen_descriptors`).  Returns the
    number of entries written."""
    from . import warmstart as _warmstart
    descs = list(descriptors) if descriptors is not None \
        else seen_descriptors()
    return _warmstart.save_manifest(path, descs)


def warmup(descriptors: Optional[Iterable[KernelDescriptor]] = None, *,
           manifest: Optional[str] = None, build: bool = True,
           interpret: Optional[bool] = None) -> Dict[str, int]:
    """Pre-resolve plans and pre-build kernels before the first request.

    The AOT warm-start entry point (DESIGN.md §15): for each descriptor —
    given directly, loaded from a ``manifest`` path, or defaulted from
    ``configure(warm_start=...)`` / ``REPRO_WARM_START`` — resolve its
    plan through the normal three tiers (no operands, so the autotune
    tier is skipped: a preloaded tuning cache serves the tuned tier and
    times nothing) and, with ``build=True``, execute the family once on
    synthesized zero operands so the kernel cache is hot.  After a
    ``reset_stats(entries=False)`` a warmed serving step then shows
    ``autotune_timings == 0`` and zero plan-cache misses.

    Returns ``{family: warmed descriptor count}``; the same counts
    accumulate in ``stats()`` under ``"warmups"``.  A descriptor whose
    build fails (or that warmup cannot synthesize operands for, e.g.
    mesh descriptors) still warms its plan — degradation is partial,
    never fatal.
    """
    cfg = get_config()
    if descriptors is None:
        path = manifest if manifest is not None else cfg.warm_start
        if not path:
            raise ValueError(
                "warmup() needs descriptors, a manifest path, or "
                "configure(warm_start=...) / REPRO_WARM_START")
        from . import warmstart as _warmstart
        descriptors = _warmstart.load_manifest(path)
    if interpret is None:
        interpret = cfg.interpret
    counts: Dict[str, int] = {}
    for desc in descriptors:
        fam = get_family(desc.family)
        plan = _resolve_plan(desc, cfg, interpret=interpret)
        if build:
            from . import warmstart as _warmstart
            try:
                synth = _warmstart.synth_operands(desc)
                if synth is not None:
                    operands, kw = synth
                    fam.execute(desc, plan, *operands,
                                interpret=interpret, **kw)
            except Exception as e:
                warnings.warn(
                    f"warmup build failed for {desc.family} "
                    f"{desc.cache_key()!r}: {e}")
        counts[desc.family] = counts.get(desc.family, 0) + 1
        with _plan_calls_lock:
            _warmups[desc.family] = _warmups.get(desc.family, 0) + 1
    return counts


def resolve_fused(plan: Any) -> bool:
    """Resolve a plan's execution path (DESIGN.md §9): the ambient
    ``config.fused`` override wins ("on"/"off"), else the ``fused`` bit
    the planner/autotuner set on the plan.  Shared by every family with a
    fused single-launch lowering (gemm, grouped_gemm)."""
    mode = get_config().fused
    if mode == "on":
        return True
    if mode == "off":
        return False
    return bool(getattr(plan, "fused", False))


def build_cached(key: tuple, builder: Callable[[], Any]) -> Any:
    """Kernel-cache helper for family executors.

    ``key`` must be descriptor-derived (``desc.cache_key() + knobs``) so
    the first element names the family for the per-family stats.
    """
    return GLOBAL_KERNEL_CACHE.get_or_build(key, builder)


# ---------------------------------------------------------------------------
# Observability
# ---------------------------------------------------------------------------

def stats() -> Dict[str, Dict[str, int]]:
    """Per-family engine stats across both cache layers.

    {family: {plan_hits, plan_misses, plan_evictions, planner_calls,
              plan_source_tuned_cache, plan_source_autotuned,
              plan_source_model, autotune_timings, launches,
              comm_bytes, collective_launches, warmups,
              kernel_hits, kernel_misses, kernel_evictions}}

    Backward families (``<family>_bwd`` descriptors, DESIGN.md §11) fold
    into their forward family's bucket under ``*_bwd``-suffixed keys
    (``launches_bwd``, ``plan_source_model_bwd``, ...), so one row tells
    the whole forward + backward story per family.
    """
    out: Dict[str, Dict[str, int]] = {}

    def bucket(fam: str) -> Dict[str, int]:
        return out.setdefault(fam, {
            **{k + sfx: 0 for sfx in ("", "_bwd") for k in (
                "plan_hits", "plan_misses", "plan_evictions",
                "planner_calls",
                *(f"plan_source_{s}" for s in PLAN_SOURCES),
                "autotune_timings", "launches",
                "comm_bytes", "collective_launches", "warmups",
                "kernel_hits", "kernel_misses", "kernel_evictions")},
        })

    def slot(fam: str):
        """Bucket + key suffix: backward families report into the forward
        family's row under ``*_bwd`` keys."""
        if fam.endswith("_bwd"):
            return bucket(fam[:-4]), "_bwd"
        return bucket(fam), ""

    for fam, c in PLAN_CACHE.family_stats().items():
        b, sfx = slot(fam)
        b["plan_hits" + sfx] = c["hits"]
        b["plan_misses" + sfx] = c["misses"]
        b["plan_evictions" + sfx] = c["evictions"]
    with _plan_calls_lock:
        for fam, n in _plan_calls.items():
            b, sfx = slot(fam)
            b["planner_calls" + sfx] = n
        for fam, sources in _plan_sources.items():
            b, sfx = slot(fam)
            for s, n in sources.items():
                b[f"plan_source_{s}{sfx}"] = n
        for fam, n in _autotune_timings.items():
            b, sfx = slot(fam)
            b["autotune_timings" + sfx] = n
        for fam, n in _launches.items():
            b, sfx = slot(fam)
            b["launches" + sfx] = n
        for fam, n in _comm_bytes.items():
            b, sfx = slot(fam)
            b["comm_bytes" + sfx] = n
        for fam, n in _collective_launches.items():
            b, sfx = slot(fam)
            b["collective_launches" + sfx] = n
        for fam, n in _warmups.items():
            b, sfx = slot(fam)
            b["warmups" + sfx] = n
    for fam, c in GLOBAL_KERNEL_CACHE.family_stats().items():
        b, sfx = slot(fam)
        b["kernel_hits" + sfx] = c["hits"]
        b["kernel_misses" + sfx] = c["misses"]
        b["kernel_evictions" + sfx] = c["evictions"]
    return out


def reset_stats(*, entries: bool = True):
    """Reset all engine counters.

    ``entries=True`` (test isolation) also drops cached plans, built
    kernels, and the in-memory tuning-cache mirrors (on-disk files stay —
    a fresh mirror reloads them, which is how tests simulate a process
    restart).  ``entries=False`` (benchmark phase boundaries) zeroes the
    counters but keeps every cache warm, so per-phase tables don't charge
    one phase for another's builds.
    """
    if entries:
        PLAN_CACHE.clear()
        GLOBAL_KERNEL_CACHE.clear()
        _autotune.reset_tuning_caches()
        _seen_descs.clear()
    else:
        PLAN_CACHE.reset_stats()
        GLOBAL_KERNEL_CACHE.reset_stats()
    with _plan_calls_lock:
        _plan_calls.clear()
        _plan_sources.clear()
        _autotune_timings.clear()
        _launches.clear()
        _comm_bytes.clear()
        _collective_launches.clear()
        _warmups.clear()
