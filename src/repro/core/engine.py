"""Descriptor-driven kernel engine — registry, planning and dispatch.

The paper's pipeline is descriptor -> blocking plan -> generated kernel ->
dispatch cache (the LIBXSMM architecture, §IV).  This module generalizes
that pipeline from the dense-GEMM family to every kernel family in the
system.  A family is registered with two callables:

  * ``planner(desc, machine) -> plan`` — machine-model-driven tile
    selection (``repro.core.blocking``);
  * ``execute(desc, plan, *operands, interpret=...) -> result`` — runs the
    (cached) shape-specialized kernel build for that plan.

``dispatch(desc, *operands)`` is the single entry point: it resolves the
ambient :mod:`~repro.core.config`, serves the plan from an LRU plan cache
(planning used to re-run on *every* call — only kernel builds were
memoized), and invokes the family executor, which in turn serves kernel
builds from the LRU kernel cache.  Both caches key off
``desc.cache_key()`` — no family hand-writes a cache-key tuple — and both
expose per-family hit/miss/eviction stats (``stats()``).

Families self-register at import time; ``dispatch`` lazily imports the
owning ``kernels/<family>/ops`` module on first use, so ``repro.core``
never statically depends on ``repro.kernels`` (DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
import importlib
import threading
from typing import Any, Callable, Dict, Optional

from .config import get_config
from .descriptor import KernelDescriptor
from .jit_cache import GLOBAL_KERNEL_CACHE, LruCache
from .machine import MachineModel


@dataclasses.dataclass(frozen=True)
class Family:
    """One registered kernel family."""

    name: str
    planner: Callable[[KernelDescriptor, MachineModel], Any]
    execute: Callable[..., Any]  # (desc, plan, *operands, interpret=...)


_REGISTRY: Dict[str, Family] = {}
_registry_lock = threading.Lock()

# family name -> module that registers it (imported lazily on first use)
_FAMILY_MODULES = {
    "gemm": "repro.kernels.gemm.ops",
    "flash_attention": "repro.kernels.flash_attention.ops",
    "grouped_gemm": "repro.kernels.grouped_gemm.ops",
    "ssd_chunk": "repro.kernels.ssd_chunk.ops",
    "transpose": "repro.kernels.transpose.ops",
}

# desc -> plan.  Sized for the shape population of a whole model zoo; a
# plan is a few hundred bytes, so 64k entries is still tiny.
PLAN_CACHE = LruCache(max_entries=65536)

# Planner invocation counter per family (distinct from plan-cache misses
# only when callers bypass the cache with an explicit plan).
_plan_calls: Dict[str, int] = {}
_plan_calls_lock = threading.Lock()


def register_family(name: str, planner, execute) -> Family:
    """Register (or replace) a kernel family.  Called at ops-module import."""
    fam = Family(name=name, planner=planner, execute=execute)
    with _registry_lock:
        _REGISTRY[name] = fam
    return fam


def get_family(name: str) -> Family:
    fam = _REGISTRY.get(name)
    if fam is None:
        module = _FAMILY_MODULES.get(name)
        if module is None:
            raise KeyError(f"unknown kernel family {name!r}; "
                           f"known: {sorted(_FAMILY_MODULES)}")
        importlib.import_module(module)  # side effect: register_family()
        fam = _REGISTRY.get(name)
        if fam is None:
            raise RuntimeError(f"module {module} did not register family "
                               f"{name!r}")
    return fam


def families() -> Dict[str, Family]:
    with _registry_lock:
        return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------

def plan_for(desc: KernelDescriptor,
             machine: Optional[MachineModel] = None) -> Any:
    """Plan cache lookup: (descriptor, machine) -> family plan."""
    fam = get_family(desc.family)
    machine = machine or get_config().machine
    key = desc.cache_key() + ("plan", machine.name)

    def build_plan():
        with _plan_calls_lock:
            _plan_calls[desc.family] = _plan_calls.get(desc.family, 0) + 1
        return fam.planner(desc, machine)

    return PLAN_CACHE.get_or_build(key, build_plan)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def dispatch(desc: KernelDescriptor, *operands, plan: Any = None,
             interpret: Optional[bool] = None, **kw) -> Any:
    """Run one kernel request through the engine.

    ``plan=None`` consults the plan cache (normal path); an explicit plan
    (benchmark sweeps, tests pinning tile sizes) bypasses it.  ``interpret``
    defaults from the ambient config — no per-call plumbing.
    """
    fam = get_family(desc.family)
    cfg = get_config()
    if plan is None:
        plan = plan_for(desc, cfg.machine)
    if interpret is None:
        interpret = cfg.interpret
    return fam.execute(desc, plan, *operands, interpret=interpret, **kw)


def build_cached(key: tuple, builder: Callable[[], Any]) -> Any:
    """Kernel-cache helper for family executors.

    ``key`` must be descriptor-derived (``desc.cache_key() + knobs``) so
    the first element names the family for the per-family stats.
    """
    return GLOBAL_KERNEL_CACHE.get_or_build(key, builder)


# ---------------------------------------------------------------------------
# Observability
# ---------------------------------------------------------------------------

def stats() -> Dict[str, Dict[str, int]]:
    """Per-family engine stats across both cache layers.

    {family: {plan_hits, plan_misses, plan_evictions, planner_calls,
              kernel_hits, kernel_misses, kernel_evictions}}
    """
    out: Dict[str, Dict[str, int]] = {}

    def bucket(fam: str) -> Dict[str, int]:
        return out.setdefault(fam, {
            "plan_hits": 0, "plan_misses": 0, "plan_evictions": 0,
            "planner_calls": 0,
            "kernel_hits": 0, "kernel_misses": 0, "kernel_evictions": 0,
        })

    for fam, c in PLAN_CACHE.family_stats().items():
        b = bucket(fam)
        b["plan_hits"] = c["hits"]
        b["plan_misses"] = c["misses"]
        b["plan_evictions"] = c["evictions"]
    with _plan_calls_lock:
        for fam, n in _plan_calls.items():
            bucket(fam)["planner_calls"] = n
    for fam, c in GLOBAL_KERNEL_CACHE.family_stats().items():
        b = bucket(fam)
        b["kernel_hits"] = c["hits"]
        b["kernel_misses"] = c["misses"]
        b["kernel_evictions"] = c["evictions"]
    return out


def reset_stats():
    """Clear both caches and all counters (test isolation)."""
    PLAN_CACHE.clear()
    GLOBAL_KERNEL_CACHE.clear()
    with _plan_calls_lock:
        _plan_calls.clear()
