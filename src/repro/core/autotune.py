"""Empirical plan autotuner + persistent tuning cache (DESIGN.md §7).

The analytical planners in :mod:`repro.core.blocking` get close; this
module wins the last mile the way "Demystifying ARM SME" does — by
*timing* the machine-legal candidate tilings instead of trusting the cost
model.  ``search`` takes the top-K candidates ranked by the model
(:func:`repro.core.blocking.candidate_plans`), runs each through the
family executor's BUILD/RUN stages on the real operands, and returns the
measured winner with ``plan_source="autotuned"``.

Winners persist in an on-disk JSON :class:`TuningCache` keyed by
``(machine.name, desc.cache_key())`` so a process restart is a warm
start: ``engine.dispatch`` consults the cache *before* autotuning, and a
populated cache means zero timing runs.  A corrupt or missing cache file
degrades to an empty cache — the engine then falls through to the
autotune or analytical tier, never to an error.

The three-tier resolution policy (tuned cache → autotune → analytical
model) lives in :func:`repro.core.engine.dispatch`; this module owns only
the search and the persistence.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time
import warnings
from typing import Any, Dict, Optional, Tuple

import jax

from .blocking import (BlockingPlan, FlashPlan, GroupedGemmPlan, Region,
                       SsdChunkPlan, TransposePlan, candidate_plans)
from .descriptor import KernelDescriptor
from .machine import MachineModel

TUNING_CACHE_VERSION = 1

# Timing discipline per candidate: one untimed call (trace + build), then
# ``_TIME_ITERS`` timed calls; the candidate's score is the minimum (least
# noise-contaminated) run.  Winners persist in the tuning cache, so a
# noisy measurement gets locked in — three iterations is the floor that
# keeps one scheduler hiccup from deciding a cache entry's lifetime.
_TIME_ITERS = 3


# ---------------------------------------------------------------------------
# Plan <-> JSON records
# ---------------------------------------------------------------------------

def _desc_dtypes(desc: KernelDescriptor) -> list:
    """The full dtype identity of one descriptor — every dtype-ish field
    plus the quant spec — recorded alongside cached knobs and re-checked
    on replay.  The descriptor's ``cache_key()`` already separates these
    (``dataclasses.astuple`` recurses into the nested ``QuantSpec``), so
    this is a belt-and-braces guard: a record written under a different
    keying scheme (or hand-edited) can never replay a wide plan onto a
    quantized problem or vice versa."""
    vals = []
    for attr in ("in_dtype", "acc_dtype", "out_dtype", "dtype"):
        v = getattr(desc, attr, None)
        if v is not None:
            vals.append(f"{attr}={v}")
    vals.append(f"quant={getattr(desc, 'quant', None)!r}")
    return vals


def plan_to_record(plan: Any) -> Dict[str, Any]:
    """Serialize one plan's tiling knobs (the descriptor is the cache key,
    so only the knobs travel — plus the dtype fingerprint as a replay
    guard)."""
    if isinstance(plan, BlockingPlan):
        rec = {"family": "gemm",
               "regions": [[r.row0, r.col0, r.rows, r.cols, r.bm, r.bn]
                           for r in plan.regions],
               "bk": plan.bk, "heterogeneous": plan.heterogeneous,
               "fused": plan.fused}
        if plan.comm is not None:
            rec["comm"] = plan.comm  # mesh strategy (DESIGN.md §14)
    elif isinstance(plan, FlashPlan):
        rec = {"family": "flash_attention",
               "block_q": plan.block_q, "block_k": plan.block_k,
               "fused": plan.fused}
    elif isinstance(plan, GroupedGemmPlan):
        rec = {"family": "grouped_gemm",
               "bm": plan.bm, "bk": plan.bk, "bn": plan.bn,
               "fused": plan.fused}
        if plan.comm is not None:
            rec["comm"] = plan.comm  # mesh strategy (DESIGN.md §14)
    elif isinstance(plan, TransposePlan):
        rec = {"family": "transpose", "bt": plan.bt}
    elif isinstance(plan, SsdChunkPlan):
        rec = {"family": "ssd_chunk", "fits_vmem": plan.fits_vmem,
               "fused": plan.fused}
    else:
        raise TypeError(f"unknown plan type: {type(plan).__name__}")
    rec["dtypes"] = _desc_dtypes(plan.desc)
    return rec


def plan_from_record(desc: KernelDescriptor,
                     record: Dict[str, Any]) -> Optional[Any]:
    """Rebuild a plan from its cached knobs; ``None`` on any mismatch
    (wrong family, malformed knobs) so callers degrade to re-planning."""
    try:
        family = record["family"]
        if family != desc.family:
            return None
        # Dtype fingerprint guard (pre-guard records lack it: accept —
        # their entry key was already dtype-separated via cache_key()).
        want = record.get("dtypes")
        if want is not None and list(want) != _desc_dtypes(desc):
            return None
        if family == "gemm":
            regions = tuple(Region(*map(int, r)) for r in record["regions"])
            # Pre-fusion cache entries lack "fused": replay them on the
            # multi-launch path they were actually timed on.
            return BlockingPlan(desc, regions, int(record["bk"]),
                                bool(record["heterogeneous"]),
                                fused=bool(record.get("fused", False)),
                                plan_source="autotuned",
                                comm=record.get("comm"))
        if family == "flash_attention":
            # Pre-schedule cache entries lack "fused": replay them on the
            # dense-grid path they were actually timed on.
            return FlashPlan(desc, int(record["block_q"]),
                             int(record["block_k"]),
                             fused=bool(record.get("fused", False)),
                             plan_source="autotuned")
        if family == "grouped_gemm":
            # Pre-schedule cache entries lack "fused": replay them on the
            # pad/scatter path they were actually timed on.
            return GroupedGemmPlan(desc, int(record["bm"]), int(record["bk"]),
                                   int(record["bn"]),
                                   fused=bool(record.get("fused", False)),
                                   plan_source="autotuned",
                                   comm=record.get("comm"))
        if family == "transpose":
            return TransposePlan(desc, int(record["bt"]),
                                 plan_source="autotuned")
        if family == "ssd_chunk":
            # Pre-schedule cache entries lack "fused": replay them on the
            # diag-kernel + XLA-scan path they were actually timed on.
            return SsdChunkPlan(desc, bool(record["fits_vmem"]),
                                fused=bool(record.get("fused", False)),
                                plan_source="autotuned")
        return None
    except (KeyError, TypeError, ValueError):
        return None


def _mode(interpret: bool) -> str:
    return "interpret" if interpret else "compiled"


def _entry_key(machine_name: str, desc: KernelDescriptor,
               interpret: bool) -> str:
    # desc.cache_key() is a tuple of ints/strings/bools/None; its repr is
    # stable and human-greppable in the JSON file.  The execution mode is
    # part of the key: a winner timed under interpret-mode emulation says
    # nothing about compiled execution and must never be replayed there.
    # Deliberately keyed by ``machine.tuning_key`` (name + network-
    # calibration provenance), not constants-fingerprint — measured
    # winners should survive run-to-run probe drift on one host, but a
    # network-calibrated host's mesh winners must never serve an
    # uncalibrated one (DESIGN.md §14).
    return f"{machine_name}|{_mode(interpret)}|{desc.cache_key()!r}"


# ---------------------------------------------------------------------------
# Persistent tuning cache
# ---------------------------------------------------------------------------

class TuningCache:
    """On-disk JSON store of autotuned winners, mirrored in memory.

    File format (DESIGN.md §7)::

        {"version": 1,
         "entries": {"<machine>|<desc-cache-key-repr>":
                     {"family": ..., <knobs...>, "us": <measured>}}}

    Loads are lazy and fault-tolerant: a missing file is an empty cache, a
    corrupt file warns once and is treated as empty (the next ``store``
    rewrites it whole).  Writes are atomic (tempfile + ``os.replace``).
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._entries: Dict[str, Dict[str, Any]] = {}
        self._load()

    def _load(self):
        try:
            with open(self.path) as f:
                data = json.load(f)
            if not isinstance(data, dict) or "entries" not in data:
                raise ValueError("not a tuning-cache file")
            entries = data["entries"]
            if not isinstance(entries, dict):
                raise ValueError("entries must be an object")
            self._entries = entries
        except FileNotFoundError:
            self._entries = {}
        except (json.JSONDecodeError, ValueError, OSError) as e:
            warnings.warn(f"ignoring corrupt tuning cache {self.path}: {e}")
            self._entries = {}

    def lookup(self, machine_name: str, desc: KernelDescriptor, *,
               interpret: bool) -> Optional[Dict[str, Any]]:
        with self._lock:
            return self._entries.get(
                _entry_key(machine_name, desc, interpret))

    def store(self, machine_name: str, desc: KernelDescriptor, plan: Any,
              measured_us: float, *, interpret: bool):
        record = plan_to_record(plan)
        record["us"] = round(float(measured_us), 3)
        # Wall-clock stamp: the fleet-merge CLI (tools/tune.py) unions
        # caches with newest-timing-wins, arbitrated by this field.
        record["ts"] = round(time.time(), 3)
        with self._lock:
            self._entries[_entry_key(machine_name, desc, interpret)] = record
            self._flush_locked()

    def _flush_locked(self):
        payload = {"version": TUNING_CACHE_VERSION, "entries": self._entries}
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tuning.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


# Path -> TuningCache.  One mirror per file per process; dropped by
# ``reset_tuning_caches`` (tests use that to simulate a cold process that
# re-reads the file).
_CACHES: Dict[str, TuningCache] = {}
_caches_lock = threading.Lock()


def get_tuning_cache(path: str) -> TuningCache:
    """The process-wide :class:`TuningCache` mirror for one file path
    (created on first use, shared after)."""
    key = os.path.abspath(path)
    with _caches_lock:
        cache = _CACHES.get(key)
        if cache is None:
            cache = _CACHES[key] = TuningCache(path)
        return cache


def reset_tuning_caches():
    """Drop all in-memory mirrors (files stay; next use reloads them)."""
    with _caches_lock:
        _CACHES.clear()


# ---------------------------------------------------------------------------
# Empirical search
# ---------------------------------------------------------------------------

def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def can_autotune(operands: tuple, kw: Dict[str, Any]) -> bool:
    """Timing needs concrete arrays: under ``jit`` tracing the operands
    are tracers and wall-clock is meaningless — skip to the model tier."""
    vals = list(operands) + [v for v in kw.values() if v is not None]
    return all(_is_concrete(v) for v in vals)


def _time_plan(execute, desc, plan, operands, interpret: bool,
               kw: Dict[str, Any]) -> float:
    """Seconds for one candidate via the family's BUILD/RUN stages."""
    jax.block_until_ready(
        execute(desc, plan, *operands, interpret=interpret, **kw))
    best = float("inf")
    for _ in range(_TIME_ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(
            execute(desc, plan, *operands, interpret=interpret, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def search(execute, desc: KernelDescriptor, machine: MachineModel,
           operands: tuple, kw: Dict[str, Any], *, interpret: bool,
           budget: int,
           tuning_cache: Optional[TuningCache] = None
           ) -> Tuple[Optional[Any], int]:
    """Time the top-``budget`` candidates; return (winner, timed_count).

    The winner carries ``plan_source="autotuned"`` and is persisted to
    ``tuning_cache`` when one is given.  A candidate whose build or run
    raises is skipped; if every candidate fails the caller falls back to
    the analytical tier (winner ``None``).
    """
    candidates = candidate_plans(desc, machine, top_k=budget)
    # A forced execution-path override (config.fused="on"/"off") makes the
    # executor ignore the candidate's ``fused`` bit, so the two lowerings
    # of one region cover would be timed on the identical path and an
    # *untimed* fused bit could be persisted.  Keep only candidates whose
    # bit matches the path that will actually run (DESIGN.md §8).
    from .config import get_config
    mode = get_config().fused
    if mode != "auto":
        want = mode == "on"
        candidates = [c for c in candidates
                      if getattr(c, "fused", want) == want]
    if len(candidates) < 2:
        # Nothing to choose between (e.g. ssd_chunk has no free knobs):
        # timing would cost real executions with no decision to make, and
        # the analytical tier returns the same plan.
        return None, 0
    best_plan, best_t, timed = None, float("inf"), 0
    for plan in candidates:
        try:
            t = _time_plan(execute, desc, plan, operands, interpret, kw)
        except Exception as e:  # build/run failure: skip this candidate
            warnings.warn(f"autotune candidate failed for {desc.family}: {e}")
            continue
        timed += 1
        if t < best_t:
            best_plan, best_t = plan, t
    if best_plan is None:
        return None, timed
    best_plan = dataclasses.replace(best_plan, plan_source="autotuned")
    if tuning_cache is not None:
        # Keyed by ``tuning_key`` (name + network-calibration provenance,
        # DESIGN.md §14): records from network-calibrated and uncalibrated
        # hosts never serve each other even when they share a name.
        tuning_cache.store(machine.tuning_key, desc, best_plan, best_t * 1e6,
                           interpret=interpret)
    return best_plan, timed
