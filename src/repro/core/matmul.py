"""Public matmul dispatch — the framework's BLAS front door.

Every dense layer in ``repro.models`` calls :func:`matmul`.  The dispatcher
either routes through the paper's engine (plan → shape-specialized Pallas
kernels, ``backend="pallas"``) or through XLA's native ``dot_general``
(``backend="xla"`` — the "vendor BLAS" of the TPU stack, and the baseline
of every paper-figure benchmark).

Backend policy: CPU containers validate the Pallas path in interpret mode
at test scale; multi-pod dry-runs lower the XLA path (identical FLOPs,
bytes and sharding semantics — see DESIGN.md §3).  On TPU hardware the
global default flips to "pallas".
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .blocking import BlockingPlan, plan_gemm
# Back-compat re-exports: the backend knobs moved to repro.core.config.
from .config import backend, get_backend, get_config, set_backend  # noqa: F401
from .descriptor import GemmDescriptor, check_bias


def matmul(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None, *,
           layout: str = "nn", epilogue: Optional[str] = None,
           bias: Optional[jax.Array] = None, out_dtype=None,
           acc_dtype=jnp.float32, plan: Optional[BlockingPlan] = None,
           backend_override: Optional[str] = None) -> jax.Array:
    """Planned (batched) GEMM: ``out = epilogue(c? + a @ op(b))``.

    ``a``: (..., M, K).  ``b``: (K, N) | (..., K, N) for layout "nn",
    (N, K) | (..., N, K) for "nt".  Leading dims of ``a`` are flattened
    into M when ``b`` is rank-2 (the dense-layer case).
    """
    be = backend_override or get_config().backend
    out_dtype = out_dtype or a.dtype
    check_bias(epilogue, bias)

    from repro.optim.compression import QuantizedTensor  # lazy: no cycle
    if isinstance(b, QuantizedTensor):
        # Quantized-at-load W8A16 weights (DESIGN.md §13): inference-only
        # direct path — no custom VJP (grads still flow to ``a`` through
        # plain ops; the frozen int weight gets none).
        return _w8a16_matmul(a, b, be, layout, epilogue, bias, out_dtype)

    if be == "xla":
        # No flattening: dot_general consumes (..., M, K) directly, so
        # sharding on the leading/sequence dims propagates through (a
        # reshape here would break SPMD propagation and force gathers).
        return _xla_gemm(a, b, c, layout, epilogue, bias, out_dtype, acc_dtype)

    lead = None
    if b.ndim == 2 and a.ndim > 2:
        lead = a.shape[:-1]
        a = a.reshape(-1, a.shape[-1])
        if c is not None:
            c = c.reshape(-1, c.shape[-1])
    if plan is None:
        # Differentiable engine path: the primal is the scheduled Pallas
        # dispatch, the backward is reference autodiff through the XLA
        # oracle (dense GEMM has no scheduled backward family — only the
        # three DESIGN.md §11 families do).
        out = _engine_vjp(layout, epilogue, jnp.dtype(out_dtype),
                          a, b, c, bias)
    else:
        # Explicit-plan path: descriptor -> caller's plan -> kernel build.
        from repro.core import engine
        desc = GemmDescriptor.from_operands(
            a, b, layout=layout, accumulate=c is not None, epilogue=epilogue,
            out_dtype=out_dtype)
        out = engine.dispatch(desc, a, b, plan=plan, bias=bias, c=c)
    if lead is not None:
        out = out.reshape(*lead, out.shape[-1])
    return out


def _w8a16_matmul(a, bq, be, layout, epilogue, bias, out_dtype):
    """Weight-only-quantized dense layer: ``epilogue(a @ deq(bq))``.

    Because every quant scheme's column scales are separable, the dequant
    commutes through the contraction: ``a @ (q * s) == (a @ q) * s``.  On
    the pallas backend this routes through the engine's quantized GEMM
    family (one fused launch, dequant in the epilogue); on the XLA
    backend it is the commuted ``dot_general`` form — either way the
    narrow weight is what moves through memory (DESIGN.md §13).
    """
    from repro.optim.compression import expand_scale
    if layout != "nn":
        raise ValueError("QuantizedTensor weights support layout='nn' only")
    n = bq.shape[1]
    lead = None
    if a.ndim > 2:
        lead = a.shape[:-1]
        a = a.reshape(-1, a.shape[-1])
    if be == "pallas":
        from repro.kernels.gemm.ops import gemm as _engine_gemm
        out = _engine_gemm(a, bq, epilogue=epilogue, bias=bias,
                           out_dtype=out_dtype)
    else:
        from repro.kernels.epilogue import apply_epilogue
        acc = jax.lax.dot_general(a, bq.q.astype(a.dtype),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        sb = expand_scale(bq.scale, bq.spec, n).reshape(1, n)
        bias_blk = None if bias is None else bias.reshape(1, n)
        out = apply_epilogue(acc, epilogue, bias_blk, sb).astype(out_dtype)
    if lead is not None:
        out = out.reshape(*lead, n)
    return out


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _engine_vjp(layout, epilogue, out_dtype, a, b, c, bias):
    """Engine GEMM with a differentiable front: pallas primal, reference
    backward.  Keeps ``backend="pallas"`` trainable end to end — the qkv /
    out / unembed projections of a training step pull their gradients
    through here while the three scheduled families (DESIGN.md §11) run
    their own single-launch backward walks."""
    from repro.core import engine
    desc = GemmDescriptor.from_operands(
        a, b, layout=layout, accumulate=c is not None, epilogue=epilogue,
        out_dtype=out_dtype)
    return engine.dispatch(desc, a, b, plan=None, bias=bias, c=c)


def _engine_vjp_fwd(layout, epilogue, out_dtype, a, b, c, bias):
    out = _engine_vjp(layout, epilogue, out_dtype, a, b, c, bias)
    return out, (a, b, c, bias)


def _engine_vjp_bwd(layout, epilogue, out_dtype, res, g):
    a, b, c, bias = res

    def oracle(a, b, c, bias):
        return _xla_gemm(a, b, c, layout, epilogue, bias, out_dtype,
                         jnp.float32)

    _, pullback = jax.vjp(oracle, a, b, c, bias)
    return pullback(g)


_engine_vjp.defvjp(_engine_vjp_fwd, _engine_vjp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _dot_spmd(a, b, layout):
    """dot_general with *bf16 cotangent* backward.

    The activation gradient (dx) is produced directly in the input dtype,
    so the tensor-parallel partial-sum collective moves bf16 (not the fp32
    the default VJP would emit — 2x the bytes) and no fp32 activation-
    sized buffers materialize.  The weight gradient keeps an fp32
    accumulate (long token-dim reduction).  This is the Megatron bf16
    grad-reduce convention expressed as a custom VJP.
    """
    return _dot_fwd_impl(a, b, layout)


def _dot_fwd_impl(a, b, layout):
    contract_b = b.ndim - (2 if layout == "nn" else 1)
    nbatch = max(a.ndim, b.ndim) - 2
    batch_dims = tuple(range(nbatch)) if a.ndim == b.ndim else ()
    dn = (((a.ndim - 1,), (contract_b,)), (batch_dims, batch_dims))
    return jax.lax.dot_general(a, b, dn, preferred_element_type=jnp.float32)


def _dot_fwd(a, b, layout):
    return _dot_fwd_impl(a, b, layout), (a, b)


def _dot_bwd(layout, res, g):
    # Both grads in the primal (bf16) dtype: dx partial-sums cross "model"
    # and dw cross "data" — bf16 on the wire AND no fp32 weight-sized
    # transients (observed 3.9 GiB per vocab-sized weight on 256k-vocab
    # archs).  The MXU still accumulates fp32 internally.
    a, b = res
    g16 = g.astype(a.dtype)
    nbatch_b = b.ndim - 2
    if a.ndim == b.ndim:  # batched b
        bd = tuple(range(nbatch_b))
        if layout == "nn":   # b: (..., K, N); g: (..., M, N)
            da = jax.lax.dot_general(
                g16, b, (((g.ndim - 1,), (b.ndim - 1,)), (bd, bd)),
                preferred_element_type=a.dtype)
            db = jax.lax.dot_general(
                a, g16, (((a.ndim - 2,), (g.ndim - 2,)), (bd, bd)),
                preferred_element_type=b.dtype)
        else:                # b: (..., N, K); g: (..., M, N)
            da = jax.lax.dot_general(
                g16, b, (((g.ndim - 1,), (b.ndim - 2,)), (bd, bd)),
                preferred_element_type=a.dtype)
            db = jax.lax.dot_general(
                g16, a, (((g.ndim - 2,), (a.ndim - 2,)), (bd, bd)),
                preferred_element_type=b.dtype)
    else:  # b rank-2, a (..., M, K)
        lead = tuple(range(a.ndim - 1))  # all but K — contracted for db
        if layout == "nn":   # b: (K, N)
            da = jax.lax.dot_general(
                g16, b, (((g.ndim - 1,), (1,)), ((), ())),
                preferred_element_type=a.dtype)
            db = jax.lax.dot_general(
                a, g16, ((lead, lead), ((), ())),
                preferred_element_type=b.dtype)
        else:                # b: (N, K)
            da = jax.lax.dot_general(
                g16, b, (((g.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=a.dtype)
            db = jax.lax.dot_general(
                g16, a, ((lead, lead), ((), ())),
                preferred_element_type=b.dtype)
    return da, db


_dot_spmd.defvjp(_dot_fwd, _dot_bwd)


def _xla_gemm(a, b, c, layout, epilogue, bias, out_dtype, acc_dtype):
    acc = _dot_spmd(a, b, layout)
    if c is not None:
        acc = acc + c.astype(acc.dtype)
    if epilogue in ("bias", "bias_gelu", "bias_silu"):
        acc = acc + bias.astype(acc.dtype)
    if epilogue in ("gelu", "bias_gelu"):
        acc = jax.nn.gelu(acc)
    elif epilogue in ("silu", "bias_silu"):
        acc = jax.nn.silu(acc)
    elif epilogue == "relu":
        acc = jnp.maximum(acc, 0)
    return acc.astype(out_dtype)


def describe(a, b, layout="nn", **kw) -> GemmDescriptor:
    """Descriptor of the GEMM ``matmul(a, b)`` would dispatch."""
    return GemmDescriptor.from_operands(a, b, layout=layout, **kw)


def plan(a, b, layout="nn", **kw) -> BlockingPlan:
    """Blocking plan of the GEMM ``matmul(a, b)`` would dispatch."""
    return plan_gemm(GemmDescriptor.from_operands(a, b, layout=layout), **kw)
