"""Process-wide engine configuration: backend, interpret mode, machine.

The paper's dispatcher has one piece of ambient state — which lowering
serves a request (generated SME kernel vs vendor BLAS).  Ours has more:

  * ``backend``   — "xla" (dot_general, the vendor-BLAS analogue; default
                    in CPU containers) or "pallas" (the paper's engine:
                    descriptor → plan → generated kernel);
  * ``interpret`` — run Pallas kernels in interpret mode (the CPU
                    correctness path) or compiled (TPU hardware);
  * ``machine``   — the :class:`~repro.core.machine.MachineModel` that
                    parameterizes every tile planner (the "Table I"
                    constants, or a microbench-calibrated model);
  * ``autotune``  — let ``engine.dispatch`` time the top-K candidate
                    tilings empirically instead of trusting the model
                    (DESIGN.md §7); ``autotune_budget`` caps K;
  * ``tuning_cache`` — path of the on-disk JSON tuning cache that makes
                    autotuned winners survive process restarts;
  * ``tuning_cache_preload`` — read-only fleet-merged tuning cache
                    (tools/tune.py) consulted after ``tuning_cache``
                    misses — the warm-start path (DESIGN.md §14);
  * ``warm_start`` — path of a recorded descriptor manifest
                    (``engine.save_manifest``); ``engine.warmup()`` with
                    no arguments replays it, pre-resolving plans and
                    pre-building kernels before the first request
                    (DESIGN.md §15);
  * ``fused``     — plan-execution policy for families with a fused
                    single-launch lowering (GEMM, grouped GEMM —
                    DESIGN.md §8/§9): "auto" follows the plan's ``fused``
                    bit (planner/autotuner choice), "on"/"off" force the
                    single-launch fused or the multi-launch / pad-scatter
                    lowering (``engine.resolve_fused``).

  * ``quant``     — ambient low-precision spec (DESIGN.md §13) applied by
                    the GEMM-family public entry points (``gemm``,
                    ``grouped_gemm``) when a call does not pass its own:
                    ``None`` (wide, the default), a
                    :class:`~repro.core.descriptor.QuantSpec`, or a
                    shorthand string (``"int8"``/``"w8a16"``/``"fp8"``).
                    Per call, ``quant=False`` opts out of the ambient
                    spec.

Env-var overrides seed the process default at import: ``REPRO_AUTOTUNE=1``,
``REPRO_TUNING_CACHE=/path/to/cache.json``,
``REPRO_TUNING_CACHE_PRELOAD=/path/to/fleet.json``,
``REPRO_AUTOTUNE_BUDGET=K``, ``REPRO_FUSED=auto|on|off``,
``REPRO_QUANT=int8|w8a16|fp8``, ``REPRO_WARM_START=/path/to/manifest.json``.

Configuration is layered: a process-wide default (``configure``) under a
thread-local override stack (``use`` context manager), so a serving thread
can pin ``backend="pallas"`` without racing a training thread.  This module
replaces the private ``_state`` that used to live in ``core.matmul`` and
the ``interpret=`` kwarg that every ``kernels/*/ops.py`` entry point
threaded through.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Optional

from .descriptor import QuantSpec, resolve_quant
from .machine import DEFAULT_MACHINE, MachineModel, get_machine

BACKENDS = ("xla", "pallas")
FUSED_MODES = ("auto", "on", "off")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One immutable snapshot of the engine's ambient configuration."""

    backend: str = "xla"
    interpret: bool = True
    machine: MachineModel = DEFAULT_MACHINE
    # Empirical plan search (DESIGN.md §7).  ``tuning_cache`` is a JSON
    # file path; empty string means "no cache" (``replace`` treats None as
    # "leave unchanged", so "" is the explicit off switch).
    autotune: bool = False
    autotune_budget: int = 8
    tuning_cache: Optional[str] = None
    # Read-only warm-start cache (DESIGN.md §14): a fleet-merged tuning
    # file (tools/tune.py merge) consulted after ``tuning_cache`` misses.
    # Never written — serving processes start with zero autotune stalls
    # without contending on the shared file.
    tuning_cache_preload: Optional[str] = None
    # AOT warm-start manifest (DESIGN.md §15): a recorded descriptor
    # population ``engine.warmup()`` replays with no arguments.  Empty
    # string = explicit off (``replace`` treats None as "leave
    # unchanged", matching ``tuning_cache`` semantics).
    warm_start: Optional[str] = None
    # Plan-execution policy for fused-capable families (DESIGN.md §8/§9):
    # "auto" honors the plan's fused bit; "on"/"off" force the
    # single-launch / multi-launch (or pad-scatter) lowering.
    fused: str = "auto"
    # Ambient quant spec for the GEMM-family entry points (DESIGN.md
    # §13); None = wide execution unless a call passes its own.
    quant: Optional[QuantSpec] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.autotune_budget < 1:
            raise ValueError(f"autotune_budget must be >= 1, "
                             f"got {self.autotune_budget}")
        if self.fused not in FUSED_MODES:
            raise ValueError(f"fused must be one of {FUSED_MODES}, "
                             f"got {self.fused!r}")
        if self.quant is not None and not isinstance(self.quant, QuantSpec):
            raise ValueError(f"quant must be None or a QuantSpec, "
                             f"got {self.quant!r}")

    def replace(self, **kw) -> "EngineConfig":
        kw = {k: v for k, v in kw.items() if v is not None}
        if isinstance(kw.get("machine"), str):
            kw["machine"] = get_machine(kw["machine"])
        if "quant" in kw:
            # quant=False is the explicit off switch (None means "leave
            # unchanged", matching tuning_cache="" semantics).
            kw["quant"] = resolve_quant(kw["quant"])
            if kw["quant"] is None:
                return dataclasses.replace(
                    self, **{k: v for k, v in kw.items() if k != "quant"},
                    quant=None)
        return dataclasses.replace(self, **kw)


def _env_default() -> EngineConfig:
    # A malformed env var must not take down `import repro`: warn and
    # fall back to the field default instead.
    budget = EngineConfig.autotune_budget
    raw = os.environ.get("REPRO_AUTOTUNE_BUDGET")
    if raw:
        try:
            budget = int(raw)
            if budget < 1:
                raise ValueError("must be >= 1")
        except ValueError as e:
            import warnings
            warnings.warn(f"ignoring REPRO_AUTOTUNE_BUDGET={raw!r}: {e}")
            budget = EngineConfig.autotune_budget
    fused = os.environ.get("REPRO_FUSED", "").lower()
    if fused in ("1", "true", "yes"):
        fused = "on"
    elif fused in ("0", "false", "no"):
        fused = "off"
    if fused not in FUSED_MODES:
        if fused:
            import warnings
            warnings.warn(f"ignoring REPRO_FUSED={fused!r}: "
                          f"must be one of {FUSED_MODES}")
        fused = "auto"
    quant = None
    raw = os.environ.get("REPRO_QUANT", "").lower()
    if raw and raw not in ("0", "false", "no", "off", "none"):
        try:
            quant = resolve_quant(raw)
        except ValueError as e:
            import warnings
            warnings.warn(f"ignoring REPRO_QUANT={raw!r}: {e}")
    return EngineConfig(
        autotune=os.environ.get("REPRO_AUTOTUNE", "").lower()
        in ("1", "true", "yes", "on"),
        autotune_budget=budget,
        tuning_cache=os.environ.get("REPRO_TUNING_CACHE") or None,
        tuning_cache_preload=os.environ.get("REPRO_TUNING_CACHE_PRELOAD")
        or None,
        warm_start=os.environ.get("REPRO_WARM_START") or None,
        fused=fused,
        quant=quant,
    )


_DEFAULT = _env_default()
_default_lock = threading.Lock()
_tls = threading.local()


def _stack() -> list:
    if not hasattr(_tls, "stack"):
        _tls.stack = []
    return _tls.stack


def get_config() -> EngineConfig:
    """Effective config: innermost thread-local override, else the global."""
    stack = _stack()
    return stack[-1] if stack else _DEFAULT


def configure(*, backend: Optional[str] = None,
              interpret: Optional[bool] = None,
              machine=None, autotune: Optional[bool] = None,
              autotune_budget: Optional[int] = None,
              tuning_cache: Optional[str] = None,
              tuning_cache_preload: Optional[str] = None,
              warm_start: Optional[str] = None,
              fused: Optional[str] = None, quant=None) -> EngineConfig:
    """Mutate the process-wide default (all threads without an override)."""
    global _DEFAULT
    with _default_lock:
        _DEFAULT = _DEFAULT.replace(backend=backend, interpret=interpret,
                                    machine=machine, autotune=autotune,
                                    autotune_budget=autotune_budget,
                                    tuning_cache=tuning_cache,
                                    tuning_cache_preload=tuning_cache_preload,
                                    warm_start=warm_start,
                                    fused=fused, quant=quant)
        return _DEFAULT


@contextlib.contextmanager
def use(*, backend: Optional[str] = None, interpret: Optional[bool] = None,
        machine=None, autotune: Optional[bool] = None,
        autotune_budget: Optional[int] = None,
        tuning_cache: Optional[str] = None,
        tuning_cache_preload: Optional[str] = None,
        warm_start: Optional[str] = None,
        fused: Optional[str] = None, quant=None):
    """Thread-local override: ``with use(backend="pallas"): ...``."""
    stack = _stack()
    stack.append(get_config().replace(backend=backend, interpret=interpret,
                                      machine=machine, autotune=autotune,
                                      autotune_budget=autotune_budget,
                                      tuning_cache=tuning_cache,
                                      tuning_cache_preload=tuning_cache_preload,
                                      warm_start=warm_start,
                                      fused=fused, quant=quant))
    try:
        yield stack[-1]
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# Back-compat shims (the pre-engine ``core.matmul`` surface)
# ---------------------------------------------------------------------------

def set_backend(backend: str, interpret: Optional[bool] = None):
    """Legacy global setter — prefer :func:`configure` / :func:`use`."""
    configure(backend=backend, interpret=interpret)


def get_backend() -> str:
    """The effective backend name ("xla" or "pallas") — legacy accessor."""
    return get_config().backend


@contextlib.contextmanager
def backend(name: str, interpret: Optional[bool] = None):
    """Legacy context manager — alias of :func:`use`."""
    with use(backend=name, interpret=interpret) as cfg:
        yield cfg
