"""Process-wide engine configuration: backend, interpret mode, machine.

The paper's dispatcher has one piece of ambient state — which lowering
serves a request (generated SME kernel vs vendor BLAS).  Ours has three:

  * ``backend``   — "xla" (dot_general, the vendor-BLAS analogue; default
                    in CPU containers) or "pallas" (the paper's engine:
                    descriptor → plan → generated kernel);
  * ``interpret`` — run Pallas kernels in interpret mode (the CPU
                    correctness path) or compiled (TPU hardware);
  * ``machine``   — the :class:`~repro.core.machine.MachineModel` that
                    parameterizes every tile planner (the "Table I"
                    constants).

Configuration is layered: a process-wide default (``configure``) under a
thread-local override stack (``use`` context manager), so a serving thread
can pin ``backend="pallas"`` without racing a training thread.  This module
replaces the private ``_state`` that used to live in ``core.matmul`` and
the ``interpret=`` kwarg that every ``kernels/*/ops.py`` entry point
threaded through.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

from .machine import DEFAULT_MACHINE, MachineModel, get_machine

BACKENDS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One immutable snapshot of the engine's ambient configuration."""

    backend: str = "xla"
    interpret: bool = True
    machine: MachineModel = DEFAULT_MACHINE

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")

    def replace(self, **kw) -> "EngineConfig":
        kw = {k: v for k, v in kw.items() if v is not None}
        if isinstance(kw.get("machine"), str):
            kw["machine"] = get_machine(kw["machine"])
        return dataclasses.replace(self, **kw)


_DEFAULT = EngineConfig()
_default_lock = threading.Lock()
_tls = threading.local()


def _stack() -> list:
    if not hasattr(_tls, "stack"):
        _tls.stack = []
    return _tls.stack


def get_config() -> EngineConfig:
    """Effective config: innermost thread-local override, else the global."""
    stack = _stack()
    return stack[-1] if stack else _DEFAULT


def configure(*, backend: Optional[str] = None,
              interpret: Optional[bool] = None,
              machine=None) -> EngineConfig:
    """Mutate the process-wide default (all threads without an override)."""
    global _DEFAULT
    with _default_lock:
        _DEFAULT = _DEFAULT.replace(backend=backend, interpret=interpret,
                                    machine=machine)
        return _DEFAULT


@contextlib.contextmanager
def use(*, backend: Optional[str] = None, interpret: Optional[bool] = None,
        machine=None):
    """Thread-local override: ``with use(backend="pallas"): ...``."""
    stack = _stack()
    stack.append(get_config().replace(backend=backend, interpret=interpret,
                                      machine=machine))
    try:
        yield stack[-1]
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# Back-compat shims (the pre-engine ``core.matmul`` surface)
# ---------------------------------------------------------------------------

def set_backend(backend: str, interpret: Optional[bool] = None):
    """Legacy global setter — prefer :func:`configure` / :func:`use`."""
    configure(backend=backend, interpret=interpret)


def get_backend() -> str:
    return get_config().backend


@contextlib.contextmanager
def backend(name: str, interpret: Optional[bool] = None):
    """Legacy context manager — alias of :func:`use`."""
    with use(backend=name, interpret=interpret) as cfg:
        yield cfg
