"""Machine model for the target accelerator (TPU v5e).

This is the "Table I" of the system: the hardware constants that the paper
derives by microbenchmarking M4's SME unit, we pin from the published TPU
v5e specifications. They feed two consumers:

  * the blocking planner (``repro.core.blocking``), which sizes VMEM
    accumulator blocks the way the paper sizes ZA register blockings, and
  * the roofline analysis (``repro.launch.roofline``), which converts
    compiled HLO FLOPs / bytes / collective bytes into seconds.

Models come from two sources, mirroring the paper's two phases:

  * **pinned** — the static Table-I constants below (``TPU_V5E``,
    ``CPU_HOST``), used when the target is not the host;
  * **calibrated** — :meth:`MachineModel.from_probes` folds
    ``repro.core.microbench`` probe results (matmul throughput per dtype,
    streaming bandwidth, per-dispatch overhead) into a copy of a base
    model, exactly like the paper's §III measurements parameterize the
    §IV code generator.  See DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import warnings
from typing import Dict, Iterable, Mapping, Optional, Union

import jax.numpy as jnp

# Fixed cost (seconds) charged per microkernel/grid-step launch by every
# planner cost model (``repro.core.blocking``).  On TPU this models grid
# sequencing + pipeline refill; calibration replaces it with the measured
# dispatch latency.  The value only needs to rank plans, not predict
# wall-clock.
DEFAULT_STEP_OVERHEAD_S = 2.0e-7

# Fixed cost (seconds) charged per *kernel launch* (one ``pallas_call``
# dispatch: argument marshalling, grid setup, pipeline warm-up).  This is
# what the fused single-launch GEMM path (DESIGN.md §8) amortizes: a
# multi-launch plan pays it once per region, the fused plan exactly once.
DEFAULT_LAUNCH_OVERHEAD_S = 2.0e-6

# Refittable lowering-cost coefficients (DESIGN.md §15).  The seed values
# are the BENCH_gemm_fused.json calibration from ``repro.core.blocking``;
# an offline ``tools/tune.py refit`` replaces them (and the two dispatch
# overheads above) with a robust least-squares fit of the fleet's
# accumulated TuningCache timings.
DEFAULT_FUSED_TILE_DECODE_S = 6e-7  # per fused grid step: table decode
DEFAULT_EXTRA_LAUNCH_FACTOR = 0.25  # cost of each launch beyond the first
DEFAULT_STITCH_DISCOUNT = 0.25      # fraction of naive stitch bytes paid

# Version of the refit-model JSON emitted by ``tools/tune.py refit`` and
# consumed by :func:`load_refit_model`.
REFIT_MODEL_VERSION = 1

# Coefficients a refit model may carry.  :func:`load_refit_model` rejects
# files mentioning anything else: an unknown key means the file was
# written by a newer tool than this reader understands (the "stale
# reader" degradation path — fall back to the probe-only base).
REFIT_COEFFICIENTS = (
    "step_overhead_s", "launch_overhead_s", "extra_launch_factor",
    "fused_tile_decode_s", "stitch_discount",
    "ici_bandwidth_gbps", "collective_launch_s", "collective_efficiency",
)


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Performance model of one accelerator chip and its interconnect."""

    name: str
    # --- compute ---------------------------------------------------------
    # Peak MACs structured as (sublane, lane) native register tiling and the
    # systolic array dimensions.  SME analogue: SVL=512b => 16x16 fp32 ZA
    # tile; TPU v5e: 128x128 MXU.
    mxu_rows: int
    mxu_cols: int
    peak_flops: Dict[str, float]  # dtype name -> FLOP/s per chip
    # --- memory hierarchy -------------------------------------------------
    hbm_bytes: int
    hbm_bw: float  # bytes/s
    vmem_bytes: int
    # native register tile (second-minor, minor) granule per dtype
    sublanes: Dict[str, int]
    lanes: int
    # --- interconnect ------------------------------------------------------
    ici_bw_per_link: float  # bytes/s per ICI link
    ici_links: int  # links per chip in the 2D torus
    dcn_bw: float  # bytes/s per chip across pods
    # --- dispatch ----------------------------------------------------------
    # per-microkernel/grid-step launch overhead charged by plan cost models
    step_overhead_s: float = DEFAULT_STEP_OVERHEAD_S
    # per-pallas_call dispatch overhead (the cost the fused single-launch
    # path pays once and the multi-launch path pays per region)
    launch_overhead_s: float = DEFAULT_LAUNCH_OVERHEAD_S
    # --- calibrated network (DESIGN.md §14) --------------------------------
    # ``None`` means *not network-calibrated*: the interconnect probes did
    # not run (1-device host, or a pinned Table-I model).  The planner then
    # falls back to the pinned per-link aggregate and ``fingerprint`` /
    # ``tuning_key`` carry the provenance so tuned-cache records never mix
    # calibrated and uncalibrated machines.
    ici_bandwidth_gbps: Optional[float] = None  # measured all_gather GB/s
    collective_launch_s: Optional[float] = None  # per-collective launch cost
    # per-collective bandwidth efficiency relative to the all_gather probe,
    # e.g. {"all_gather": 1.0, "all_to_all": 0.7, "psum": 0.5}
    collective_efficiency: Optional[Dict[str, float]] = None
    # --- refittable lowering costs (DESIGN.md §15) -------------------------
    # Per fused grid step: tile-table decode + predication — what the
    # fused single-launch path pays instead of per-region dispatch.
    fused_tile_decode_s: float = DEFAULT_FUSED_TILE_DECODE_S
    # Cost of each kernel launch beyond the first, as a fraction of
    # ``launch_overhead_s`` (later launches reuse warm dispatch state).
    extra_launch_factor: float = DEFAULT_EXTRA_LAUNCH_FACTOR
    # Fraction of the naive stitch-traffic bytes the multi-launch path
    # really pays (operand slices + C assembly overlap with compute).
    stitch_discount: float = DEFAULT_STITCH_DISCOUNT
    # --- refit provenance (DESIGN.md §15) ----------------------------------
    # ``None`` = probe-only / pinned coefficients.  Otherwise the
    # fingerprint of the offline refit model (``tools/tune.py refit``)
    # that replaced them: ``fingerprint`` / ``tuning_key`` then grow a
    # ``+refit`` suffix so tuned-cache records never mix fitted and
    # probe-only machines — the same isolation rule as PR 9's ``+net``.
    refit_fingerprint: Optional[str] = None

    # ---------------------------------------------------------------------
    @property
    def network_calibrated(self) -> bool:
        """True when the interconnect probes parameterized this model."""
        return self.ici_bandwidth_gbps is not None

    @property
    def _provenance(self) -> str:
        """Provenance suffix shared by ``fingerprint`` and ``tuning_key``:
        ``+net`` for network-calibrated models, ``+refit`` for offline-
        refitted coefficients — composable (``+net+refit``)."""
        return (("+net" if self.network_calibrated else "")
                + ("+refit" if self.refit_fingerprint else ""))

    @property
    def fingerprint(self) -> str:
        """Short digest of every model constant.

        Cache keys that would otherwise trust ``name`` alone include this:
        two calibrations of the same host share a name but can carry
        different measured constants, and analytical plans derived from
        one must not be served for the other.  Network-calibrated models
        carry a ``+net`` provenance suffix and offline-refitted models a
        ``+refit`` suffix so the digest alone makes the calibration state
        legible in cache records and logs.
        """
        blob = repr(dataclasses.astuple(self)).encode()
        digest = hashlib.md5(blob).hexdigest()[:8]
        return digest + self._provenance

    @property
    def tuning_key(self) -> str:
        """Name used to key :class:`~repro.core.autotune.TuningCache`
        records.  Uncalibrated machines keep their plain ``name`` (existing
        on-disk records stay valid); network-calibrated machines get a
        ``+net`` suffix and offline-refitted machines a ``+refit`` suffix
        so their records never mix with probe-only ones — the cost models
        rank candidates differently (DESIGN.md §14/§15).
        """
        return self.name + self._provenance

    def peak(self, dtype) -> float:
        return self.peak_flops[canonical_dtype(dtype)]

    def reg_tile(self, dtype) -> tuple[int, int]:
        """Native (sublane, lane) register tile for ``dtype``.

        The analogue of the paper's SVL-determined tile: on M4 a ZA fp32
        tile is 16x16; on TPU the packing granule is (8,128) fp32 /
        (16,128) bf16 / (32,128) int8.
        """
        return (self.sublanes[canonical_dtype(dtype)], self.lanes)

    def mxu_tile(self) -> tuple[int, int]:
        return (self.mxu_rows, self.mxu_cols)

    # Roofline helpers ------------------------------------------------------
    def compute_seconds(self, flops: float, dtype="bfloat16", chips: int = 1) -> float:
        return flops / (self.peak(dtype) * chips)

    def memory_seconds(self, nbytes: float, chips: int = 1) -> float:
        return nbytes / (self.hbm_bw * chips)

    def collective_seconds(self, nbytes: float, chips: int = 1,
                           collective: str = "all_gather") -> float:
        """Seconds to move ``nbytes`` through one ``collective``.

        Calibrated path: measured all_gather bandwidth scaled by the
        per-collective efficiency ratio, plus the measured launch cost —
        the §III-style "honest" model the mesh planner charges
        (DESIGN.md §14).  Uncalibrated path: the pinned per-link
        aggregate, launch cost folded in from ``launch_overhead_s`` so
        gathered/distributed candidates still rank.
        """
        if self.network_calibrated:
            eff = 1.0
            if self.collective_efficiency:
                eff = self.collective_efficiency.get(collective, 1.0)
            bw = self.ici_bandwidth_gbps * 1e9 * max(eff, 1e-6)
            launch = self.collective_launch_s or 0.0
            return launch + nbytes / (bw * chips)
        # Aggregate ICI model: each chip drives ici_links links.
        return (self.launch_overhead_s
                + nbytes / (self.ici_bw_per_link * chips))

    # Calibration -----------------------------------------------------------
    @classmethod
    def from_probes(cls, probes: Union[Mapping[str, "object"], Iterable],
                    base: "MachineModel" = None,
                    name: str = "calibrated") -> "MachineModel":
        """Build a calibrated model from ``repro.core.microbench`` probes.

        ``probes`` is the dict returned by ``microbench.characterize`` (or
        any iterable of its ``ProbeResult``s).  Recognized probes override
        the corresponding ``base`` constants (default: ``CPU_HOST``):

          * ``matmul_<dtype>``  [GFLOP/s] -> ``peak_flops[dtype]``
          * ``copy_bw``         [GB/s]    -> ``hbm_bw``
          * ``dispatch_latency``[us]      -> ``step_overhead_s``
          * ``all_gather_bw``   [GB/s]    -> ``ici_bandwidth_gbps``
          * ``all_to_all_bw`` / ``psum_bw`` [GB/s]
                                -> ``collective_efficiency`` ratios
          * ``collective_latency`` [us]   -> ``collective_launch_s``

        Unrecognized probes (e.g. the ``target_*`` echo entries) are
        ignored; missing probes leave the base constant in place — a
        partial probe run still yields a usable model (DESIGN.md §7).
        The interconnect probes are all-or-nothing per DESIGN.md §14: on a
        1-device host they report value 0 and the network fields stay the
        explicit ``None`` ("not network-calibrated"), never a fake number.
        """
        base = base if base is not None else CPU_HOST
        if isinstance(probes, Mapping):
            probes = probes.values()
        peak = dict(base.peak_flops)
        hbm_bw = base.hbm_bw
        overhead = base.step_overhead_s
        launch = base.launch_overhead_s
        net = {}
        for p in probes:
            pname, value = p.name, p.value
            if pname.startswith("matmul_"):
                dtype = pname[len("matmul_"):]
                if dtype in peak and value > 0:
                    peak[dtype] = value * 1e9
            elif pname == "copy_bw" and value > 0:
                hbm_bw = value * 1e9
            elif pname == "dispatch_latency" and value > 0:
                # The probe measures one full dispatch round-trip: it is
                # both the per-step pipeline cost bound (PR 2 semantics)
                # and the per-pallas_call launch cost the fused GEMM path
                # amortizes (DESIGN.md §8).
                overhead = value * 1e-6
                launch = value * 1e-6
            elif pname in ("all_gather_bw", "all_to_all_bw", "psum_bw",
                           "collective_latency") and value > 0:
                net[pname] = value
        kwargs = dict(name=name, peak_flops=peak, hbm_bw=hbm_bw,
                      step_overhead_s=overhead, launch_overhead_s=launch)
        if "all_gather_bw" in net:
            ag = net["all_gather_bw"]
            eff = {"all_gather": 1.0}
            if "all_to_all_bw" in net:
                eff["all_to_all"] = net["all_to_all_bw"] / ag
            if "psum_bw" in net:
                eff["psum"] = net["psum_bw"] / ag
            kwargs["ici_bandwidth_gbps"] = ag
            kwargs["collective_efficiency"] = eff
            kwargs["collective_launch_s"] = (
                net["collective_latency"] * 1e-6
                if "collective_latency" in net else launch)
        return dataclasses.replace(base, **kwargs)


# fp8 support is build-dependent: gate every fp8 path on this flag
# instead of letting an AttributeError surface mid-dispatch (DESIGN.md
# §13).  ``FP8_DTYPE`` is the jnp dtype when present, else None.
HAS_FP8 = hasattr(jnp, "float8_e4m3fn")
FP8_DTYPE = jnp.float8_e4m3fn if HAS_FP8 else None


def canonical_dtype(dtype) -> str:
    """Canonical descriptor dtype name ("bfloat16"/"float32"/...) for any
    dtype-like — descriptors never store raw ``jnp.dtype`` objects."""
    if isinstance(dtype, str) and dtype in ("float8_e4m3", "float8_e4m3fn"):
        # The canonical name maps the *fn* jnp dtype; accept it even on
        # builds without the dtype so descriptors mentioning fp8 can be
        # keyed (execution is gated separately on HAS_FP8).
        return "float8_e4m3"
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.bfloat16):
        return "bfloat16"
    if d == jnp.dtype(jnp.float32):
        return "float32"
    if d == jnp.dtype(jnp.float16):
        return "float16"
    if d == jnp.dtype(jnp.int8):
        return "int8"
    if HAS_FP8 and d == jnp.dtype(FP8_DTYPE):
        return "float8_e4m3"
    if d == jnp.dtype(jnp.float64):
        return "float64"
    raise ValueError(f"unsupported dtype for machine model: {dtype}")


# TPU v5e constants.  peak bf16 = 197 TFLOP/s (given); fp32 through the MXU
# runs at half rate with fp32 accumulate; int8 doubles bf16 — mirroring the
# dtype asymmetry the paper measures in Table I (where M4 is FP32-centric;
# v5e is bf16-centric: the engine's dtype default flips accordingly).
TPU_V5E = MachineModel(
    name="tpu_v5e",
    mxu_rows=128,
    mxu_cols=128,
    peak_flops={
        "bfloat16": 197e12,
        "float16": 197e12,
        "float32": 98.5e12,
        "int8": 394e12,
        "float8_e4m3": 394e12,  # fp8 rides the int8 MAC rate
        "float64": 0.5e12,  # emulated; not a target dtype
    },
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    vmem_bytes=128 * 1024**2,
    sublanes={"float32": 8, "bfloat16": 16, "float16": 16, "int8": 32,
              "float8_e4m3": 32, "float64": 8},
    lanes=128,
    ici_bw_per_link=50e9,
    ici_links=4,
    dcn_bw=25e9 / 8,  # ~25 Gb/s effective per chip across pods
)

# The CPU host we validate on (interpret mode).  Only used to sanity-scale
# wall-clock expectations in benchmarks; never by the planner.
CPU_HOST = MachineModel(
    name="cpu_host",
    mxu_rows=1,
    mxu_cols=1,
    peak_flops={"bfloat16": 5e9, "float16": 5e9, "float32": 1e10, "int8": 2e10,
                "float8_e4m3": 2e10, "float64": 5e9},
    hbm_bytes=32 * 1024**3,
    hbm_bw=20e9,
    vmem_bytes=1 * 1024**2,
    sublanes={"float32": 8, "bfloat16": 16, "float16": 16, "int8": 32,
              "float8_e4m3": 32, "float64": 8},
    lanes=128,
    ici_bw_per_link=1e9,
    ici_links=1,
    dcn_bw=1e9,
)

DEFAULT_MACHINE = TPU_V5E


def get_machine(name: str = "tpu_v5e") -> MachineModel:
    """Look up a built-in machine model by name."""
    return {"tpu_v5e": TPU_V5E, "cpu_host": CPU_HOST}[name]


def _validate_refit(data, base: MachineModel) -> Optional[str]:
    """The reason a refit-model payload cannot be applied, or None."""
    if not isinstance(data, dict):
        return "not a JSON object"
    if data.get("kind") != "machine-refit":
        return f"kind={data.get('kind')!r}, expected 'machine-refit'"
    if data.get("version") != REFIT_MODEL_VERSION:
        return (f"version={data.get('version')!r}, expected "
                f"{REFIT_MODEL_VERSION} (stale model or stale reader)")
    fp = data.get("fingerprint")
    if not isinstance(fp, str) or not fp:
        return "missing provenance fingerprint"
    if data.get("base") not in (None, base.name):
        return (f"fitted against base {data.get('base')!r}, "
                f"refusing to overlay onto {base.name!r}")
    coeffs = data.get("coefficients")
    if not isinstance(coeffs, dict) or not coeffs:
        return "missing coefficients"
    for key, value in coeffs.items():
        if key not in REFIT_COEFFICIENTS:
            return f"unknown coefficient {key!r} (stale reader?)"
        if key == "collective_efficiency":
            if not isinstance(value, dict) or not all(
                    isinstance(k, str) and isinstance(v, (int, float))
                    and math.isfinite(v) and v > 0
                    for k, v in value.items()):
                return "collective_efficiency must map names to ratios > 0"
        elif (not isinstance(value, (int, float)) or isinstance(value, bool)
              or not math.isfinite(value) or value < 0):
            return f"coefficient {key}={value!r} is not a finite number >= 0"
    return None


def load_refit_model(path: str,
                     base: Optional[MachineModel] = None) -> MachineModel:
    """Overlay an offline-refit coefficient model onto ``base``.

    Reads the versioned JSON that ``tools/tune.py refit`` emits and
    returns ``base`` with the fitted cost coefficients applied and
    ``refit_fingerprint`` set — so ``fingerprint`` / ``tuning_key`` grow
    the ``+refit`` provenance suffix (DESIGN.md §15).

    Degradation mirrors the tuning cache's: a missing, corrupt, stale
    (wrong version/kind), wrong-base or out-of-range file warns once and
    returns ``base`` unchanged — a bad refit artifact must never take
    down serving, it just keeps the probe-only model.
    """
    base = base if base is not None else DEFAULT_MACHINE
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        warnings.warn(f"ignoring refit model {path}: {e}")
        return base
    reason = _validate_refit(data, base)
    if reason is not None:
        warnings.warn(f"ignoring refit model {path}: {reason}")
        return base
    return dataclasses.replace(base, **data["coefficients"],
                               refit_fingerprint=data["fingerprint"])
