"""Offline MachineModel coefficient refit from TuningCache timings.

The system's loop so far is *measure → generate*: §III-style probes
calibrate a :class:`~repro.core.machine.MachineModel`, the model ranks
candidate plans, and the autotuner corrects individual rankings with
real timings that accumulate in the :class:`~repro.core.autotune.
TuningCache`.  This module closes the remaining arc — *generate →
re-measure → refit* (DESIGN.md §15): the accumulated fleet timings are
regressed back onto the model's cost coefficients, so the analytical
tier itself gets honest, not just the individual cached winners.

Mechanics: every plan's ``predicted_seconds(machine)`` is affine in the
five dispatch coefficients (``step_overhead_s``, ``launch_overhead_s``,
``launch_overhead_s * extra_launch_factor``, ``fused_tile_decode_s``,
``stitch_discount``), so exact per-record features come from finite
differencing the predictor against a coefficient-zeroed machine — no
per-family analytic decomposition, and any future family cost model is
fitted automatically.  The residual (measured seconds minus the
coefficient-free roofline base) is solved by least squares with Huber
IRLS reweighting (fleet timings contain outliers) and non-negativity
clipping.  Mesh records additionally feed a second linear stage that
backs out ``collective_launch_s``, ``ici_bandwidth_gbps`` and the
``collective_efficiency`` ratios from the modeled collective events.

The output is a versioned refit-model JSON with a provenance fingerprint
(:data:`~repro.core.machine.REFIT_MODEL_VERSION`); applying it stamps
``refit_fingerprint`` so ``fingerprint`` / ``tuning_key`` grow the
``+refit`` suffix and tuned records never mix fitted and probe-only
machines.  ``tools/tune.py refit`` is the CLI wrapper.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import autotune as _autotune
from .blocking import mesh_comm_events
from .descriptor import descriptor_from_cache_key
from .machine import (DEFAULT_MACHINE, MachineModel, REFIT_MODEL_VERSION)

# The five dispatch coefficients the main fit solves for, in feature
# order.  ``extra_launch_s`` is the linearized product
# ``launch_overhead_s * extra_launch_factor`` (the factor itself is
# recovered by division after the solve).
FIT_FEATURES = ("step_overhead_s", "launch_overhead_s", "extra_launch_s",
                "fused_tile_decode_s", "stitch_discount")

# Coefficient values that zero every fitted term out of the predictor,
# leaving only the roofline base (compute/memory/bandwidth terms).
_ZEROED = dict(step_overhead_s=0.0, launch_overhead_s=0.0,
               extra_launch_factor=0.0, fused_tile_decode_s=0.0,
               stitch_discount=0.0)

_COLLECTIVES = ("all_gather", "all_to_all", "psum")


def parse_entry(key: str, record: dict) -> Optional[Tuple[str, str, Any]]:
    """Decode one TuningCache entry into ``(machine_key, mode, plan)``.

    The entry key is ``<machine.tuning_key>|<mode>|<desc-cache-key-repr>``
    (see ``autotune._entry_key``); the cache-key repr is invertible via
    :func:`~repro.core.descriptor.descriptor_from_cache_key` and the
    knob record rebuilds the exact timed plan via ``plan_from_record``.
    Returns ``None`` for anything unparsable or without a measured
    ``us`` — the fit just skips it.
    """
    try:
        machine_key, mode, desc_repr = key.split("|", 2)
        desc = descriptor_from_cache_key(ast.literal_eval(desc_repr))
    except (ValueError, SyntaxError, TypeError, KeyError):
        return None
    if not isinstance(record, dict) or "us" not in record:
        return None
    plan = _autotune.plan_from_record(desc, record)
    if plan is None:
        return None
    return machine_key, mode, plan


def plan_features(plan: Any, machine: MachineModel
                  ) -> Tuple[float, Tuple[float, ...]]:
    """``(base_seconds, per-coefficient features)`` of one plan.

    ``predicted_seconds`` is affine in each fitted coefficient, so the
    features are exact finite differences against a coefficient-zeroed
    copy of ``machine``: predicted = base + features · coefficients.
    """
    zero = dataclasses.replace(machine, **_ZEROED)
    base = plan.predicted_seconds(zero)

    def bump(**kw) -> float:
        return plan.predicted_seconds(
            dataclasses.replace(zero, **kw)) - base

    f_step = bump(step_overhead_s=1.0)
    f_launch = bump(launch_overhead_s=1.0)
    # launch term: lo * (1 + (L-1) * ef) — with lo=ef=1 the difference
    # minus f_launch isolates the (L-1) extra-launch feature.
    f_extra = bump(launch_overhead_s=1.0,
                   extra_launch_factor=1.0) - f_launch
    f_decode = bump(fused_tile_decode_s=1.0)
    f_stitch = bump(stitch_discount=1.0)
    return base, (f_step, f_launch, f_extra, f_decode, f_stitch)


def _irls_lstsq(X: np.ndarray, y: np.ndarray,
                robust_iters: int) -> np.ndarray:
    """Least squares with Huber IRLS reweighting (column-scaled)."""
    scale = np.abs(X).max(axis=0)
    scale[scale == 0] = 1.0
    Xs = X / scale
    w = np.ones(len(y))
    beta = np.zeros(X.shape[1])
    for _ in range(robust_iters + 1):
        sw = np.sqrt(w)[:, None]
        beta, *_ = np.linalg.lstsq(Xs * sw, y * np.sqrt(w), rcond=None)
        r = y - Xs @ beta
        s = 1.4826 * np.median(np.abs(r)) + 1e-12
        w = np.minimum(1.0, 1.345 * s / np.maximum(np.abs(r), 1e-12))
    return beta / scale


def fit_records(records: Iterable[Tuple[Any, float]],
                base: MachineModel = DEFAULT_MACHINE, *,
                robust_iters: int = 3) -> Dict[str, Any]:
    """Fit the dispatch coefficients from ``(plan, measured_us)`` pairs.

    Returns the refit payload core: ``coefficients`` (fitted values,
    unfitted ones carried over from ``base``), ``fitted`` (which names
    the record set could actually identify — a column nothing exercises,
    e.g. ``stitch_discount`` with no multi-region records, keeps the base
    value), ``entries`` and before/after RMS residuals in µs.  Raises
    ``ValueError`` when no record is usable.
    """
    plans, bases, rows, y = [], [], [], []
    for plan, us in records:
        b, f = plan_features(plan, base)
        plans.append(plan)
        bases.append(b)
        rows.append(f)
        y.append(us * 1e-6 - b)
    if not rows:
        raise ValueError("no usable records to fit")
    X = np.asarray(rows, float)
    yv = np.asarray(y, float)
    active = np.flatnonzero(np.abs(X).max(axis=0) > 0)
    beta = np.zeros(X.shape[1])
    if active.size:
        beta[active] = _irls_lstsq(X[:, active], yv, robust_iters)
    beta = np.maximum(beta, 0.0)  # a charge cannot be negative
    step, launch, extra, decode, stitch = beta
    fitted = [FIT_FEATURES[i] for i in active]
    coeffs = {
        "step_overhead_s": float(step) if "step_overhead_s" in fitted
        else base.step_overhead_s,
        "launch_overhead_s": float(launch) if "launch_overhead_s" in fitted
        else base.launch_overhead_s,
        "fused_tile_decode_s": float(decode)
        if "fused_tile_decode_s" in fitted else base.fused_tile_decode_s,
        # stitch feature was computed at discount 1.0, so the coefficient
        # IS the discount; it is a fraction of naive bytes by definition.
        "stitch_discount": float(min(stitch, 1.0))
        if "stitch_discount" in fitted else base.stitch_discount,
    }
    if "extra_launch_s" in fitted and launch > 1e-12:
        coeffs["extra_launch_factor"] = float(
            np.clip(extra / launch, 0.0, 4.0))
        fitted[fitted.index("extra_launch_s")] = "extra_launch_factor"
    else:
        coeffs["extra_launch_factor"] = base.extra_launch_factor
        if "extra_launch_s" in fitted:
            fitted.remove("extra_launch_s")
    before = np.asarray(
        [plan.predicted_seconds(base) for plan in plans]) \
        - (np.asarray(bases) + yv)
    after = (np.asarray(bases) + X @ beta) - (np.asarray(bases) + yv)
    return {
        "coefficients": coeffs,
        "fitted": fitted,
        "entries": len(plans),
        "residual_us": {
            "before": round(float(np.sqrt(np.mean(before**2))) * 1e6, 3),
            "after": round(float(np.sqrt(np.mean(after**2))) * 1e6, 3),
        },
    }


def _comm_free(machine: MachineModel) -> MachineModel:
    """A copy of ``machine`` whose collective costs are ~zero, so a mesh
    plan's ``predicted_seconds`` yields just the local-kernel part."""
    return dataclasses.replace(machine, ici_bandwidth_gbps=1e30,
                               collective_launch_s=0.0,
                               collective_efficiency=None)


def fit_network(records: Iterable[Tuple[Any, float]],
                fitted_machine: MachineModel) -> Optional[Dict[str, Any]]:
    """Back out collective coefficients from mesh records.

    Solves ``measured - local_pred = n_events * collective_launch_s +
    Σ_c bytes_c * seconds_per_byte_c`` over the records that carry a
    mesh strategy, then converts seconds-per-byte back to
    ``ici_bandwidth_gbps`` (from the all_gather column) and
    ``collective_efficiency`` ratios.  Returns ``None`` when the mesh
    population cannot identify the system (too few records, or no
    all_gather traffic) — the network model then stays probe-only.
    """
    rows, y = [], []
    for plan, us in records:
        comm = getattr(plan, "comm", None)
        if comm is None or getattr(plan.desc, "mesh", None) is None:
            continue
        events = mesh_comm_events(plan.desc, comm)
        if not events:
            continue
        feat = [float(len(events))] + [0.0] * len(_COLLECTIVES)
        for c, nbytes in events:
            if c in _COLLECTIVES:
                feat[1 + _COLLECTIVES.index(c)] += float(nbytes)
        local = plan.predicted_seconds(_comm_free(fitted_machine))
        rows.append(feat)
        y.append(us * 1e-6 - local)
    if not rows:
        return None
    X = np.asarray(rows, float)
    yv = np.asarray(y, float)
    active = np.flatnonzero(np.abs(X).max(axis=0) > 0)
    if len(rows) < active.size or 1 not in active:  # all_gather column
        return None
    beta = np.zeros(X.shape[1])
    beta[active] = np.maximum(_irls_lstsq(X[:, active], yv, 2), 0.0)
    spb_ag = beta[1]
    if spb_ag <= 0:
        return None
    eff = {"all_gather": 1.0}
    for i, c in enumerate(_COLLECTIVES[1:], start=2):
        if beta[i] > 0:
            eff[c] = float(np.clip(spb_ag / beta[i], 1e-3, 1.0))
    return {"collective_launch_s": float(beta[0]),
            "ici_bandwidth_gbps": float(1.0 / (spb_ag * 1e9)),
            "collective_efficiency": eff,
            "entries": len(rows)}


def fit_cache_entries(entries: Dict[str, dict],
                      base: MachineModel = DEFAULT_MACHINE, *,
                      machine: Optional[str] = None,
                      mode: Optional[str] = None) -> Dict[str, Any]:
    """Fit a refit model from raw TuningCache entries.

    ``entries`` is the ``{key: record}`` dict of one (possibly fleet-
    merged) tuning-cache file; ``machine`` filters by tuning-key prefix
    (the ``+net``/``+refit`` provenance rules of ``tools/tune.py``
    apply) and ``mode`` by ``"interpret"``/``"compiled"``.  Returns the
    full versioned refit-model payload for :func:`save_refit_model` /
    :func:`~repro.core.machine.load_refit_model`, with a provenance
    fingerprint digesting the exact records fitted plus the base model.
    """
    records: List[Tuple[Any, float]] = []
    lines = []
    skipped = 0
    for key in sorted(entries):
        parsed = parse_entry(key, entries[key])
        if parsed is None:
            skipped += 1
            continue
        machine_key, entry_mode, plan = parsed
        if machine and not machine_key.startswith(machine):
            continue
        if mode and entry_mode != mode:
            continue
        us = float(entries[key]["us"])
        records.append((plan, us))
        lines.append(f"{key}:{us}")
    fit = fit_records(records, base)
    net = fit_network(records, dataclasses.replace(
        base, **{k: v for k, v in fit["coefficients"].items()}))
    if net is not None:
        fit["coefficients"]["collective_launch_s"] = \
            net["collective_launch_s"]
        fit["coefficients"]["ici_bandwidth_gbps"] = \
            net["ici_bandwidth_gbps"]
        fit["coefficients"]["collective_efficiency"] = \
            net["collective_efficiency"]
        fit["fitted"] += ["collective_launch_s", "ici_bandwidth_gbps",
                          "collective_efficiency"]
    blob = (base.fingerprint + "\n" + "\n".join(lines)).encode()
    return {
        "version": REFIT_MODEL_VERSION,
        "kind": "machine-refit",
        "base": base.name,
        "machine": machine or "",
        "mode": mode or "any",
        "fingerprint": hashlib.md5(blob).hexdigest()[:12],
        "skipped": skipped,
        **fit,
    }


def apply_fit(base: MachineModel, model: Dict[str, Any]) -> MachineModel:
    """Overlay an in-memory refit payload (``fit_cache_entries`` output)
    onto ``base``, stamping the ``+refit`` provenance.  The validated
    from-disk path is :func:`~repro.core.machine.load_refit_model`."""
    return dataclasses.replace(base, **model["coefficients"],
                               refit_fingerprint=model["fingerprint"])


def save_refit_model(path: str, model: Dict[str, Any]) -> None:
    """Atomic JSON write of one refit-model payload."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".refit.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(model, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def count_misranks(pairs: Iterable[Tuple[Any, Any, float, float]],
                   machine: MachineModel, *,
                   deadband: float = 0.1) -> Tuple[int, int]:
    """``(misranks, considered)`` of the analytical tier on measured pairs.

    ``pairs`` holds ``(plan_a, plan_b, us_a, us_b)`` — the two lowerings
    of one problem with their measured times.  A pair counts as a
    misrank when the model prefers one lowering and the measurement
    (outside the ``deadband`` relative margin — near-ties prove nothing
    either way) prefers the other.  Used by ``benchmarks/
    fig89_gemm_sweep.py`` to score a machine model before/after refit.
    """
    bad = considered = 0
    for pa, pb, ua, ub in pairs:
        lo = min(ua, ub)
        if lo <= 0 or abs(ua - ub) / lo < deadband:
            continue
        considered += 1
        model_a = (pa.predicted_seconds(machine)
                   < pb.predicted_seconds(machine))
        if model_a != (ua < ub):
            bad += 1
    return bad, considered
