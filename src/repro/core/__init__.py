"""The paper's primary contribution: a JIT small-GEMM engine for matrix
units, adapted from M4/SME (the paper's target) to TPU/MXU.

  * ``machine``    — hardware model ("Table I" constants)
  * ``descriptor`` — GEMM metadata (libxsmm descriptor analogue)
  * ``blocking``   — heterogeneous accumulator-blocking planner (§IV-B)
  * ``jit_cache``  — kernel registry (libxsmm JIT dispatch analogue)
  * ``matmul``     — public dispatch used by every model layer
  * ``microbench`` — machine-characterization harness (§III analogue)
"""
from repro.core.descriptor import GemmDescriptor  # noqa: F401
from repro.core.blocking import BlockingPlan, Region, plan_gemm, palette  # noqa: F401
from repro.core.machine import MachineModel, TPU_V5E, DEFAULT_MACHINE, get_machine  # noqa: F401
from repro.core.matmul import matmul, set_backend, get_backend, backend  # noqa: F401
from repro.core.jit_cache import GLOBAL_KERNEL_CACHE, KernelCache  # noqa: F401
