"""The paper's primary contribution: a JIT small-GEMM engine for matrix
units, adapted from M4/SME (the paper's target) to TPU/MXU — generalized
to every kernel family in the system (DESIGN.md).

  * ``machine``    — hardware model ("Table I" constants + calibration)
  * ``config``     — process-wide backend/interpret/machine/autotune config
  * ``descriptor`` — per-family kernel metadata (libxsmm descriptor analogue)
  * ``blocking``   — machine-model tile planners, all families (§IV-B)
  * ``schedule``   — fused-execution tile schedules + predication helpers
  * ``autotune``   — empirical plan search + persistent tuning cache (§7)
  * ``jit_cache``  — LRU kernel registry (libxsmm JIT dispatch analogue)
  * ``engine``     — family registry + three-tier planning + dispatch
  * ``matmul``     — public GEMM dispatch used by every model layer
  * ``microbench`` — machine-characterization harness (§III analogue)
"""
from repro.core.descriptor import (  # noqa: F401
    FlashBwdDescriptor, FlashDescriptor, GemmDescriptor,
    GroupedGemmBwdDescriptor, GroupedGemmDescriptor, KernelDescriptor,
    MeshSpec, SsdChunkBwdDescriptor, SsdChunkDescriptor,
    TransposeDescriptor)
from repro.core.blocking import (  # noqa: F401
    BlockingPlan, FlashPlan, GroupedGemmPlan, MESH_STRATEGIES, Region,
    SsdChunkPlan, TransposePlan, candidate_plans, flash_bwd_fused_legal,
    flash_fused_legal, fused_legal, grouped_bwd_fused_legal,
    grouped_fused_legal, mesh_comm_events, mesh_comm_seconds,
    mesh_local_desc, palette, plan_flash, plan_flash_bwd, plan_gemm,
    plan_grouped, plan_grouped_bwd, plan_ssd, plan_ssd_bwd, plan_transpose,
    ssd_bwd_fused_legal, ssd_fused_legal)
from repro.core.schedule import (  # noqa: F401
    FlashTileSchedule, GroupedTileSchedule, TileSchedule,
    flash_tile_schedule, flatten_regions, plan_launches)
from repro.core.machine import (  # noqa: F401
    CPU_HOST, MachineModel, TPU_V5E, DEFAULT_MACHINE, get_machine)
from repro.core.config import (  # noqa: F401
    EngineConfig, backend, configure, get_backend, get_config, set_backend,
    use)
from repro.core.autotune import TuningCache  # noqa: F401
from repro.core.matmul import matmul  # noqa: F401
from repro.core.jit_cache import (  # noqa: F401
    GLOBAL_KERNEL_CACHE, KernelCache, LruCache)
from repro.core import engine  # noqa: F401
