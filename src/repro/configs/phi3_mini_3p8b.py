"""Phi-3-mini-3.8B [arXiv:2404.14219; unverified]. Dense MHA (kv=32),
head_dim=96 (non-lane-aligned edge case for the GEMM planner), RoPE,
SwiGLU."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope=True,
    mlp_act="silu",
    mlp_gated=True,
    source="arXiv:2404.14219 (unverified)",
))
