"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified]. 38 blocks,
pattern (rec, rec, local-attn) = 1 local-attention per 2 RG-LRU blocks,
MQA (kv=1), window 2048, GeGLU MLP, embed scaling. Sub-quadratic:
long_500k runs."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    rope=True,
    attn_window=2048,
    block_pattern=("rec", "rec", "local"),
    rglru_width=4096,
    conv1d_width=4,
    mlp_act="gelu",
    mlp_gated=True,
    embed_scale=True,
    source="arXiv:2402.19427 (unverified)",
))
