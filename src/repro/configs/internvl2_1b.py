"""InternVL2-1B [arXiv:2404.16821; hf]. InternViT-300M frontend (STUB:
precomputed patch embeddings, 1024-d) + Qwen2-0.5B LM backbone: 24L,
d=896, 14 heads (GQA kv=2), head_dim=64, QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    rope=True,
    rope_theta=1000000.0,
    qkv_bias=True,
    mlp_act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    modality="vision",
    modality_dim=1024,
    num_modality_tokens=256,
    source="arXiv:2404.16821 (verified: hf)",
))
