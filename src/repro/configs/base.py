"""ModelConfig dataclass + architecture registry (``--arch <id>``)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention features ---------------------------------------------
    rope: bool = True
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None

    # --- mlp ---------------------------------------------------------------
    mlp_act: str = "silu"
    mlp_gated: bool = True
    mlp_bias: bool = False
    block_has_mlp: bool = True

    # --- moe ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 1024
    moe_renormalize: bool = True

    # --- hybrid / ssm -------------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)
    rglru_width: int = 0
    conv1d_width: int = 4
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # --- enc-dec ------------------------------------------------------------
    encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- modality frontends (stubs) ------------------------------------------
    modality: Optional[str] = None  # None | "vision" | "audio"
    modality_dim: int = 0
    num_modality_tokens: int = 0

    # --- norms / embeddings / dtypes ------------------------------------------
    norm_type: str = "rmsnorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"
    logits_dtype: str = "bfloat16"  # CE upcasts to fp32 in-reduction
    remat: bool = True

    # --- provenance ------------------------------------------------------------
    source: str = ""  # citation + verification tier

    # -------------------------------------------------------------------------
    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no global full-attention block."""
        return all(k in ("rec", "ssm", "local") for k in self.block_pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (enc-dec included)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hq, hkv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        per_kind = {}
        attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
        if self.qkv_bias:
            attn += hq * hd + 2 * hkv * hd
        per_kind["attn"] = per_kind["local"] = attn
        if self.rglru_width:
            w = self.rglru_width
            per_kind["rec"] = 2 * d * w + w * d + 2 * w * w + \
                self.conv1d_width * w + w
        if self.ssm_state:
            d_in = self.ssm_expand * d
            h = d_in // self.ssm_head_dim
            g, n = self.ssm_ngroups, self.ssm_state
            conv_dim = d_in + 2 * g * n
            per_kind["ssm"] = d * (2 * d_in + 2 * g * n + h) + d_in * d + \
                self.conv1d_width * conv_dim + conv_dim + 3 * h + d_in
        if self.num_experts:
            ff = self.num_experts * (2 if not self.mlp_gated else 3) * d * f \
                + d * self.num_experts
        elif self.mlp_gated:
            ff = 3 * d * f
        else:
            ff = 2 * d * f
        total = 0
        for i in range(self.num_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            total += per_kind[kind] + (ff if self.block_has_mlp else 0)
        total += v * d  # embed
        if not self.tie_embeddings:
            total += v * d  # head
        if self.encoder_decoder:
            total += self.num_encoder_layers * (per_kind["attn"] + 3 * d * f)
            total += self.num_layers * per_kind["attn"]  # cross-attn
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k of E experts)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.mlp_gated else 2) * d * f
        inactive = self.num_layers * (self.num_experts - self.num_experts_per_tok) \
            * per_expert
        return self.param_count() - inactive


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    return sorted(_REGISTRY)


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test scale: same family/features, tiny dims."""
    pat = cfg.block_pattern
    base = dict(
        num_layers=max(2, 2 * len(pat)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        moe_group=64,
        rglru_width=64 if cfg.rglru_width else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        attn_window=16 if cfg.attn_window else None,
        num_encoder_layers=2 if cfg.encoder_decoder else 0,
        modality_dim=32 if cfg.modality else 0,
        num_modality_tokens=4 if cfg.modality else 0,
        dtype="float32",
        kv_cache_dtype="float32",
        logits_dtype="float32",
        remat=False,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
