"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct; hf].
16 experts, top-2 routing, GQA kv=8, SwiGLU experts."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    rope=True,
    num_experts=16,
    num_experts_per_tok=2,
    mlp_act="silu",
    mlp_gated=True,
    source="hf:microsoft/Phi-3.5-MoE-instruct (verified: hf)",
))
