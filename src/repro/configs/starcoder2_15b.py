"""StarCoder2-15B [arXiv:2402.19173; hf]. Dense GQA + RoPE, non-gated GELU
MLP (d_ff = 4·d), LayerNorm, learned biases on linears."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope=True,
    rope_theta=100000.0,
    qkv_bias=True,
    mlp_act="gelu",
    mlp_gated=False,
    mlp_bias=True,
    norm_type="layernorm",
    norm_eps=1e-5,
    source="arXiv:2402.19173; hf (verified: hf)",
))
