"""Qwen2.5-3B [hf:Qwen/Qwen2.5 family; hf]. Dense GQA kv=2, QKV bias,
SwiGLU, tied embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    rope=True,
    rope_theta=1000000.0,
    qkv_bias=True,
    mlp_act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen2.5-3B (verified: hf)",
))
