"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf]. Encoder-decoder backbone:
24L encoder over audio-frame embeddings (STUB frontend), 24L decoder with
cross-attention; MHA kv=16, GeGLU-free classic MLP per original (gated
kept off), LayerNorm."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope=False,  # learned sinusoidal in original; RoPE off for backbone stub
    mlp_act="relu",
    mlp_gated=False,
    norm_type="layernorm",
    norm_eps=1e-5,
    encoder_decoder=True,
    num_encoder_layers=24,
    modality="audio",
    modality_dim=160,
    source="arXiv:2308.11596 (verified: hf)",
))
