"""Grok-1 314B [hf:xai-org/grok-1; unverified]. 8 experts top-2, GQA kv=8,
attention/final logit softcaps (30.0), embed scaling."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    rope=True,
    attn_logit_softcap=30.0,
    final_logit_softcap=30.0,
    embed_scale=True,
    num_experts=8,
    num_experts_per_tok=2,
    mlp_act="gelu",
    mlp_gated=True,
    source="hf:xai-org/grok-1 (unverified)",
))
