"""Architecture configs (one module per assigned architecture) + registry."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, register, get_config, list_configs, reduced_config,
)
# Import for registration side-effects.
from repro.configs import (  # noqa: F401
    starcoder2_15b, qwen3_0p6b, qwen2p5_3b, phi3_mini_3p8b, phi3p5_moe_42b,
    grok1_314b, internvl2_1b, seamless_m4t_large_v2, recurrentgemma_9b,
    mamba2_130m,
)
from repro.configs.shapes import SHAPES, input_specs, shape_for  # noqa: F401
