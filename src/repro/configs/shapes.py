"""Input-shape suites (assigned) + ShapeDtypeStruct input specs.

Four shapes per architecture (40 cells total):

  * train_4k    — seq 4096,   global batch 256  (train_step)
  * prefill_32k — seq 32768,  global batch 32   (prefill_step)
  * decode_32k  — seq 32768,  global batch 128  (serve_step: 1 new token
                  against a seq_len-deep cache)
  * long_500k   — seq 524288, global batch 1    (serve_step; sub-quadratic
                  archs only — full-attention archs are recorded as skips)

``input_specs`` returns weak-type-correct ShapeDtypeStructs (no device
allocation) for the dry-run; ``sample_batch`` materializes small real
batches for smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524288, 1, "decode"),
}


def shape_for(name: str) -> ShapeSuite:
    return SHAPES[name]


def cell_applicable(cfg: ModelConfig, shape: ShapeSuite) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention: a 524288-token dense KV decode is "
                "the regime this arch does not support (DESIGN.md §4)")
    return None


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.modality == "vision":
        return seq_len - cfg.num_modality_tokens
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeSuite) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every step input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if shape.kind == "train":
        specs = {}
        st = _text_len(cfg, s)
        specs["tokens"] = jax.ShapeDtypeStruct((b, st), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, st), i32)
        if cfg.modality == "vision":
            specs["modality_feats"] = jax.ShapeDtypeStruct(
                (b, cfg.num_modality_tokens, cfg.modality_dim), f32)
        if cfg.encoder_decoder:
            specs["modality_feats"] = jax.ShapeDtypeStruct(
                (b, s, cfg.modality_dim), f32)
        return specs
    if shape.kind == "prefill":
        specs = {}
        st = _text_len(cfg, s)
        specs["tokens"] = jax.ShapeDtypeStruct((b, st), i32)
        if cfg.modality == "vision":
            specs["modality_feats"] = jax.ShapeDtypeStruct(
                (b, cfg.num_modality_tokens, cfg.modality_dim), f32)
        if cfg.encoder_decoder:
            specs["modality_feats"] = jax.ShapeDtypeStruct(
                (b, s, cfg.modality_dim), f32)
        return specs
    # decode: one token against a seq_len-capacity cache
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.encoder_decoder:
        specs["enc_out"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                jnp.dtype(cfg.dtype))
    return specs


def sample_batch(cfg: ModelConfig, shape: ShapeSuite, seed: int = 0):
    """Small real arrays matching input_specs (smoke tests only)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, spec in input_specs(cfg, shape).items():
        if jnp.issubdtype(spec.dtype, jnp.integer):
            hi = cfg.vocab_size if k in ("tokens", "labels") else max(2, shape.seq_len)
            arr = rng.integers(0, hi, size=spec.shape, dtype=np.int64)
            out[k] = jnp.asarray(arr, spec.dtype)
        else:
            out[k] = jnp.asarray(rng.standard_normal(spec.shape), spec.dtype)
    return out
