"""Mamba2-130M [arXiv:2405.21060; unverified]. Attention-free SSD
(state-space duality): 24 SSD blocks (no MLP), d=768, expand 2 (d_inner
1536), headdim 64 (24 heads), state 128, chunk 256. Sub-quadratic:
long_500k runs."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,       # SSD heads (d_inner/headdim); attention unused
    num_kv_heads=24,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    rope=False,
    block_pattern=("ssm",),
    block_has_mlp=False,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_expand=2,
    ssm_chunk=256,
    conv1d_width=4,
    tie_embeddings=True,
    source="arXiv:2405.21060 (unverified)",
))
