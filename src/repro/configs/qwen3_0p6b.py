"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family; hf]. Dense GQA with qk-norm,
head_dim=128 (projection width != d_model), SwiGLU, tied embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    rope=True,
    rope_theta=1000000.0,
    qk_norm=True,
    mlp_act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-0.6B (verified: hf)",
))
