"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel lives in its own subpackage with three files:

  * ``kernel.py`` — the ``pl.pallas_call`` + ``BlockSpec`` implementation
    (TPU target; executed via ``interpret=True`` on CPU),
  * ``ops.py``    — the jit'd public wrapper (planning, padding/masking
    policy, backend dispatch),
  * ``ref.py``    — the pure-jnp oracle used by tests and benchmarks.
"""
