"""Version compatibility for the Pallas TPU API surface.

The TPU compiler-params dataclass was renamed across jax releases
(``TPUCompilerParams`` -> ``CompilerParams``); resolve whichever this
container ships so the kernel builders are version-agnostic.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
