"""Shared epilogue lowering — one fusion vocabulary for every family.

The paper's generator fuses the C-update tail (bias add, activation) into
the kernel it emits instead of launching follow-up elementwise passes.
:func:`apply_epilogue` is that tail, shared by the dense GEMM bodies and
the grouped-GEMM bodies (per-expert bias: the caller passes the bias
*block* its scalar-prefetch dispatch selected — the epilogue itself is
family-agnostic).  The legal epilogue names live on the descriptor layer
(:data:`repro.core.descriptor.EPILOGUES`).

Applied to the fp32 accumulator before the output cast, so fused and
multi-launch lowerings of one plan stay bit-identical.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.descriptor import BIAS_EPILOGUES


def needs_bias(epilogue: Optional[str]) -> bool:
    """Does this epilogue consume a bias operand?"""
    return epilogue in BIAS_EPILOGUES


def apply_epilogue(x, epilogue: Optional[str], bias_blk=None, dequant=None):
    """Lower one epilogue onto an accumulator block.

    ``bias_blk`` is the (1, bn)-broadcastable bias window of the output
    block — for grouped GEMM, the dispatching kernel has already selected
    the owning expert's row.

    ``dequant`` is the fused dequantization stage of the quant axis
    (DESIGN.md §13): an f32 factor broadcastable against the accumulator
    block — ``sa_col * sb_row`` for a fully-quantized GEMM, the weight
    scale row alone for W8A16.  It is applied to the (int32 or f32)
    accumulator *before* bias/activation, exactly where a separate
    dequant launch would have run, so the fused and reference lowerings
    of one quantized plan stay bit-identical.
    """
    if dequant is not None:
        x = x.astype(jnp.float32) * dequant
    if needs_bias(epilogue):
        x = x + bias_blk.astype(x.dtype)
    if epilogue in ("gelu", "bias_gelu"):
        x = jax.nn.gelu(x)
    elif epilogue in ("silu", "bias_silu"):
        x = jax.nn.silu(x)
    elif epilogue == "relu":
        x = jnp.maximum(x, 0)
    return x
