"""Oracle for the flash-attention kernel: plain causal softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_paged_decode_attention(q, k_pool, v_pool, block_tables,
                               lengths) -> jax.Array:
    """Oracle for the paged decode kernel (DESIGN.md §12).

    q: (S, h, hd); k_pool/v_pool: (pages, P, hkv, hd); block_tables:
    (S, max_blocks) int32; lengths: (S,) -> (S, h, hd).  Gathers each
    slot's block-table pages into a contiguous KV view (gathered column
    ``j`` holds absolute position ``j``), masks ``j >= length``, and runs
    plain fp32 softmax attention — also the XLA fallback formulation in
    ``repro.models.attention``.  A zero-length slot returns zeros."""
    s, h, hd = q.shape
    pages, p, hkv, _ = k_pool.shape
    b = block_tables.shape[1]
    gk = k_pool[jnp.clip(block_tables, 0, pages - 1)]  # (S, B, P, hkv, hd)
    gv = v_pool[jnp.clip(block_tables, 0, pages - 1)]
    gk = gk.reshape(s, b * p, hkv, hd).astype(q.dtype)
    gv = gv.reshape(s, b * p, hkv, hd).astype(q.dtype)
    if h != hkv:
        gk = jnp.repeat(gk, h // hkv, axis=2)
        gv = jnp.repeat(gv, h // hkv, axis=2)
    scale = hd ** -0.5
    scores = jnp.einsum("shd,skhd->shk", q, gk,
                        preferred_element_type=jnp.float32) * scale
    live = jnp.arange(b * p)[None, :] < lengths[:, None]  # (S, B*P)
    scores = jnp.where(live[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(live[:, None, :], probs, 0)  # len-0 slots: exact 0
    out = jnp.einsum("shk,skhd->shd", probs.astype(gv.dtype), gv,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def ref_attention(q, k, v, *, causal: bool = True) -> jax.Array:
    """q/k/v: (b, s, h, d) -> (b, s, h, d), fp32 softmax."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
