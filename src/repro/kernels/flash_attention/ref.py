"""Oracle for the flash-attention kernel: plain causal softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True) -> jax.Array:
    """q/k/v: (b, s, h, d) -> (b, s, h, d), fp32 softmax."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
