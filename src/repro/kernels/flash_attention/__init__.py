from repro.kernels.flash_attention.ops import (flash_attention,  # noqa: F401
                                               paged_decode_attention)
from repro.kernels.flash_attention.ref import (ref_attention,  # noqa: F401
                                               ref_paged_decode_attention)
