"""Flash-attention forward Pallas kernels (causal, online softmax).

Built from the same microkernel discipline as the GEMM engine: the
(block_q, block_k) score tile is the ZA-accumulator analogue, the K-grid
is the contraction loop, and causal masking is trace-time-specialized
predication (§IV-B).  Two lowerings (DESIGN.md §10):

  * **fused** (``build_fused_flash_kernel``): ONE ``pallas_call`` walks
    the causal-aware :class:`~repro.core.schedule.FlashTileSchedule` —
    fully-masked k-blocks are dropped at *plan* time, so the supergrid is
    ``(batch_heads, active_tiles)`` rather than the dense
    ``(b*h, q_blocks, k_blocks)`` cube.  The online-softmax m/l/acc carry
    threads through the flat tile walk as VMEM accumulator state (reset
    at each q-block's ``first`` tile, drained at its ``last``); ragged
    sq/sk tails use the schedule layer's two-step clamped windows and
    predicated RMW stores instead of padding.
  * **dense grid** (``build_flash_kernel``, the pre-schedule lowering,
    kept for VMEM-oversized problems and as the autotuner's
    alternative): grid = (b*h, q_blocks, k_blocks); masked causal tiles
    are branched away with ``pl.when`` but still pay their grid steps.

Serving path on TPU; training uses the XLA chunked formulation in
``repro.models.attention`` (same math, autodiff-friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import (FlashTileSchedule, ownership_mask,
                                 pack_table, predicated_store)
from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _carry_init(m_ref, l_ref, acc_ref):
    """Reset the online-softmax carry (running max / denominator / output
    accumulator) — shared by both lowerings so their float ops coincide."""
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _online_softmax_update(s, v, m_ref, l_ref, acc_ref):
    """One online-softmax step on a masked score tile ``s`` (fp32) and its
    value tile ``v``.  Both lowerings call exactly this, which is what the
    fused path's bit-identical parity contract rests on (DESIGN.md §10)."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _carry_drain(l_ref, acc_ref, out_dtype):
    """Normalized output of a drained carry, cast to the output dtype."""
    return (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(out_dtype)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q, block_k, k_steps, sk, causal, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _carry_init(m_ref, l_ref, acc_ref)

    # causal: skip tiles strictly above the diagonal (ZA-cover analogue)
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    k_ragged = sk % block_k != 0

    @pl.when(run)
    def _step():
        q = q_ref[0]  # (block_q, d)
        k = k_ref[0]  # (block_k, d)
        v = v_ref[0]
        if k_ragged:
            # KV-tail predication (trace-time specialized, §IV-B): padded
            # rows may be garbage/NaN — `where`, never multiply.
            krow = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_k, 1), 0)
            v = jnp.where(krow < sk, v, 0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        if causal and k_ragged:
            s = jnp.where((kpos <= qpos) & (kpos < sk), s, NEG_INF)
        elif causal:
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        elif k_ragged:
            s = jnp.where(kpos < sk, s, NEG_INF)

        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(ki == k_steps - 1)
    def _store():
        o_ref[0] = _carry_drain(l_ref, acc_ref, o_ref.dtype)


def build_flash_kernel(*, batch_heads: int, sq: int, sk: int, d: int,
                       block_q: int = 512, block_k: int = 512,
                       causal: bool = True, dtype=jnp.bfloat16,
                       interpret: bool = True):
    """Returns f(q:(BH,sq,d), k:(BH,sk,d), v:(BH,sk,d)) -> (BH,sq,d)."""
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (batch_heads, pl.cdiv(sq, block_q), pl.cdiv(sk, block_k))
    body = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k,
        k_steps=grid[2], sk=sk, causal=causal, scale=d ** -0.5)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch_heads, sq, d), dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused scheduled lowering (DESIGN.md §10): one launch, causal tiles
# dropped at plan time, m/l carry threaded through the flat tile walk
# ---------------------------------------------------------------------------

def _fused_flash_kernel(tbl_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, bq, bk, d, causal, scale):
    """Walk the flattened causal-aware tile table: one grid step = one
    active (q-block, k-block) pair.  q/k/v/out are staged whole per
    batch-head slice (clamped ragged windows need element-granular
    origins); the online-softmax carry lives in VMEM scratch, reset at
    ``first`` tiles and drained into the output — with a predicated
    two-step RMW store over the owned query rows — at ``last`` tiles."""
    t = pl.program_id(1)
    q0, q_end, qs = tbl_ref[t, 0], tbl_ref[t, 1], tbl_ref[t, 2]
    k0, k_end, ks = tbl_ref[t, 3], tbl_ref[t, 4], tbl_ref[t, 5]

    @pl.when(tbl_ref[t, 6] == 1)
    def _init():
        _carry_init(m_ref, l_ref, acc_ref)

    q = q_ref[0, pl.ds(qs, bq), :]  # (bq, d), two-step clamped window
    k = k_ref[0, pl.ds(ks, bk), :]  # (bk, d)
    v = v_ref[0, pl.ds(ks, bk), :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # Predicate the tile's contribution range [k0, k_end): the clamped
    # window may revisit columns owned by the previous k tile (sk tail)
    # — plus the causal triangle.  `where`, never multiply (§IV-B).
    qpos = qs + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ks + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = (kpos >= k0) & (kpos < k_end)
    if causal:
        valid &= kpos <= qpos
    s = jnp.where(valid, s, NEG_INF)

    _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(tbl_ref[t, 7] == 1)
    def _store():
        out = _carry_drain(l_ref, acc_ref, o_ref.dtype)
        # Predicated two-step store: the clamped window covers rows the
        # previous q-block already drained — write only owned rows.
        own = ownership_mask((bq, d), qs, 0, q0, q_end, 0, d)
        predicated_store(o_ref, (0, pl.ds(qs, bq), pl.ds(0, d)), out, own)


def build_fused_flash_kernel(*, schedule: FlashTileSchedule,
                             batch_heads: int, d: int,
                             dtype=jnp.bfloat16, interpret: bool = True):
    """Generate ONE pallas_call executing a whole flash tile schedule.

    Returns ``f(q:(BH,sq,d), k:(BH,sk,d), v:(BH,sk,d)) -> (BH,sq,d)``.
    The supergrid is ``(batch_heads, schedule.num_tiles)`` — batch x heads
    folded in as the leading parallel dimension, the causal-pruned tile
    walk as the sequential carry dimension — and the tile table rides in
    scalar-prefetch SMEM (DESIGN.md §10).
    """
    sq, sk = schedule.sq, schedule.sk
    bq, bk = schedule.bq, schedule.bk
    table = pack_table(schedule.tiles)  # (tiles, 8) int32, trace-time

    body = functools.partial(
        _fused_flash_kernel, bq=bq, bk=bk, d=d, causal=schedule.causal,
        scale=d ** -0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the tile table
        grid=(batch_heads, schedule.num_tiles),
        in_specs=[
            pl.BlockSpec((1, sq, d), lambda b, t, tbl: (b, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda b, t, tbl: (b, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda b, t, tbl: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, d), lambda b, t, tbl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running denom
            pltpu.VMEM((bq, d), jnp.float32),  # output accumulator
        ],
    )

    kernel = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch_heads, sq, d), dtype),
        compiler_params=CompilerParams(
            # batch x heads parallel; the tile walk is the sequential
            # carry dimension (the online-softmax state threads it)
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )

    def run(q, k, v):
        return kernel(table, q, k, v)

    return run
