"""Flash-attention forward Pallas kernels (causal, online softmax).

Built from the same microkernel discipline as the GEMM engine: the
(block_q, block_k) score tile is the ZA-accumulator analogue, the K-grid
is the contraction loop, and causal masking is trace-time-specialized
predication (§IV-B).  Two lowerings (DESIGN.md §10):

  * **fused** (``build_fused_flash_kernel``): ONE ``pallas_call`` walks
    the causal-aware :class:`~repro.core.schedule.FlashTileSchedule` —
    fully-masked k-blocks are dropped at *plan* time, so the supergrid is
    ``(batch_heads, active_tiles)`` rather than the dense
    ``(b*h, q_blocks, k_blocks)`` cube.  The online-softmax m/l/acc carry
    threads through the flat tile walk as VMEM accumulator state (reset
    at each q-block's ``first`` tile, drained at its ``last``); ragged
    sq/sk tails use the schedule layer's two-step clamped windows and
    predicated RMW stores instead of padding.
  * **dense grid** (``build_flash_kernel``, the pre-schedule lowering,
    kept for VMEM-oversized problems and as the autotuner's
    alternative): grid = (b*h, q_blocks, k_blocks); masked causal tiles
    are branched away with ``pl.when`` but still pay their grid steps.

Serving path on TPU; training uses the XLA chunked formulation in
``repro.models.attention`` (same math, autodiff-friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import (DecodeTileSchedule, FlashTileSchedule,
                                 ownership_mask, pack_table,
                                 predicated_store)
from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _carry_init(m_ref, l_ref, acc_ref):
    """Reset the online-softmax carry (running max / denominator / output
    accumulator) — shared by both lowerings so their float ops coincide."""
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _online_softmax_update(s, v, m_ref, l_ref, acc_ref):
    """One online-softmax step on a masked score tile ``s`` (fp32) and its
    value tile ``v``.  Both lowerings call exactly this, which is what the
    fused path's bit-identical parity contract rests on (DESIGN.md §10)."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _carry_drain(l_ref, acc_ref, out_dtype):
    """Normalized output of a drained carry, cast to the output dtype."""
    return (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(out_dtype)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q, block_k, k_steps, sk, causal, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _carry_init(m_ref, l_ref, acc_ref)

    # causal: skip tiles strictly above the diagonal (ZA-cover analogue)
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    k_ragged = sk % block_k != 0

    @pl.when(run)
    def _step():
        q = q_ref[0]  # (block_q, d)
        k = k_ref[0]  # (block_k, d)
        v = v_ref[0]
        if k_ragged:
            # KV-tail predication (trace-time specialized, §IV-B): padded
            # rows may be garbage/NaN — `where`, never multiply.
            krow = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_k, 1), 0)
            v = jnp.where(krow < sk, v, 0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        if causal and k_ragged:
            s = jnp.where((kpos <= qpos) & (kpos < sk), s, NEG_INF)
        elif causal:
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        elif k_ragged:
            s = jnp.where(kpos < sk, s, NEG_INF)

        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(ki == k_steps - 1)
    def _store():
        o_ref[0] = _carry_drain(l_ref, acc_ref, o_ref.dtype)


def build_flash_kernel(*, batch_heads: int, sq: int, sk: int, d: int,
                       block_q: int = 512, block_k: int = 512,
                       causal: bool = True, dtype=jnp.bfloat16,
                       interpret: bool = True):
    """Returns f(q:(BH,sq,d), k:(BH,sk,d), v:(BH,sk,d)) -> (BH,sq,d)."""
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (batch_heads, pl.cdiv(sq, block_q), pl.cdiv(sk, block_k))
    body = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k,
        k_steps=grid[2], sk=sk, causal=causal, scale=d ** -0.5)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch_heads, sq, d), dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused scheduled lowering (DESIGN.md §10): one launch, causal tiles
# dropped at plan time, m/l carry threaded through the flat tile walk
# ---------------------------------------------------------------------------

def _fused_flash_kernel(tbl_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, bq, bk, d, causal, scale,
                        lse_ref=None):
    """Walk the flattened causal-aware tile table: one grid step = one
    active (q-block, k-block) pair.  q/k/v/out are staged whole per
    batch-head slice (clamped ragged windows need element-granular
    origins); the online-softmax carry lives in VMEM scratch, reset at
    ``first`` tiles and drained into the output — with a predicated
    two-step RMW store over the owned query rows — at ``last`` tiles."""
    t = pl.program_id(1)
    q0, q_end, qs = tbl_ref[t, 0], tbl_ref[t, 1], tbl_ref[t, 2]
    k0, k_end, ks = tbl_ref[t, 3], tbl_ref[t, 4], tbl_ref[t, 5]

    @pl.when(tbl_ref[t, 6] == 1)
    def _init():
        _carry_init(m_ref, l_ref, acc_ref)

    q = q_ref[0, pl.ds(qs, bq), :]  # (bq, d), two-step clamped window
    k = k_ref[0, pl.ds(ks, bk), :]  # (bk, d)
    v = v_ref[0, pl.ds(ks, bk), :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # Predicate the tile's contribution range [k0, k_end): the clamped
    # window may revisit columns owned by the previous k tile (sk tail)
    # — plus the causal triangle.  `where`, never multiply (§IV-B).
    qpos = qs + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ks + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = (kpos >= k0) & (kpos < k_end)
    if causal:
        valid &= kpos <= qpos
    s = jnp.where(valid, s, NEG_INF)

    _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(tbl_ref[t, 7] == 1)
    def _store():
        out = _carry_drain(l_ref, acc_ref, o_ref.dtype)
        # Predicated two-step store: the clamped window covers rows the
        # previous q-block already drained — write only owned rows.
        own = ownership_mask((bq, d), qs, 0, q0, q_end, 0, d)
        predicated_store(o_ref, (0, pl.ds(qs, bq), pl.ds(0, d)), out, own)
        if lse_ref is not None:
            # Log-sum-exp rows for the backward walk (DESIGN.md §11):
            # lse = m + log(l), the softmax statistics the VJP recomputes
            # P from without re-running the online reduction.
            lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
            own1 = ownership_mask((bq, 1), qs, 0, q0, q_end, 0, 1)
            predicated_store(lse_ref, (0, pl.ds(qs, bq), pl.ds(0, 1)),
                             lse, own1)


def build_fused_flash_kernel(*, schedule: FlashTileSchedule,
                             batch_heads: int, d: int,
                             dtype=jnp.bfloat16, interpret: bool = True,
                             return_lse: bool = False):
    """Generate ONE pallas_call executing a whole flash tile schedule.

    Returns ``f(q:(BH,sq,d), k:(BH,sk,d), v:(BH,sk,d)) -> (BH,sq,d)``.
    The supergrid is ``(batch_heads, schedule.num_tiles)`` — batch x heads
    folded in as the leading parallel dimension, the causal-pruned tile
    walk as the sequential carry dimension — and the tile table rides in
    scalar-prefetch SMEM (DESIGN.md §10).

    ``return_lse=True`` additionally drains the log-sum-exp rows
    (``(BH, sq)`` fp32) — the residual the backward walk recomputes P
    from (DESIGN.md §11); the forward math is bit-identical either way.
    """
    sq, sk = schedule.sq, schedule.sk
    bq, bk = schedule.bq, schedule.bk
    table = pack_table(schedule.tiles)  # (tiles, 8) int32, trace-time

    opts = dict(bq=bq, bk=bk, d=d, causal=schedule.causal, scale=d ** -0.5)
    if return_lse:
        def body(tbl, q, k, v, o_ref, lse_ref, m_ref, l_ref, acc_ref):
            _fused_flash_kernel(tbl, q, k, v, o_ref, m_ref, l_ref, acc_ref,
                                lse_ref=lse_ref, **opts)
        out_shape = [jax.ShapeDtypeStruct((batch_heads, sq, d), dtype),
                     jax.ShapeDtypeStruct((batch_heads, sq, 1), jnp.float32)]
        out_specs = [pl.BlockSpec((1, sq, d), lambda b, t, tbl: (b, 0, 0)),
                     pl.BlockSpec((1, sq, 1), lambda b, t, tbl: (b, 0, 0))]
    else:
        body = functools.partial(_fused_flash_kernel, **opts)
        out_shape = jax.ShapeDtypeStruct((batch_heads, sq, d), dtype)
        out_specs = pl.BlockSpec((1, sq, d), lambda b, t, tbl: (b, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the tile table
        grid=(batch_heads, schedule.num_tiles),
        in_specs=[
            pl.BlockSpec((1, sq, d), lambda b, t, tbl: (b, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda b, t, tbl: (b, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda b, t, tbl: (b, 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running denom
            pltpu.VMEM((bq, d), jnp.float32),  # output accumulator
        ],
    )

    kernel = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            # batch x heads parallel; the tile walk is the sequential
            # carry dimension (the online-softmax state threads it)
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )

    def run(q, k, v):
        if return_lse:
            o, lse = kernel(table, q, k, v)
            return o, lse[..., 0]
        return kernel(table, q, k, v)

    return run


# ---------------------------------------------------------------------------
# Paged decode lowering (DESIGN.md §12): one launch walks the runtime
# DecodeTileSchedule — one grid step = one live KV page of one sequence,
# pulled from the pool by a table-driven BlockSpec index map
# ---------------------------------------------------------------------------

def _decode_flash_kernel(tbl_ref, *refs, page_size, rep, scale,
                         kv_quant=False):
    """One grid step of the paged decode walk.

    ``tbl_ref`` rows are ``(seq, page, k_len, first, last)``
    (:class:`~repro.core.schedule.DecodeTileSchedule`): the BlockSpec
    index maps already pulled query row ``seq`` and pool page ``page``
    into VMEM, so the body only masks the page tail (``k_len``), runs the
    per-head online-softmax update, and drains the carry into the owned
    output row at ``last`` — the same m/l/acc discipline as the fused
    flash walk, batched over heads instead of query rows.

    ``kv_quant`` (DESIGN.md §13): the pools are int8 with per-token f32
    scale rows riding as two extra ``(1, P)`` operands on the same
    table-driven index map.  The scales are *separable by page position*,
    so dequant never touches the (P, hkv, hd) tiles: the K scales
    multiply the score columns (``q . (k*s) == (q . k) * s``) and the V
    scales fold into P before the PV contraction
    (``sum_p p . (v*s) == sum_p (p*s) . v``) — both lane-dim row
    broadcasts, no 3-D elementwise dequant."""
    idx = 0
    q_ref = refs[idx]; idx += 1
    k_ref = refs[idx]; idx += 1
    v_ref = refs[idx]; idx += 1
    ks_ref = vs_ref = None
    if kv_quant:
        ks_ref = refs[idx]; idx += 1
        vs_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    m_ref, l_ref, acc_ref = refs[idx], refs[idx + 1], refs[idx + 2]

    t = pl.program_id(0)
    k_len = tbl_ref[t, 2]

    @pl.when(tbl_ref[t, 3] == 1)
    def _init():
        _carry_init(m_ref, l_ref, acc_ref)

    q = q_ref[0]                       # (h, hd)
    k = k_ref[0].astype(q.dtype)       # (page_size, hkv, hd) — int8 wire
    v = v_ref[0].astype(q.dtype)       # values are exact in the wide dtype
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)  # GQA: -> (page_size, h, hd)
        v = jnp.repeat(v, rep, axis=1)
    # Dead page slots may hold stale sequences' values — `where`, never
    # multiply (§IV-B); zeroed v also keeps a fully-masked (empty-slot)
    # tile draining exact zeros.
    col = jax.lax.broadcasted_iota(jnp.int32, (page_size, 1, 1), 0)
    v = jnp.where(col < k_len, v, 0)
    # scores (h, page_size): heads are the batch dim of both tile GEMMs.
    s = jax.lax.dot_general(q, k, (((1,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32) * scale
    if kv_quant:
        s = s * ks_ref[...].astype(jnp.float32)  # (1, P) over (h, P)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols < k_len, s, NEG_INF)

    # Per-head online-softmax update — the m/l algebra of
    # `_online_softmax_update` with the PV contraction batched over heads.
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = p
    if kv_quant:
        pv = p * vs_ref[...].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pv.astype(v.dtype), v, (((1,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(tbl_ref[t, 4] == 1)
    def _store():
        o_ref[0] = _carry_drain(l_ref, acc_ref, o_ref.dtype)


def build_decode_flash_kernel(*, schedule: DecodeTileSchedule,
                              num_heads: int, num_kv_heads: int,
                              head_dim: int, dtype=jnp.bfloat16,
                              kv_dtype=None, kv_quant: bool = False,
                              interpret: bool = True):
    """Generate ONE pallas_call executing a whole paged decode step.

    Returns ``f(table, q:(S,h,hd), k_pool:(pages,P,hkv,hd), v_pool) ->
    (S,h,hd)`` where ``table`` is the runtime ``(max_tiles, 5)`` int32
    tile table (:meth:`DecodeTileSchedule.tables`).  Unlike the fused
    flash kernel's trace-time table, this one is a *scalar-prefetch
    operand*: the batch composition is data, so the kernel compiles once
    per pool geometry and the churning batch never retraces.  The
    BlockSpec index maps read the table — grid step ``t`` stages exactly
    query row ``table[t, 0]`` and pool page ``table[t, 1]``, which is
    how the walk touches only live pages (DESIGN.md §12)."""
    S, P = schedule.num_seqs, schedule.page_size
    h, hkv, hd = num_heads, num_kv_heads, head_dim
    kv_dtype = kv_dtype or dtype
    body = functools.partial(_decode_flash_kernel, page_size=P,
                             rep=h // hkv, scale=hd ** -0.5,
                             kv_quant=kv_quant)

    in_specs = [
        pl.BlockSpec((1, h, hd), lambda t, tbl: (tbl[t, 0], 0, 0)),
        pl.BlockSpec((1, P, hkv, hd),
                     lambda t, tbl: (tbl[t, 1], 0, 0, 0)),
        pl.BlockSpec((1, P, hkv, hd),
                     lambda t, tbl: (tbl[t, 1], 0, 0, 0)),
    ]
    if kv_quant:
        # per-token dequant scale rows of the walked page (DESIGN.md §13)
        in_specs += [
            pl.BlockSpec((1, P), lambda t, tbl: (tbl[t, 1], 0)),
            pl.BlockSpec((1, P), lambda t, tbl: (tbl[t, 1], 0)),
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the runtime tile table
        grid=(schedule.max_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, hd), lambda t, tbl: (tbl[t, 0], 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),   # running max
            pltpu.VMEM((h, 1), jnp.float32),   # running denom
            pltpu.VMEM((h, hd), jnp.float32),  # output accumulator
        ],
    )

    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, h, hd), dtype),
        compiler_params=CompilerParams(
            # one sequential dimension: the carry threads the page walk
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused scheduled backward (DESIGN.md §11): ONE launch walks the same
# causal-pruned tile table as the forward, producing dQ/dK/dV with the
# D = rowsum(dO . O) precompute fused into each q-block's first tile
# ---------------------------------------------------------------------------

def _fused_flash_bwd_kernel(tbl_ref, q_ref, k_ref, v_ref, o_ref, do_ref,
                            lse_ref, dq_ref, dk_ref, dv_ref,
                            d_ref, dqacc_ref, *, bq, bk, d, causal, scale):
    """One grid step = one active (q-block, k-block) pair of the forward
    schedule.  P is recomputed from the staged LSE rows (no second online
    reduction); dK/dV accumulate fp32 across q-blocks by read-modify-write
    on the whole-staged outputs (contributions outside a tile's owned
    rows/cols are masked to zero, so clamped-window overlap adds zero);
    dQ accumulates in scratch across a q-block's k walk and drains with a
    predicated store at ``last`` tiles."""
    t = pl.program_id(1)
    q0, q_end, qs = tbl_ref[t, 0], tbl_ref[t, 1], tbl_ref[t, 2]
    k0, k_end, ks = tbl_ref[t, 3], tbl_ref[t, 4], tbl_ref[t, 5]

    @pl.when(t == 0)
    def _zero_outputs():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    o_win = o_ref[0, pl.ds(qs, bq), :].astype(jnp.float32)
    do_win = do_ref[0, pl.ds(qs, bq), :].astype(jnp.float32)

    @pl.when(tbl_ref[t, 6] == 1)
    def _init():
        # D = rowsum(dO . O), computed once per q-block on its first tile
        # and carried in scratch for the rest of the k walk.
        d_ref[...] = jnp.sum(do_win * o_win, axis=1, keepdims=True)
        dqacc_ref[...] = jnp.zeros_like(dqacc_ref)

    q = q_ref[0, pl.ds(qs, bq), :]
    k = k_ref[0, pl.ds(ks, bk), :]
    v = v_ref[0, pl.ds(ks, bk), :]
    lse = lse_ref[0, pl.ds(qs, bq), :]  # (bq, 1) fp32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # Own both axes: unlike the forward (whose per-q-block carry only
    # needed the k-range predicate), the backward RMW-accumulates dK/dV
    # across q-blocks, so clamped-window rows another q-block owns must
    # contribute exactly zero.
    qpos = qs + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ks + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = (kpos >= k0) & (kpos < k_end) & (qpos >= q0) & (qpos < q_end)
    if causal:
        valid &= kpos <= qpos
    p = jnp.where(valid, jnp.exp(s - lse), 0.0)  # (bq, bk) fp32

    # dV += P^T @ dO — zero rows outside [k0, k_end) make the clamped
    # k-window overlap-add a no-op.
    dv_ref[0, pl.ds(ks, bk), :] += jax.lax.dot_general(
        p, do_win, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    dp = jax.lax.dot_general(do_win, v.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - d_ref[...]) * scale  # (bq, bk) fp32

    # dK += dS^T @ Q
    dk_ref[0, pl.ds(ks, bk), :] += jax.lax.dot_general(
        ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # dQ accumulates over the q-block's k walk in scratch.
    dqacc_ref[...] += jax.lax.dot_general(
        ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(tbl_ref[t, 7] == 1)
    def _store_dq():
        own = ownership_mask((bq, d), qs, 0, q0, q_end, 0, d)
        predicated_store(dq_ref, (0, pl.ds(qs, bq), pl.ds(0, d)),
                         dqacc_ref[...], own)


def build_fused_flash_bwd_kernel(*, schedule: FlashTileSchedule,
                                 batch_heads: int, d: int,
                                 dtype=jnp.bfloat16, interpret: bool = True):
    """Generate ONE pallas_call executing a whole flash backward schedule.

    Returns ``f(q, k, v, o, do, lse) -> (dq, dk, dv)`` over ``(BH, s, d)``
    operands (``lse``: ``(BH, sq)`` fp32); gradients come back fp32 (the
    ops wrapper casts).  Supergrid, tile table and predication mirror
    :func:`build_fused_flash_kernel` — the backward walks the *same*
    causal-pruned schedule, so it skips the same fully-masked k-blocks
    (DESIGN.md §11).
    """
    sq, sk = schedule.sq, schedule.sk
    bq, bk = schedule.bq, schedule.bk
    table = pack_table(schedule.tiles)

    body = functools.partial(
        _fused_flash_bwd_kernel, bq=bq, bk=bk, d=d, causal=schedule.causal,
        scale=d ** -0.5)

    spec_q = pl.BlockSpec((1, sq, d), lambda b, t, tbl: (b, 0, 0))
    spec_k = pl.BlockSpec((1, sk, d), lambda b, t, tbl: (b, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch_heads, schedule.num_tiles),
        in_specs=[spec_q, spec_k, spec_k, spec_q, spec_q,
                  pl.BlockSpec((1, sq, 1), lambda b, t, tbl: (b, 0, 0))],
        out_specs=[spec_q, spec_k, spec_k],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # D = rowsum(dO . O)
            pltpu.VMEM((bq, d), jnp.float32),  # dQ accumulator
        ],
    )

    kernel = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((batch_heads, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((batch_heads, sk, d), jnp.float32),
            jax.ShapeDtypeStruct((batch_heads, sk, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )

    def run(q, k, v, o, do, lse):
        return kernel(table, q, k, v, o, do, lse[..., None])

    return run
