"""Flash-attention forward Pallas kernel (causal, online softmax).

Built from the same microkernel discipline as the GEMM engine: the
(block_q, block_k) score tile is the ZA-accumulator analogue, the K-grid
is the contraction loop, and causal masking is trace-time-specialized
predication (§IV-B).  Grid = (b*h, q_blocks, k_blocks) with running
max/denominator carried in VMEM scratch across the k dimension —
activation memory O(block_q x block_k) regardless of sequence length.

Off-diagonal fully-masked tiles are skipped with ``pl.when`` (no DMA, no
MXU work) — the heterogeneous-cover idea applied to the causal triangle:
only ~half the grid does work.

Serving path on TPU; training uses the XLA chunked formulation in
``repro.models.attention`` (same math, autodiff-friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q, block_k, k_steps, sk, causal, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip tiles strictly above the diagonal (ZA-cover analogue)
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    k_ragged = sk % block_k != 0

    @pl.when(run)
    def _step():
        q = q_ref[0]  # (block_q, d)
        k = k_ref[0]  # (block_k, d)
        v = v_ref[0]
        if k_ragged:
            # KV-tail predication (trace-time specialized, §IV-B): padded
            # rows may be garbage/NaN — `where`, never multiply.
            krow = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_k, 1), 0)
            v = jnp.where(krow < sk, v, 0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        if causal and k_ragged:
            s = jnp.where((kpos <= qpos) & (kpos < sk), s, NEG_INF)
        elif causal:
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        elif k_ragged:
            s = jnp.where(kpos < sk, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


def build_flash_kernel(*, batch_heads: int, sq: int, sk: int, d: int,
                       block_q: int = 512, block_k: int = 512,
                       causal: bool = True, dtype=jnp.bfloat16,
                       interpret: bool = True):
    """Returns f(q:(BH,sq,d), k:(BH,sk,d), v:(BH,sk,d)) -> (BH,sq,d)."""
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (batch_heads, pl.cdiv(sq, block_q), pl.cdiv(sk, block_k))
    body = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k,
        k_steps=grid[2], sk=sk, causal=causal, scale=d ** -0.5)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch_heads, sq, d), dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
