"""Flash-attention family: engine-planned block sizes, engine-cached build.

Executes a :class:`repro.core.blocking.FlashPlan` one of two ways,
resolved by ``engine.resolve_fused`` exactly as for dense GEMM
(DESIGN.md §10):

  * **fused** (``plan.fused``, default whenever the staged operands fit
    VMEM): the plan's causal-aware
    :class:`~repro.core.schedule.FlashTileSchedule` drops fully-masked
    k-blocks at plan time and ONE ``pallas_call`` walks the surviving
    tiles over a ``(batch_heads, tiles)`` supergrid, with the
    online-softmax carry threaded through the walk as accumulator state;
  * **dense grid** (the pre-schedule lowering, kept for VMEM-oversized
    problems and as the autotuner's alternative): a
    ``(b*h, q_blocks, k_blocks)`` grid that branches masked causal tiles
    away at run time but still pays their grid steps.

``block_q``/``block_k`` default to the machine-model-driven plan
(:func:`repro.core.blocking.plan_flash`); explicit values pin the plan
(benchmark sweeps, tests).  Both paths report traced launch counts
through ``engine.count_launches`` → ``engine.stats()``.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import engine
from repro.core.blocking import FlashPlan, plan_flash
from repro.core.descriptor import FlashDescriptor
from repro.core.schedule import plan_launches
from repro.kernels.flash_attention.kernel import (build_flash_kernel,
                                                  build_fused_flash_kernel)


def _fused_executor(desc: FlashDescriptor, plan: FlashPlan, dtype,
                    interpret: bool):
    """Build (and cache) the single scheduled kernel for one flash plan.

    ``(block_q, block_k)`` fully determine the tile table, so the cache
    key stays O(1) and the O(tiles) flattening only runs on a miss."""
    key = desc.cache_key() + ("fused", plan.block_q, plan.block_k, interpret)
    return engine.build_cached(key, lambda: build_fused_flash_kernel(
        schedule=plan.tile_schedule(), batch_heads=desc.batch_heads,
        d=desc.d, dtype=dtype, interpret=interpret))


def execute(desc: FlashDescriptor, plan: FlashPlan, qf, kf, vf, *,
            interpret: bool = False) -> jax.Array:
    """Engine executor: run one planned flash attention forward."""
    fused = engine.resolve_fused(plan)
    engine.count_launches("flash_attention", plan_launches(plan, fused))
    if fused:
        return _fused_executor(desc, plan, qf.dtype, interpret)(qf, kf, vf)
    key = desc.cache_key() + ("kernel", plan.block_q, plan.block_k, interpret)
    kernel = engine.build_cached(key, lambda: build_flash_kernel(
        batch_heads=desc.batch_heads, sq=desc.sq, sk=desc.sk, d=desc.d,
        block_q=plan.block_q, block_k=plan.block_k, causal=desc.causal,
        dtype=qf.dtype, interpret=interpret))
    return kernel(qf, kf, vf)


engine.register_family("flash_attention", planner=plan_flash, execute=execute)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    fused: Optional[bool] = None) -> jax.Array:
    """q/k/v: (b, s, h, d) -> (b, s, h, d).

    ``fused=True/False`` pins the scheduled single-launch vs dense-grid
    lowering for this call (default: follow config + plan, DESIGN.md §10).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    desc = FlashDescriptor.from_operands(q, k, causal=causal)
    plan = None
    if block_q is not None or block_k is not None:
        # Fill unpinned knobs from the (cached) engine plan.
        auto = engine.plan_for(desc)
        plan = FlashPlan(desc, block_q or auto.block_q,
                         block_k or auto.block_k, fused=auto.fused)
    if fused is None:
        out = engine.dispatch(desc, qf, kf, vf, plan=plan)
    else:
        from repro.core.config import use
        with use(fused="on" if fused else "off"):
            out = engine.dispatch(desc, qf, kf, vf, plan=plan)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
