"""Flash-attention family: engine-planned block sizes, engine-cached build.

``block_q``/``block_k`` default to the machine-model-driven plan
(:func:`repro.core.blocking.plan_flash`) — the hardcoded 512s are gone;
explicit values pin the plan (benchmark sweeps, tests).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import engine
from repro.core.blocking import FlashPlan, plan_flash
from repro.core.descriptor import FlashDescriptor
from repro.kernels.flash_attention.kernel import build_flash_kernel


def execute(desc: FlashDescriptor, plan: FlashPlan, qf, kf, vf, *,
            interpret: bool = False) -> jax.Array:
    key = desc.cache_key() + ("kernel", plan.block_q, plan.block_k, interpret)
    kernel = engine.build_cached(key, lambda: build_flash_kernel(
        batch_heads=desc.batch_heads, sq=desc.sq, sk=desc.sk, d=desc.d,
        block_q=plan.block_q, block_k=plan.block_k, causal=desc.causal,
        dtype=qf.dtype, interpret=interpret))
    return kernel(qf, kf, vf)


engine.register_family("flash_attention", planner=plan_flash, execute=execute)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None) -> jax.Array:
    """q/k/v: (b, s, h, d) -> (b, s, h, d)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    desc = FlashDescriptor.from_operands(q, k, causal=causal)
    plan = None
    if block_q is not None or block_k is not None:
        # Fill unpinned knobs from the (cached) engine plan.
        auto = engine.plan_for(desc)
        plan = FlashPlan(desc, block_q or auto.block_q,
                         block_k or auto.block_k)
    out = engine.dispatch(desc, qf, kf, vf, plan=plan)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
