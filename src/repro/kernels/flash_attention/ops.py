"""Flash-attention family: engine-planned block sizes, engine-cached build.

Executes a :class:`repro.core.blocking.FlashPlan` one of two ways,
resolved by ``engine.resolve_fused`` exactly as for dense GEMM
(DESIGN.md §10):

  * **fused** (``plan.fused``, default whenever the staged operands fit
    VMEM): the plan's causal-aware
    :class:`~repro.core.schedule.FlashTileSchedule` drops fully-masked
    k-blocks at plan time and ONE ``pallas_call`` walks the surviving
    tiles over a ``(batch_heads, tiles)`` supergrid, with the
    online-softmax carry threaded through the walk as accumulator state;
  * **dense grid** (the pre-schedule lowering, kept for VMEM-oversized
    problems and as the autotuner's alternative): a
    ``(b*h, q_blocks, k_blocks)`` grid that branches masked causal tiles
    away at run time but still pays their grid steps.

``block_q``/``block_k`` default to the machine-model-driven plan
(:func:`repro.core.blocking.plan_flash`); explicit values pin the plan
(benchmark sweeps, tests).  Both paths report traced launch counts
through ``engine.count_launches`` → ``engine.stats()``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocking import (FlashDecodePlan, FlashPlan,
                                 flash_bwd_fused_legal, plan_flash,
                                 plan_flash_bwd, plan_flash_decode)
from repro.core.config import get_config
from repro.core.descriptor import (FlashBwdDescriptor, FlashDecodeDescriptor,
                                   FlashDescriptor)
from repro.core.machine import canonical_dtype
from repro.core.schedule import plan_launches
from repro.kernels.flash_attention.kernel import (NEG_INF,
                                                  build_decode_flash_kernel,
                                                  build_flash_kernel,
                                                  build_fused_flash_bwd_kernel,
                                                  build_fused_flash_kernel)


def _fused_executor(desc: FlashDescriptor, plan: FlashPlan, dtype,
                    interpret: bool):
    """Build (and cache) the single scheduled kernel for one flash plan.

    ``(block_q, block_k)`` fully determine the tile table, so the cache
    key stays O(1) and the O(tiles) flattening only runs on a miss."""
    key = desc.cache_key() + ("fused", plan.block_q, plan.block_k, interpret)
    return engine.build_cached(key, lambda: build_fused_flash_kernel(
        schedule=plan.tile_schedule(), batch_heads=desc.batch_heads,
        d=desc.d, dtype=dtype, interpret=interpret))


def execute(desc: FlashDescriptor, plan: FlashPlan, qf, kf, vf, *,
            interpret: bool = False) -> jax.Array:
    """Engine executor: run one planned flash attention forward."""
    fused = engine.resolve_fused(plan)
    engine.count_launches("flash_attention", plan_launches(plan, fused))
    if fused:
        return _fused_executor(desc, plan, qf.dtype, interpret)(qf, kf, vf)
    key = desc.cache_key() + ("kernel", plan.block_q, plan.block_k, interpret)
    kernel = engine.build_cached(key, lambda: build_flash_kernel(
        batch_heads=desc.batch_heads, sq=desc.sq, sk=desc.sk, d=desc.d,
        block_q=plan.block_q, block_k=plan.block_k, causal=desc.causal,
        dtype=qf.dtype, interpret=interpret))
    return kernel(qf, kf, vf)


engine.register_family("flash_attention", planner=plan_flash, execute=execute)


# ---------------------------------------------------------------------------
# Backward family (DESIGN.md §11): ONE pallas_call walks the forward's
# causal-pruned tile table, producing dQ/dK/dV
# ---------------------------------------------------------------------------

def execute_bwd(desc: FlashBwdDescriptor, plan: FlashPlan, qf, kf, vf, o, do,
                lse, *, interpret: bool = False):
    """Engine executor: run one planned flash attention backward.

    Single lowering — the scheduled walk; illegal descriptors never reach
    the engine (the custom VJP falls back to reference autodiff first).
    """
    engine.count_launches("flash_attention_bwd", 1)
    key = desc.cache_key() + ("fused", plan.block_q, plan.block_k, interpret)
    kernel = engine.build_cached(key, lambda: build_fused_flash_bwd_kernel(
        schedule=plan.tile_schedule(), batch_heads=desc.batch_heads,
        d=desc.d, dtype=qf.dtype, interpret=interpret))
    return kernel(qf, kf, vf, o, do, lse)


engine.register_family("flash_attention_bwd", planner=plan_flash_bwd,
                       execute=execute_bwd)


# ---------------------------------------------------------------------------
# Paged decode family (DESIGN.md §12): ONE pallas_call per decode step,
# riding the runtime DecodeTileSchedule tables over live KV pages
# ---------------------------------------------------------------------------

def execute_decode(desc: FlashDecodeDescriptor, plan: FlashDecodePlan,
                   q, k_pool, v_pool, block_tables, lengths, *,
                   k_scale=None, v_scale=None, interpret: bool = False):
    """Engine executor: run one planned paged decode-attention step.

    The kernel is cached on the static pool geometry alone; the batch
    composition (block tables + lengths) becomes the runtime tile table,
    built with jnp ops at trace time and shipped as a scalar-prefetch
    operand — so a churning batch re-enters the same compiled launch.
    KV-int8 pools (DESIGN.md §13) ride the same launch: per-token scale
    rows ``(pages, page_size)`` join as two extra table-indexed operands.
    """
    engine.count_launches("flash_decode", 1)
    kv_quant = k_scale is not None
    schedule = plan.tile_schedule()
    key = desc.cache_key() + ("decode", canonical_dtype(k_pool.dtype),
                              kv_quant, interpret)
    kernel = engine.build_cached(key, lambda: build_decode_flash_kernel(
        schedule=schedule, num_heads=desc.num_heads,
        num_kv_heads=desc.num_kv_heads, head_dim=desc.head_dim,
        dtype=q.dtype, kv_dtype=k_pool.dtype, kv_quant=kv_quant,
        interpret=interpret))
    table = schedule.tables(block_tables, lengths)
    if kv_quant:
        return kernel(table, q, k_pool, v_pool,
                      k_scale.astype(jnp.float32),
                      v_scale.astype(jnp.float32))
    return kernel(table, q, k_pool, v_pool)


engine.register_family("flash_decode", planner=plan_flash_decode,
                       execute=execute_decode)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                           k_scale=None, v_scale=None) -> jax.Array:
    """One decode step against a paged KV pool (DESIGN.md §12).

    q: (S, h, hd) — one query row per decode slot; k_pool/v_pool:
    (pages, page_size, hkv, hd); block_tables: (S, max_blocks) int32 page
    ids; lengths: (S,) live KV length per slot (0 = inactive, output row
    is zeros).  Returns (S, h, hd).

    With int8 pools, ``k_scale``/``v_scale`` are the per-token dequant
    rows ``(pages, page_size)`` f32 (DESIGN.md §13) — same launch count,
    the scales fold into the score/PV algebra in-kernel.
    """
    desc = FlashDecodeDescriptor.from_operands(q, k_pool, block_tables)
    return engine.dispatch(desc, q, k_pool, v_pool, block_tables, lengths,
                           k_scale=k_scale, v_scale=v_scale)


def _flat_desc(causal, qf, kf) -> FlashDescriptor:
    return FlashDescriptor(batch_heads=qf.shape[0], sq=qf.shape[1],
                           sk=kf.shape[1], d=qf.shape[2], causal=causal,
                           dtype=canonical_dtype(qf.dtype))


def _ref_flat(causal, qf, kf, vf):
    """Pure-jnp reference over flattened (BH, s, d) operands — the
    differentiable oracle the VJP falls back to when the scheduled
    backward is not legal (and the gradient-parity baseline in tests)."""
    scale = qf.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", qf.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if causal:
        # Same convention as the kernels: kpos <= qpos, no diagonal offset.
        sq, sk = qf.shape[1], kf.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool))
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      vf.astype(jnp.float32)).astype(qf.dtype)


def _flash_dispatch(causal, qf, kf, vf):
    """The engine-dispatched forward on flattened operands (primal path)."""
    return engine.dispatch(_flat_desc(causal, qf, kf), qf, kf, vf)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_vjp(causal, qf, kf, vf):
    """Differentiable flattened flash attention (custom VJP,
    DESIGN.md §11): forward = the engine-dispatched kernel; backward =
    the scheduled single-launch dQ/dK/dV walk when legal, reference-path
    autodiff otherwise."""
    return _flash_dispatch(causal, qf, kf, vf)


def _flash_vjp_fwd(causal, qf, kf, vf):
    cfg = get_config()
    desc = _flat_desc(causal, qf, kf)
    bdesc = FlashBwdDescriptor.from_forward(desc)
    fused_ok = (cfg.fused != "off"
                and flash_bwd_fused_legal(bdesc, cfg.machine))
    if fused_ok:
        plan = engine.plan_for(desc)
        fused_ok = engine.resolve_fused(plan)
    if not fused_ok:
        # Reference-path fallback: primal still runs the engine forward;
        # only the backward re-derives through the jnp reference.
        return _flash_dispatch(causal, qf, kf, vf), {"ref": (qf, kf, vf)}
    # Forward with the LSE rows drained for the backward walk — same
    # schedule, same online-softmax math as the primal fused kernel.
    interpret = cfg.interpret
    key = desc.cache_key() + ("fused_lse", plan.block_q, plan.block_k,
                              interpret)
    kernel = engine.build_cached(key, lambda: build_fused_flash_kernel(
        schedule=plan.tile_schedule(), batch_heads=desc.batch_heads,
        d=desc.d, dtype=qf.dtype, interpret=interpret, return_lse=True))
    engine.count_launches("flash_attention", 1)
    o, lse = kernel(qf, kf, vf)
    return o, {"fused": (qf, kf, vf, o, lse)}


def _flash_vjp_bwd(causal, res, g):
    if "fused" in res:
        qf, kf, vf, o, lse = res["fused"]
        bdesc = FlashBwdDescriptor.from_forward(_flat_desc(causal, qf, kf))
        dq, dk, dv = engine.dispatch(bdesc, qf, kf, vf, o, g, lse)
    else:
        qf, kf, vf = res["ref"]
        _, vjp = jax.vjp(functools.partial(_ref_flat, causal), qf, kf, vf)
        dq, dk, dv = vjp(g.astype(qf.dtype))
    return (dq.astype(qf.dtype), dk.astype(kf.dtype), dv.astype(vf.dtype))


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    fused: Optional[bool] = None) -> jax.Array:
    """q/k/v: (b, s, h, d) -> (b, s, h, d).

    ``fused=True/False`` pins the scheduled single-launch vs dense-grid
    lowering for this call (default: follow config + plan, DESIGN.md §10).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    desc = FlashDescriptor.from_operands(q, k, causal=causal)
    plan = None
    if block_q is not None or block_k is not None:
        # Fill unpinned knobs from the (cached) engine plan.
        auto = engine.plan_for(desc)
        plan = FlashPlan(desc, block_q or auto.block_q,
                         block_k or auto.block_k, fused=auto.fused)
    if plan is None and fused is None:
        # Default path: differentiable — training flows through the
        # custom VJP onto the scheduled backward walk (DESIGN.md §11).
        out = _flash_vjp(causal, qf, kf, vf)
    elif fused is None:
        out = engine.dispatch(desc, qf, kf, vf, plan=plan)
    else:
        from repro.core.config import use
        with use(fused="on" if fused else "off"):
            out = engine.dispatch(desc, qf, kf, vf, plan=plan)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
