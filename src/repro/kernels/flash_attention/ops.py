"""jit'd wrapper for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jit_cache import GLOBAL_KERNEL_CACHE
from repro.kernels.flash_attention.kernel import build_flash_kernel


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = True) -> jax.Array:
    """q/k/v: (b, s, h, d) -> (b, s, h, d)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    key = ("flash", b * h, sq, sk, d, causal, block_q, block_k,
           str(q.dtype), interpret)
    kernel = GLOBAL_KERNEL_CACHE.get_or_build(
        key, lambda: build_flash_kernel(
            batch_heads=b * h, sq=sq, sk=sk, d=d, block_q=block_q,
            block_k=block_k, causal=causal, dtype=q.dtype,
            interpret=interpret))
    out = kernel(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
