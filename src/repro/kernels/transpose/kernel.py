"""Tile-transpose Pallas kernel — the ZA horizontal/vertical trick (Lst. 5).

The paper transposes 16x16 blocks of B by writing vector registers into a
ZA tile through its *horizontal* view and reading them back through the
*vertical* view, staging the result in aligned scratch memory.  The TPU
analogue: each grid step stages one (bt, bt) block in a VMEM scratch tile,
transposes it in-register (Mosaic lowers ``.T`` of a VMEM tile to its
native sublane/lane rotations — the horizontal/vertical-view analogue) and
writes it to the mirrored block position ``(j, i)`` of the output.

Used by the two-pass "panel transpose then NN-GEMM" path for ``C += A·B``
with strided-contraction B (§IV-C), benchmarked against the fused
in-kernel transpose in fig89.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _transpose_body(x_ref, o_ref, scratch_ref):
    # Stage the tile through scratch (the ZA tile), then emit its transpose.
    scratch_ref[...] = x_ref[0]
    o_ref[0] = scratch_ref[...].T


def build_transpose_kernel(rows: int, cols: int, bt_r: int = 256,
                           bt_c: int = 256, dtype=jnp.float32,
                           interpret: bool = True, batch: int = 0):
    """Generate a (nb, rows, cols) -> (nb, cols, rows) transpose.

    Block (bt_r, bt_c) is read at block-index (b, i, j) and written at
    (b, j, i); partial edge blocks rely on Pallas store clipping (reads of
    the padded region are garbage but land outside the clipped store).
    Batch walks as the leading grid dimension — a batched transpose is ONE
    ``pallas_call``, not ``vmap``-stacked launches (DESIGN.md §9); the
    caller reshapes the unbatched case to ``nb = 1``.
    """
    nb = max(1, batch)
    grid = (nb, pl.cdiv(rows, bt_r), pl.cdiv(cols, bt_c))
    return pl.pallas_call(
        _transpose_body,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bt_r, bt_c), lambda b, i, j: (b, i, j))],
        out_specs=pl.BlockSpec((1, bt_c, bt_r), lambda b, i, j: (b, j, i)),
        out_shape=jax.ShapeDtypeStruct((nb, cols, rows), dtype),
        scratch_shapes=[pltpu.VMEM((bt_r, bt_c), dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )
