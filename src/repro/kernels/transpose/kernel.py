"""Tile-transpose Pallas kernel — the ZA horizontal/vertical trick (Lst. 5).

The paper transposes 16x16 blocks of B by writing vector registers into a
ZA tile through its *horizontal* view and reading them back through the
*vertical* view, staging the result in aligned scratch memory.  The TPU
analogue: each grid step stages one (bt, bt) block in a VMEM scratch tile,
transposes it in-register (Mosaic lowers ``.T`` of a VMEM tile to its
native sublane/lane rotations — the horizontal/vertical-view analogue) and
writes it to the mirrored block position ``(j, i)`` of the output.

Used by the two-pass "panel transpose then NN-GEMM" path for ``C += A·B``
with strided-contraction B (§IV-C), benchmarked against the fused
in-kernel transpose in fig89.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _transpose_body(x_ref, o_ref, scratch_ref):
    # Stage the tile through scratch (the ZA tile), then emit its transpose.
    scratch_ref[...] = x_ref[...]
    o_ref[...] = scratch_ref[...].T


def build_transpose_kernel(rows: int, cols: int, bt_r: int = 256,
                           bt_c: int = 256, dtype=jnp.float32,
                           interpret: bool = True):
    """Generate a (rows, cols) -> (cols, rows) transpose.

    Block (bt_r, bt_c) is read at block-index (i, j) and written at (j, i);
    partial edge blocks rely on Pallas store clipping (reads of the padded
    region are garbage but land outside the clipped store).
    """
    grid = (pl.cdiv(rows, bt_r), pl.cdiv(cols, bt_c))
    return pl.pallas_call(
        _transpose_body,
        grid=grid,
        in_specs=[pl.BlockSpec((bt_r, bt_c), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bt_c, bt_r), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((cols, rows), dtype),
        scratch_shapes=[pltpu.VMEM((bt_r, bt_c), dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )
