"""Oracle for the tile-transpose kernel."""
import jax
import jax.numpy as jnp


def ref_transpose(x: jax.Array) -> jax.Array:
    return jnp.swapaxes(x, -2, -1)
