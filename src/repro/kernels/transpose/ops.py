"""jit'd wrapper for the tile-transpose kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jit_cache import GLOBAL_KERNEL_CACHE
from repro.kernels.transpose.kernel import build_transpose_kernel


def transpose(x: jax.Array, *, bt: int = 256, interpret: bool = True) -> jax.Array:
    """Blocked 2-D (or batched) transpose through VMEM scratch tiles."""
    if x.ndim == 3:
        return jax.vmap(lambda xx: transpose(xx, bt=bt, interpret=interpret))(x)
    rows, cols = x.shape
    key = ("transpose", rows, cols, bt, str(x.dtype), interpret)
    kernel = GLOBAL_KERNEL_CACHE.get_or_build(
        key, lambda: build_transpose_kernel(rows, cols, bt, bt, x.dtype, interpret))
    return kernel(x)
