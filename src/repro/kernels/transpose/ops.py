"""Tile-transpose family: engine-planned tile edge, engine-cached build."""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import engine
from repro.core.blocking import TransposePlan, plan_transpose
from repro.core.descriptor import TransposeDescriptor
from repro.kernels.transpose.kernel import build_transpose_kernel


def execute(desc: TransposeDescriptor, plan: TransposePlan, x, *,
            interpret: bool = False) -> jax.Array:
    key = desc.cache_key() + ("kernel", plan.bt, interpret)
    kernel = engine.build_cached(key, lambda: build_transpose_kernel(
        desc.rows, desc.cols, plan.bt, plan.bt, x.dtype, interpret,
        batch=desc.batch))
    # Batch walks as a grid dimension of the single launch (DESIGN.md §9),
    # so count_launches sees a batched transpose as exactly 1.
    engine.count_launches("transpose", 1)
    if desc.batch:
        return kernel(x)
    return kernel(x[None])[0]


engine.register_family("transpose", planner=plan_transpose, execute=execute)


def transpose(x: jax.Array, *, bt: Optional[int] = None) -> jax.Array:
    """Blocked 2-D (or batched) transpose through VMEM scratch tiles.

    Rank-3 input transposes the trailing two dims; the batch dim walks as
    a leading grid dimension of ONE ``pallas_call`` (DESIGN.md §9), not a
    ``vmap`` over per-slice launches.  ``bt=None`` takes the
    machine-model-planned tile edge
    (:func:`repro.core.blocking.plan_transpose`).
    """
    desc = TransposeDescriptor.from_operands(x)
    plan = TransposePlan(desc, bt) if bt is not None else None
    return engine.dispatch(desc, x, plan=plan)
