from repro.kernels.transpose.ops import transpose  # noqa: F401
from repro.kernels.transpose.ref import ref_transpose  # noqa: F401
