"""Ragged grouped GEMM Pallas kernel (MoE expert compute).

The quintessential "batch of small, odd GEMMs" from the paper, §IV-B: each
expert's token group is a GEMM whose M dim is decided by the router at
runtime.  MegaBlocks-style mapping onto a static grid:

  * tokens arrive sorted by expert; each (bm)-row block belongs to exactly
    one expert (groups are padded to bm multiples by the caller);
  * the expert id of every row block rides in a *scalar-prefetch* operand
    (SMEM), and the B BlockSpec's index_map reads it to pull the right
    expert's weight tile — the LIBXSMM dispatch-by-descriptor analogue,
    moved into the grid;
  * row blocks past the total padded token count are skipped via
    ``pl.when`` (no DMA, no MXU work — the masked-invocation analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _grouped_kernel(block_expert_ref, nrows_ref, x_ref, w_ref, o_ref,
                    acc_ref, *, bm, bk, bn, k_steps, k_rem):
    i = pl.program_id(0)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    active = (i * bm) < nrows_ref[0]

    @pl.when(active)
    def _():
        a = x_ref[...]
        b = w_ref[...]
        if k_rem:
            kidx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
            valid = jnp.where(kk == k_steps - 1, k_rem, bk)
            a = jnp.where(kidx < valid, a, 0)
            kidx_b = jax.lax.broadcasted_iota(jnp.int32, b.shape, 0)
            b = jnp.where(kidx_b < valid, b, 0)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == k_steps - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def build_grouped_gemm_kernel(*, t_padded: int, k: int, n: int, num_experts: int,
                              bm: int = 128, bk: int = 512, bn: int = 256,
                              in_dtype=jnp.float32, out_dtype=jnp.float32,
                              interpret: bool = True):
    """Returns f(x:(Tp,K), w:(E,K,N), block_expert:(nb,), nrows:(1,)) -> (Tp,N)."""
    bn = min(bn, n)
    bk = min(bk, k)
    grid_m = pl.cdiv(t_padded, bm)
    grid_n = pl.cdiv(n, bn)
    grid_k = pl.cdiv(k, bk)

    body = functools.partial(_grouped_kernel, bm=bm, bk=bk, bn=bn,
                             k_steps=grid_k, k_rem=k % bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_expert, nrows
        grid=(grid_m, grid_n, grid_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, be, nr: (i, kk)),
            # weight tile of the expert owning row-block i
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, kk, be, nr: (be[i], kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, be, nr: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )

    kernel = pl.pallas_call(
        lambda be, nr, x, w, o, acc: body(be, nr, x, _squeeze_w(w), o, acc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_padded, n), out_dtype),
        interpret=interpret,
    )

    def run(x, w, block_expert, nrows):
        return kernel(block_expert, nrows, x, w)

    return run


class _SqueezedRef:
    """View of a (1, bk, bn) weight block ref as (bk, bn)."""

    def __init__(self, ref):
        self._ref = ref

    def __getitem__(self, idx):
        if idx is Ellipsis:
            return self._ref[0]
        return self._ref[(0,) + tuple(idx)]

    @property
    def shape(self):
        return self._ref.shape[1:]


def _squeeze_w(ref):
    return _SqueezedRef(ref)
