"""Ragged grouped GEMM Pallas kernels (MoE expert compute).

The quintessential "batch of small, odd GEMMs" from the paper, §IV-B: each
expert's token group is a GEMM whose M dim is decided by the router at
runtime.  Two lowerings (DESIGN.md §9):

  * **fused** (``build_fused_grouped_kernel``): ONE ``pallas_call`` walks
    the ragged expert row-blocks directly.  The runtime tile table — one
    row per ``bm``-row block, ``(row0, row_end, row_start, expert,
    state)``, built from ``group_sizes`` by
    :meth:`repro.core.schedule.GroupedTileSchedule.tables` — rides in
    scalar-prefetch SMEM; the owning expert's weight panel is pulled by
    the table-driven BlockSpec index map; edge blocks use the two-step
    clamped-window load and a predicated RMW store, so there is **no
    pad-to-``t_padded`` intermediate and no gather-back** — tokens are
    touched exactly once.
  * **pad/scatter** (``build_grouped_gemm_kernel``, the pre-schedule
    lowering, kept for VMEM-oversized problems and as the autotuner's
    alternative): MegaBlocks-style mapping onto a static grid — tokens
    sorted by expert are padded to ``bm`` multiples by the caller, each
    row block belongs to exactly one expert (``block_expert`` scalar
    prefetch), and blocks past the padded total are skipped via
    ``pl.when``.

Both lowerings share the epilogue vocabulary (``repro.kernels.epilogue``)
with a *per-expert* bias operand of shape (E, N) — the scalar-prefetch
dispatch that selects an expert's weight panel selects its bias row the
same way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import (TILE_COMPUTE, TILE_ZERO, GroupedTileSchedule,
                                 clamped_k_window, k_tail_mask,
                                 ownership_mask, predicated_store)
from repro.kernels.epilogue import apply_epilogue, needs_bias


# ---------------------------------------------------------------------------
# Fused scheduled lowering (DESIGN.md §9): one launch, no pad, no gather
# ---------------------------------------------------------------------------

def _fused_grouped_kernel(tbl_ref, *refs, kdim, n, bm, bk, bn, k_steps,
                          epilogue, out_dtype, quant=None):
    """Walk the ragged tile table: one grid step = one (row-block, N-block,
    K-panel).  refs: x, w, [sx], [sw], [bias], out, acc_scratch — x/out
    staged whole (clamped row windows need element-granular origins),
    w/bias pulled per-expert by the table-driven index maps.

    Under a ``quant`` spec (DESIGN.md §13) the staged operands are the
    wire dtype, accumulation is exact-wide (int32 for int8, f32 for fp8
    / weight-only), and the dequant vectors ride alongside: ``sx`` the
    per-row activation scales ``(T, 1)`` (fully-quantized only), ``sw``
    the per-expert column scales ``(E, N)`` whose owning row the same
    table-driven index map selects — dequant fuses into the epilogue."""
    weight_only = quant is not None and quant.weight_only
    full_quant = quant is not None and not quant.weight_only
    acc_dt = jnp.int32 if (full_quant and quant.dtype == "int8") \
        else jnp.float32

    idx = 0
    x_ref = refs[idx]; idx += 1
    w_ref = refs[idx]; idx += 1
    sx_ref = sw_ref = None
    if full_quant:
        sx_ref = refs[idx]; idx += 1
    if quant is not None:
        sw_ref = refs[idx]; idx += 1
    bias_ref = None
    if needs_bias(epilogue):
        bias_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    g = pl.program_id(0)
    j = pl.program_id(1)
    ks = pl.program_id(2)
    row0, row_end, rs = tbl_ref[g, 0], tbl_ref[g, 1], tbl_ref[g, 2]
    state = tbl_ref[g, 4]

    col0 = j * bn                       # nominal N-block start (ownership)
    cs = jnp.minimum(col0, n - bn)      # clamped window origin (N tail)
    col_end = jnp.minimum(col0 + bn, n)
    k0, kstart = clamped_k_window(ks, bk, kdim)

    @pl.when(state == TILE_COMPUTE)
    def _compute():
        @pl.when(ks == 0)
        def _init():
            acc_ref[...] = jnp.zeros((bm, bn), acc_dt)

        a = x_ref[pl.ds(rs, bm), pl.ds(kstart, bk)]
        b = w_ref[0, pl.ds(kstart, bk), pl.ds(cs, bn)]
        if weight_only:
            # int8 weight values are exact in the wide dtype; the column
            # scales stay in the epilogue.
            b = b.astype(a.dtype)
        if kdim % bk:  # K-tail predication on the clamped-window overlap
            a = k_tail_mask(a, 1, k0, kstart)
            b = k_tail_mask(b, 0, k0, kstart)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dt)

        @pl.when(ks == k_steps - 1)
        def _store():
            out = acc_ref[...]
            dequant = None
            if sw_ref is not None:
                dequant = sw_ref[0:1, pl.ds(cs, bn)]
                if sx_ref is not None:
                    dequant = sx_ref[pl.ds(rs, bm), 0:1] * dequant
            bias_blk = None
            if bias_ref is not None:
                bias_blk = bias_ref[0:1, pl.ds(cs, bn)]
            out = apply_epilogue(out, epilogue, bias_blk, dequant)
            own = ownership_mask((bm, bn), rs, cs,
                                 row0, row_end, col0, col_end)
            predicated_store(o_ref, (pl.ds(rs, bm), pl.ds(cs, bn)),
                             out.astype(out_dtype), own)

    # Rows past sum(group_sizes) belong to no expert -> zero (matches
    # ref.py); the zero-fill pseudo-group's tiles own exactly those rows.
    @pl.when((state == TILE_ZERO) & (ks == k_steps - 1))
    def _zero():
        own = ownership_mask((bm, bn), rs, cs, row0, row_end, col0, col_end)
        predicated_store(o_ref, (pl.ds(rs, bm), pl.ds(cs, bn)),
                         jnp.zeros((bm, bn), out_dtype), own)


def build_fused_grouped_kernel(*, schedule: GroupedTileSchedule,
                               epilogue: Optional[str] = None,
                               in_dtype=jnp.float32, out_dtype=jnp.float32,
                               interpret: bool = True, quant=None):
    """Generate ONE pallas_call executing a whole ragged grouped dispatch.

    Returns ``f(table, x, w, [bias], sx=None, sw=None) -> (T, N)`` where
    ``table`` is the runtime ``(max_tiles, 5)`` int32 tile table
    (:meth:`GroupedTileSchedule.tables`), ``x: (T, K)`` rows sorted by
    group, ``w: (E, K, N)``, ``bias: (E, N)``.  The supergrid is
    ``(max_tiles, n_steps, k_steps)``.

    With a :class:`~repro.core.descriptor.QuantSpec` the operands arrive
    in the wire dtype and the dequant scales are extra operands: ``sx``
    per-row ``(T,)`` (fully-quantized only) staged whole as ``(T, 1)``,
    ``sw`` per-expert dense columns ``(E, N)`` whose owning row the tile
    table's expert column selects — same index map as the weight panel.
    """
    t, kdim, n = schedule.t, schedule.k, schedule.n
    bm, bk, bn = schedule.bm, schedule.bk, schedule.bn
    has_bias = needs_bias(epilogue)
    has_sx = quant is not None and not quant.weight_only
    has_sw = quant is not None
    int_acc = has_sx and quant.dtype == "int8"

    body = functools.partial(
        _fused_grouped_kernel, kdim=kdim, n=n, bm=bm, bk=bk, bn=bn,
        k_steps=schedule.k_steps, epilogue=epilogue,
        out_dtype=jnp.dtype(out_dtype), quant=quant)

    in_specs = [
        pl.BlockSpec((t, kdim), lambda g, j, ks, tbl: (0, 0)),
        # the whole weight panel of the expert owning row-block g
        pl.BlockSpec((1, kdim, n), lambda g, j, ks, tbl: (tbl[g, 3], 0, 0)),
    ]
    if has_sx:
        # per-row activation scales, whole-staged like x (clamped row
        # windows need element-granular origins)
        in_specs.append(
            pl.BlockSpec((t, 1), lambda g, j, ks, tbl: (0, 0)))
    if has_sw:
        # the scale row of the expert owning row-block g
        in_specs.append(
            pl.BlockSpec((1, n), lambda g, j, ks, tbl: (tbl[g, 3], 0)))
    if has_bias:
        in_specs.append(
            pl.BlockSpec((1, n), lambda g, j, ks, tbl: (tbl[g, 3], 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the tile table
        grid=(schedule.max_tiles, schedule.n_steps, schedule.k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((t, n), lambda g, j, ks, tbl: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32 if int_acc else jnp.float32)],
    )

    kernel = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.dtype(out_dtype)),
        interpret=interpret,
    )

    def run(table, x, w, bias=None, sx=None, sw=None):
        args = [table, x, w]
        if has_sx:
            assert sx is not None
            args.append(sx.reshape(t, 1).astype(jnp.float32))
        if has_sw:
            assert sw is not None
            args.append(sw.astype(jnp.float32))
        if has_bias:
            assert bias is not None
            args.append(bias)
        return kernel(*args)

    return run


# ---------------------------------------------------------------------------
# Fused scheduled backward (DESIGN.md §11): ONE launch over the same
# runtime tile tables computes dgrad (dX = dY @ W^T) and wgrad
# (dW = X^T @ dY, plus db for biased epilogues) — neither gradient ever
# touches the pad/scatter path
# ---------------------------------------------------------------------------

def _fused_grouped_bwd_kernel(tbl_ref, *refs, kdim, n, bm, bk, bn,
                              k_steps, n_steps, with_db):
    """Walk the ragged tile table with the grid reordered to
    ``(row-block, K-panel, N-block)``: the dX tile ``(bm, bk)``
    accumulates over the innermost N walk in scratch and drains with a
    predicated store; dW (and db) are whole-staged fp32 and accumulate by
    read-modify-write — contributions outside a tile's owned rows /
    nominal columns are masked to zero, so clamped-window overlap and
    revisits add nothing.  dW/db zero at the very first grid step,
    *outside* the tile-state conditional, so zero-size experts (which own
    no COMPUTE tile) still come back zero rather than garbage."""
    idx = 0
    x_ref = refs[idx]; idx += 1
    dy_ref = refs[idx]; idx += 1
    w_ref = refs[idx]; idx += 1
    dx_ref = refs[idx]; idx += 1
    dw_ref = refs[idx]; idx += 1
    db_ref = None
    if with_db:
        db_ref = refs[idx]; idx += 1
    dxacc_ref = refs[idx]

    g = pl.program_id(0)
    ks = pl.program_id(1)
    j = pl.program_id(2)
    row0, row_end, rs = tbl_ref[g, 0], tbl_ref[g, 1], tbl_ref[g, 2]
    e = tbl_ref[g, 3]
    state = tbl_ref[g, 4]

    @pl.when((g == 0) & (ks == 0) & (j == 0))
    def _zero_wgrad():
        dw_ref[...] = jnp.zeros_like(dw_ref)
        if db_ref is not None:
            db_ref[...] = jnp.zeros_like(db_ref)

    col0 = j * bn
    cs = jnp.minimum(col0, n - bn)
    k0, kstart = clamped_k_window(ks, bk, kdim)
    k_end = jnp.minimum(k0 + bk, kdim)

    @pl.when(state == TILE_COMPUTE)
    def _compute():
        @pl.when(j == 0)
        def _init():
            dxacc_ref[...] = jnp.zeros_like(dxacc_ref)

        # dY window, masked to owned rows and nominal columns (the
        # clamped N window may revisit columns of the previous block).
        dy_blk = dy_ref[pl.ds(rs, bm), pl.ds(cs, bn)].astype(jnp.float32)
        own_dy = ownership_mask((bm, bn), rs, cs, row0, row_end, col0, n)
        dy_m = jnp.where(own_dy, dy_blk, 0.0)
        w_blk = w_ref[0, pl.ds(kstart, bk), pl.ds(cs, bn)].astype(jnp.float32)

        # dgrad: dX[rows, kpanel] += dY @ W^T — masked dY zeroes every
        # term another tile owns, so no W-side mask is needed.
        dxacc_ref[...] += jax.lax.dot_general(
            dy_m, w_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        # wgrad: dW[e, kpanel, nblock] += X^T @ dY.
        x_blk = x_ref[pl.ds(rs, bm), pl.ds(kstart, bk)].astype(jnp.float32)
        own_x = ownership_mask((bm, bk), rs, kstart, row0, row_end, k0, kdim)
        x_m = jnp.where(own_x, x_blk, 0.0)
        dw_ref[pl.ds(e, 1), pl.ds(kstart, bk), pl.ds(cs, bn)] += (
            jax.lax.dot_general(x_m, dy_m, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)[None])

        if db_ref is not None:
            @pl.when(ks == 0)
            def _db():
                db_ref[pl.ds(e, 1), pl.ds(cs, bn)] += (
                    jnp.sum(dy_m, axis=0, keepdims=True))

        @pl.when(j == n_steps - 1)
        def _store_dx():
            own = ownership_mask((bm, bk), rs, kstart,
                                 row0, row_end, k0, k_end)
            predicated_store(dx_ref, (pl.ds(rs, bm), pl.ds(kstart, bk)),
                             dxacc_ref[...], own)

    # Rows past sum(group_sizes) belong to no expert -> zero dX rows.
    @pl.when((state == TILE_ZERO) & (j == n_steps - 1))
    def _zero_dx():
        own = ownership_mask((bm, bk), rs, kstart, row0, row_end, k0, k_end)
        predicated_store(dx_ref, (pl.ds(rs, bm), pl.ds(kstart, bk)),
                         jnp.zeros((bm, bk), jnp.float32), own)


def build_fused_grouped_bwd_kernel(*, schedule: GroupedTileSchedule,
                                   with_db: bool = False,
                                   in_dtype=jnp.float32,
                                   interpret: bool = True):
    """Generate ONE pallas_call executing a whole grouped backward.

    Returns ``f(table, x, dy, w) -> (dx, dw[, db])`` with
    ``x: (T, K)``, ``dy: (T, N)`` (the *pre-epilogue* cotangent — the ops
    wrapper peels activations off first), ``w: (E, K, N)``; gradients
    come back fp32 (the ops wrapper casts).  The supergrid is
    ``(max_tiles, k_steps, n_steps)`` — K outside N so the dX tile drains
    once per K-panel (DESIGN.md §11).
    """
    t, kdim, n = schedule.t, schedule.k, schedule.n
    bm, bk, bn = schedule.bm, schedule.bk, schedule.bn
    e = schedule.num_experts

    body = functools.partial(
        _fused_grouped_bwd_kernel, kdim=kdim, n=n, bm=bm, bk=bk, bn=bn,
        k_steps=schedule.k_steps, n_steps=schedule.n_steps, with_db=with_db)

    in_specs = [
        pl.BlockSpec((t, kdim), lambda g, ks, j, tbl: (0, 0)),
        pl.BlockSpec((t, n), lambda g, ks, j, tbl: (0, 0)),
        pl.BlockSpec((1, kdim, n), lambda g, ks, j, tbl: (tbl[g, 3], 0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((t, kdim), lambda g, ks, j, tbl: (0, 0)),
        pl.BlockSpec((e, kdim, n), lambda g, ks, j, tbl: (0, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((t, kdim), jnp.float32),
        jax.ShapeDtypeStruct((e, kdim, n), jnp.float32),
    ]
    if with_db:
        out_specs.append(pl.BlockSpec((e, n), lambda g, ks, j, tbl: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((e, n), jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the tile table
        grid=(schedule.max_tiles, schedule.k_steps, schedule.n_steps),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
    )

    kernel = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )

    def run(table, x, dy, w):
        return tuple(kernel(table, x, dy, w))

    return run


# ---------------------------------------------------------------------------
# Pad/scatter lowering (pre-schedule fallback + autotune alternative)
# ---------------------------------------------------------------------------

def _grouped_kernel(block_expert_ref, nrows_ref, *refs, bm, bk, bn,
                    k_steps, k_rem, epilogue, out_dtype):
    idx = 0
    x_ref = refs[idx]; idx += 1
    w_ref = refs[idx]; idx += 1
    bias_ref = None
    if needs_bias(epilogue):
        bias_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    i = pl.program_id(0)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    active = (i * bm) < nrows_ref[0]

    @pl.when(active)
    def _():
        a = x_ref[...]
        b = w_ref[0]
        if k_rem:
            kidx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
            valid = jnp.where(kk == k_steps - 1, k_rem, bk)
            a = jnp.where(kidx < valid, a, 0)
            kidx_b = jax.lax.broadcasted_iota(jnp.int32, b.shape, 0)
            b = jnp.where(kidx_b < valid, b, 0)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == k_steps - 1)
    def _():
        out = acc_ref[...]
        bias_blk = bias_ref[...] if bias_ref is not None else None
        out = apply_epilogue(out, epilogue, bias_blk)
        o_ref[...] = out.astype(out_dtype)


def build_grouped_gemm_kernel(*, t_padded: int, k: int, n: int, num_experts: int,
                              bm: int = 128, bk: int = 512, bn: int = 256,
                              epilogue: Optional[str] = None,
                              in_dtype=jnp.float32, out_dtype=jnp.float32,
                              interpret: bool = True):
    """Returns f(x:(Tp,K), w:(E,K,N), [bias:(E,N)], block_expert:(nb,),
    nrows:(1,)) -> (Tp,N)."""
    bn = min(bn, n)
    bk = min(bk, k)
    grid_m = pl.cdiv(t_padded, bm)
    grid_n = pl.cdiv(n, bn)
    grid_k = pl.cdiv(k, bk)
    has_bias = needs_bias(epilogue)

    body = functools.partial(_grouped_kernel, bm=bm, bk=bk, bn=bn,
                             k_steps=grid_k, k_rem=k % bk, epilogue=epilogue,
                             out_dtype=jnp.dtype(out_dtype))

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk, be, nr: (i, kk)),
        # weight tile of the expert owning row-block i
        pl.BlockSpec((1, bk, bn),
                     lambda i, j, kk, be, nr: (be[i], kk, j)),
    ]
    if has_bias:
        # ... and the same expert's bias row
        in_specs.append(
            pl.BlockSpec((1, bn), lambda i, j, kk, be, nr: (be[i], j)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_expert, nrows
        grid=(grid_m, grid_n, grid_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, be, nr: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )

    kernel = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_padded, n), out_dtype),
        interpret=interpret,
    )

    def run(x, w, block_expert, nrows, bias=None):
        args = [block_expert, nrows, x, w]
        if has_bias:
            assert bias is not None
            args.append(bias)
        return kernel(*args)

    return run
