"""Oracle for the ragged grouped GEMM (MoE expert compute).

rows of ``x`` are sorted by group; ``group_sizes[e]`` rows belong to group
``e`` and are multiplied by ``w[e]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_grouped_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array
                     ) -> jax.Array:
    """x: (T, K); w: (E, K, N); group_sizes: (E,) summing to <= T.

    Rows past ``sum(group_sizes)`` produce zeros.
    """
    t, k = x.shape
    e, _, n = w.shape
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes.astype(jnp.int32))])
    row = jnp.arange(t)
    # expert of each row: searchsorted over offsets
    expert = jnp.clip(jnp.searchsorted(offsets, row, side="right") - 1, 0, e - 1)
    valid = row < offsets[-1]
    w_rows = w[expert]  # (T, K, N) gather
    out = jnp.einsum("tk,tkn->tn", x.astype(jnp.float32),
                     w_rows.astype(jnp.float32))
    return jnp.where(valid[:, None], out, 0.0).astype(x.dtype)
