"""Ragged grouped GEMM family (MoE expert compute).

Takes pre-sorted rows + group sizes and dispatches one of two lowerings
(DESIGN.md §9), resolved by ``engine.resolve_fused`` exactly as for
dense GEMM:

  * **fused** (``plan.fused``, default whenever the staged operands fit
    VMEM): the plan's :class:`~repro.core.schedule.GroupedTileSchedule`
    turns ``group_sizes`` into a runtime tile table and ONE
    ``pallas_call`` walks the ragged expert row-blocks directly —
    no pad-to-``t_padded`` intermediate, no ``out_padded[dest]``
    gather-back;
  * **pad/scatter** (the pre-schedule lowering, kept for VMEM-oversized
    problems and as the autotuner's alternative): pad each group to the
    row-block multiple, build the block→expert map, dispatch the static
    grid, gather the rows back out.

Epilogues (bias/gelu/silu/relu, per-expert bias of shape (E, N)) lower
through ``repro.kernels.epilogue`` on both paths.  Tile sizes
(bm, bk, bn) come from the engine's machine-model planner
(:func:`repro.core.blocking.plan_grouped`); explicit kwargs pin the plan.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocking import (GroupedGemmPlan, grouped_bwd_fused_legal,
                                 mesh_comm_events, plan_grouped,
                                 plan_grouped_bwd)
from repro.core.config import get_config
from repro.core.descriptor import (GroupedGemmBwdDescriptor,
                                   GroupedGemmDescriptor, MeshSpec,
                                   check_bias)
from repro.core.schedule import plan_launches
from repro.kernels.epilogue import apply_epilogue, needs_bias
from repro.kernels.grouped_gemm.kernel import (build_fused_grouped_bwd_kernel,
                                               build_fused_grouped_kernel,
                                               build_grouped_gemm_kernel)


def plan_groups(group_sizes: jax.Array, num_experts: int, bm: int,
                t_padded: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Row offsets per group after padding each group to a bm multiple.

    Returns (padded_offsets (E+1,), block_expert (nb,), nrows (1,)).
    All shapes static; values dynamic (runtime router output).
    """
    sizes = group_sizes.astype(jnp.int32)
    padded = ((sizes + bm - 1) // bm) * bm
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)])
    nb = t_padded // bm
    block_row = jnp.arange(nb, dtype=jnp.int32) * bm
    block_expert = jnp.clip(
        jnp.searchsorted(offsets, block_row, side="right") - 1,
        0, num_experts - 1).astype(jnp.int32)
    nrows = offsets[-1:].astype(jnp.int32)
    return offsets, block_expert, nrows


def scatter_rows(x_sorted_by_group, group_sizes, offsets, bm, t_padded):
    """Place each group's rows at its padded offset (zeros between)."""
    t, kdim = x_sorted_by_group.shape
    src_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes.astype(jnp.int32))])
    row = jnp.arange(t, dtype=jnp.int32)
    grp = jnp.clip(jnp.searchsorted(src_off, row, side="right") - 1,
                   0, group_sizes.shape[0] - 1)
    dest = offsets[grp] + (row - src_off[grp])
    out = jnp.zeros((t_padded, kdim), x_sorted_by_group.dtype)
    return out.at[dest].set(x_sorted_by_group), dest


def _execute_fused(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x, w,
                   group_sizes, bias, interpret: bool,
                   sx=None, sw=None) -> jax.Array:
    """Single scheduled launch: runtime tables, direct ragged stores."""
    sched = plan.tile_schedule()
    table = sched.tables(group_sizes)
    key = desc.cache_key() + ("fused", sched.bm, sched.bk, sched.bn,
                              interpret)
    kernel = engine.build_cached(key, lambda: build_fused_grouped_kernel(
        schedule=sched, epilogue=desc.epilogue, in_dtype=x.dtype,
        out_dtype=jnp.dtype(desc.dtype), interpret=interpret,
        quant=desc.quant))
    return kernel(table, x, w, bias, sx=sx, sw=sw)


def _execute_padded(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x, w,
                    group_sizes, bias, interpret: bool) -> jax.Array:
    """Pad/scatter lowering: pad groups to bm multiples, gather back."""
    bm, bk, bn = plan.bm, plan.bk, plan.bn
    t_padded = plan.t_padded
    offsets, block_expert, nrows = plan_groups(
        group_sizes, desc.num_experts, bm, t_padded)
    x_padded, dest = scatter_rows(x, group_sizes, offsets, bm, t_padded)

    key = desc.cache_key() + ("kernel", bm, bk, bn, interpret)
    kernel = engine.build_cached(key, lambda: build_grouped_gemm_kernel(
        t_padded=t_padded, k=desc.k, n=desc.n,
        num_experts=desc.num_experts, bm=bm, bk=bk, bn=bn,
        epilogue=desc.epilogue, in_dtype=x.dtype, out_dtype=x.dtype,
        interpret=interpret))
    out_padded = kernel(x_padded, w, block_expert, nrows, bias)
    # gather back to the caller's (sorted, unpadded) row order; rows past
    # sum(group_sizes) belong to no group -> zero (matches ref).
    total = jnp.sum(group_sizes.astype(jnp.int32))
    valid = (jnp.arange(desc.t, dtype=jnp.int32) < total)[:, None]
    return jnp.where(valid, out_padded[dest], 0).astype(x.dtype)


def _xla_quant_grouped(desc: GroupedGemmDescriptor, x, w, group_sizes,
                       bias, sx, sw) -> jax.Array:
    """Non-fused quant lowering: the XLA formulation.

    Quantized operands -> one exact-wide-accumulation contraction ->
    dequant + epilogue through the SAME :func:`apply_epilogue` the fused
    kernel calls, term for term — bit-identical for int8 (integer
    accumulation is exact under any tiling) and the parity oracle for
    tests.  No ``pallas_call``: counts zero launches.  The pad/scatter
    kernel stays wide-only (DESIGN.md §13).
    """
    q = desc.quant
    t = x.shape[0]
    sizes = group_sizes.astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)])
    row = jnp.arange(t, dtype=jnp.int32)
    grp = jnp.clip(jnp.searchsorted(offsets, row, side="right") - 1,
                   0, group_sizes.shape[0] - 1)
    if q.weight_only:
        acc = jnp.einsum("tk,tkn->tn", x, w[grp].astype(x.dtype),
                         preferred_element_type=jnp.float32)
        factor = sw[grp].astype(jnp.float32)
    else:
        pref = jnp.int32 if q.dtype == "int8" else jnp.float32
        acc = jnp.einsum("tk,tkn->tn", x, w[grp],
                         preferred_element_type=pref)
        factor = (sx.reshape(t, 1).astype(jnp.float32)
                  * sw[grp].astype(jnp.float32))
    out = apply_epilogue(acc, desc.epilogue,
                         None if bias is None else bias[grp], factor)
    valid = (row < offsets[-1])[:, None]
    return jnp.where(valid, out, 0).astype(jnp.dtype(desc.dtype))


def _execute_mesh(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x4, w,
                  group_sizes, bias, interpret: bool) -> jax.Array:
    """Mesh execution (DESIGN.md §14): run the plan's strategy under
    ``shard_map`` over the descriptor's mesh axis.

    ``x4`` is the capacity-slot layout ``(n, e, cap, k)`` with the token
    group dim ``n`` sharded over the axis and ``w`` the ``(e, k, f)``
    expert bank sharded (or gathered) over its expert dim.  Both
    strategies reduce to the SAME per-shard local grouped call
    (``plan.local_desc`` with the plan's tiling knobs), so the fused
    single-launch property holds per shard:

      * **gathered** — ``w`` enters replicated (``P(None)``): any weight
        movement is XLA-implicit outside the engine, and the engine comm
        counters stay zero;
      * **distributed** — ``w`` stays expert-sharded and two explicit
        ``lax.all_to_all`` calls move the capacity slots to their
        expert's owner and back (the olmax ``all2all`` idiom), counted
        via ``engine.count_comm`` at trace time.
    """
    if desc.quant is not None:
        raise NotImplementedError("mesh grouped GEMM is wide-only")
    if bias is not None:
        raise NotImplementedError("mesh grouped GEMM has no bias path")
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.runtime.shardlib import current_mesh
    mesh = current_mesh()
    axis, s = desc.mesh.axis, desc.mesh.size
    if mesh is None or mesh.shape.get(axis, 0) != s:
        raise ValueError(f"descriptor mesh {desc.mesh} does not match the "
                         f"active device mesh {mesh}")
    comm = plan.comm or "gathered"
    local = plan.local_desc
    lplan = GroupedGemmPlan(local, plan.bm, plan.bk, plan.bn,
                            fused=plan.fused, plan_source=plan.plan_source)
    nt, e, cap, k = x4.shape
    f = desc.n
    e_loc = e // s

    def run_local(rows, w_loc, n_groups):
        sizes = jnp.full((n_groups,), rows.shape[0] // n_groups, jnp.int32)
        return execute(local, lplan, rows, w_loc, sizes, bias=None,
                       interpret=interpret)

    if comm == "gathered":
        def body(xl, w_full):
            nl = xl.shape[0]
            rows = xl.transpose(1, 0, 2, 3).reshape(e * nl * cap, k)
            y = run_local(rows, w_full, e)
            return y.reshape(e, nl, cap, f).transpose(1, 0, 2, 3)

        fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(None)),
                       out_specs=P(axis), check_rep=False)
        return fn(x4, w)

    events = mesh_comm_events(desc, "distributed")
    engine.count_comm("grouped_gemm", sum(b for _, b in events),
                      launches=len(events))

    def body(xl, w_loc):
        nl = xl.shape[0]
        # Slot tokens by owner shard: (s, nl, e_loc, cap, k), dim0 = the
        # destination; all_to_all turns dim0 into the SOURCE shard index.
        h = xl.reshape(nl, s, e_loc, cap, k).transpose(1, 0, 2, 3, 4)
        h = jax.lax.all_to_all(h, axis, split_axis=0, concat_axis=0)
        # Rows sorted by local expert, uniform s*nl*cap rows each.
        rows = h.transpose(2, 0, 1, 3, 4).reshape(e_loc * s * nl * cap, k)
        y = run_local(rows, w_loc, e_loc)
        # Inverse shuffle: back to (nl, e, cap, f) token-major layout.
        y = y.reshape(e_loc, s, nl, cap, f).transpose(1, 2, 0, 3, 4)
        y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0)
        return y.transpose(1, 0, 2, 3, 4).reshape(nl, e, cap, f)

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P(axis), check_rep=False)
    return fn(x4, w)


def execute(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x, w,
            group_sizes, *, bias=None, sx=None, sw=None,
            interpret: bool = False) -> jax.Array:
    if desc.mesh is not None:
        # Mesh descriptor (DESIGN.md §14): gathered / distributed
        # execution under shard_map; the operand layout is the 4-D
        # capacity-slot form (see expert_parallel_grouped_gemm).
        return _execute_mesh(desc, plan, x, w, group_sizes, bias, interpret)
    check_bias(desc.epilogue, bias)
    if desc.quant is not None:
        # Quantized axis (DESIGN.md §13): fused -> the scheduled walk in
        # the wire dtype with dequant in the epilogue; otherwise the XLA
        # formulation (zero engine launches).
        if engine.resolve_fused(plan):
            engine.count_launches("grouped_gemm",
                                  plan_launches(plan, fused=True))
            return _execute_fused(desc, plan, x, w, group_sizes, bias,
                                  interpret, sx=sx, sw=sw)
        engine.count_launches("grouped_gemm", 0)
        return _xla_quant_grouped(desc, x, w, group_sizes, bias, sx, sw)
    fused = engine.resolve_fused(plan)
    engine.count_launches("grouped_gemm", plan_launches(plan, fused=fused))
    if fused:
        return _execute_fused(desc, plan, x, w, group_sizes, bias, interpret)
    return _execute_padded(desc, plan, x, w, group_sizes, bias, interpret)


engine.register_family("grouped_gemm", planner=plan_grouped, execute=execute)


# ---------------------------------------------------------------------------
# Backward family (DESIGN.md §11): ONE pallas_call walks the same runtime
# tile tables producing dX and dW (and db) — never the pad/scatter path
# ---------------------------------------------------------------------------

def execute_bwd(desc: GroupedGemmBwdDescriptor, plan: GroupedGemmPlan, x, dy,
                w, group_sizes, *, interpret: bool = False):
    """Engine executor: run one planned grouped-GEMM backward.

    ``dy`` is the *pre-epilogue* cotangent (the custom VJP peels the
    activation chain off first).  Single lowering — the scheduled walk;
    illegal descriptors never reach the engine (the custom VJP falls back
    to reference autodiff first).
    """
    engine.count_launches("grouped_gemm_bwd", 1)
    sched = plan.tile_schedule()
    table = sched.tables(group_sizes)
    key = desc.cache_key() + ("fused", sched.bm, sched.bk, sched.bn,
                              interpret)
    kernel = engine.build_cached(key, lambda: build_fused_grouped_bwd_kernel(
        schedule=sched, with_db=needs_bias(desc.epilogue),
        in_dtype=x.dtype, interpret=interpret))
    return kernel(table, x, dy, w)


engine.register_family("grouped_gemm_bwd", planner=plan_grouped_bwd,
                       execute=execute_bwd)


_ACTIVATIONS = {"gelu": jax.nn.gelu, "silu": jax.nn.silu,
                "relu": lambda p: jnp.maximum(p, 0)}


def _act_name(epilogue: Optional[str]) -> Optional[str]:
    """The activation half of an epilogue name (None when linear)."""
    if epilogue is None or epilogue == "bias":
        return None
    return epilogue.split("_")[-1]


def _ref_grouped(epilogue, x, w, group_sizes, bias):
    """Pure-jnp epilogue-aware reference — the differentiable oracle the
    VJP falls back to when the scheduled backward is not legal (and the
    gradient-parity baseline in tests).  Rows past ``sum(group_sizes)``
    are zero regardless of epilogue, matching both kernel lowerings."""
    t = x.shape[0]
    sizes = group_sizes.astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)])
    row = jnp.arange(t, dtype=jnp.int32)
    grp = jnp.clip(jnp.searchsorted(offsets, row, side="right") - 1,
                   0, group_sizes.shape[0] - 1)
    out = jnp.einsum("tk,tkn->tn", x.astype(jnp.float32),
                     w.astype(jnp.float32)[grp])
    out = apply_epilogue(out, epilogue,
                         None if bias is None else bias[grp])
    valid = (row < offsets[-1])[:, None]
    return jnp.where(valid, out, 0).astype(x.dtype)


def _grouped_dispatch(epilogue, x, w, group_sizes, bias):
    """The engine-dispatched forward (primal path)."""
    desc = GroupedGemmDescriptor.from_operands(x, w, epilogue=epilogue)
    return engine.dispatch(desc, x, w, group_sizes, bias=bias)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grouped_vjp(epilogue, x, w, group_sizes, bias):
    """Differentiable grouped GEMM (custom VJP, DESIGN.md §11): forward =
    the engine-dispatched kernel; backward = the scheduled single-launch
    dX/dW walk over the same runtime tile tables when legal,
    reference-path autodiff otherwise."""
    return _grouped_dispatch(epilogue, x, w, group_sizes, bias)


def _grouped_vjp_fwd(epilogue, x, w, group_sizes, bias):
    cfg = get_config()
    desc = GroupedGemmDescriptor.from_operands(x, w, epilogue=epilogue)
    bdesc = GroupedGemmBwdDescriptor.from_forward(desc)
    fused_ok = (cfg.fused != "off"
                and grouped_bwd_fused_legal(bdesc, cfg.machine))
    out = engine.dispatch(desc, x, w, group_sizes, bias=bias)
    # Residual dict keys are pytree *structure* — the backward branch is
    # resolved at trace time, not with traced booleans.
    res = {"fused" if fused_ok else "ref": (x, w, group_sizes, bias)}
    return out, res


def _grouped_vjp_bwd(epilogue, res, g):
    if "fused" in res:
        x, w, group_sizes, bias = res["fused"]
        dpre = g.astype(jnp.float32)
        act = _act_name(epilogue)
        if act is not None:
            # Peel the activation off the chain: recompute the
            # pre-activation via the engine forward with the activation
            # stripped from the epilogue, then pull ``g`` through the
            # activation alone.  What remains (``dpre``) is the cotangent
            # of x @ w (+ bias), which the scheduled walk consumes — the
            # same quantity db sums per expert.
            biased = needs_bias(epilogue)
            pre = _grouped_dispatch("bias" if biased else None, x, w,
                                    group_sizes, bias if biased else None)
            _, act_vjp = jax.vjp(
                lambda p: _ACTIVATIONS[act](p.astype(jnp.float32)), pre)
            dpre = act_vjp(dpre)[0]
        bdesc = GroupedGemmBwdDescriptor.from_forward(
            GroupedGemmDescriptor.from_operands(x, w, epilogue=epilogue))
        grads = engine.dispatch(bdesc, x, dpre, w, group_sizes)
        dx, dw = grads[0], grads[1]
        db = grads[2].astype(bias.dtype) if needs_bias(epilogue) else None
    else:
        x, w, group_sizes, bias = res["ref"]
        if bias is None:
            _, vjp = jax.vjp(
                lambda x_, w_: _ref_grouped(epilogue, x_, w_, group_sizes,
                                            None), x, w)
            (dx, dw), db = vjp(g.astype(x.dtype)), None
        else:
            _, vjp = jax.vjp(
                lambda x_, w_, b_: _ref_grouped(epilogue, x_, w_,
                                                group_sizes, b_), x, w, bias)
            dx, dw, db = vjp(g.astype(x.dtype))
    return (dx.astype(x.dtype), dw.astype(w.dtype), None, db)


_grouped_vjp.defvjp(_grouped_vjp_fwd, _grouped_vjp_bwd)


def _quantize_grouped_w(w, spec):
    """Per-expert quantization of the (E, K, N) bank along output columns.

    Every expert panel gets its own scales (the schemes resolve per
    expert: per_tensor -> one scalar each, per_channel -> per output
    column, per_tile -> per 128-column block), expanded dense so the
    kernel stages one ``(E, N)`` f32 scale table indexed by the tile
    table's expert column.
    """
    from repro.optim.compression import quantize_operand
    wq, sw = jax.vmap(lambda wi: quantize_operand(wi, spec, axis=1))(w)
    return wq, sw


def grouped_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                 epilogue: Optional[str] = None,
                 bias: Optional[jax.Array] = None,
                 bm: Optional[int] = None, bk: Optional[int] = None,
                 bn: Optional[int] = None,
                 fused: Optional[bool] = None,
                 quant=None) -> jax.Array:
    """Ragged grouped GEMM via the engine.

    x: (T, K) rows sorted by group; w: (E, K, N); group_sizes: (E,)
    (dynamic, sum <= T).  Returns (T, N): row i multiplied by its group's
    weight; rows beyond sum(group_sizes) are zero.  ``epilogue`` fuses the
    GEMM tail (``bias`` is per-expert, shape (E, N)); ``fused=True/False``
    pins the scheduled single-launch vs pad/scatter lowering for this
    call (default: follow config + plan, DESIGN.md §9).

    ``quant`` selects the low-precision axis (DESIGN.md §13): a spec /
    alias ("int8", "w8a16", "fp8") quantizes at dispatch — the expert
    bank per expert along output columns, the activations per row for
    fully-quantized specs — with dequant fused into the epilogue.
    ``quant=False`` opts this call out of an ambient ``config.quant``.
    The quant path is inference-only (no custom VJP; the wide path keeps
    the scheduled backward).
    """
    from repro.core.descriptor import resolve_quant
    spec = resolve_quant(get_config().quant if quant is None else quant)
    sx = sw = None
    if spec is not None:
        # Descriptor from the *wide* operands: desc.dtype stays the
        # logical compute/output dtype, the spec implies wire dtypes.
        desc = GroupedGemmDescriptor.from_operands(x, w, epilogue=epilogue,
                                                   quant=spec)
        from repro.optim.compression import quantize_operand
        w, sw = _quantize_grouped_w(w, spec)
        if not spec.weight_only:
            x, sx = quantize_operand(x, spec, axis=0)
    else:
        desc = GroupedGemmDescriptor.from_operands(x, w, epilogue=epilogue)
    plan = None
    if bm is not None or bk is not None or bn is not None:
        # Fill unpinned knobs from the (cached) engine plan.
        auto = engine.plan_for(desc)
        plan = GroupedGemmPlan(desc, bm or auto.bm, bk or auto.bk,
                               bn or auto.bn, fused=auto.fused)
    if spec is not None:
        # Inference-direct dispatch (no VJP wrapper on the quant axis).
        check_bias(epilogue, bias)
        if fused is None:
            return engine.dispatch(desc, x, w, group_sizes, plan=plan,
                                   bias=bias, sx=sx, sw=sw)
        from repro.core.config import use
        with use(fused="on" if fused else "off"):
            return engine.dispatch(desc, x, w, group_sizes, plan=plan,
                                   bias=bias, sx=sx, sw=sw)
    if plan is None and fused is None:
        # Default path: differentiable — training flows through the
        # custom VJP onto the scheduled backward walk (DESIGN.md §11).
        check_bias(epilogue, bias)
        return _grouped_vjp(epilogue, x, w, group_sizes, bias)
    if fused is None:
        return engine.dispatch(desc, x, w, group_sizes, plan=plan, bias=bias)
    from repro.core.config import use
    with use(fused="on" if fused else "off"):
        return engine.dispatch(desc, x, w, group_sizes, plan=plan, bias=bias)


# ---------------------------------------------------------------------------
# Expert-parallel entry point (DESIGN.md §14)
# ---------------------------------------------------------------------------

def _ref_ep(epilogue, x4, w):
    """Differentiable XLA oracle of the capacity-slot expert GEMM — the
    custom VJP's backward formulation (partitions under SPMD) and the
    numerical baseline in tests."""
    out = jnp.einsum("neck,ekf->necf", x4.astype(jnp.float32),
                     w.astype(jnp.float32))
    out = apply_epilogue(out, epilogue, None)
    return out.astype(x4.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ep_vjp(axis, epilogue, x4, w):
    """Forward = the engine's mesh dispatch; backward = autodiff of the
    XLA oracle (the olmax all2all custom-gradient idiom: the collective
    shuffle is engine-owned on the forward pass, while gradients flow
    through a formulation XLA partitions on its own)."""
    return _ep_dispatch(axis, epilogue, x4, w)


def _ep_dispatch(axis, epilogue, x4, w):
    from repro.core.descriptor import canonical_dtype
    from repro.runtime.shardlib import current_mesh
    mesh = current_mesh()
    s = mesh.shape.get(axis, 1) if mesh is not None else 1
    nt, e, cap, k = x4.shape
    desc = GroupedGemmDescriptor(
        t=nt * e * cap, k=k, n=int(w.shape[-1]), num_experts=e,
        dtype=canonical_dtype(x4.dtype), epilogue=epilogue,
        mesh=MeshSpec(axis, s))
    return engine.dispatch(desc, x4, w, None).reshape(nt, e, cap, -1)


def _ep_vjp_fwd(axis, epilogue, x4, w):
    return _ep_dispatch(axis, epilogue, x4, w), (x4, w)


def _ep_vjp_bwd(axis, epilogue, res, g):
    x4, w = res
    _, vjp = jax.vjp(lambda a, b: _ref_ep(epilogue, a, b), x4, w)
    dx, dw = vjp(g.astype(x4.dtype))
    return dx.astype(x4.dtype), dw.astype(w.dtype)


_ep_vjp.defvjp(_ep_vjp_fwd, _ep_vjp_bwd)


def expert_parallel_grouped_gemm(x4: jax.Array, w: jax.Array, *,
                                 axis: str = "model",
                                 epilogue: Optional[str] = None) -> jax.Array:
    """Expert-parallel capacity-slot grouped GEMM (DESIGN.md §14).

    ``x4``: ``(n, e, cap, k)`` dispatch slots (MoE layout — ``n`` token
    groups, ``e`` experts, ``cap`` capacity); ``w``: ``(e, k, f)`` expert
    bank.  Returns ``(n, e, cap, f)``.

    Under an active mesh whose ``axis`` divides both ``n`` and ``e``, the
    call enters the engine as a MESH descriptor: the comm-charged planner
    arbitrates *gathered* (all-gather weights, compute locally) vs
    *distributed* (keep weight shards, ``all_to_all`` the slots) and the
    chosen strategy runs under ``shard_map`` with the fused single-launch
    property per shard.  Off-mesh (or on indivisible shapes) it degrades
    to the ordinary differentiable :func:`grouped_gemm` path.
    """
    nt, e, cap, k = x4.shape
    from repro.runtime.shardlib import current_mesh
    mesh = current_mesh()
    s = mesh.shape.get(axis, 1) if mesh is not None else 1
    if s <= 1 or e % s or nt % s:
        xt = x4.transpose(1, 0, 2, 3).reshape(e * nt * cap, k)
        sizes = jnp.full((e,), nt * cap, jnp.int32)
        out = grouped_gemm(xt, w, sizes, epilogue=epilogue)
        return out.reshape(e, nt, cap, -1).transpose(1, 0, 2, 3)
    return _ep_vjp(axis, epilogue, x4, w)
