"""jit'd wrapper for the ragged grouped GEMM (MoE expert compute).

Takes unsorted per-row expert assignments OR pre-sorted rows + group
sizes.  Pads each group to the row-block multiple (bm), builds the
block→expert map, and dispatches the scalar-prefetch kernel.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.jit_cache import GLOBAL_KERNEL_CACHE
from repro.kernels.grouped_gemm.kernel import build_grouped_gemm_kernel


def plan_groups(group_sizes: jax.Array, num_experts: int, bm: int,
                t_padded: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Row offsets per group after padding each group to a bm multiple.

    Returns (padded_offsets (E+1,), block_expert (nb,), nrows (1,)).
    All shapes static; values dynamic (runtime router output).
    """
    sizes = group_sizes.astype(jnp.int32)
    padded = ((sizes + bm - 1) // bm) * bm
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)])
    nb = t_padded // bm
    block_row = jnp.arange(nb, dtype=jnp.int32) * bm
    block_expert = jnp.clip(
        jnp.searchsorted(offsets, block_row, side="right") - 1,
        0, num_experts - 1).astype(jnp.int32)
    nrows = offsets[-1:].astype(jnp.int32)
    return offsets, block_expert, nrows


def scatter_rows(x_sorted_by_group, group_sizes, offsets, bm, t_padded):
    """Place each group's rows at its padded offset (zeros between)."""
    t, kdim = x_sorted_by_group.shape
    src_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes.astype(jnp.int32))])
    row = jnp.arange(t, dtype=jnp.int32)
    grp = jnp.clip(jnp.searchsorted(src_off, row, side="right") - 1,
                   0, group_sizes.shape[0] - 1)
    dest = offsets[grp] + (row - src_off[grp])
    out = jnp.zeros((t_padded, kdim), x_sorted_by_group.dtype)
    return out.at[dest].set(x_sorted_by_group), dest


def grouped_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                 bm: int = 128, bk: int = 512, bn: int = 256,
                 interpret: bool = True) -> jax.Array:
    """Ragged grouped GEMM.

    x: (T, K) rows sorted by group; w: (E, K, N); group_sizes: (E,)
    (dynamic, sum <= T).  Returns (T, N): row i multiplied by its group's
    weight; rows beyond sum(group_sizes) are zero.
    """
    t, kdim = x.shape
    e, _, n = w.shape
    t_padded = ((t + bm - 1) // bm) * bm + e * bm  # room for per-group pad
    offsets, block_expert, nrows = plan_groups(group_sizes, e, bm, t_padded)
    x_padded, dest = scatter_rows(x, group_sizes, offsets, bm, t_padded)

    key = ("grouped_gemm", t_padded, kdim, n, e, bm, bk, bn,
           str(x.dtype), interpret)
    kernel = GLOBAL_KERNEL_CACHE.get_or_build(
        key, lambda: build_grouped_gemm_kernel(
            t_padded=t_padded, k=kdim, n=n, num_experts=e, bm=bm, bk=bk,
            bn=bn, in_dtype=x.dtype, out_dtype=x.dtype, interpret=interpret))
    out_padded = kernel(x_padded, w, block_expert, nrows)
    # gather back to the caller's (sorted, unpadded) row order; rows past
    # sum(group_sizes) belong to no group -> zero (matches ref).
    total = jnp.sum(group_sizes.astype(jnp.int32))
    valid = (jnp.arange(t, dtype=jnp.int32) < total)[:, None]
    return jnp.where(valid, out_padded[dest], 0).astype(x.dtype)
