"""Ragged grouped GEMM family (MoE expert compute).

Takes pre-sorted rows + group sizes and dispatches one of two lowerings
(DESIGN.md §9), resolved by ``engine.resolve_fused`` exactly as for
dense GEMM:

  * **fused** (``plan.fused``, default whenever the staged operands fit
    VMEM): the plan's :class:`~repro.core.schedule.GroupedTileSchedule`
    turns ``group_sizes`` into a runtime tile table and ONE
    ``pallas_call`` walks the ragged expert row-blocks directly —
    no pad-to-``t_padded`` intermediate, no ``out_padded[dest]``
    gather-back;
  * **pad/scatter** (the pre-schedule lowering, kept for VMEM-oversized
    problems and as the autotuner's alternative): pad each group to the
    row-block multiple, build the block→expert map, dispatch the static
    grid, gather the rows back out.

Epilogues (bias/gelu/silu/relu, per-expert bias of shape (E, N)) lower
through ``repro.kernels.epilogue`` on both paths.  Tile sizes
(bm, bk, bn) come from the engine's machine-model planner
(:func:`repro.core.blocking.plan_grouped`); explicit kwargs pin the plan.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocking import GroupedGemmPlan, plan_grouped
from repro.core.descriptor import GroupedGemmDescriptor, check_bias
from repro.core.schedule import plan_launches
from repro.kernels.grouped_gemm.kernel import (build_fused_grouped_kernel,
                                               build_grouped_gemm_kernel)


def plan_groups(group_sizes: jax.Array, num_experts: int, bm: int,
                t_padded: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Row offsets per group after padding each group to a bm multiple.

    Returns (padded_offsets (E+1,), block_expert (nb,), nrows (1,)).
    All shapes static; values dynamic (runtime router output).
    """
    sizes = group_sizes.astype(jnp.int32)
    padded = ((sizes + bm - 1) // bm) * bm
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)])
    nb = t_padded // bm
    block_row = jnp.arange(nb, dtype=jnp.int32) * bm
    block_expert = jnp.clip(
        jnp.searchsorted(offsets, block_row, side="right") - 1,
        0, num_experts - 1).astype(jnp.int32)
    nrows = offsets[-1:].astype(jnp.int32)
    return offsets, block_expert, nrows


def scatter_rows(x_sorted_by_group, group_sizes, offsets, bm, t_padded):
    """Place each group's rows at its padded offset (zeros between)."""
    t, kdim = x_sorted_by_group.shape
    src_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes.astype(jnp.int32))])
    row = jnp.arange(t, dtype=jnp.int32)
    grp = jnp.clip(jnp.searchsorted(src_off, row, side="right") - 1,
                   0, group_sizes.shape[0] - 1)
    dest = offsets[grp] + (row - src_off[grp])
    out = jnp.zeros((t_padded, kdim), x_sorted_by_group.dtype)
    return out.at[dest].set(x_sorted_by_group), dest


def _execute_fused(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x, w,
                   group_sizes, bias, interpret: bool) -> jax.Array:
    """Single scheduled launch: runtime tables, direct ragged stores."""
    sched = plan.tile_schedule()
    table = sched.tables(group_sizes)
    key = desc.cache_key() + ("fused", sched.bm, sched.bk, sched.bn,
                              interpret)
    kernel = engine.build_cached(key, lambda: build_fused_grouped_kernel(
        schedule=sched, epilogue=desc.epilogue,
        in_dtype=x.dtype, out_dtype=x.dtype, interpret=interpret))
    return kernel(table, x, w, bias)


def _execute_padded(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x, w,
                    group_sizes, bias, interpret: bool) -> jax.Array:
    """Pad/scatter lowering: pad groups to bm multiples, gather back."""
    bm, bk, bn = plan.bm, plan.bk, plan.bn
    t_padded = plan.t_padded
    offsets, block_expert, nrows = plan_groups(
        group_sizes, desc.num_experts, bm, t_padded)
    x_padded, dest = scatter_rows(x, group_sizes, offsets, bm, t_padded)

    key = desc.cache_key() + ("kernel", bm, bk, bn, interpret)
    kernel = engine.build_cached(key, lambda: build_grouped_gemm_kernel(
        t_padded=t_padded, k=desc.k, n=desc.n,
        num_experts=desc.num_experts, bm=bm, bk=bk, bn=bn,
        epilogue=desc.epilogue, in_dtype=x.dtype, out_dtype=x.dtype,
        interpret=interpret))
    out_padded = kernel(x_padded, w, block_expert, nrows, bias)
    # gather back to the caller's (sorted, unpadded) row order; rows past
    # sum(group_sizes) belong to no group -> zero (matches ref).
    total = jnp.sum(group_sizes.astype(jnp.int32))
    valid = (jnp.arange(desc.t, dtype=jnp.int32) < total)[:, None]
    return jnp.where(valid, out_padded[dest], 0).astype(x.dtype)


def execute(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x, w,
            group_sizes, *, bias=None, interpret: bool = False) -> jax.Array:
    check_bias(desc.epilogue, bias)
    fused = engine.resolve_fused(plan)
    engine.count_launches("grouped_gemm", plan_launches(plan, fused=fused))
    if fused:
        return _execute_fused(desc, plan, x, w, group_sizes, bias, interpret)
    return _execute_padded(desc, plan, x, w, group_sizes, bias, interpret)


engine.register_family("grouped_gemm", planner=plan_grouped, execute=execute)


def grouped_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                 epilogue: Optional[str] = None,
                 bias: Optional[jax.Array] = None,
                 bm: Optional[int] = None, bk: Optional[int] = None,
                 bn: Optional[int] = None,
                 fused: Optional[bool] = None) -> jax.Array:
    """Ragged grouped GEMM via the engine.

    x: (T, K) rows sorted by group; w: (E, K, N); group_sizes: (E,)
    (dynamic, sum <= T).  Returns (T, N): row i multiplied by its group's
    weight; rows beyond sum(group_sizes) are zero.  ``epilogue`` fuses the
    GEMM tail (``bias`` is per-expert, shape (E, N)); ``fused=True/False``
    pins the scheduled single-launch vs pad/scatter lowering for this
    call (default: follow config + plan, DESIGN.md §9).
    """
    desc = GroupedGemmDescriptor.from_operands(x, w, epilogue=epilogue)
    plan = None
    if bm is not None or bk is not None or bn is not None:
        # Fill unpinned knobs from the (cached) engine plan.
        auto = engine.plan_for(desc)
        plan = GroupedGemmPlan(desc, bm or auto.bm, bk or auto.bk,
                               bn or auto.bn, fused=auto.fused)
    if fused is None:
        return engine.dispatch(desc, x, w, group_sizes, plan=plan, bias=bias)
    from repro.core.config import use
    with use(fused="on" if fused else "off"):
        return engine.dispatch(desc, x, w, group_sizes, plan=plan, bias=bias)
