"""Ragged grouped GEMM family (MoE expert compute).

Takes unsorted per-row expert assignments OR pre-sorted rows + group
sizes.  Pads each group to the row-block multiple (bm), builds the
block→expert map, and dispatches the scalar-prefetch kernel.

Tile sizes (bm, bk, bn) come from the engine's machine-model planner
(:func:`repro.core.blocking.plan_grouped`) — the hardcoded 128/512/256
are gone; explicit kwargs pin the plan.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocking import GroupedGemmPlan, plan_grouped
from repro.core.descriptor import GroupedGemmDescriptor
from repro.kernels.grouped_gemm.kernel import build_grouped_gemm_kernel


def plan_groups(group_sizes: jax.Array, num_experts: int, bm: int,
                t_padded: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Row offsets per group after padding each group to a bm multiple.

    Returns (padded_offsets (E+1,), block_expert (nb,), nrows (1,)).
    All shapes static; values dynamic (runtime router output).
    """
    sizes = group_sizes.astype(jnp.int32)
    padded = ((sizes + bm - 1) // bm) * bm
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)])
    nb = t_padded // bm
    block_row = jnp.arange(nb, dtype=jnp.int32) * bm
    block_expert = jnp.clip(
        jnp.searchsorted(offsets, block_row, side="right") - 1,
        0, num_experts - 1).astype(jnp.int32)
    nrows = offsets[-1:].astype(jnp.int32)
    return offsets, block_expert, nrows


def scatter_rows(x_sorted_by_group, group_sizes, offsets, bm, t_padded):
    """Place each group's rows at its padded offset (zeros between)."""
    t, kdim = x_sorted_by_group.shape
    src_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes.astype(jnp.int32))])
    row = jnp.arange(t, dtype=jnp.int32)
    grp = jnp.clip(jnp.searchsorted(src_off, row, side="right") - 1,
                   0, group_sizes.shape[0] - 1)
    dest = offsets[grp] + (row - src_off[grp])
    out = jnp.zeros((t_padded, kdim), x_sorted_by_group.dtype)
    return out.at[dest].set(x_sorted_by_group), dest


def execute(desc: GroupedGemmDescriptor, plan: GroupedGemmPlan, x, w,
            group_sizes, *, interpret: bool = False) -> jax.Array:
    bm, bk, bn = plan.bm, plan.bk, plan.bn
    t_padded = plan.t_padded
    offsets, block_expert, nrows = plan_groups(
        group_sizes, desc.num_experts, bm, t_padded)
    x_padded, dest = scatter_rows(x, group_sizes, offsets, bm, t_padded)

    key = desc.cache_key() + ("kernel", bm, bk, bn, interpret)
    kernel = engine.build_cached(key, lambda: build_grouped_gemm_kernel(
        t_padded=t_padded, k=desc.k, n=desc.n,
        num_experts=desc.num_experts, bm=bm, bk=bk, bn=bn,
        in_dtype=x.dtype, out_dtype=x.dtype, interpret=interpret))
    out_padded = kernel(x_padded, w, block_expert, nrows)
    # gather back to the caller's (sorted, unpadded) row order; rows past
    # sum(group_sizes) belong to no group -> zero (matches ref).
    total = jnp.sum(group_sizes.astype(jnp.int32))
    valid = (jnp.arange(desc.t, dtype=jnp.int32) < total)[:, None]
    return jnp.where(valid, out_padded[dest], 0).astype(x.dtype)


engine.register_family("grouped_gemm", planner=plan_grouped, execute=execute)


def grouped_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                 bm: Optional[int] = None, bk: Optional[int] = None,
                 bn: Optional[int] = None) -> jax.Array:
    """Ragged grouped GEMM via the engine.

    x: (T, K) rows sorted by group; w: (E, K, N); group_sizes: (E,)
    (dynamic, sum <= T).  Returns (T, N): row i multiplied by its group's
    weight; rows beyond sum(group_sizes) are zero.
    """
    desc = GroupedGemmDescriptor.from_operands(x, w)
    plan = None
    if bm is not None or bk is not None or bn is not None:
        # Fill unpinned knobs from the (cached) engine plan.
        auto = engine.plan_for(desc)
        plan = GroupedGemmPlan(desc, bm or auto.bm, bk or auto.bk,
                               bn or auto.bn)
    return engine.dispatch(desc, x, w, group_sizes, plan=plan)
