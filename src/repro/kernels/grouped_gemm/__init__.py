from repro.kernels.grouped_gemm.ops import (  # noqa: F401
    expert_parallel_grouped_gemm, grouped_gemm)
from repro.kernels.grouped_gemm.ref import ref_grouped_gemm  # noqa: F401
