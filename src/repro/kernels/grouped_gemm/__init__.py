from repro.kernels.grouped_gemm.ops import grouped_gemm  # noqa: F401
from repro.kernels.grouped_gemm.ref import ref_grouped_gemm  # noqa: F401
