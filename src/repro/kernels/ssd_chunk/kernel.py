"""SSD chunked-scan Pallas kernels — the paper's small-GEMM ladder in its
Mamba-2 habitat (arXiv:2405.21060 §6, "state-space duality").

Each grid step processes one (batch x chunk x head) cell entirely in
VMEM: two back-to-back small GEMMs — (Q,n)x(n,Q) then the decay-masked
(Q,Q)x(Q,p) — with the (Q,Q) score tile as the ZA-style accumulator that
never touches HBM.  Q, n, p are all in the 64-256 range: exactly the
"small odd GEMM" population the paper's engine targets (DESIGN.md §4).

Two lowerings (DESIGN.md §10):

  * **fused scan** (``build_ssd_scan_kernel``): ONE ``pallas_call`` over
    a ``(groups, chunks)`` supergrid executes the *whole* chunked scan —
    the intra-chunk ladder above plus the inter-chunk recurrence — with
    the ``(p, n)`` state carried across the sequential chunk dimension
    as VMEM accumulator scratch.  The per-chunk state tensors the XLA
    formulation materializes around its associative scan never exist.
  * **intra-chunk only** (``build_ssd_chunk_kernel``, the pre-schedule
    lowering, kept as the fallback half of the non-fused path): the diag
    ladder over a flat group grid; the inter-chunk recurrence then runs
    as separate XLA ops in ``repro.kernels.ssd_chunk.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_chunk_body(c_ref, b_ref, l_ref, x_ref, o_ref, s_ref):
    c = c_ref[0]          # (Q, n)
    b = b_ref[0]          # (Q, n)
    l = l_ref[0]          # (Q, Q) decay mask
    x = x_ref[0]          # (Q, p)
    # GEMM 1: scores = C · Bᵀ (contract the state dim; fused transpose)
    s_ref[...] = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # elementwise decay mask in-register (the predication analogue)
    w = (s_ref[...] * l.astype(jnp.float32)).astype(x.dtype)
    # GEMM 2: y = W · xdt
    o_ref[0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def build_ssd_chunk_kernel(*, groups: int, q: int, n: int, p: int,
                           dtype=jnp.float32, interpret: bool = True):
    """f(C:(G,Q,n), B:(G,Q,n), L:(G,Q,Q), xdt:(G,Q,p)) -> (G,Q,p)."""
    return pl.pallas_call(
        _ssd_chunk_body,
        grid=(groups,),
        in_specs=[
            pl.BlockSpec((1, q, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, q), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, p), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, q, p), dtype),
        scratch_shapes=[pltpu.VMEM((q, q), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused carried-state scan (DESIGN.md §10): one launch for the whole scan
# ---------------------------------------------------------------------------

def _ssd_scan_body(c_ref, b_ref, l_ref, x_ref, di_ref, do_ref, s0_ref,
                   y_ref, sf_ref, *rest, q, chunks):
    """One grid step = one (group, chunk) cell; the chunk dimension is
    sequential, so ``state_ref`` (the (p, n) SSM state, fp32) carries
    across it as accumulator scratch — the inter-chunk recurrence *is*
    the tile walk, not a separate dispatch.  With ``return_states`` the
    state *entering* each chunk is also drained per cell (the residual
    the backward walk replays, DESIGN.md §11)."""
    states_ref = rest[0] if len(rest) == 2 else None
    state_ref = rest[-1]
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    if states_ref is not None:
        states_ref[0, 0] = state_ref[...]

    c = c_ref[0, 0]          # (Q, n)
    b = b_ref[0, 0]          # (Q, n)
    l = l_ref[0, 0]          # (Q, Q) decay mask
    x = x_ref[0, 0]          # (Q, p)
    di = di_ref[0, 0]        # (Q,)  decay into each row from chunk start
    do = do_ref[0, 0]        # (Q,)  decay from each row to chunk end
    state = state_ref[...]   # (p, n) state *entering* this chunk

    # inter-chunk contribution: y_off = (C · S_prevᵀ) ⊙ decay_in
    y_off = jax.lax.dot_general(
        c.astype(jnp.float32), state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * di[:, None]
    # intra-chunk ladder (identical math to _ssd_chunk_body)
    s = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    w = (s * l.astype(jnp.float32)).astype(x.dtype)
    y_diag = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S ← S · exp(da_tot) + Bᵀ · (xdt ⊙ decay_out); the
    # whole-chunk decay is decay_in's last element (da_cs[-1] == da_tot).
    xw = (x.astype(jnp.float32) * do[:, None]).astype(x.dtype)
    bx = jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = state * di[q - 1] + bx

    @pl.when(ci == chunks - 1)
    def _final():
        sf_ref[0] = state_ref[...]


def build_ssd_scan_kernel(*, groups: int, chunks: int, q: int, n: int,
                          p: int, dtype=jnp.float32, interpret: bool = True,
                          return_states: bool = False):
    """Generate ONE pallas_call executing a whole chunked SSD scan.

    Returns ``f(C, B, L, xdt, decay_in, decay_out, s0) -> (y, s_final)``
    over ``C/B: (G, NC, Q, n)``, ``L: (G, NC, Q, Q)``,
    ``xdt: (G, NC, Q, p)``, ``decay_in/decay_out: (G, NC, Q)``,
    ``s0: (G, p, n)`` fp32 — yielding ``y: (G, NC, Q, p)`` and the final
    state ``(G, p, n)`` fp32.  The supergrid is ``(groups, chunks)`` with
    the chunk dimension sequential (the carried-state walk).

    ``return_states`` appends a third output, the fp32 state *entering*
    each chunk, ``(G, NC, p, n)`` — the residual the reverse-walk
    backward replays (DESIGN.md §11).
    """
    body = functools.partial(_ssd_scan_body, q=q, chunks=chunks)
    out_specs = [
        pl.BlockSpec((1, 1, q, p), lambda g, c: (g, c, 0, 0)),
        pl.BlockSpec((1, p, n), lambda g, c: (g, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((groups, chunks, q, p), dtype),
        jax.ShapeDtypeStruct((groups, p, n), jnp.float32),
    ]
    if return_states:
        out_specs.append(pl.BlockSpec((1, 1, p, n), lambda g, c: (g, c, 0, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((groups, chunks, p, n), jnp.float32))
    kernel = pl.pallas_call(
        body,
        grid=(groups, chunks),
        in_specs=[
            pl.BlockSpec((1, 1, q, n), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, q, q), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, q, p), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, 1, q), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, p, n), lambda g, c: (g, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel


# ---------------------------------------------------------------------------
# Fused carried-state backward (DESIGN.md §11): one reverse-walk launch
# ---------------------------------------------------------------------------

def _ssd_scan_bwd_body(c_ref, b_ref, l_ref, x_ref, di_ref, do_ref,
                       states_ref, dy_ref, dsf_ref, dc_ref, db_ref, dl_ref,
                       dx_ref, ddi_ref, ddo_ref, ds0_ref, ds_ref, *,
                       q, chunks):
    """One grid step = one (group, chunk) cell walked in *reverse* chunk
    order (the BlockSpec index maps flip the chunk coordinate); the
    ``(p, n)`` state cotangent carries backward through the walk as
    accumulator scratch, exactly mirroring the forward's carried state.
    Every per-cell quantity the chain rule needs (scores, decay-weighted
    windows) is recomputed in-register from the staged operands — only
    the carried state itself rides in from the forward as a residual."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        ds_ref[...] = dsf_ref[0]

    c = c_ref[0, 0].astype(jnp.float32)      # (Q, n)
    b = b_ref[0, 0].astype(jnp.float32)      # (Q, n)
    l = l_ref[0, 0].astype(jnp.float32)      # (Q, Q)
    x = x_ref[0, 0].astype(jnp.float32)      # (Q, p)
    di = di_ref[0, 0]                        # (Q,) fp32
    do = do_ref[0, 0]                        # (Q,) fp32
    s_in = states_ref[0, 0]                  # (p, n) state entering chunk
    dy = dy_ref[0, 0].astype(jnp.float32)    # (Q, p)
    ds_out = ds_ref[...]                     # (p, n) cotangent of S_out

    # state update S_out = S_in * di[Q-1] + Bᵀ(x ⊙ do) backward: the
    # carried cotangent splits into the decay leg and the Bx leg.
    ds_in = ds_out * di[q - 1]
    ddi_last = jnp.sum(s_in * ds_out)        # scalar -> ddi[Q-1]
    dxw = jax.lax.dot_general(b, ds_out, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q, p)
    xw = x * do[:, None]
    db = jax.lax.dot_general(xw, ds_out, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, n)
    dx = dxw * do[:, None]
    ddo = jnp.sum(dxw * x, axis=1, keepdims=True)                  # (Q, 1)

    # intra-chunk ladder backward: recompute scores/W, then walk
    # y = (scores ⊙ L) · x backward.
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * l
    dw = jax.lax.dot_general(dy, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    dx += jax.lax.dot_general(w, dy, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dscores = dw * l
    dl = dw * scores
    dc = jax.lax.dot_general(dscores, b, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    db += jax.lax.dot_general(dscores, c, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    # inter-chunk offset y_off = (C · S_inᵀ) ⊙ di backward.
    a = dy * di[:, None]
    y_off_raw = jax.lax.dot_general(c, s_in, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    dc += jax.lax.dot_general(a, s_in, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ds_in += jax.lax.dot_general(a, c, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ddi = jnp.sum(dy * y_off_raw, axis=1, keepdims=True)           # (Q, 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, 1), 0)
    ddi += jnp.where(row == q - 1, ddi_last, 0.0)

    dc_ref[0, 0] = dc.astype(dc_ref.dtype)
    db_ref[0, 0] = db.astype(db_ref.dtype)
    dl_ref[0, 0] = dl.astype(dl_ref.dtype)
    dx_ref[0, 0] = dx.astype(dx_ref.dtype)
    ddi_ref[0, 0] = ddi[:, 0]
    ddo_ref[0, 0] = ddo[:, 0]
    ds_ref[...] = ds_in

    @pl.when(ci == chunks - 1)
    def _final():
        ds0_ref[0] = ds_ref[...]


def build_ssd_scan_bwd_kernel(*, groups: int, chunks: int, q: int, n: int,
                              p: int, dtype=jnp.float32,
                              interpret: bool = True):
    """Generate ONE reverse-walk pallas_call for the chunked-scan backward.

    Returns ``f(C, B, L, xdt, decay_in, decay_out, states, dY, dSf) ->
    (dC, dB, dL, dxdt, d_decay_in, d_decay_out, ds0)`` — cell shapes as
    the forward, ``states: (G, NC, p, n)`` fp32 (the per-chunk entering
    states the forward drained), gradients fp32.  The supergrid is
    ``(groups, chunks)`` with the chunk coordinate *flipped* in every
    index map, so the sequential dimension walks chunks last-to-first and
    the state cotangent carries in scratch (DESIGN.md §11).
    """
    last = chunks - 1
    body = functools.partial(_ssd_scan_bwd_body, q=q, chunks=chunks)
    kernel = pl.pallas_call(
        body,
        grid=(groups, chunks),
        in_specs=[
            pl.BlockSpec((1, 1, q, n), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q, q), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q, p), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda g, c: (g, last - c, 0)),
            pl.BlockSpec((1, 1, q), lambda g, c: (g, last - c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q, p), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, p, n), lambda g, c: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, n), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q, q), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q, p), lambda g, c: (g, last - c, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda g, c: (g, last - c, 0)),
            pl.BlockSpec((1, 1, q), lambda g, c: (g, last - c, 0)),
            pl.BlockSpec((1, p, n), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((groups, chunks, q, n), jnp.float32),
            jax.ShapeDtypeStruct((groups, chunks, q, n), jnp.float32),
            jax.ShapeDtypeStruct((groups, chunks, q, q), jnp.float32),
            jax.ShapeDtypeStruct((groups, chunks, q, p), jnp.float32),
            jax.ShapeDtypeStruct((groups, chunks, q), jnp.float32),
            jax.ShapeDtypeStruct((groups, chunks, q), jnp.float32),
            jax.ShapeDtypeStruct((groups, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel
