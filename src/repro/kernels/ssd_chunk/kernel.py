"""SSD intra-chunk Pallas kernel — the paper's small-GEMM ladder in its
Mamba-2 habitat (arXiv:2405.21060 §6, "state-space duality").

Each grid step processes one (batch x chunk x head) cell entirely in
VMEM: two back-to-back small GEMMs — (Q,n)x(n,Q) then the decay-masked
(Q,Q)x(Q,p) — with the (Q,Q) score tile as the ZA-style accumulator that
never touches HBM.  Q, n, p are all in the 64-256 range: exactly the
"small odd GEMM" population the paper's engine targets (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_chunk_body(c_ref, b_ref, l_ref, x_ref, o_ref, s_ref):
    c = c_ref[0]          # (Q, n)
    b = b_ref[0]          # (Q, n)
    l = l_ref[0]          # (Q, Q) decay mask
    x = x_ref[0]          # (Q, p)
    # GEMM 1: scores = C · Bᵀ (contract the state dim; fused transpose)
    s_ref[...] = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # elementwise decay mask in-register (the predication analogue)
    w = (s_ref[...] * l.astype(jnp.float32)).astype(x.dtype)
    # GEMM 2: y = W · xdt
    o_ref[0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def build_ssd_chunk_kernel(*, groups: int, q: int, n: int, p: int,
                           dtype=jnp.float32, interpret: bool = True):
    """f(C:(G,Q,n), B:(G,Q,n), L:(G,Q,Q), xdt:(G,Q,p)) -> (G,Q,p)."""
    return pl.pallas_call(
        _ssd_chunk_body,
        grid=(groups,),
        in_specs=[
            pl.BlockSpec((1, q, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, q), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, p), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, q, p), dtype),
        scratch_shapes=[pltpu.VMEM((q, q), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )
