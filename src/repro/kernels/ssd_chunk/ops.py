"""jit'd wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax

from repro.core.jit_cache import GLOBAL_KERNEL_CACHE
from repro.kernels.ssd_chunk.kernel import build_ssd_chunk_kernel


def ssd_chunk_diag(c_mat, b_mat, l_mat, xdt, *, interpret: bool = True):
    """Batched intra-chunk SSD: (G,Q,n)x2, (G,Q,Q), (G,Q,p) -> (G,Q,p)."""
    g, q, n = c_mat.shape
    p = xdt.shape[-1]
    key = ("ssd_chunk", g, q, n, p, str(xdt.dtype), interpret)
    kernel = GLOBAL_KERNEL_CACHE.get_or_build(
        key, lambda: build_ssd_chunk_kernel(
            groups=g, q=q, n=n, p=p, dtype=xdt.dtype, interpret=interpret))
    return kernel(c_mat, b_mat, l_mat, xdt)
