"""SSD intra-chunk family: engine-dispatched small-GEMM ladder."""
from __future__ import annotations

import jax

from repro.core import engine
from repro.core.blocking import SsdChunkPlan, plan_ssd
from repro.core.descriptor import SsdChunkDescriptor
from repro.kernels.ssd_chunk.kernel import build_ssd_chunk_kernel


def execute(desc: SsdChunkDescriptor, plan: SsdChunkPlan, c_mat, b_mat,
            l_mat, xdt, *, interpret: bool = False) -> jax.Array:
    key = desc.cache_key() + ("kernel", interpret)
    kernel = engine.build_cached(key, lambda: build_ssd_chunk_kernel(
        groups=desc.groups, q=desc.q, n=desc.n, p=desc.p,
        dtype=xdt.dtype, interpret=interpret))
    return kernel(c_mat, b_mat, l_mat, xdt)


engine.register_family("ssd_chunk", planner=plan_ssd, execute=execute)


def ssd_chunk_diag(c_mat, b_mat, l_mat, xdt):
    """Batched intra-chunk SSD: (G,Q,n)x2, (G,Q,Q), (G,Q,p) -> (G,Q,p)."""
    desc = SsdChunkDescriptor.from_operands(c_mat, xdt)
    return engine.dispatch(desc, c_mat, b_mat, l_mat, xdt)
