"""SSD chunked-scan family: engine-dispatched small-GEMM ladder + scan.

Two public surfaces over one engine family (DESIGN.md §10):

  * :func:`ssd_chunk_diag` — the intra-chunk (diagonal-block) ladder on
    a flat ``(G, Q, ·)`` group batch (``desc.chunks == 0``);
  * :func:`ssd_chunk_scan` — the whole chunked scan on a
    ``(G, chunks, Q, ·)`` layout, returning outputs *and* the final SSM
    state.  Resolved by ``engine.resolve_fused`` exactly as for dense
    GEMM: the fused lowering is ONE ``pallas_call`` with the ``(p, n)``
    state carried across the sequential chunk grid dimension as
    accumulator scratch; the fallback runs the diag kernel plus the XLA
    associative-scan inter-chunk recurrence (the pre-schedule
    formulation, kept for VMEM-oversized cells and as the autotuner's
    alternative).  Both report traced launch counts through
    ``engine.count_launches`` → ``engine.stats()``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocking import SsdChunkPlan, plan_ssd, plan_ssd_bwd, \
    ssd_bwd_fused_legal
from repro.core.config import get_config
from repro.core.descriptor import SsdChunkBwdDescriptor, SsdChunkDescriptor
from repro.core.schedule import plan_launches
from repro.kernels.ssd_chunk.kernel import (build_ssd_chunk_kernel,
                                            build_ssd_scan_bwd_kernel,
                                            build_ssd_scan_kernel)
from repro.kernels.ssd_chunk.ref import ref_ssd_chunk_scan


def _execute_diag(desc: SsdChunkDescriptor, groups: int, c_mat, b_mat,
                  l_mat, xdt, interpret: bool) -> jax.Array:
    """Build (and cache) the intra-chunk ladder kernel and run it on a
    flat ``(groups, Q, ·)`` batch."""
    key = (desc.family, "diag", groups, desc.q, desc.n, desc.p,
           desc.dtype, interpret)
    kernel = engine.build_cached(key, lambda: build_ssd_chunk_kernel(
        groups=groups, q=desc.q, n=desc.n, p=desc.p,
        dtype=xdt.dtype, interpret=interpret))
    return kernel(c_mat, b_mat, l_mat, xdt)


def _execute_scan_fallback(desc: SsdChunkDescriptor, c, b, l, xdt,
                           decay_in, decay_out, s0, interpret: bool):
    """Non-fused scan: diag kernel for y_diag, XLA ops for the
    inter-chunk recurrence (associative scan over per-chunk states)."""
    g, nc, q, n = c.shape
    p = xdt.shape[-1]
    flat = (g * nc, q)
    y_diag = _execute_diag(
        desc, g * nc, c.reshape(*flat, n), b.reshape(*flat, n),
        l.reshape(*flat, q), xdt.reshape(*flat, p),
        interpret).reshape(g, nc, q, p)

    # per-chunk state contributions: bx[g,c] = Bᵀ · (xdt ⊙ decay_out)
    xw = (xdt.astype(jnp.float32)
          * decay_out[..., None]).astype(xdt.dtype)
    bx = jnp.einsum("gcqn,gcqp->gcpn", b, xw,
                    preferred_element_type=jnp.float32)
    dec = decay_in[..., -1]  # whole-chunk decay: da_cs[-1] == da_tot

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, sl * dr[..., None, None] + sr

    dcum, s_incl = jax.lax.associative_scan(
        combine, (dec.astype(jnp.float32), bx.astype(jnp.float32)), axis=1)
    s_incl = s_incl + dcum[..., None, None] * s0[:, None]
    s_prev = jnp.concatenate([s0[:, None], s_incl[:, :-1]], axis=1)

    y_off = jnp.einsum("gcqn,gcpn->gcqp", c.astype(jnp.float32), s_prev,
                       preferred_element_type=jnp.float32)
    y_off = y_off * decay_in[..., None]
    y = (y_diag.astype(jnp.float32) + y_off).astype(xdt.dtype)
    return y, s_incl[:, -1]


def _execute_scan_fused(desc: SsdChunkDescriptor, c, b, l, xdt,
                        decay_in, decay_out, s0, interpret: bool):
    """Single carried-state launch over the (groups, chunks) supergrid."""
    key = desc.cache_key() + ("fused", interpret)
    kernel = engine.build_cached(key, lambda: build_ssd_scan_kernel(
        groups=desc.groups, chunks=desc.chunks, q=desc.q, n=desc.n,
        p=desc.p, dtype=xdt.dtype, interpret=interpret))
    return kernel(c, b, l, xdt, decay_in, decay_out, s0)


def execute(desc: SsdChunkDescriptor, plan: SsdChunkPlan, c_mat, b_mat,
            l_mat, xdt, *rest, interpret: bool = False):
    """Engine executor: run one planned SSD dispatch (either form)."""
    if not desc.chunks:
        engine.count_launches("ssd_chunk", 1)
        return _execute_diag(desc, desc.groups, c_mat, b_mat, l_mat, xdt,
                             interpret)
    decay_in, decay_out, s0 = rest
    fused = engine.resolve_fused(plan)
    engine.count_launches("ssd_chunk", plan_launches(plan, fused))
    if fused:
        return _execute_scan_fused(desc, c_mat, b_mat, l_mat, xdt,
                                   decay_in, decay_out, s0, interpret)
    return _execute_scan_fallback(desc, c_mat, b_mat, l_mat, xdt,
                                  decay_in, decay_out, s0, interpret)


engine.register_family("ssd_chunk", planner=plan_ssd, execute=execute)


# ---------------------------------------------------------------------------
# Backward family (DESIGN.md §11): ONE reverse-walk pallas_call carrying
# the (p, n) state cotangent as accumulator scratch
# ---------------------------------------------------------------------------

def execute_bwd(desc: SsdChunkBwdDescriptor, plan: SsdChunkPlan, c, b, l,
                xdt, decay_in, decay_out, states, dy, dsf, *,
                interpret: bool = False):
    """Engine executor: run one planned SSD chunked-scan backward.

    ``states`` is the forward's per-chunk entering-state residual
    ``(G, NC, p, n)`` fp32; ``dy``/``dsf`` the output cotangents.  Single
    lowering — the reverse carried-state walk; illegal descriptors never
    reach the engine (the custom VJP falls back to reference autodiff
    first).
    """
    engine.count_launches("ssd_chunk_bwd", 1)
    key = desc.cache_key() + ("fused", interpret)
    kernel = engine.build_cached(key, lambda: build_ssd_scan_bwd_kernel(
        groups=desc.groups, chunks=desc.chunks, q=desc.q, n=desc.n,
        p=desc.p, dtype=xdt.dtype, interpret=interpret))
    return kernel(c, b, l, xdt, decay_in, decay_out, states, dy, dsf)


engine.register_family("ssd_chunk_bwd", planner=plan_ssd_bwd,
                       execute=execute_bwd)


def _scan_dispatch(c, b, l, xdt, decay_in, decay_out, s0):
    """The engine-dispatched scan (primal path)."""
    desc = SsdChunkDescriptor.from_scan_operands(c, xdt)
    return engine.dispatch(desc, c, b, l, xdt, decay_in, decay_out, s0)


@jax.custom_vjp
def _ssd_vjp(c, b, l, xdt, decay_in, decay_out, s0):
    """Differentiable chunked SSD scan (custom VJP, DESIGN.md §11):
    forward = the engine-dispatched kernel; backward = the single
    reverse-walk launch carrying the state cotangent when legal,
    reference-path autodiff otherwise."""
    return _scan_dispatch(c, b, l, xdt, decay_in, decay_out, s0)


def _ssd_vjp_fwd(c, b, l, xdt, decay_in, decay_out, s0):
    cfg = get_config()
    desc = SsdChunkDescriptor.from_scan_operands(c, xdt)
    bdesc = SsdChunkBwdDescriptor.from_forward(desc)
    fused_ok = (cfg.fused != "off"
                and ssd_bwd_fused_legal(bdesc, cfg.machine))
    if fused_ok:
        # The backward replays the per-chunk entering states, so the
        # forward must run fused too (the states drain from its walk).
        fused_ok = engine.resolve_fused(engine.plan_for(desc))
    if not fused_ok:
        out = _scan_dispatch(c, b, l, xdt, decay_in, decay_out, s0)
        return out, {"ref": (c, b, l, xdt, decay_in, decay_out, s0)}
    # Forward with the entering states drained for the reverse walk —
    # same schedule, same carried-state math as the primal fused kernel.
    interpret = cfg.interpret
    key = desc.cache_key() + ("fused_states", interpret)
    kernel = engine.build_cached(key, lambda: build_ssd_scan_kernel(
        groups=desc.groups, chunks=desc.chunks, q=desc.q, n=desc.n,
        p=desc.p, dtype=xdt.dtype, interpret=interpret, return_states=True))
    engine.count_launches("ssd_chunk", 1)
    y, sf, states = kernel(c, b, l, xdt, decay_in, decay_out, s0)
    return (y, sf), {"fused": (c, b, l, xdt, decay_in, decay_out, states)}


def _ssd_vjp_bwd(res, g):
    dy, dsf = g
    if "fused" in res:
        c, b, l, xdt, decay_in, decay_out, states = res["fused"]
        bdesc = SsdChunkBwdDescriptor.from_forward(
            SsdChunkDescriptor.from_scan_operands(c, xdt))
        dc, db, dl, dx, ddi, ddo, ds0 = engine.dispatch(
            bdesc, c, b, l, xdt, decay_in, decay_out, states,
            dy.astype(jnp.float32), dsf.astype(jnp.float32))
    else:
        c, b, l, xdt, decay_in, decay_out, s0 = res["ref"]
        _, vjp = jax.vjp(ref_ssd_chunk_scan, c, b, l, xdt,
                         decay_in, decay_out, s0)
        dc, db, dl, dx, ddi, ddo, ds0 = vjp(
            (dy.astype(xdt.dtype), dsf.astype(jnp.float32)))
    return (dc.astype(c.dtype), db.astype(b.dtype), dl.astype(l.dtype),
            dx.astype(xdt.dtype), ddi.astype(decay_in.dtype),
            ddo.astype(decay_out.dtype), ds0.astype(jnp.float32))


_ssd_vjp.defvjp(_ssd_vjp_fwd, _ssd_vjp_bwd)


def ssd_chunk_diag(c_mat, b_mat, l_mat, xdt):
    """Batched intra-chunk SSD: (G,Q,n)x2, (G,Q,Q), (G,Q,p) -> (G,Q,p)."""
    desc = SsdChunkDescriptor.from_operands(c_mat, xdt)
    return engine.dispatch(desc, c_mat, b_mat, l_mat, xdt)


def ssd_chunk_scan(c_mat, b_mat, l_mat, xdt, decay_in, decay_out, s0):
    """Whole chunked SSD scan via the engine (DESIGN.md §10).

    ``c_mat``/``b_mat``: (G, NC, Q, n); ``l_mat``: (G, NC, Q, Q);
    ``xdt``: (G, NC, Q, p); ``decay_in``/``decay_out``: (G, NC, Q) fp32
    (``exp(da_cs)`` and ``exp(da_tot - da_cs)``); ``s0``: (G, p, n) fp32
    initial state.  Returns ``(y: (G, NC, Q, p), s_final: (G, p, n))``
    with the inter-chunk recurrence carried inside the kernel when the
    plan is fused.  Differentiable: training flows through the custom
    VJP onto the reverse carried-state walk (DESIGN.md §11).
    """
    return _ssd_vjp(c_mat, b_mat, l_mat, xdt, decay_in, decay_out, s0)
