from repro.kernels.ssd_chunk.ops import ssd_chunk_diag  # noqa: F401
from repro.kernels.ssd_chunk.ref import ref_ssd_chunk_diag  # noqa: F401
