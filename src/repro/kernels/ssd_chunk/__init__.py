from repro.kernels.ssd_chunk.ops import (  # noqa: F401
    ssd_chunk_diag, ssd_chunk_scan)
from repro.kernels.ssd_chunk.ref import (  # noqa: F401
    ref_ssd_chunk_diag, ref_ssd_chunk_scan)
