"""Oracle for the SSD intra-chunk (diagonal-block) kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_ssd_chunk_diag(c_mat, b_mat, l_mat, xdt) -> jax.Array:
    """y = (C·Bᵀ ∘ L) · xdt, batched over the leading dim.

    c_mat/b_mat: (G, Q, n); l_mat: (G, Q, Q); xdt: (G, Q, p) -> (G, Q, p).
    """
    scores = jnp.einsum("gqn,gkn->gqk", c_mat, b_mat,
                        preferred_element_type=jnp.float32)
    w = scores * l_mat.astype(jnp.float32)
    return jnp.einsum("gqk,gkp->gqp", w.astype(xdt.dtype), xdt,
                      preferred_element_type=jnp.float32).astype(xdt.dtype)
