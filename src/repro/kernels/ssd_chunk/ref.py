"""Oracles for the SSD intra-chunk kernel and the carried-state scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_ssd_chunk_diag(c_mat, b_mat, l_mat, xdt) -> jax.Array:
    """y = (C·Bᵀ ∘ L) · xdt, batched over the leading dim.

    c_mat/b_mat: (G, Q, n); l_mat: (G, Q, Q); xdt: (G, Q, p) -> (G, Q, p).
    """
    scores = jnp.einsum("gqn,gkn->gqk", c_mat, b_mat,
                        preferred_element_type=jnp.float32)
    w = scores * l_mat.astype(jnp.float32)
    return jnp.einsum("gqk,gkp->gqp", w.astype(xdt.dtype), xdt,
                      preferred_element_type=jnp.float32).astype(xdt.dtype)


def ref_ssd_chunk_scan(c_mat, b_mat, l_mat, xdt, decay_in, decay_out, s0):
    """Sequential-recurrence oracle for the carried-state chunked scan.

    Same signature as :func:`repro.kernels.ssd_chunk.ssd_chunk_scan`;
    walks the chunks one by one in fp64-free plain jnp, which is exactly
    the recurrence the fused kernel carries in scratch.
    """
    g, nc, q, n = c_mat.shape
    p = xdt.shape[-1]
    y_diag = ref_ssd_chunk_diag(
        c_mat.reshape(g * nc, q, n), b_mat.reshape(g * nc, q, n),
        l_mat.reshape(g * nc, q, q),
        xdt.reshape(g * nc, q, p)).reshape(g, nc, q, p)
    state = s0.astype(jnp.float32)
    ys = []
    for ci in range(nc):
        y_off = jnp.einsum("gqn,gpn->gqp", c_mat[:, ci].astype(jnp.float32),
                           state) * decay_in[:, ci, :, None]
        ys.append((y_diag[:, ci].astype(jnp.float32) + y_off)
                  .astype(xdt.dtype))
        xw = (xdt[:, ci].astype(jnp.float32)
              * decay_out[:, ci, :, None]).astype(xdt.dtype)
        bx = jnp.einsum("gqp,gqn->gpn", xw, b_mat[:, ci],
                        preferred_element_type=jnp.float32)
        state = state * decay_in[:, ci, -1][:, None, None] + bx
    return jnp.stack(ys, axis=1), state
