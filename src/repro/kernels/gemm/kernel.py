"""Shape-specialized blocked GEMM Pallas kernel — the SME microkernel analogue.

Paper mapping (Lst. 4 / Fig. 6):

  * the ZA accumulator tiles      -> an fp32 VMEM scratch accumulator block
    holding a (bm, bn) sub-block of C for the whole K loop;
  * the FMOPA outer-product chain -> one rank-``bk`` MXU update per K grid
    step, ``acc += A[bm,bk] @ B[bk,bn]`` (a systolic array consumes a
    K-panel; bk plays the role the 4-deep FMOPA tile rotation plays on SME:
    it hides the unit's accumulation latency);
  * predicate registers P0/P1      -> trace-time-specialized ``jnp.where``
    masks on the K tail (only emitted when ``K % bk != 0`` — the JIT
    "hardwires" the mask exactly like LIBXSMM hardwires loop trip counts);
  * the two-step load path         -> the Pallas grid pipeline, which stages
    HBM blocks into VMEM with double buffering;
  * transposed-B handling (§IV-C)  -> the "nt" variant contracts against
    B's minor dimension in-register (fused transpose); the two-pass
    scratch-panel variant lives in ``repro.kernels.transpose``.

The kernel is *generated*: ``build_gemm_kernel`` closes over all static
metadata (block shapes, layout, masking, epilogue) so each distinct
descriptor produces a distinct specialized kernel, cached by
``repro.core.jit_cache``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import (clamped_k_window, k_tail_mask,
                                 ownership_mask, pack_table,
                                 predicated_store)
from repro.kernels.epilogue import apply_epilogue, needs_bias
from repro.kernels.pallas_compat import CompilerParams


def _gemm_kernel_body(*refs, layout, k_steps, k_rem, bk, epilogue,
                      accumulate, out_dtype):
    """Kernel body. refs: a, b, [bias], [c_in], out, acc_scratch."""
    idx = 0
    a_ref = refs[idx]; idx += 1
    b_ref = refs[idx]; idx += 1
    bias_ref = None
    if needs_bias(epilogue):
        bias_ref = refs[idx]; idx += 1
    c_ref = None
    if accumulate:
        c_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if accumulate:
            acc_ref[...] = c_ref[...].astype(jnp.float32)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]

    if k_rem:  # K tail masking — the predicate-register analogue (§IV-B).
        # Only the final K step is partial; `where` (not multiply) because
        # out-of-bounds pads may be NaN.
        kk = jax.lax.broadcasted_iota(jnp.int32, a.shape, dimension=1)
        valid = jnp.where(k == k_steps - 1, k_rem, bk)
        a = jnp.where(kk < valid, a, 0)
        if layout == "nn":
            kkb = jax.lax.broadcasted_iota(jnp.int32, b.shape, dimension=0)
        else:
            kkb = jax.lax.broadcasted_iota(jnp.int32, b.shape, dimension=1)
        b = jnp.where(kkb < valid, b, 0)

    if layout == "nn":
        dn = (((1,), (0,)), ((), ()))
    else:  # nt: B block is (bn, bk); contract minor dims (fused transpose)
        dn = (((1,), (1,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(a, b, dn,
                                        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _store():
        out = acc_ref[...]
        bias_blk = bias_ref[...] if bias_ref is not None else None
        out = apply_epilogue(out, epilogue, bias_blk)
        o_ref[...] = out.astype(out_dtype)


def build_gemm_kernel(*, m: int, n: int, k: int, bm: int, bn: int, bk: int,
                      layout: str = "nn", epilogue: Optional[str] = None,
                      accumulate: bool = False, in_dtype=jnp.float32,
                      out_dtype=jnp.float32, interpret: bool = True):
    """Generate the shape-specialized pallas_call for one GEMM region.

    Returns a function ``f(a, b, [bias], [c_in]) -> out`` of exact shapes
    ``a:(m,k)``, ``b:(k,n)|(n,k)``, ``out:(m,n)``.  All metadata is
    hardwired at build time (the LIBXSMM JIT analogue).
    """
    grid_m, grid_n, grid_k = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)
    k_rem = k % bk

    body = functools.partial(
        _gemm_kernel_body, layout=layout, k_steps=grid_k, k_rem=k_rem,
        bk=bk, epilogue=epilogue, accumulate=accumulate,
        out_dtype=jnp.dtype(out_dtype))

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)) if layout == "nn"
        else pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
    ]
    if needs_bias(epilogue):
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if accumulate:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))

    kernel = pl.pallas_call(
        body,
        grid=(grid_m, grid_n, grid_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )

    def run(a, b, bias=None, c_in=None):
        args = [a, b]
        if needs_bias(epilogue):
            assert bias is not None
            args.append(bias.reshape(1, n))
        if accumulate:
            assert c_in is not None
            args.append(c_in)
        return kernel(*args)

    return run


# ---------------------------------------------------------------------------
# Fused single-launch plan execution (DESIGN.md §8/§9)
# ---------------------------------------------------------------------------

def _fused_kernel_body(tbl_ref, *refs, blocks, layout, k, bk, k_steps,
                       epilogue, accumulate, out_dtype, quant=None):
    """Walk the flattened tile schedule: one grid step = one (tile, K-panel).

    refs: a, b, [sa], [sb], [bias], [c_in], out, acc_scratch — each a full
    per-batch operand block.  The tile table rides in scalar-prefetch
    SMEM; per-tile geometry is selected by ``lax.switch`` over the
    distinct effective block shapes, and every load/store is the paper's
    two-step path: a fixed-shape window at a clamped origin plus an
    ownership mask (the predication helpers of ``repro.core.schedule``,
    DESIGN.md §9).

    Under a ``quant`` spec (DESIGN.md §13) the operands arrive in the
    wire dtype, accumulation is exact-wide (int32 for int8, f32 for fp8
    / weight-only), and ``sa``/``sb`` are the expanded f32 dequant
    vectors — column scales ``(1, n)`` and, for fully quantized runs, row
    scales ``(m, 1)`` — windowed by the same clamped tile origins as the
    operands and applied in :func:`apply_epilogue` before bias/act, so a
    quantized output never round-trips through a separate dequant launch.
    """
    weight_only = quant is not None and quant.weight_only
    full_quant = quant is not None and not quant.weight_only
    int_acc = full_quant and quant.dtype == "int8"
    acc_dt = jnp.int32 if int_acc else jnp.float32

    idx = 0
    a_ref = refs[idx]; idx += 1
    b_ref = refs[idx]; idx += 1
    sa_ref = sb_ref = None
    if full_quant:
        sa_ref = refs[idx]; idx += 1
    if quant is not None:
        sb_ref = refs[idx]; idx += 1
    bias_ref = None
    if needs_bias(epilogue):
        bias_ref = refs[idx]; idx += 1
    c_ref = None
    if accumulate:
        c_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    t = pl.program_id(1)
    ks = pl.program_id(2)
    row0, col0 = tbl_ref[t, 0], tbl_ref[t, 1]
    row_end, col_end = tbl_ref[t, 2], tbl_ref[t, 3]
    rs, cs = tbl_ref[t, 4], tbl_ref[t, 5]

    k0, kstart = clamped_k_window(ks, bk, k)  # two-step K load (tail)

    def make_branch(bm_e, bn_e):
        def branch():
            @pl.when(ks == 0)
            def _init():
                if accumulate:
                    cw = c_ref[0, pl.ds(rs, bm_e), pl.ds(cs, bn_e)]
                    acc_ref[0:bm_e, 0:bn_e] = cw.astype(jnp.float32)
                else:
                    acc_ref[0:bm_e, 0:bn_e] = jnp.zeros((bm_e, bn_e),
                                                        acc_dt)

            a = a_ref[0, pl.ds(rs, bm_e), pl.ds(kstart, bk)]
            if layout == "nn":
                b = b_ref[0, pl.ds(kstart, bk), pl.ds(cs, bn_e)]
                dn = (((1,), (0,)), ((), ()))
                b_k_dim = 0
            else:  # nt: B window is (bn_e, bk); contract minor dims
                b = b_ref[0, pl.ds(cs, bn_e), pl.ds(kstart, bk)]
                dn = (((1,), (1,)), ((), ()))
                b_k_dim = 1
            if weight_only:
                # W8A16: int8 weight values are exactly representable in
                # the wide dtype; the column scales stay in the epilogue.
                b = b.astype(a.dtype)
            if k % bk:
                # K-tail predication: the clamped window overlaps the
                # previous panel; keep only lanes at/after the nominal
                # start (repro.core.schedule.k_tail_mask).
                a = k_tail_mask(a, 1, k0, kstart)
                b = k_tail_mask(b, b_k_dim, k0, kstart)
            acc_ref[0:bm_e, 0:bn_e] += jax.lax.dot_general(
                a, b, dn, preferred_element_type=acc_dt)

            @pl.when(ks == k_steps - 1)
            def _store():
                out = acc_ref[0:bm_e, 0:bn_e]
                dequant = None
                if sb_ref is not None:
                    dequant = sb_ref[0:1, pl.ds(cs, bn_e)]
                    if sa_ref is not None:
                        dequant = sa_ref[pl.ds(rs, bm_e), 0:1] * dequant
                bias_blk = None
                if bias_ref is not None:
                    bias_blk = bias_ref[0:1, pl.ds(cs, bn_e)]
                out = apply_epilogue(out, epilogue, bias_blk, dequant)
                out = out.astype(out_dtype)
                # Predicated two-step store: write only the elements this
                # tile owns, preserving neighbours under the clamped
                # window (each C element is owned by exactly one tile).
                own = ownership_mask((bm_e, bn_e), rs, cs,
                                     row0, row_end, col0, col_end)
                predicated_store(
                    o_ref, (0, pl.ds(rs, bm_e), pl.ds(cs, bn_e)), out, own)
        return branch

    branches = [make_branch(bm_e, bn_e) for bm_e, bn_e in blocks]
    if len(branches) == 1:
        branches[0]()
    else:
        jax.lax.switch(tbl_ref[t, 6], branches)


def build_fused_gemm_kernel(*, schedule, batch: int = 0, layout: str = "nn",
                            epilogue: Optional[str] = None,
                            accumulate: bool = False, in_dtype=jnp.float32,
                            out_dtype=jnp.float32, interpret: bool = True,
                            quant=None):
    """Generate ONE pallas_call executing a whole blocking plan + batch.

    ``schedule`` is a :class:`repro.core.blocking.TileSchedule`.  Returns
    ``f(a, b, [bias], [c_in], [sa], [sb]) -> out`` over rank-3 operands
    ``a:(nb,m,k)``, ``b:(nb,k,n)|(nb,n,k)``, ``out:(nb,m,n)`` with
    ``nb = max(1, batch)`` — the batch is a leading grid dimension, not a
    ``vmap``.  The supergrid is ``(batch, tiles, k_steps)``; the tile
    table travels as a scalar-prefetch operand (DESIGN.md §8).

    With a :class:`~repro.core.descriptor.QuantSpec` ``quant``, the
    operand dtypes are the wire format, the accumulator scratch is int32
    (int8) or f32 (fp8 / weight-only), and the expanded dequant vectors
    ride as extra operands — ``sa: (m, 1)`` row scales (fully-quantized
    runs only) and ``sb: (1, n)`` column scales — fused into the epilogue
    (DESIGN.md §13).
    """
    m, n, k = schedule.m, schedule.n, schedule.k
    bk, k_steps = schedule.bk, schedule.k_steps
    nb = max(1, batch)
    has_bias = needs_bias(epilogue)
    has_sa = quant is not None and not quant.weight_only
    has_sb = quant is not None
    int_acc = has_sa and quant.dtype == "int8"
    bm_max = max(b[0] for b in schedule.blocks)
    bn_max = max(b[1] for b in schedule.blocks)
    table = pack_table(schedule.tiles)  # (tiles, 8) int32, trace-time

    body = functools.partial(
        _fused_kernel_body, blocks=schedule.blocks, layout=layout, k=k,
        bk=bk, k_steps=k_steps, epilogue=epilogue, accumulate=accumulate,
        out_dtype=jnp.dtype(out_dtype), quant=quant)

    in_specs = [
        pl.BlockSpec((1, m, k), lambda b, t, ks, tbl: (b, 0, 0)),
        pl.BlockSpec((1, k, n) if layout == "nn" else (1, n, k),
                     lambda b, t, ks, tbl: (b, 0, 0)),
    ]
    if has_sa:
        in_specs.append(pl.BlockSpec((m, 1), lambda b, t, ks, tbl: (0, 0)))
    if has_sb:
        in_specs.append(pl.BlockSpec((1, n), lambda b, t, ks, tbl: (0, 0)))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, n), lambda b, t, ks, tbl: (0, 0)))
    if accumulate:
        in_specs.append(pl.BlockSpec((1, m, n),
                                     lambda b, t, ks, tbl: (b, 0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the tile table
        grid=(nb, schedule.num_tiles, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m, n), lambda b, t, ks, tbl: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((bm_max, bn_max),
                                   jnp.int32 if int_acc else jnp.float32)],
    )

    kernel = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, m, n), jnp.dtype(out_dtype)),
        interpret=interpret,
    )

    def run(a, b, bias=None, c_in=None, sa=None, sb=None):
        args = [table, a, b]
        if has_sa:
            assert sa is not None
            args.append(sa.reshape(m, 1).astype(jnp.float32))
        if has_sb:
            assert sb is not None
            args.append(sb.reshape(1, n).astype(jnp.float32))
        if has_bias:
            assert bias is not None
            args.append(bias.reshape(1, n))
        if accumulate:
            assert c_in is not None
            args.append(c_in)
        return kernel(*args)

    return run
