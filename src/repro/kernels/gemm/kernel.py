"""Shape-specialized blocked GEMM Pallas kernel — the SME microkernel analogue.

Paper mapping (Lst. 4 / Fig. 6):

  * the ZA accumulator tiles      -> an fp32 VMEM scratch accumulator block
    holding a (bm, bn) sub-block of C for the whole K loop;
  * the FMOPA outer-product chain -> one rank-``bk`` MXU update per K grid
    step, ``acc += A[bm,bk] @ B[bk,bn]`` (a systolic array consumes a
    K-panel; bk plays the role the 4-deep FMOPA tile rotation plays on SME:
    it hides the unit's accumulation latency);
  * predicate registers P0/P1      -> trace-time-specialized ``jnp.where``
    masks on the K tail (only emitted when ``K % bk != 0`` — the JIT
    "hardwires" the mask exactly like LIBXSMM hardwires loop trip counts);
  * the two-step load path         -> the Pallas grid pipeline, which stages
    HBM blocks into VMEM with double buffering;
  * transposed-B handling (§IV-C)  -> the "nt" variant contracts against
    B's minor dimension in-register (fused transpose); the two-pass
    scratch-panel variant lives in ``repro.kernels.transpose``.

The kernel is *generated*: ``build_gemm_kernel`` closes over all static
metadata (block shapes, layout, masking, epilogue) so each distinct
descriptor produces a distinct specialized kernel, cached by
``repro.core.jit_cache``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _apply_epilogue(x, epilogue: Optional[str], bias_blk):
    if epilogue in ("bias", "bias_gelu", "bias_silu"):
        x = x + bias_blk.astype(x.dtype)
    if epilogue in ("gelu", "bias_gelu"):
        x = jax.nn.gelu(x)
    elif epilogue in ("silu", "bias_silu"):
        x = jax.nn.silu(x)
    elif epilogue == "relu":
        x = jnp.maximum(x, 0)
    return x


def _gemm_kernel_body(*refs, layout, k_steps, k_rem, bk, epilogue,
                      accumulate, out_dtype):
    """Kernel body. refs: a, b, [bias], [c_in], out, acc_scratch."""
    idx = 0
    a_ref = refs[idx]; idx += 1
    b_ref = refs[idx]; idx += 1
    bias_ref = None
    if epilogue in ("bias", "bias_gelu", "bias_silu"):
        bias_ref = refs[idx]; idx += 1
    c_ref = None
    if accumulate:
        c_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if accumulate:
            acc_ref[...] = c_ref[...].astype(jnp.float32)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]

    if k_rem:  # K tail masking — the predicate-register analogue (§IV-B).
        # Only the final K step is partial; `where` (not multiply) because
        # out-of-bounds pads may be NaN.
        kk = jax.lax.broadcasted_iota(jnp.int32, a.shape, dimension=1)
        valid = jnp.where(k == k_steps - 1, k_rem, bk)
        a = jnp.where(kk < valid, a, 0)
        if layout == "nn":
            kkb = jax.lax.broadcasted_iota(jnp.int32, b.shape, dimension=0)
        else:
            kkb = jax.lax.broadcasted_iota(jnp.int32, b.shape, dimension=1)
        b = jnp.where(kkb < valid, b, 0)

    if layout == "nn":
        dn = (((1,), (0,)), ((), ()))
    else:  # nt: B block is (bn, bk); contract minor dims (fused transpose)
        dn = (((1,), (1,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(a, b, dn,
                                        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _store():
        out = acc_ref[...]
        bias_blk = bias_ref[...] if bias_ref is not None else None
        out = _apply_epilogue(out, epilogue, bias_blk)
        o_ref[...] = out.astype(out_dtype)


def build_gemm_kernel(*, m: int, n: int, k: int, bm: int, bn: int, bk: int,
                      layout: str = "nn", epilogue: Optional[str] = None,
                      accumulate: bool = False, in_dtype=jnp.float32,
                      out_dtype=jnp.float32, interpret: bool = True):
    """Generate the shape-specialized pallas_call for one GEMM region.

    Returns a function ``f(a, b, [bias], [c_in]) -> out`` of exact shapes
    ``a:(m,k)``, ``b:(k,n)|(n,k)``, ``out:(m,n)``.  All metadata is
    hardwired at build time (the LIBXSMM JIT analogue).
    """
    grid_m, grid_n, grid_k = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)
    k_rem = k % bk

    body = functools.partial(
        _gemm_kernel_body, layout=layout, k_steps=grid_k, k_rem=k_rem,
        bk=bk, epilogue=epilogue, accumulate=accumulate,
        out_dtype=jnp.dtype(out_dtype))

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)) if layout == "nn"
        else pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
    ]
    if epilogue in ("bias", "bias_gelu", "bias_silu"):
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if accumulate:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))

    kernel = pl.pallas_call(
        body,
        grid=(grid_m, grid_n, grid_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )

    def run(a, b, bias=None, c_in=None):
        args = [a, b]
        if epilogue in ("bias", "bias_gelu", "bias_silu"):
            assert bias is not None
            args.append(bias.reshape(1, n))
        if accumulate:
            assert c_in is not None
            args.append(c_in)
        return kernel(*args)

    return run
