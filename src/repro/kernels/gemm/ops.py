"""Blocked GEMM — the engine's founding kernel family.

Executes a :class:`repro.core.blocking.BlockingPlan`: each plan region
becomes one shape-specialized ``pallas_call`` (the paper's "seven
microkernel executions", Fig 7), whose outputs are assembled into C with
``dynamic_update_slice`` — under ``jit`` XLA fuses the assembly.

Registered with :mod:`repro.core.engine` as family ``"gemm"``: planning,
caching (plan and kernel layers, descriptor-derived keys) and interpret
policy all live in the engine; this module owns only the lowering.

Edge strategies (benchmarked against each other in fig45_alignment):

  * ``mask`` — exact-shape kernels; Pallas clips partial output blocks and
    the kernel masks the K tail (the SME predication analogue);
  * ``pad``  — operands zero-padded to block multiples outside the kernel
    (the copy-based strategy the paper's predication avoids).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocking import BlockingPlan, plan_gemm, round_up
from repro.core.descriptor import GemmDescriptor, check_bias
from repro.kernels.gemm.kernel import build_gemm_kernel


def _region_executor(desc: GemmDescriptor, region, bk: int, edge: str,
                     interpret: bool):
    """Build (and cache) the kernel for one plan region."""
    rows, cols, k = region.rows, region.cols, desc.k
    bm, bn = region.bm, region.bn
    if edge == "pad":
        rows_p, cols_p, k_p = round_up(rows, bm), round_up(cols, bn), round_up(k, bk)
    else:
        rows_p, cols_p, k_p = rows, cols, k
    # Key on the region build inputs only — NOT the whole-problem (m, n)
    # — so descriptors of different shapes share identical region/corner
    # kernels (the cross-shape reuse the kernel cache exists for).
    key = (desc.family, "region", rows_p, cols_p, k_p, bm, bn, bk,
           desc.layout, desc.epilogue, desc.accumulate, desc.in_dtype,
           desc.out_dtype, interpret)

    def builder():
        return build_gemm_kernel(
            m=rows_p, n=cols_p, k=k_p, bm=bm, bn=bn, bk=min(bk, round_up(k_p, 128)),
            layout=desc.layout, epilogue=desc.epilogue,
            accumulate=desc.accumulate,
            in_dtype=jnp.dtype(desc.in_dtype), out_dtype=jnp.dtype(desc.out_dtype),
            interpret=interpret)

    kernel = engine.build_cached(key, builder)

    def run(a_r, b_r, bias_r, c_r):
        if edge == "pad":
            a_r = jnp.pad(a_r, ((0, rows_p - rows), (0, k_p - k)))
            if desc.layout == "nn":
                b_r = jnp.pad(b_r, ((0, k_p - k), (0, cols_p - cols)))
            else:
                b_r = jnp.pad(b_r, ((0, cols_p - cols), (0, k_p - k)))
            if bias_r is not None:
                bias_r = jnp.pad(bias_r, ((0, cols_p - cols),))
            if c_r is not None:
                c_r = jnp.pad(c_r, ((0, rows_p - rows), (0, cols_p - cols)))
        out = kernel(a_r, b_r, bias_r, c_r)
        if edge == "pad" and (rows_p != rows or cols_p != cols):
            out = out[:rows, :cols]
        return out

    return run


def gemm_region(a, b, region, desc: GemmDescriptor, bk: int,
                bias=None, c=None, edge: str = "mask",
                interpret: Optional[bool] = None):
    """Run one region's microkernel on the corresponding operand slices."""
    if interpret is None:
        from repro.core.config import get_config
        interpret = get_config().interpret
    r = region
    a_r = jax.lax.dynamic_slice(a, (r.row0, 0), (r.rows, desc.k))
    if desc.layout == "nn":
        b_r = jax.lax.dynamic_slice(b, (0, r.col0), (desc.k, r.cols))
    else:
        b_r = jax.lax.dynamic_slice(b, (r.col0, 0), (r.cols, desc.k))
    bias_r = None
    if bias is not None:
        bias_r = jax.lax.dynamic_slice(bias, (r.col0,), (r.cols,))
    c_r = None
    if c is not None:
        c_r = jax.lax.dynamic_slice(c, (r.row0, r.col0), (r.rows, r.cols))
    run = _region_executor(desc, r, bk, edge, interpret)
    return run(a_r, b_r, bias_r, c_r)


def _gemm2d(a, b, plan: BlockingPlan, bias, c, interpret: bool):
    desc = plan.desc
    if len(plan.regions) == 1 and plan.regions[0].rows == desc.m \
            and plan.regions[0].cols == desc.n:
        return gemm_region(a, b, plan.regions[0], desc, plan.bk,
                           bias, c, desc.edge, interpret)
    out = jnp.zeros((desc.m, desc.n), jnp.dtype(desc.out_dtype))
    for r in plan.regions:
        blk = gemm_region(a, b, r, desc, plan.bk, bias, c, desc.edge, interpret)
        out = jax.lax.dynamic_update_slice(out, blk, (r.row0, r.col0))
    return out


def execute(desc: GemmDescriptor, plan: BlockingPlan, a, b, *,
            bias=None, c=None, interpret: bool = False) -> jax.Array:
    """Engine executor: run one planned (possibly batched) GEMM."""
    check_bias(desc.epilogue, bias)
    f = functools.partial(_gemm2d, plan=plan, interpret=interpret)
    if desc.batch:
        def batched(a_, b_, c_):
            return f(a_, b_, bias=bias, c=c_)
        return jax.vmap(batched, in_axes=(0, 0, 0 if c is not None else None))(a, b, c)
    return f(a, b, bias=bias, c=c)


engine.register_family("gemm", planner=plan_gemm, execute=execute)


def gemm(a, b, c: Optional[jax.Array] = None, *, layout: str = "nn",
         epilogue: Optional[str] = None, bias: Optional[jax.Array] = None,
         out_dtype=None, edge: str = "mask", plan: Optional[BlockingPlan] = None,
         heterogeneous: bool = True) -> jax.Array:
    """Planned, shape-specialized (batched) GEMM via the engine.

    ``a``: (..., M, K); ``b``: (..., K, N) for layout "nn" or (..., N, K)
    for "nt"; optional ``c`` accumulator of shape (..., M, N).  Interpret
    policy comes from :mod:`repro.core.config`.
    """
    desc = GemmDescriptor.from_operands(
        a, b, layout=layout, accumulate=c is not None, epilogue=epilogue,
        out_dtype=out_dtype or a.dtype, edge=edge)
    if plan is None and not heterogeneous:
        # Non-default planner knob: plan directly, bypassing the plan cache
        # (the cache serves only the canonical planner configuration).
        plan = plan_gemm(desc, heterogeneous=False)
    return engine.dispatch(desc, a, b, plan=plan, bias=bias, c=c)
