"""Blocked GEMM — the engine's founding kernel family.

Executes a :class:`repro.core.blocking.BlockingPlan` one of two ways
(DESIGN.md §8):

  * **fused** (``plan.fused``, the paper's §IV stance): the whole plan —
    every region's tile grid *and* the batch — runs in ONE
    ``pallas_call``.  The plan's flattened :meth:`tile_schedule` rides in
    a scalar-prefetch table; the kernel walks a ``(batch, tiles, k)``
    supergrid, selects per-region block geometry by static table, and
    writes each tile straight into the real output buffer with predicated
    two-step stores.  No ``dynamic_slice`` operand copies, no ``zeros`` +
    ``dynamic_update_slice`` assembly, no ``vmap``.
  * **multi-launch** (the pre-fusion lowering, kept for VMEM-oversized
    problems and as the autotuner's alternative): each plan region becomes
    one shape-specialized ``pallas_call`` (the paper's "seven microkernel
    executions", Fig 7) whose outputs are stitched into C with
    ``dynamic_update_slice``; batch goes through ``jax.vmap``.

Which path runs is ``config.fused`` ("auto" follows the plan bit that the
planner/autotuner set; "on"/"off" force it).  Both paths report traced
launch counts through ``engine.count_launches`` → ``engine.stats()``.

Registered with :mod:`repro.core.engine` as family ``"gemm"``: planning,
caching (plan and kernel layers, descriptor-derived keys) and interpret
policy all live in the engine; this module owns only the lowering.

Edge strategies for the multi-launch path (benchmarked in fig45_alignment):

  * ``mask`` — exact-shape kernels; Pallas clips partial output blocks and
    the kernel masks the K tail (the SME predication analogue);
  * ``pad``  — operands zero-padded to block multiples outside the kernel
    (the copy-based strategy the paper's predication avoids).

The fused path subsumes both: masking is inherent to its tile schedule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocking import BlockingPlan, plan_gemm, round_up
from repro.core.schedule import plan_launches
from repro.core.descriptor import GemmDescriptor, check_bias
from repro.kernels.gemm.kernel import (build_fused_gemm_kernel,
                                       build_gemm_kernel)


def _region_executor(desc: GemmDescriptor, region, bk: int, edge: str,
                     interpret: bool):
    """Build (and cache) the kernel for one plan region."""
    rows, cols, k = region.rows, region.cols, desc.k
    bm, bn = region.bm, region.bn
    if edge == "pad":
        rows_p, cols_p, k_p = round_up(rows, bm), round_up(cols, bn), round_up(k, bk)
    else:
        rows_p, cols_p, k_p = rows, cols, k
    # Key on the region build inputs only — NOT the whole-problem (m, n)
    # — so descriptors of different shapes share identical region/corner
    # kernels (the cross-shape reuse the kernel cache exists for).
    key = (desc.family, "region", rows_p, cols_p, k_p, bm, bn, bk,
           desc.layout, desc.epilogue, desc.accumulate, desc.in_dtype,
           desc.out_dtype, interpret)

    def builder():
        # bk clamps to the (padded) K extent: tiny-K builds must not stage
        # oversized K panels (k_p is already bk-aligned under "pad").
        return build_gemm_kernel(
            m=rows_p, n=cols_p, k=k_p, bm=bm, bn=bn, bk=min(bk, k_p),
            layout=desc.layout, epilogue=desc.epilogue,
            accumulate=desc.accumulate,
            in_dtype=jnp.dtype(desc.in_dtype), out_dtype=jnp.dtype(desc.out_dtype),
            interpret=interpret)

    kernel = engine.build_cached(key, builder)

    def run(a_r, b_r, bias_r, c_r):
        if edge == "pad":
            a_r = jnp.pad(a_r, ((0, rows_p - rows), (0, k_p - k)))
            if desc.layout == "nn":
                b_r = jnp.pad(b_r, ((0, k_p - k), (0, cols_p - cols)))
            else:
                b_r = jnp.pad(b_r, ((0, cols_p - cols), (0, k_p - k)))
            if bias_r is not None:
                bias_r = jnp.pad(bias_r, ((0, cols_p - cols),))
            if c_r is not None:
                c_r = jnp.pad(c_r, ((0, rows_p - rows), (0, cols_p - cols)))
        out = kernel(a_r, b_r, bias_r, c_r)
        if edge == "pad" and (rows_p != rows or cols_p != cols):
            out = out[:rows, :cols]
        return out

    return run


def gemm_region(a, b, region, desc: GemmDescriptor, bk: int,
                bias=None, c=None, edge: str = "mask",
                interpret: Optional[bool] = None):
    """Run one region's microkernel on the corresponding operand slices."""
    if interpret is None:
        from repro.core.config import get_config
        interpret = get_config().interpret
    r = region
    a_r = jax.lax.dynamic_slice(a, (r.row0, 0), (r.rows, desc.k))
    if desc.layout == "nn":
        b_r = jax.lax.dynamic_slice(b, (0, r.col0), (desc.k, r.cols))
    else:
        b_r = jax.lax.dynamic_slice(b, (r.col0, 0), (r.cols, desc.k))
    bias_r = None
    if bias is not None:
        bias_r = jax.lax.dynamic_slice(bias, (r.col0,), (r.cols,))
    c_r = None
    if c is not None:
        c_r = jax.lax.dynamic_slice(c, (r.row0, r.col0), (r.rows, r.cols))
    run = _region_executor(desc, r, bk, edge, interpret)
    return run(a_r, b_r, bias_r, c_r)


def _gemm2d(a, b, plan: BlockingPlan, bias, c, interpret: bool):
    desc = plan.desc
    if len(plan.regions) == 1 and plan.regions[0].rows == desc.m \
            and plan.regions[0].cols == desc.n:
        return gemm_region(a, b, plan.regions[0], desc, plan.bk,
                           bias, c, desc.edge, interpret)
    out = jnp.zeros((desc.m, desc.n), jnp.dtype(desc.out_dtype))
    for r in plan.regions:
        blk = gemm_region(a, b, r, desc, plan.bk, bias, c, desc.edge, interpret)
        out = jax.lax.dynamic_update_slice(out, blk, (r.row0, r.col0))
    return out


def _fused_executor(desc: GemmDescriptor, plan: BlockingPlan,
                    interpret: bool):
    """Build (and cache) the single fused kernel for a whole plan.

    ``(regions, bk)`` fully determine the tile schedule, so the cache key
    stays O(regions) and the O(tiles) flattening only runs on a miss.
    ``desc.edge`` is normalized out: it selects between multi-launch edge
    strategies and the fused kernel ignores it (masking is inherent).
    """
    key = (dataclasses.replace(desc, edge="mask").cache_key()
           + ("fused", plan.regions, plan.bk, interpret))

    def builder():
        return build_fused_gemm_kernel(
            schedule=plan.tile_schedule(), batch=desc.batch,
            layout=desc.layout, epilogue=desc.epilogue,
            accumulate=desc.accumulate, in_dtype=jnp.dtype(desc.in_dtype),
            out_dtype=jnp.dtype(desc.out_dtype), interpret=interpret,
            quant=desc.quant)

    return engine.build_cached(key, builder)


def _xla_quant_gemm(desc: GemmDescriptor, a, b, bias, sa, sb):
    """The pre-quant fallback lowering: one XLA dot in the exact-wide
    accumulator dtype, dequant + epilogue as jnp ops (DESIGN.md §13).

    This is what "a separate dequant launch" looks like — the path the
    fused kernel exists to beat — kept as the non-fused lowering and
    autotune candidate.  int32 accumulation is exact, and the dequant /
    bias / activation ops match :func:`apply_epilogue` term for term, so
    for int8 this is bit-identical to the fused kernel.
    """
    from repro.kernels.epilogue import apply_epilogue
    q = desc.quant
    dn = (((1,), (0,)), ((), ())) if desc.layout == "nn" \
        else (((1,), (1,)), ((), ()))
    if q.weight_only:
        acc = jax.lax.dot_general(a, b.astype(a.dtype), dn,
                                  preferred_element_type=jnp.float32)
        factor = sb.reshape(1, desc.n).astype(jnp.float32)
    else:
        pref = jnp.int32 if q.dtype == "int8" else jnp.float32
        acc = jax.lax.dot_general(a, b, dn, preferred_element_type=pref)
        factor = (sa.reshape(desc.m, 1).astype(jnp.float32)
                  * sb.reshape(1, desc.n).astype(jnp.float32))
    bias_blk = None if bias is None else bias.reshape(1, desc.n)
    out = apply_epilogue(acc, desc.epilogue, bias_blk, factor)
    return out.astype(jnp.dtype(desc.out_dtype))


def execute(desc: GemmDescriptor, plan: BlockingPlan, a, b, *,
            bias=None, c=None, sa=None, sb=None,
            interpret: bool = False) -> jax.Array:
    """Engine executor: run one planned (possibly batched) GEMM.

    ``sa``/``sb`` are the expanded f32 dequant vectors of a quantized
    descriptor (``(m,)`` row scales for fully-quantized runs, ``(n,)``
    column scales for any quant spec) — the public entry point quantized
    the operands and expanded the scheme-shaped scales before dispatch.
    """
    check_bias(desc.epilogue, bias)
    if desc.quant is not None:
        if engine.resolve_fused(plan):
            engine.count_launches("gemm", plan_launches(plan, fused=True))
            run = _fused_executor(desc, plan, interpret)
            return run(a[None], b[None], bias, None, sa=sa, sb=sb)[0]
        # The pre-quant path: no pallas_call at all — quantized operands,
        # one XLA dot, dequant+epilogue as separate jnp ops.
        engine.count_launches("gemm", 0)
        return _xla_quant_gemm(desc, a, b, bias, sa, sb)
    if engine.resolve_fused(plan):
        engine.count_launches("gemm", plan_launches(plan, fused=True))
        run = _fused_executor(desc, plan, interpret)
        if desc.batch:
            out = run(a, b, bias, c)
        else:
            out = run(a[None], b[None], bias,
                      None if c is None else c[None])
            out = out[0]
        return out
    engine.count_launches("gemm", plan_launches(plan, fused=False))
    f = functools.partial(_gemm2d, plan=plan, interpret=interpret)
    if desc.batch:
        def batched(a_, b_, c_):
            return f(a_, b_, bias=bias, c=c_)
        return jax.vmap(batched, in_axes=(0, 0, 0 if c is not None else None))(a, b, c)
    return f(a, b, bias=bias, c=c)


engine.register_family("gemm", planner=plan_gemm, execute=execute)


def gemm(a, b, c: Optional[jax.Array] = None, *, layout: str = "nn",
         epilogue: Optional[str] = None, bias: Optional[jax.Array] = None,
         out_dtype=None, edge: str = "mask", plan: Optional[BlockingPlan] = None,
         heterogeneous: bool = True, fused: Optional[bool] = None,
         quant=None) -> jax.Array:
    """Planned, shape-specialized (batched) GEMM via the engine.

    ``a``: (..., M, K); ``b``: (..., K, N) for layout "nn" or (..., N, K)
    for "nt"; optional ``c`` accumulator of shape (..., M, N).  Interpret
    policy comes from :mod:`repro.core.config`; ``fused=True/False`` pins
    the single-launch vs multi-launch lowering for this call (default:
    follow config + plan, DESIGN.md §8).

    ``quant`` selects the low-precision axis (DESIGN.md §13): a
    :class:`~repro.core.descriptor.QuantSpec`, a shorthand string
    (``"int8"``/``"w8a16"``/``"fp8"``), ``False`` to opt out of an
    ambient ``config.quant``, or ``None`` to follow the config.  Wide
    operands are quantized here at dispatch; alternatively ``b`` may be a
    pre-quantized :class:`~repro.optim.compression.QuantizedTensor`
    (the quantize-once-at-load W8A16 path), whose spec then wins.
    """
    from repro.optim.compression import (QuantizedTensor, expand_scale,
                                         quantize_operand)
    sa = sb = None
    spec = None
    if isinstance(b, QuantizedTensor):
        # Quantized-at-load weights: always weight-only — A stays wide.
        spec = dataclasses.replace(b.spec, weight_only=True)
        n_axis = 1 if layout == "nn" else 0
        if b.axis % b.ndim != n_axis:
            raise ValueError(
                f"QuantizedTensor b is quantized along axis {b.axis}, but "
                f"layout {layout!r} needs output-column (axis {n_axis}) "
                f"scales for the dequant to commute through the GEMM")
        sb = expand_scale(b.scale, b.spec, b.shape[n_axis])
        b = b.q
    else:
        from repro.core.config import get_config
        from repro.core.descriptor import resolve_quant
        spec = resolve_quant(get_config().quant if quant is None else quant)
        if spec is not None:
            if a.ndim != 2:
                raise ValueError("quantized GEMM is unbatched; flatten "
                                 "leading dims first")
            out_dtype = out_dtype or a.dtype
            b, sb = quantize_operand(b, spec,
                                     axis=1 if layout == "nn" else 0)
            if not spec.weight_only:
                a, sa = quantize_operand(a, spec, axis=0)
    desc = GemmDescriptor.from_operands(
        a, b, layout=layout, accumulate=c is not None, epilogue=epilogue,
        out_dtype=out_dtype or a.dtype, edge=edge, quant=spec)
    if plan is None and not heterogeneous:
        # Non-default planner knob: plan directly, bypassing the plan cache
        # (the cache serves only the canonical planner configuration).
        plan = plan_gemm(desc, heterogeneous=False)
    if fused is None:
        return engine.dispatch(desc, a, b, plan=plan, bias=bias, c=c,
                               sa=sa, sb=sb)
    from repro.core.config import use
    with use(fused="on" if fused else "off"):
        return engine.dispatch(desc, a, b, plan=plan, bias=bias, c=c,
                               sa=sa, sb=sb)
