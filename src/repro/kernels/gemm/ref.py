"""Pure-jnp oracle for the blocked GEMM kernel.

Semantics (shared with the Pallas kernel):

    out = epilogue( C? + A @ op(B) )

with fp32 accumulation regardless of input dtype (the widening-accumulate
structure of SME's BFMOPA / the MXU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _epilogue(x, epilogue: Optional[str], bias):
    if epilogue in ("bias", "bias_gelu", "bias_silu"):
        assert bias is not None
        x = x + bias.astype(x.dtype)
    if epilogue in ("gelu", "bias_gelu"):
        x = jax.nn.gelu(x)
    elif epilogue in ("silu", "bias_silu"):
        x = jax.nn.silu(x)
    elif epilogue == "relu":
        x = jnp.maximum(x, 0)
    return x


def ref_gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None,
             *, layout: str = "nn", epilogue: Optional[str] = None,
             bias: Optional[jax.Array] = None,
             out_dtype=None) -> jax.Array:
    """Oracle: fp32-accumulated (batched) GEMM with optional epilogue."""
    assert layout in ("nn", "nt")
    contract_b = b.ndim - (2 if layout == "nn" else 1)
    batch_dims = tuple(range(a.ndim - 2))
    dn = (((a.ndim - 1,), (contract_b,)), (batch_dims, batch_dims))
    acc = jax.lax.dot_general(a, b, dn, preferred_element_type=jnp.float32)
    if c is not None:
        acc = acc + c.astype(jnp.float32)
    acc = _epilogue(acc, epilogue, bias)
    return acc.astype(out_dtype or a.dtype)
