from repro.kernels.gemm.ops import gemm, gemm_region  # noqa: F401
from repro.kernels.gemm.ref import ref_gemm  # noqa: F401
