"""Fault-tolerant training loop.

Production posture (scaled to this container):

  * periodic async checkpoints with atomic commit + exact data-position
    resume (the data pipeline is counter-based, so "skip to step" is free);
  * a restart supervisor (``run_with_restarts``): any step exception rolls
    the job back to the last committed checkpoint, with bounded retries —
    the single-process stand-in for a multi-host coordinator re-scheduling
    failed workers;
  * straggler detection: per-step wall-times feed an online quantile
    estimate; steps slower than ``straggler_factor`` x median are counted
    and surfaced in metrics (on real fleets this signal drives hot-spare
    swaps; here it drives logging/alerting);
  * elastic restarts: checkpoints store logical arrays, so a restart may
    use a different mesh/device count (reshard-on-load in
    ``repro.checkpoint``).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import engine


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    save_every: int = 50
    max_to_keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    max_restarts: int = 3


@dataclasses.dataclass
class StragglerStats:
    times: List[float] = dataclasses.field(default_factory=list)
    stragglers: int = 0

    def observe(self, dt: float, factor: float) -> bool:
        self.times.append(dt)
        if len(self.times) >= 10:
            med = statistics.median(self.times[-100:])
            if dt > factor * med:
                self.stragglers += 1
                return True
        return False


def train(step_fn: Callable, params, opt_state, batch_fn: Callable[[int], Any],
          loop_cfg: TrainLoopConfig, *, start_step: int = 0,
          log_fn: Callable[[int, Dict], None] = None) -> Dict[str, Any]:
    """Run the (jitted) ``step_fn`` from ``start_step`` to completion."""
    mgr = CheckpointManager(loop_cfg.ckpt_dir, loop_cfg.save_every,
                            loop_cfg.max_to_keep)
    stats = StragglerStats()
    metrics_hist = []
    step = start_step
    while step < loop_cfg.total_steps:
        t0 = time.time()
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jax.numpy.asarray(step))
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        slow = stats.observe(dt, loop_cfg.straggler_factor)
        scalars = {k: float(v) for k, v in metrics.items()
                   if hasattr(v, "shape") and np.ndim(v) == 0}
        scalars["step_seconds"] = dt
        scalars["straggler"] = float(slow)
        metrics_hist.append(scalars)
        if log_fn and (step % loop_cfg.log_every == 0
                       or step == loop_cfg.total_steps - 1):
            log_fn(step, scalars)
        step += 1
        mgr.maybe_save(step, {"params": params, "opt_state": opt_state},
                       meta={"data_step": step})
    mgr.maybe_save(step, {"params": params, "opt_state": opt_state},
                   meta={"data_step": step}, force=True)
    mgr.wait()
    return {"params": params, "opt_state": opt_state,
            "metrics": metrics_hist, "stragglers": stats.stragglers,
            "final_step": step,
            # Engine provenance for the run: per-family plan/launch
            # counters including the backward (``*_bwd``) slots, so a
            # training job reports whether its gradients flowed through
            # the scheduled single-launch backward walks (DESIGN.md §11)
            # or the reference fallback.
            "engine_stats": engine.stats()}


def run_with_restarts(make_state: Callable[[], tuple], step_fn, batch_fn,
                      loop_cfg: TrainLoopConfig, *,
                      fault_injector: Optional[Callable[[int], None]] = None,
                      log_fn=None) -> Dict[str, Any]:
    """Supervisor: (re)start training from the latest checkpoint until the
    step budget completes or restarts are exhausted.

    ``fault_injector(step)`` may raise to simulate node failure (tests).
    """
    restarts = 0
    while True:
        params, opt_state = make_state()
        mgr = CheckpointManager(loop_cfg.ckpt_dir, loop_cfg.save_every,
                                loop_cfg.max_to_keep)
        restored, meta = mgr.restore_latest(
            {"params": params, "opt_state": opt_state})
        start = 0
        if restored is not None:
            params, opt_state = restored["params"], restored["opt_state"]
            start = int(meta["data_step"])

        wrapped_batch_fn = batch_fn
        if fault_injector is not None:
            def wrapped_batch_fn(step, _orig=batch_fn):
                fault_injector(step)
                return _orig(step)

        try:
            out = train(step_fn, params, opt_state, wrapped_batch_fn,
                        loop_cfg, start_step=start, log_fn=log_fn)
            out["restarts"] = restarts
            return out
        except Exception:
            restarts += 1
            if restarts > loop_cfg.max_restarts:
                raise
            # loop: restore from last committed checkpoint and continue
