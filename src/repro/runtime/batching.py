"""Continuous-batching scheduler over the paged serving cache.

The serving runtime (DESIGN.md §12) decouples *requests* from *slots*:
requests arrive on a queue (Poisson-style in the benchmark trace), the
scheduler admits them into free decode slots as pool pages allow, and
every decode step runs the whole churning batch through ONE jitted
:func:`repro.runtime.steps.make_paged_serve_step` — batch composition
changes flow through block-table / length *values*, never through new
traces, so ``engine.stats()`` launch counts stay flat while sequences
come and go.

Scheduling policy (deliberately simple, and deterministic so evict →
re-admit is greedy-token-identical to an uninterrupted run):

  * FIFO admission with head-of-line blocking: the queue head is
    admitted iff a slot is free and the free list covers its context
    (+1 headroom page-worth for the first decode write); nothing behind
    it jumps ahead.
  * Per-step growth: before each decode step every active slot is grown
    to cover position ``length`` (the one being written).  When the pool
    runs dry mid-decode, the *most recently admitted* sequence is
    evicted — its pages are freed and it re-enters the queue front with
    its prompt + tokens generated so far; re-admission re-prefills that
    full context, which under greedy decoding reproduces the exact
    token stream.
  * Admission overflow never crashes: requests simply wait.

Everything host-side here is numpy/python — the device only ever sees
the shape-stable step inputs.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.models.attention import PageSpec
from repro.runtime import steps as steps_lib
from repro.runtime.pages import (OutOfPages, PagePool, init_serving_cache,
                                 pages_for, refresh_tables, write_prefill)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray        # (L,) int32
    max_new: int
    arrival: float = 0.0      # scheduler-tick time the request appears


@dataclasses.dataclass
class _Seq:
    """Host-side state of one admitted (or evicted-and-queued) request."""
    req: Request
    generated: List[int] = dataclasses.field(default_factory=list)
    evictions: int = 0
    admit_order: int = -1     # monotonic stamp of the latest admission
    t_visible: float = 0.0    # wall time the request hit the queue
    t_last: float = 0.0       # wall time of the previous emitted token

    @property
    def context(self) -> np.ndarray:
        """prompt + generated-so-far — what a re-prefill must replay."""
        gen = np.asarray(self.generated, np.int32)
        return np.concatenate([self.req.prompt.astype(np.int32), gen])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new


class ContinuousBatchingEngine:
    """Admission/eviction scheduler + single-launch paged decode loop."""

    def __init__(self, cfg, params, *, num_slots: int, spec: PageSpec):
        if cfg.encoder_decoder:
            raise ValueError("continuous batching serves decoder-only archs")
        self.cfg = cfg
        self.params = params
        self.spec = spec
        self.num_slots = num_slots
        self.max_len = spec.max_blocks * spec.page_size

        self.pool = PagePool(spec, num_slots)
        self.cache = init_serving_cache(cfg, num_slots, spec)
        self._step = jax.jit(steps_lib.make_paged_serve_step(cfg),
                             donate_argnums=(1,))
        self._prefills: Dict[int, object] = {}  # context length -> jitted

        self.queue: deque = deque()
        self.slots: List[Optional[_Seq]] = [None] * num_slots
        self.lengths = np.zeros(num_slots, np.int64)
        self.next_token = np.zeros(num_slots, np.int32)
        self.tick = 0
        self.evictions = 0
        self._admit_counter = 0
        self.finished: Dict[int, _Seq] = {}
        self.token_latencies: List[float] = []
        self._tables_dirty = True
        # Wall-clock per scheduler phase (DESIGN.md §15) — "prefill" is
        # deducted from the admission block so the four never overlap.
        self.phase_seconds: Dict[str, float] = {
            "admission": 0.0, "prefill": 0.0, "decode": 0.0,
            "eviction": 0.0}

    # -- warm-start ---------------------------------------------------------

    def warmup(self, prompt_lens=(), *, manifest: Optional[str] = None
               ) -> Dict:
        """Trace/build everything a serving loop will touch, pre-traffic.

        Three layers, outermost first (DESIGN.md §15):

          * kernel families — ``engine.warmup`` over a descriptor
            manifest (or ``configure(warm_start=...)``), resolving plans
            through the tuned tier and building each kernel once;
          * prefill traces — one jit trace per distinct prompt length in
            ``prompt_lens`` (the per-length ``_prefill_fn`` cache);
          * the decode step — traced once on an all-inactive batch (no
            active slot, so nothing scatters into the paged cache; the
            donated cache buffer is reassigned like a real step).

        After this returns, a serving run with the same shapes performs
        zero kernel builds, zero plan-cache misses and zero new traces —
        provable via ``engine.stats()``.  Returns a summary dict.
        """
        from repro.core.config import get_config
        t0 = time.time()
        kernels: Dict[str, int] = {}
        if manifest is not None or get_config().warm_start:
            kernels = engine.warmup(manifest=manifest)
        lengths = sorted({int(L) for L in prompt_lens})
        for L in lengths:
            jax.block_until_ready(self._prefill_fn(L)(
                self.params, {"tokens": jnp.zeros((1, L), jnp.int32)}))
        toks, self.cache, _ = self._step(
            self.params, self.cache,
            jnp.zeros((self.num_slots, 1), jnp.int32),
            jnp.zeros((self.num_slots,), jnp.int32),
            jnp.zeros((self.num_slots,), bool))
        jax.block_until_ready(toks)
        return {"seconds": time.time() - t0, "kernels": kernels,
                "prefill_lengths": lengths}

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        seq = _Seq(req=req, t_visible=time.time())
        seq.t_last = seq.t_visible
        self.queue.append(seq)

    # -- internals ----------------------------------------------------------

    def _prefill_fn(self, length: int):
        fn = self._prefills.get(length)
        if fn is None:
            fn = jax.jit(steps_lib.make_prefill_step(self.cfg, length))
            self._prefills[length] = fn
        return fn

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _admit(self, seq: _Seq, slot: int) -> None:
        # Fresh admission prefills the prompt and emits its argmax — same
        # as the static path.  RE-admission replays prompt + all-but-last
        # generated token: that reproduces exactly the cache an
        # uninterrupted run would hold (the last emitted token is never
        # in the cache yet), then the normal decode step recomputes from
        # it — so evict/re-admit cycles stay greedy-token-identical.
        readmit = bool(seq.generated)
        ctx = seq.context[:-1] if readmit else seq.context
        L = len(ctx)
        page_ids = self.pool.owned_pages(slot)
        page_ids += self.pool.grow(slot, L)
        t0 = time.time()
        logits, dense = self._prefill_fn(L)(
            self.params, {"tokens": jnp.asarray(ctx)[None, :]})
        self.cache = write_prefill(self.cache, dense, slot=slot, length=L,
                                   page_ids=page_ids,
                                   page_size=self.spec.page_size)
        self.phase_seconds["prefill"] += time.time() - t0
        if readmit:
            tok = seq.generated[-1]
        else:
            tok = int(jnp.argmax(logits[0]))
            self._emit(seq, tok)
        self.slots[slot] = seq
        self.lengths[slot] = L
        self.next_token[slot] = tok
        self._tables_dirty = True

    def _emit(self, seq: _Seq, tok: int) -> None:
        now = time.time()
        seq.generated.append(tok)
        self.token_latencies.append(now - seq.t_last)
        seq.t_last = now

    def _release(self, slot: int) -> None:
        self.pool.release(slot)
        self.slots[slot] = None
        self.lengths[slot] = 0
        self._tables_dirty = True

    def _evict_for_growth(self, needy_slot: int) -> None:
        """Free pages by evicting the most recently admitted other slot."""
        victims = [i for i, s in enumerate(self.slots)
                   if s is not None and i != needy_slot]
        if not victims:
            raise OutOfPages(
                f"slot {needy_slot} cannot grow and no other sequence can "
                f"be evicted — pool too small for one sequence")
        # LIFO victim choice: the most recently admitted sequence has the
        # least decode investment to replay on re-admission.
        t0 = time.time()
        victim = max(victims, key=lambda i: self.slots[i].admit_order)
        seq = self.slots[victim]
        seq.evictions += 1
        self.evictions += 1
        self._release(victim)
        self.queue.appendleft(seq)
        self.phase_seconds["eviction"] += time.time() - t0

    def _try_admissions(self) -> None:
        while self.queue:
            seq = self.queue[0]
            L = len(seq.context)
            if L + 1 > self.max_len:
                raise ValueError(
                    f"request {seq.req.rid} context {L}+1 exceeds "
                    f"max mappable length {self.max_len}")
            slot = self._free_slot()
            # +1 headroom: the first decode step writes position L.
            if slot is None or not self.pool.can_admit(L, headroom=1):
                break  # head-of-line blocking keeps admission FIFO-fair
            self.queue.popleft()
            self._admit_counter += 1
            seq.admit_order = self._admit_counter
            self._admit(seq, slot)

    def _grow_active(self) -> None:
        for slot, seq in enumerate(self.slots):
            if seq is None:
                continue
            while True:
                try:
                    if self.pool.grow(slot, int(self.lengths[slot]) + 1):
                        self._tables_dirty = True
                    break
                except OutOfPages:
                    self._evict_for_growth(slot)

    # -- one scheduler tick -------------------------------------------------

    def step(self) -> int:
        """Retire finished sequences, admit what fits, grow, run ONE
        decode launch over the live batch.  Returns the number of live
        slots this step decoded (0 = idle tick)."""
        t_admit = time.time()
        pf0 = self.phase_seconds["prefill"]
        for slot, seq in enumerate(self.slots):
            if seq is not None and seq.done:
                self.finished[seq.req.rid] = seq
                self._release(slot)
        self._try_admissions()
        # Admission emits one token (the prefill argmax) — sequences that
        # completed right there retire without ever decoding.
        for slot, seq in enumerate(self.slots):
            if seq is not None and seq.done:
                self.finished[seq.req.rid] = seq
                self._release(slot)
        self.phase_seconds["admission"] += (
            time.time() - t_admit - (self.phase_seconds["prefill"] - pf0))
        self.tick += 1
        if not any(s is not None for s in self.slots):
            return 0
        # Growth may evict — the mask MUST be taken after it, or an
        # evicted slot would decode as active and scatter its KV through
        # the zeroed block table into page 0 (owned by someone else).
        self._grow_active()
        active_mask = np.array([s is not None for s in self.slots])
        n_active = int(active_mask.sum())
        if n_active == 0:
            return 0
        if self._tables_dirty:
            self.cache = refresh_tables(self.cache,
                                        self.pool.device_tables())
            self._tables_dirty = False
        t_dec = time.time()
        toks, self.cache, _ = self._step(
            self.params, self.cache,
            jnp.asarray(self.next_token)[:, None],
            jnp.asarray(self.lengths, dtype=jnp.int32),
            jnp.asarray(active_mask))
        toks = np.asarray(toks)[:, 0]
        for slot, seq in enumerate(self.slots):
            if seq is None or not active_mask[slot]:
                continue
            self._emit(seq, int(toks[slot]))
            self.lengths[slot] += 1
            self.next_token[slot] = int(toks[slot])
        self.phase_seconds["decode"] += time.time() - t_dec
        return n_active

    # -- driver -------------------------------------------------------------

    def run(self, requests: List[Request], *,
            max_steps: int = 100_000) -> Dict:
        """Drive the scheduler until every request finished.

        Requests become visible when ``self.tick`` reaches their
        ``arrival`` (tick-time Poisson arrivals in the benchmark trace).
        Returns per-request outputs plus throughput / latency / launch
        metrics."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        stats0 = engine.stats()
        t0 = time.time()
        decode_steps = 0
        while pending or self.queue or any(s is not None
                                           for s in self.slots):
            while pending and pending[0].arrival <= self.tick:
                self.submit(pending.pop(0))
            if self.step():
                decode_steps += 1
            if self.tick > max_steps:
                raise RuntimeError("scheduler did not converge "
                                   f"within {max_steps} steps")
        wall = time.time() - t0
        stats1 = engine.stats()

        lat = np.asarray(self.token_latencies)
        total_tokens = sum(len(s.generated) for s in self.finished.values())
        fam = "flash_decode"
        launches = (stats1.get(fam, {}).get("launches", 0)
                    - stats0.get(fam, {}).get("launches", 0))
        return {
            "outputs": {rid: np.asarray(s.generated, np.int32)
                        for rid, s in self.finished.items()},
            "evictions": {rid: s.evictions
                          for rid, s in self.finished.items()},
            "metrics": {
                "requests": len(self.finished),
                "total_tokens": int(total_tokens),
                "decode_steps": decode_steps,
                "wall_seconds": wall,
                "tokens_per_s": total_tokens / max(wall, 1e-9),
                "p50_token_latency_s": float(np.percentile(lat, 50))
                if lat.size else 0.0,
                "p99_token_latency_s": float(np.percentile(lat, 99))
                if lat.size else 0.0,
                "evictions": self.evictions,
                "flash_decode_launches": int(launches),
                "phase_seconds": dict(self.phase_seconds),
            },
            "engine_stats": stats1,
        }


def poisson_trace(*, num_requests: int, rate: float, prompt_lens,
                  max_new, vocab_size: int, seed: int = 0) -> List[Request]:
    """A reproducible Poisson-style request trace.

    ``rate``: expected arrivals per scheduler tick; inter-arrival gaps
    are exponential.  ``prompt_lens``/``max_new`` may be ints or
    (lo, hi) ranges sampled uniformly.  Everything derives from ``seed``
    so benchmark runs are comparable across commits."""
    rng = np.random.default_rng(seed)

    def draw(spec):
        if isinstance(spec, int):
            return spec
        lo, hi = spec
        return int(rng.integers(lo, hi + 1))

    t = 0.0
    out = []
    for rid in range(num_requests):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        L = draw(prompt_lens)
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab_size, size=L).astype(np.int32),
            max_new=draw(max_new),
            arrival=t))
    return out
