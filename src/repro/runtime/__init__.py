"""Distributed runtime: sharding policy, training/serving loops, fault
tolerance, elasticity."""
