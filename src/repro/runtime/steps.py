"""Step builders: train_step / prefill_step / serve_step for any arch.

These are the functions the launcher jits with explicit in/out shardings
and the dry-run lowers against ShapeDtypeStructs.  All model-family
branching (dec-only vs enc-dec vs modality prefix) is resolved here, at
trace time, from the config.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import EncoderDecoderModel, LanguageModel
from repro.models.losses import softmax_cross_entropy

AUX_LOSS_WEIGHT = 0.01
Z_LOSS = 1e-4


def model_for(cfg):
    return EncoderDecoderModel if cfg.encoder_decoder else LanguageModel


def forward(cfg, params, batch: Dict[str, Any], *, cache=None, positions=None,
            logits_mode="all"):
    if cfg.encoder_decoder:
        return EncoderDecoderModel.apply(
            params, cfg, batch["tokens"], feats=batch.get("modality_feats"),
            enc_out=batch.get("enc_out"), positions=positions, cache=cache,
            logits_mode=logits_mode)
    return LanguageModel.apply(
        params, cfg, batch["tokens"], positions=positions, cache=cache,
        modality_feats=batch.get("modality_feats"), logits_mode=logits_mode)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_loss_fn(cfg):
    def loss_fn(params, batch):
        logits, _, aux = forward(cfg, params, batch)
        labels = batch["labels"]
        if cfg.modality == "vision":
            # loss over the text positions only (prefix carries no labels)
            logits = logits[:, -labels.shape[1]:]
        loss, metrics = softmax_cross_entropy(logits, labels, z_loss=Z_LOSS)
        total = loss + AUX_LOSS_WEIGHT * aux
        metrics = dict(metrics, aux_loss=aux, loss=total)
        return total, metrics

    return loss_fn


def make_train_step(cfg, optimizer, *, microbatches: int = 1,
                    grad_compress: bool = False):
    """Build the jittable train step.

    ``microbatches`` > 1 splits the global batch along the batch dim and
    accumulates gradients across a ``lax.scan`` — activation memory scales
    with 1/microbatches while the global batch (and the numerics, up to
    fp32 grad-sum order) is preserved.  ``grad_compress`` applies int8
    error-feedback quantization to the accumulated gradient (simulating
    the compressed cross-pod wire format; see repro.optim.compression).
    """
    loss_fn = make_loss_fn(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (_, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (_, metrics), grads = grads_of(params, mb)
                # bf16 accumulation: halves the resident grad buffer (the
                # Megatron bf16-grad convention; loss scale is 1 in bf16).
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.bfloat16), acc, grads)
                return acc, metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                                 params)
            grads, metrics_stack = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics_stack)

        if grad_compress:
            from repro.optim.compression import error_feedback_compress
            # residual is carried in opt_state["ef_residual"] when enabled
            res = opt_state.get("ef_residual") if isinstance(opt_state, dict) \
                else None
            grads, new_res = error_feedback_compress(grads, res)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state if not grad_compress else
            {k: v for k, v in opt_state.items() if k != "ef_residual"},
            params, step)
        if grad_compress:
            new_opt = dict(new_opt, ef_residual=new_res)
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, capacity: int):
    """Prefill: forward the prompt, return last-position logits + cache."""
    model = model_for(cfg)

    def prefill_step(params, batch):
        b = batch["tokens"].shape[0]
        cache = model.init_cache(cfg, b, capacity)
        # unembed only the last position: skips a (b, s, V) matmul + its
        # HBM round-trip (EXPERIMENTS.md §Perf, prefill iteration 1)
        logits, cache, _ = forward(cfg, params, batch, cache=cache,
                                   logits_mode="last")
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg):
    """One decode step:
    (params, cache, tokens(b,1), pos) -> (logits, cache, pos + 1).

    ``pos`` is carried *through* the jitted step (returned incremented)
    so decode loops never rebuild the position scalar host-side each
    iteration — rebuilding forced a host->device transfer per token.
    """

    def serve_step(params, cache, tokens, pos, enc_out=None):
        batch = {"tokens": tokens}
        if enc_out is not None:
            batch["enc_out"] = enc_out
        positions = pos[None] if pos.ndim == 0 else pos
        logits, new_cache, _ = forward(cfg, params, batch, cache=cache,
                                       positions=positions)
        return logits[:, -1], new_cache, pos + 1

    return serve_step


# ---------------------------------------------------------------------------
# continuous batching (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _where_slot(active, new, old, axis: int):
    shp = [1] * new.ndim
    shp[axis] = active.shape[0]
    return jnp.where(active.reshape(shp), new, old)


def _merge_inactive(new_cache, old_cache, active):
    """Keep state rows of inactive slots from the previous step.

    Inactive slots run through the forward with position -1: their
    PagedKVCache scatters are already dropped in-kernel (shared pool —
    nothing to merge), but ring/recurrent/ssm rows compute garbage
    updates that must be masked back to the old state.  Grouped leaves
    carry the stacked-layer dim first (slot axis 1); "rem" leaves are
    slot-major (axis 0)."""
    from repro.models.attention import PagedKVCache

    def merge(n, o, axis):
        def f(nl, ol):
            if isinstance(nl, PagedKVCache):
                return nl
            return _where_slot(active, nl, ol, axis)

        return jax.tree.map(f, n, o,
                            is_leaf=lambda x: isinstance(x, PagedKVCache))

    out = {"groups": None, "rem": []}
    if new_cache["groups"] is not None:
        out["groups"] = merge(new_cache["groups"], old_cache["groups"], 1)
    out["rem"] = [merge(n, o, 0)
                  for n, o in zip(new_cache["rem"], old_cache["rem"])]
    return out


def make_paged_serve_step(cfg):
    """One continuous-batching decode step over the paged serving cache.

    (params, cache, tokens(S,1), lengths(S,), active(S,)) ->
    (next_tokens(S,1), cache, lengths') — greedy argmax decode; inactive
    slots are frozen (state merged back, length unchanged, token row is
    garbage the scheduler ignores).  The signature is shape-stable in
    everything but the cache pytree, so the whole churning batch re-enters
    ONE compiled step; batch composition changes only flow through the
    block tables / lengths *values*.
    """

    def paged_serve_step(params, cache, tokens, lengths, active):
        positions = jnp.where(active, lengths, -1).astype(jnp.int32)[:, None]
        logits, new_cache, _ = forward(cfg, params, {"tokens": tokens},
                                       cache=cache, positions=positions)
        new_cache = _merge_inactive(new_cache, cache, active)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        new_lengths = jnp.where(active, lengths + 1, lengths)
        return tok[:, None], new_cache, new_lengths

    return paged_serve_step


# ---------------------------------------------------------------------------
# shape-only helpers for the dry-run
# ---------------------------------------------------------------------------

def param_shapes(cfg, rng=None):
    model = model_for(cfg)
    rng = jax.random.PRNGKey(0) if rng is None else rng
    return jax.eval_shape(functools.partial(model.init, cfg=cfg), rng)


def cache_shapes(cfg, batch: int, capacity: int):
    model = model_for(cfg)
    return jax.eval_shape(
        functools.partial(model.init_cache, cfg, batch, capacity))


def opt_state_shapes(cfg, optimizer, params_shapes):
    return jax.eval_shape(optimizer.init, params_shapes)
