"""Activation-sharding hooks.

Model code calls :func:`shard_activation` at block boundaries.  Outside a
mesh context it is a no-op, so single-device tests and examples run
unchanged; under ``use_mesh`` the hook emits
``jax.lax.with_sharding_constraint`` with the named axes that exist on the
active mesh (absent axes are dropped, so the same model code serves the
(data, model) single-pod mesh, the (pod, data, model) multi-pod mesh, and
1-device smoke tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _state.mesh = prev


AxisName = Union[None, str, Sequence[str]]


def _filter_axes(mesh: Mesh, axes: Sequence[AxisName]) -> P:
    names = set(mesh.axis_names)

    def keep(a: AxisName):
        if a is None:
            return None
        if isinstance(a, str):
            return a if a in names else None
        kept = tuple(x for x in a if x in names)
        return kept if kept else None

    return P(*[keep(a) for a in axes])


def _axis_size(mesh: Mesh, axis: AxisName) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape.get(axis, 1)
    n = 1
    for a in axis:
        n *= _axis_size(mesh, a)
    return n


def shard_activation(x: jax.Array, axes: Sequence[AxisName]) -> jax.Array:
    """Constrain ``x`` to ``axes`` (by mesh axis name) if a mesh is active.

    Axes absent from the mesh are dropped; axes that do not divide the
    corresponding dim are dropped too (GQA/odd-head fallback replication).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        # Allow passing logical specs shorter than rank: right-pad with None.
        axes = tuple(axes) + (None,) * (x.ndim - len(axes))
    spec = _filter_axes(mesh, axes)
    cleaned = []
    for dim, axis in zip(x.shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            if not isinstance(axis, str):
                axis = next(
                    (a for a in axis if dim % _axis_size(mesh, a) == 0), None)
            else:
                axis = None
        cleaned.append(axis)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))


def named_sharding(mesh: Mesh, *axes: AxisName) -> NamedSharding:
    return NamedSharding(mesh, _filter_axes(mesh, axes))
