"""Sharding policy: PartitionSpecs for params, optimizer state, caches and
batches, per (architecture x shape x mesh).

Conventions (DESIGN.md §5):

  * "data" is DP + FSDP: parameters/optimizer state store sharded on it
    (ZeRO-3 style); XLA all-gathers weights at use (bf16, since the model
    casts params at point-of-use).
  * "model" is TP/EP: Megatron column/row-parallel linears; expert
    parallelism when E divides the axis; vocab-parallel embeddings.
  * "pod" is cross-pod DP only — parameters replicate across pods, the
    batch and gradients reduce over (pod, data).
  * Every spec is *sanitized*: an axis is dropped from a dim that it does
    not divide (GQA kv-head fallback replication etc.), so one rule set
    serves all 10 architectures on any mesh, including 1-device tests.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.attention import KVCache
from repro.models.rglru import RecurrentState
from repro.models.ssd import SSMState

BATCH_AXES = ("pod", "data")


# ---------------------------------------------------------------------------
# sanitation
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.axis_names else 1
    n = 1
    for a in axis:
        n *= _axis_size(mesh, a)
    return n


def _filter_axis(mesh: Mesh, axis):
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.axis_names else None
    kept = tuple(a for a in axis if a in mesh.axis_names)
    return kept if kept else None


def sanitize(mesh: Mesh, spec: Sequence, shape: Tuple[int, ...]) -> P:
    """Drop axes that don't exist on the mesh or don't divide the dim."""
    spec = tuple(spec)
    if len(spec) < len(shape):  # left-pad for stacked leading dims
        spec = (None,) * (len(shape) - len(spec)) + spec
    spec = spec[-len(shape):] if shape else ()
    out = []
    for dim, axis in zip(shape, spec):
        axis = _filter_axis(mesh, axis)
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            # try single-axis fallback for composite axes
            if not isinstance(axis, str):
                axis = next((a for a in axis if dim % _axis_size(mesh, a) == 0),
                            None)
            else:
                axis = None
        if isinstance(axis, tuple) and len(axis) == 1:
            # collapse 1-element composites: newer jax normalizes
            # P(('a',),) == P('a'), older releases compare unequal
            axis = axis[0]
        out.append(axis)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (path regex, spec for the *trailing* dims).  First match wins.  "F" is
# the FSDP axis, "M" the tensor-parallel axis (substituted below).
_PARAM_RULES = [
    (r"embed/table$", ("M", None)),                # (V, D) vocab-parallel;
    # no FSDP on D: tied-unembed contracts over D and an FSDP'd D would
    # force a weight all-gather along the *batch* axis every step.
    (r"lm_head/w$", ("F", "M")),                   # (D, V)
    (r"(wq|wk|wv)/w$", ("F", "M")),                # column-parallel
    (r"wo/w$", ("M", "F")),                        # row-parallel
    (r"(w_gate|w_up)/w$", ("F", "M")),             # (d, f) or (E, d, f): EP prefix added
    (r"w_down/w$", ("M", "F")),                    # (f, d) or (E, f, d)
    (r"router/w$", ("F", None)),
    (r"(lin_y|lin_x|gate_a|gate_x)/w$", ("F", "M")),
    (r"lin_out/w$", ("M", "F")),
    (r"in_proj/w$", ("F", "M")),
    (r"out_proj/w$", ("M", "F")),
    (r"conv_w$", (None, "M")),
    (r"lambda$", ("M",)),
    (r"(proj1|proj2|adapter)/w$", ("F", "M")),
    (r"(A_log|D|dt_bias|conv_b)$", (None,)),
    (r"(scale|bias)$", (None,)),
    (r"/b$", ("M",)),                              # linear biases follow out dim
]


def _path_to_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"#{p.idx}")
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspec(path_str: str, shape: Tuple[int, ...], cfg, mesh: Mesh,
                *, fsdp: bool = True) -> P:
    fs = "data" if fsdp else None
    # expert-parallel prefix for stacked expert weights (E, d, f)/(E, f, d)
    is_expert = bool(re.search(r"(w_gate|w_up|w_down)/w$", path_str)) \
        and cfg.num_experts > 0
    for pattern, spec in _PARAM_RULES:
        if re.search(pattern, path_str):
            spec = tuple({"F": fs, "M": "model"}.get(s, s) if isinstance(s, str)
                         else s for s in spec)
            if is_expert:
                msize = _axis_size(mesh, "model")
                if cfg.num_experts % max(msize, 1) == 0 and msize > 1:
                    # expert parallelism: E on "model", FSDP on d/f
                    spec = ("model", fs, None)
                else:
                    spec = (None,) + spec
            return sanitize(mesh, spec, shape)
    return sanitize(mesh, (None,) * len(shape), shape)


def param_pspecs(params, cfg, mesh: Mesh, *, fsdp: bool = True):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_pspec(_path_to_str(path), leaf.shape, cfg, mesh, fsdp=fsdp)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# optimizer-state rules (mirror the param spec; factored leaves drop a dim)
# ---------------------------------------------------------------------------

def opt_pspecs(opt_state, params, cfg, mesh: Mesh, *, fsdp: bool = True):
    pspecs = param_pspecs(params, cfg, mesh, fsdp=fsdp)

    def mirror(ps, leaf_state):
        if isinstance(leaf_state, dict) and set(leaf_state) == {"r", "c"}:
            parts = tuple(ps)
            rspec = P(*parts[:-1]) if parts else P()
            cspec = P(*(parts[:-2] + parts[-1:])) if len(parts) >= 2 else P()
            return {"r": rspec, "c": cspec}
        return ps

    def walk(state_sub):
        return jax.tree.map(
            mirror, pspecs, state_sub,
            is_leaf=lambda x: isinstance(x, P))

    # state = {"m": tree-like-params, "v": tree with factored leaves}
    out = {}
    for key, sub in opt_state.items():
        out[key] = jax.tree.map(
            lambda ps, st: mirror(ps, st), pspecs, sub,
            is_leaf=lambda x: isinstance(x, P) or (
                isinstance(x, dict) and set(x) == {"r", "c"}))
    return out


# ---------------------------------------------------------------------------
# cache / state rules
# ---------------------------------------------------------------------------

def cache_pspecs(cache, cfg, mesh: Mesh):
    """Specs matching a stack_cache pytree (leading group dim or not)."""
    bd = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    bd = bd if bd else None
    msize = _axis_size(mesh, "model")
    heads_divisible = msize > 1 and cfg.num_kv_heads % msize == 0

    def kv_component(x, role):
        # (G?, b, S, hkv, hd) or (G?, b, S) for pos
        if role == "pos":
            return sanitize(mesh, (bd, None), x.shape)
        if heads_divisible:
            return sanitize(mesh, (bd, None, "model", None), x.shape)
        # GQA fallback: shard the sequence (SPMD split-K decode)
        return sanitize(mesh, (bd, "model", None, None), x.shape)

    def walk(node):
        if isinstance(node, KVCache):
            return KVCache(k=kv_component(node.k, "k"),
                           v=kv_component(node.v, "v"),
                           pos=kv_component(node.pos, "pos"))
        if isinstance(node, RecurrentState):
            return RecurrentState(
                h=sanitize(mesh, (bd, "model"), node.h.shape),
                conv=sanitize(mesh, (bd, None, "model"), node.conv.shape))
        if isinstance(node, SSMState):
            return SSMState(
                conv=sanitize(mesh, (bd, None, "model"), node.conv.shape),
                s=sanitize(mesh, (bd, "model", None, None), node.s.shape))
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        if node is None:
            return None
        # bare array leaf
        return sanitize(mesh, (None,) * node.ndim, node.shape)

    return walk(cache)


# ---------------------------------------------------------------------------
# batch rules
# ---------------------------------------------------------------------------

def batch_pspecs(batch_specs, mesh: Mesh):
    bd = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    bd = bd if bd else None

    def one(spec):
        if spec.ndim == 0:
            return P()
        return sanitize(mesh, (bd,) + (None,) * (spec.ndim - 1), spec.shape)

    return jax.tree.map(one, batch_specs)


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
