"""Paged KV/state cache for the continuous-batching serving runtime.

The serving cache (DESIGN.md §12) replaces the dense capacity-sized
per-slot KV of the static batch path with a *pool* of fixed-size pages
plus per-slot block tables:

  * :class:`PagePool` — the host-side free-list allocator.  It owns the
    int32 block tables as numpy state; admission/growth/eviction move
    page *indices* on the host, never KV bytes on the device.
  * :func:`init_serving_cache` — builds the device cache pytree: "attn"
    blocks become :class:`~repro.models.attention.PagedKVCache` pools,
    "local"/"rec"/"ssm" states stay slot-major dense (they are already
    O(window)/O(1) per slot).
  * :func:`write_prefill` — copies one sequence's freshly prefilled
    dense cache (batch=1, capacity=length) into its serving slot:
    paged KV scatters into the slot's pool pages, ring/recurrent state
    row-copies (resetting the ring first so stale entries from an
    evicted longer sequence cannot leak into the window mask).
  * :func:`refresh_tables` — pushes the host block tables into every
    PagedKVCache leaf after the allocator has moved pages.

All functions are eager host-path helpers: they run at admission time,
outside the jitted decode step, so python-int lengths are fine and no
retracing is induced on the hot loop.
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import KVCache, PagedKVCache, PageSpec


class OutOfPages(RuntimeError):
    """Admission/growth needs more pages than the free list holds."""


def pages_for(length: int, page_size: int) -> int:
    """Number of pages needed to hold ``length`` KV positions."""
    if length <= 0:
        return 0
    return -(-length // page_size)


class PagePool:
    """Host-side free-list page allocator + per-slot block tables.

    Invariants (checked by :meth:`check_invariants`, property-tested in
    tests/test_schedule.py):

      * every page id is owned by exactly one slot OR sits on the free
        list — never both, never neither;
      * slot ``i`` owns exactly ``pages_for(len_i, P)`` pages, recorded
        in block-table order in ``tables[i, :nblocks]``.
    """

    def __init__(self, spec: PageSpec, num_slots: int):
        self.spec = spec
        self.num_slots = num_slots
        # pop() hands out ascending ids first — deterministic allocation
        # order makes serving traces reproducible under a fixed seed.
        self._free: List[int] = list(range(spec.num_pages - 1, -1, -1))
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]
        self.tables = np.zeros((num_slots, spec.max_blocks), np.int32)

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def owned_pages(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def slot_blocks(self, slot: int) -> int:
        return len(self._owned[slot])

    def can_admit(self, length: int, *, headroom: int = 0) -> bool:
        """Can a sequence of ``length`` positions (plus ``headroom``
        future decode tokens) be admitted right now?"""
        return pages_for(length + headroom,
                         self.spec.page_size) <= len(self._free)

    # -- mutation -----------------------------------------------------------

    def grow(self, slot: int, length: int) -> List[int]:
        """Ensure ``slot`` owns enough pages for ``length`` positions.

        Returns the newly allocated page ids (empty when the slot already
        covers ``length``).  Raises :class:`OutOfPages` when the free
        list cannot supply them and ValueError when ``length`` exceeds
        what ``max_blocks`` can ever map."""
        need = pages_for(length, self.spec.page_size)
        if need > self.spec.max_blocks:
            raise ValueError(
                f"length {length} needs {need} pages > max_blocks "
                f"{self.spec.max_blocks}")
        cur = len(self._owned[slot])
        if need <= cur:
            return []
        if need - cur > len(self._free):
            raise OutOfPages(
                f"slot {slot} needs {need - cur} pages, free list has "
                f"{len(self._free)}")
        new = [self._free.pop() for _ in range(need - cur)]
        self._owned[slot].extend(new)
        self.tables[slot, cur:need] = np.asarray(new, np.int32)
        return new

    def release(self, slot: int) -> int:
        """Free every page the slot owns; returns how many were freed."""
        freed = self._owned[slot]
        self._free.extend(freed)
        self._owned[slot] = []
        self.tables[slot, :] = 0
        return len(freed)

    # -- device views -------------------------------------------------------

    def device_tables(self) -> jax.Array:
        return jnp.asarray(self.tables)

    # -- checking -----------------------------------------------------------

    def check_invariants(self,
                         lengths: Optional[List[int]] = None) -> None:
        all_pages = sorted(self._free
                           + [p for o in self._owned for p in o])
        if all_pages != list(range(self.spec.num_pages)):
            raise AssertionError(
                f"page conservation broken: {all_pages}")
        for i, owned in enumerate(self._owned):
            n = len(owned)
            if list(self.tables[i, :n]) != owned:
                raise AssertionError(
                    f"slot {i} tables {self.tables[i, :n]} != owned {owned}")
            if lengths is not None:
                want = pages_for(lengths[i], self.spec.page_size)
                if n != want:
                    raise AssertionError(
                        f"slot {i} owns {n} pages, length {lengths[i]} "
                        f"wants {want}")


# ---------------------------------------------------------------------------
# Serving-cache pytree helpers
# ---------------------------------------------------------------------------

def init_serving_cache(cfg, num_slots: int, spec: PageSpec):
    """The device cache for a continuous batch of ``num_slots`` slots."""
    from repro.models import LanguageModel
    capacity = spec.max_blocks * spec.page_size
    return LanguageModel.init_cache(cfg, num_slots, capacity, paged=spec)


def _is_block(x) -> bool:
    return isinstance(x, (KVCache, PagedKVCache))


def _write_one(sv, dv, *, slot: int, length: int, page_ids, page_size: int):
    """Write one prefilled sequence (dense leaf ``dv``, batch=1) into
    slot ``slot`` of one serving leaf ``sv`` — single-group shapes; the
    grouped case vmaps this over the leading stack dim."""
    if isinstance(sv, PagedKVCache):
        # Dense attn prefill ran with capacity == length, so dv.k[0, :L]
        # is position-ordered.  Pad to whole pages and scatter the page
        # rows into the pool at this slot's block-table entries.
        n = len(page_ids)
        pad = n * page_size - length
        ids = jnp.asarray(np.asarray(page_ids, np.int32))

        def pages_of(dense):
            rows = jnp.pad(dense[0, :length],
                           ((0, pad),) + ((0, 0),) * (dense.ndim - 2))
            return rows.reshape(n, page_size, *dense.shape[2:])

        def scatter(pool, dense):
            return pool.at[ids].set(pages_of(dense).astype(pool.dtype))

        if sv.k_scale is not None:
            # int8 pool (DESIGN.md §13): quantize the prefilled rows with
            # the same symmetric per-token scaling the decode write uses.
            def qscatter(pool, spool, dense):
                rows = pages_of(dense).astype(jnp.float32)  # (n, P, hkv, hd)
                s = jnp.max(jnp.abs(rows), axis=(2, 3)) / 127.0 + 1e-12
                qv = jnp.clip(jnp.round(rows / s[..., None, None]),
                              -127, 127).astype(jnp.int8)
                return (pool.at[ids].set(qv),
                        spool.at[ids].set(s.astype(jnp.float32)))

            k_new, ks_new = qscatter(sv.k, sv.k_scale, dv.k)
            v_new, vs_new = qscatter(sv.v, sv.v_scale, dv.v)
            return PagedKVCache(k_new, v_new, sv.tables, ks_new, vs_new)

        return PagedKVCache(scatter(sv.k, dv.k), scatter(sv.v, dv.v),
                            sv.tables, sv.k_scale, sv.v_scale)
    if isinstance(sv, KVCache):
        # Local ring: the dense prefill ring (cap_d = min(L, window)) and
        # the serving ring (cap_s = min(capacity, window)) may disagree
        # on capacity, so re-slot each live entry by its position.  The
        # row is reset FIRST — an evicted longer sequence leaves stale
        # (k, v, pos) entries whose positions could otherwise survive the
        # window mask of the re-admitted shorter one.
        cap_s = sv.k.shape[1]
        pos_d = dv.pos[0]
        # drop-sentinel: positive OOB index (negative would wrap).
        tgt = jnp.where(pos_d >= 0, pos_d % cap_s, cap_s)
        k_row = jnp.zeros_like(sv.k[slot]).at[tgt].set(
            dv.k[0].astype(sv.k.dtype), mode="drop")
        v_row = jnp.zeros_like(sv.v[slot]).at[tgt].set(
            dv.v[0].astype(sv.v.dtype), mode="drop")
        p_row = jnp.full((cap_s,), -1, jnp.int32).at[tgt].set(
            pos_d, mode="drop")
        return KVCache(sv.k.at[slot].set(k_row), sv.v.at[slot].set(v_row),
                       sv.pos.at[slot].set(p_row))
    # Plain array leaf (rec/ssm state): slot-major row copy.
    return sv.at[slot].set(dv[0].astype(sv.dtype))


def _write_tree(sv, dv, grouped: bool, **kw):
    fn = functools.partial(_write_one, **kw)
    one = (lambda s, d: jax.vmap(fn)(s, d)) if grouped else fn
    return jax.tree.map(one, sv, dv, is_leaf=_is_block)


def write_prefill(serving, dense, *, slot: int, length: int, page_ids,
                  page_size: int):
    """Copy a batch=1 dense prefill cache into serving slot ``slot``.

    ``page_ids``: the slot's block table prefix (from
    ``PagePool.grow``/``owned_pages``) — must cover ``length``.
    Returns the updated serving cache pytree."""
    assert len(page_ids) == pages_for(length, page_size), \
        (len(page_ids), length, page_size)
    kw = dict(slot=slot, length=length, page_ids=page_ids,
              page_size=page_size)
    groups = serving["groups"]
    if groups is not None:
        groups = _write_tree(groups, dense["groups"], True, **kw)
    rem = [_write_tree(s, d, False, **kw)
           for s, d in zip(serving["rem"], dense["rem"])]
    return {"groups": groups, "rem": rem}


def refresh_tables(cache, tables):
    """Replace every PagedKVCache leaf's block tables with ``tables``
    ((num_slots, max_blocks) int32) — called after the allocator moved
    pages; grouped leaves broadcast over the leading stack dim."""
    tables = jnp.asarray(tables, jnp.int32)

    def f(x):
        if isinstance(x, PagedKVCache):
            t = tables if x.tables.ndim == 2 \
                else jnp.broadcast_to(tables, x.tables.shape)
            return x._replace(tables=t)
        return x

    return jax.tree.map(f, cache,
                        is_leaf=lambda x: isinstance(x, PagedKVCache))
