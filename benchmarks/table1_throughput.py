"""Table I analogue: per-dtype matmul throughput of the engine.

The paper measures per-instruction-class throughput on M4 (FMOPA fp32 =
2009 GFLOPS etc.).  Our target (v5e MXU) is modeled, the host is CPU, so
we report: (a) measured CPU wall-clock GFLOP/s of the XLA path per dtype
(the real measurement this container supports), and (b) the machine-model
peak the planner uses for that dtype (the "Table I" constant), as
``derived``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import matmul, use
from repro.core.machine import TPU_V5E

M = N = K = 512


def run():
    rng = np.random.default_rng(0)
    a32 = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    flops = 2 * M * N * K
    for dtype in ("float32", "bfloat16", "float16"):
        a, b = a32.astype(dtype), b32.astype(dtype)

        def f(a, b):
            with use(backend="xla"):
                return matmul(a, b)

        jf = jax.jit(f)
        us = time_fn(jf, a, b)
        gflops = flops / us / 1e3
        peak = TPU_V5E.peak(dtype) / 1e9
        emit(f"table1/xla_{dtype}", us,
             f"cpu_gflops={gflops:.1f};v5e_model_peak_gflops={peak:.0f}")

    # int8: XLA CPU dot int8xint8->int32
    ai = jnp.asarray(rng.integers(-127, 127, (M, K)), jnp.int8)
    bi = jnp.asarray(rng.integers(-127, 127, (K, N)), jnp.int8)

    def fi(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)

    us = time_fn(jax.jit(fi), ai, bi)
    emit("table1/xla_int8", us,
         f"cpu_gops={flops/us/1e3:.1f};"
         f"v5e_model_peak_gops={TPU_V5E.peak('int8')/1e9:.0f}")

    # engine (pallas interpret) single data point for provenance
    def fp(a, b):
        with use(backend="pallas"):
            return matmul(a, b)

    us = time_fn(jax.jit(fp), a32, b32, iters=3, warmup=1)
    emit("table1/pallas_interpret_float32", us,
         f"cpu_gflops={flops/us/1e3:.2f};note=interpret_mode_correctness_path")
