"""Fig 1 analogue: multi-unit scaling.

The paper scales threads across M4's two shared SME units; our analogue
scales the mesh.  From the dry-run records we report, per architecture,
the single-pod vs multi-pod per-device compute/collective terms — ideal
scaling keeps per-device compute constant (the batch is fixed global, so
work per device halves with 2 pods) while the pod axis only adds DCN
gradient reduction.
"""
import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def run():
    recs = {}
    for path in glob.glob(os.path.join(RESULTS, "*train_4k*.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs[(r["arch"], r["mesh"])] = r
    archs = sorted({a for a, _ in recs})
    for arch in archs:
        pod = recs.get((arch, "pod"))
        multi = recs.get((arch, "multipod"))
        if not pod or not multi:
            continue
        f_pod = pod["cost"]["flops_per_device"]
        f_multi = multi["cost"]["flops_per_device"]
        # fixed global batch: ideal multi-pod per-device flops = pod/2
        eff = (f_pod / 2) / max(f_multi, 1.0)
        c_pod = pod["collective_bytes_per_device"]
        c_multi = multi["collective_bytes_per_device"]
        emit(f"fig1/{arch}", 0.0,
             f"scaling_efficiency={eff:.2f};"
             f"coll_bytes_pod={c_pod:.3g};coll_bytes_multipod={c_multi:.3g}")
