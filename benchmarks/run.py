"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  table1  — per-dtype matmul throughput (Table I)
  fig1    — mesh scaling efficiency from dry-run records (Fig 1)
  fig23   — data-movement staging strategies (Figs 2/3)
  fig45   — alignment / edge-handling strategies (Figs 4/5)
  fig7    — homogeneous vs heterogeneous blocking (Fig 7)
  fig89   — small-GEMM sweep vs the vendor (XLA) baseline (Figs 8/9)
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig7,fig89")
    args = ap.parse_args()
    from benchmarks import (table1_throughput, fig1_scaling, fig23_bandwidth,
                            fig45_alignment, fig7_blocking, fig89_gemm_sweep)
    suites = {
        "table1": table1_throughput.run,
        "fig1": fig1_scaling.run,
        "fig23": fig23_bandwidth.run,
        "fig45": fig45_alignment.run,
        "fig7": fig7_blocking.run,
        "fig89": fig89_gemm_sweep.run,
    }
    chosen = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    for name in chosen:
        suites[name]()

    # Engine observability: per-family plan/kernel cache traffic for the
    # whole benchmark run (the paper's dispatch-layer hit/miss view).
    from repro.core import engine
    for fam, c in sorted(engine.stats().items()):
        print(f"engine/{fam},0,"
              f"plan_hits={c['plan_hits']};plan_misses={c['plan_misses']};"
              f"kernel_hits={c['kernel_hits']};"
              f"kernel_misses={c['kernel_misses']};"
              f"kernel_evictions={c['kernel_evictions']}")


if __name__ == '__main__':
    main()
