"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines, followed after each phase
by per-family engine counters (cache traffic + plan provenance + traced
launch counts, ``engine/<phase>/<family>`` rows).  Counters are reset at
phase boundaries with ``engine.reset_stats(entries=False)`` — caches stay
warm — so every table is per-phase, not cumulative.

  table1  — per-dtype matmul throughput (Table I)
  fig1    — mesh scaling efficiency from dry-run records (Fig 1)
  fig23   — data-movement staging strategies (Figs 2/3)
  fig45   — alignment / edge-handling strategies (Figs 4/5)
  fig7    — homogeneous vs heterogeneous blocking (Fig 7)
  fig89   — small-GEMM sweep vs the vendor (XLA) baseline (Figs 8/9),
            incl. fused-vs-multi-launch deltas (BENCH_gemm_fused.json)
  grouped — scheduled grouped GEMM: fused single-launch vs pad/scatter
            deltas + launch counts (BENCH_grouped_fused.json)
  flash   — scheduled flash attention: fused causal-pruned walk vs the
            dense grid, deltas + skipped-tile counts
            (BENCH_flash_fused.json)
  train   — fused-VJP vs reference-autodiff train-step time on a small
            LM config, plus per-family gradient deltas and backward
            launch counts (BENCH_train.json)
  serve   — continuous-batching Poisson trace through the paged serving
            runtime (DESIGN.md §12): tokens/s + p50/p99 per-token
            latency + the flat-launch-count proof (BENCH_serve.json)
  quant   — the low-precision axis (DESIGN.md §13): int8/W8A16 vs f32
            GEMM throughput + wire-byte savings on the fig89 shapes,
            plus the W8A16 + KV-int8 serving tokens/s delta
            (BENCH_quant.json)
  mesh    — mesh-aware expert dispatch (DESIGN.md §14): gathered vs
            distributed (all_to_all) grouped-GEMM step time, comm bytes
            and launches-per-shard on a host-count-forced 8-device mesh
            (BENCH_mesh.json; runs in a subprocess so the forced device
            count never leaks into this process)

``--smoke`` is the CI job (interpret mode): it runs the fig89 sweep plus
the grouped, flash, train, serve, quant and mesh suites at reduced size,
exercising the fused single-launch GEMM, the scheduled grouped-GEMM and
flash paths, the scheduled backward walks (DESIGN.md §11), the
continuous-batching decode path (DESIGN.md §12), the quantized
execution axis (DESIGN.md §13) *and* the mesh-aware expert dispatch
(DESIGN.md §14) end-to-end on every PR, still emitting
``BENCH_gemm_fused.json`` + ``BENCH_grouped_fused.json`` +
``BENCH_flash_fused.json`` + ``BENCH_train.json`` + ``BENCH_serve.json``
+ ``BENCH_quant.json`` + ``BENCH_mesh.json``.  After the suites it runs
the fused-ranking regression gate over ``BENCH_gemm_fused.json``:
any entry where the planner chose fused but the measured fused/multi
speedup is < 0.9 fails the job.
"""
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig7,fig89")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-size CI run of the GEMM sweep "
                         "(fused path end-to-end)")
    args = ap.parse_args()
    from benchmarks import (table1_throughput, fig1_scaling, fig23_bandwidth,
                            fig45_alignment, fig7_blocking, fig89_gemm_sweep,
                            flash_fused, grouped_fused, mesh_overlap,
                            quant_gemm, serve_trace, train_step)
    suites = {
        "table1": table1_throughput.run,
        "fig1": fig1_scaling.run,
        "fig23": fig23_bandwidth.run,
        "fig45": fig45_alignment.run,
        "fig7": fig7_blocking.run,
        "fig89": fig89_gemm_sweep.run,
        "grouped": grouped_fused.run,
        "flash": flash_fused.run,
        "train": train_step.run,
        "serve": serve_trace.run,
        "quant": quant_gemm.run,
        "mesh": mesh_overlap.run,
    }
    if args.smoke:
        if args.only:
            ap.error("--smoke selects its own suite; drop --only")
        suites = {"fig89": lambda: fig89_gemm_sweep.run(smoke=True),
                  "grouped": lambda: grouped_fused.run(smoke=True),
                  "flash": lambda: flash_fused.run(smoke=True),
                  "train": lambda: train_step.run(smoke=True),
                  "serve": lambda: serve_trace.run(smoke=True),
                  "quant": lambda: quant_gemm.run(smoke=True),
                  "mesh": lambda: mesh_overlap.run(smoke=True)}
    chosen = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    from repro.core import engine
    for name in chosen:
        # Per-phase counters: zero the stats (keeping every cache warm) so
        # each phase's table reports its own traffic, not the cumulative
        # run — `entries=False` avoids charging a phase for rebuilding
        # kernels an earlier phase already built.
        engine.reset_stats(entries=False)
        suites[name]()
        _emit_engine_stats(name, engine)
    if args.smoke:
        _check_fused_ranking()


def _check_fused_ranking() -> None:
    """Regression gate (DESIGN.md §8): fail the smoke run when the
    planner *chose* fused on an entry whose measured fused/multi speedup
    is < 0.9 — a misranked lowering, not just a slow one."""
    with open("BENCH_gemm_fused.json") as f:
        entries = json.load(f)["entries"]
    bad = {label: e["speedup"] for label, e in sorted(entries.items())
           if e.get("chosen_fused") and e.get("speedup") is not None
           and e["speedup"] < 0.9}
    if bad:
        for label, speedup in bad.items():
            print(f"FUSED-RANKING REGRESSION: {label}: planner chose fused "
                  f"but measured fused/multi speedup = {speedup}",
                  file=sys.stderr)
        sys.exit(1)
    print(f"fused_ranking_gate,0,entries={len(entries)};violations=0")


def _emit_engine_stats(phase: str, engine) -> None:
    """Per-family plan/kernel cache traffic, plan provenance and traced
    launch counts for one phase (the paper's dispatch-layer view)."""
    for fam, c in sorted(engine.stats().items()):
        print(f"engine/{phase}/{fam},0,"
              f"plan_hits={c['plan_hits']};plan_misses={c['plan_misses']};"
              f"kernel_hits={c['kernel_hits']};"
              f"kernel_misses={c['kernel_misses']};"
              f"kernel_evictions={c['kernel_evictions']};"
              f"launches={c['launches']};"
              f"launches_bwd={c['launches_bwd']};"
              f"plan_src_model={c['plan_source_model']};"
              f"plan_src_autotuned={c['plan_source_autotuned']};"
              f"plan_src_tuned_cache={c['plan_source_tuned_cache']};"
              f"autotune_timings={c['autotune_timings']};"
              f"comm_bytes={c['comm_bytes']};"
              f"collective_launches={c['collective_launches']}")


if __name__ == '__main__':
    main()
