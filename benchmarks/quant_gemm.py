"""Low-precision axis benchmark (DESIGN.md §13).

Two phases, one artifact:

  * **GEMM sweep** — the fig 8/9 shapes (M=N, K=512) run wide (f32) and
    quantized (int8 full and W8A16), all through the fused single-launch
    lowering, recording GFLOP/s, the descriptor's wire-byte traffic
    (``in_bytes`` — the planner's own accounting of what quantization
    saves), and the traced launch counts proving the dequant epilogue
    never costs a second launch.
  * **W8A16 serving delta** — the serve_trace Poisson run (DESIGN.md
    §12) with ``quantize_model`` weights + int8 KV pools
    (``PageSpec(kv_quant="int8")``) against the wide baseline: tokens/s
    delta plus the fraction of tokens that match the wide run.  Unlike
    ``serve_trace.py`` there is no token-identity *assert* — quantized
    logits may legitimately flip a token — the match fraction is
    recorded instead.

Writes ``BENCH_quant.json``; ``run(smoke=True)`` is the CI variant
(reduced sizes/trace, same code paths), wired into
``benchmarks/run.py --smoke``.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import GemmDescriptor, engine
from repro.core.config import use
from repro.core.descriptor import resolve_quant
from repro.kernels.gemm import gemm

SIZES = [16, 64, 80, 128, 250, 512]
SMOKE_SIZES = [16, 80]
K = 512
QUANT_JSON = "BENCH_quant.json"

TRACE_FULL = (8, 0.6, (8, 16), (4, 10), 4, 48, 8, 8)
TRACE_SMOKE = (3, 0.5, (6, 10), (3, 5), 3, 24, 8, 6)


def _launches(fn) -> int:
    before = engine.stats().get("gemm", {}).get("launches", 0)
    jax.block_until_ready(fn())
    return engine.stats()["gemm"]["launches"] - before


def _sweep(sizes, iters, warmup, entries):
    rng = np.random.default_rng(0)
    for mn in sizes:
        a = jnp.asarray(rng.standard_normal((mn, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, mn)), jnp.float32)
        flops = 2 * mn * mn * K
        with use(backend="pallas"):
            fns = {
                "f32": jax.jit(lambda a, b: gemm(a, b, fused=True)),
                "int8": jax.jit(lambda a, b: gemm(a, b, quant="int8",
                                                  fused=True)),
                "w8a16": jax.jit(lambda a, b: gemm(a, b, quant="w8a16",
                                                   fused=True)),
            }
            us = {k: time_fn(f, a, b, iters=iters, warmup=warmup)
                  for k, f in fns.items()}
            launches = {
                k: _launches(lambda q=q: gemm(a, b, quant=q, fused=True))
                for k, q in [("f32", False), ("int8", "int8"),
                             ("w8a16", "w8a16")]}
        d32 = GemmDescriptor(m=mn, n=mn, k=K)
        dq = GemmDescriptor(m=mn, n=mn, k=K, in_dtype="int8",
                            quant=resolve_quant("int8"))
        entry = {
            "m": mn, "n": mn, "k": K,
            "in_bytes_f32": d32.in_bytes, "in_bytes_int8": dq.in_bytes,
            "bytes_saved": d32.in_bytes - dq.in_bytes,
        }
        for kind in ("f32", "int8", "w8a16"):
            entry[f"{kind}_us"] = round(us[kind], 1)
            entry[f"{kind}_gflops"] = round(flops / us[kind] / 1e3, 2)
            entry[f"{kind}_launches"] = launches[kind]
        entry["int8_speedup"] = round(us["f32"] / max(us["int8"], 1e-9), 3)
        entries[f"gemm_{mn}"] = entry
        emit(f"quant_gemm/{mn}x{mn}", us["int8"],
             f"f32_us={us['f32']:.0f};w8a16_us={us['w8a16']:.0f};"
             f"int8_gflops={entry['int8_gflops']};"
             f"bytes_saved={entry['bytes_saved']};"
             f"launches={launches['int8']}")
    return entries


def _serve_phase(cfg, params, backend, trace_args, seed, kv_quant=None):
    from repro.models.attention import PageSpec
    from repro.runtime.batching import ContinuousBatchingEngine, \
        poisson_trace
    n_req, rate, plens, mnew, slots, pages, psize, blocks = trace_args
    reqs = poisson_trace(num_requests=n_req, rate=rate, prompt_lens=plens,
                         max_new=mnew, vocab_size=cfg.vocab_size, seed=seed)
    with use(backend=backend):
        engine.reset_stats(entries=False)
        serving = ContinuousBatchingEngine(
            cfg, params, num_slots=slots,
            spec=PageSpec(pages, psize, blocks, kv_quant=kv_quant))
        result = serving.run(reqs)
    return reqs, result


def _serve_delta(trace_args, seed, entries):
    from repro.configs import get_config, reduced_config
    from repro.models import LanguageModel
    from repro.optim.compression import quantize_model
    cfg = reduced_config(get_config("qwen3-0.6b"))
    params = LanguageModel.init(jax.random.PRNGKey(0), cfg)
    qparams = quantize_model(params, "w8a16")

    reqs, wide = _serve_phase(cfg, params, "pallas", trace_args, seed)
    _, quant = _serve_phase(cfg, qparams, "pallas", trace_args, seed,
                            kv_quant="int8")
    match = total = 0
    for r in reqs:
        w = np.asarray(wide["outputs"][r.rid])
        q = np.asarray(quant["outputs"][r.rid])
        match += int(np.sum(w == q))
        total += len(w)
    mw, mq = wide["metrics"], quant["metrics"]
    entries["serve"] = {
        "arch": cfg.name, "requests": mw["requests"],
        "wide_tokens_per_s": round(mw["tokens_per_s"], 1),
        "w8a16_tokens_per_s": round(mq["tokens_per_s"], 1),
        "tokens_per_s_delta": round(
            mq["tokens_per_s"] - mw["tokens_per_s"], 1),
        "speedup": round(mq["tokens_per_s"]
                         / max(mw["tokens_per_s"], 1e-9), 3),
        "token_match_frac": round(match / max(total, 1), 3),
        "kv_quant": "int8",
    }
    e = entries["serve"]
    emit("quant_gemm/serve_w8a16", 0,
         f"wide_tok_s={e['wide_tokens_per_s']};"
         f"w8a16_tok_s={e['w8a16_tokens_per_s']};"
         f"speedup={e['speedup']};"
         f"token_match={e['token_match_frac']}")


def run(smoke: bool = False, seed: int = 0):
    sizes = SMOKE_SIZES if smoke else SIZES
    iters, warmup = (2, 1) if smoke else (3, 1)
    entries = {}
    _sweep(sizes, iters, warmup, entries)
    _serve_delta(TRACE_SMOKE if smoke else TRACE_FULL, seed, entries)
    with open(QUANT_JSON, "w") as f:
        json.dump({"mode": "smoke" if smoke else "full",
                   "entries": entries}, f, indent=1, sort_keys=True)
    emit("quant_gemm/json", 0, f"wrote={QUANT_JSON};entries={len(entries)}")


if __name__ == "__main__":
    run(smoke=True)
