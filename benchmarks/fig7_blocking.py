"""Fig 7 analogue: homogeneous vs heterogeneous register blocking.

The paper's 80x80 example needs 10 homogeneous 32x32 microkernels but
only 7 heterogeneous ones.  We sweep ragged output shapes at TPU
granularity and report microkernel counts, utilization, and the planner's
predicted v5e time for both strategies — the planner-level reproduction
of the paper's core optimization.

A second sweep closes the measure→generate loop (DESIGN.md §7): for a
few shapes it runs the empirical autotuner over the model-ranked
candidates and reports the measured model-plan vs autotuned-plan delta
plus each plan's provenance (``plan_source``).
"""
import functools

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import GemmDescriptor, autotune, engine, plan_gemm, use

SHAPES = [(640, 640), (320, 320), (896, 384), (2048, 272), (160, 1184),
          (80, 80)]
# Shapes small enough to time for real in interpret mode on the host.
MEASURED_SHAPES = [(80, 80), (320, 320)]
AUTOTUNE_BUDGET = 4
K = 512


def run():
    for m, n in SHAPES:
        d = GemmDescriptor(m=m, n=n, k=K)
        het = plan_gemm(d, heterogeneous=True)
        hom = plan_gemm(d, heterogeneous=False, force_block=(256, 256))
        emit(f"fig7/{m}x{n}", het.predicted_seconds() * 1e6,
             f"het_microkernels={het.num_microkernels};"
             f"hom_microkernels={hom.num_microkernels};"
             f"het_util={het.utilization:.3f};hom_util={hom.utilization:.3f};"
             f"hom_predicted_us={hom.predicted_seconds()*1e6:.1f}")

    # Quant axis (DESIGN.md §13): the measured host int8 probe next to the
    # model peak the planner prices narrow plans with, and the planner's
    # predicted narrow-vs-wide delta on one sweep shape (wire-byte traffic
    # + int8 MAC pricing both feed _predict_seconds).
    from repro.core.descriptor import resolve_quant
    from repro.core.machine import TPU_V5E
    from repro.core.microbench import probe_matmul_flops
    r = probe_matmul_flops("int8", size=256, iters=3)
    emit("fig7/quant_probe_int8", 2 * 256**3 / (r.value * 1e9) * 1e6,
         f"host_gops={r.value:.1f};"
         f"target_peak_int8_gops={TPU_V5E.peak('int8')/1e9:.0f}")
    d32 = GemmDescriptor(m=640, n=640, k=K)
    dq = GemmDescriptor(m=640, n=640, k=K, in_dtype="int8",
                        quant=resolve_quant("int8"))
    p32, pq = plan_gemm(d32), plan_gemm(dq)
    emit("fig7/quant_predicted_640", pq.predicted_seconds() * 1e6,
         f"wide_predicted_us={p32.predicted_seconds()*1e6:.2f};"
         f"in_bytes_int8={dq.in_bytes};in_bytes_f32={d32.in_bytes}")

    # Measured model-vs-autotuned delta through the engine's BUILD/RUN
    # stages (the three-tier policy's middle tier, run explicitly).
    from repro.kernels.gemm import gemm
    from repro.kernels.gemm.ops import execute as gemm_execute
    rng = np.random.default_rng(0)
    for m, n in MEASURED_SHAPES:
        a = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, n)), jnp.float32)
        d = GemmDescriptor(m=m, n=n, k=K)
        with use(backend="pallas") as cfg:
            model_plan = engine.plan_for(d)
            tuned_plan, timed = autotune.search(
                gemm_execute, d, cfg.machine, (a, b), {},
                interpret=cfg.interpret, budget=AUTOTUNE_BUDGET)
            model_us = time_fn(functools.partial(gemm, plan=model_plan), a, b)
            if tuned_plan is None:  # every candidate failed: model only
                emit(f"fig7/measured/{m}x{n}", model_us,
                     f"model_src={model_plan.plan_source};autotune=failed")
                continue
            tuned_us = time_fn(functools.partial(gemm, plan=tuned_plan), a, b)
        emit(f"fig7/measured/{m}x{n}", model_us,
             f"autotuned_us={tuned_us:.1f};"
             f"speedup={model_us / max(tuned_us, 1e-9):.3f};"
             f"model_src={model_plan.plan_source};"
             f"tuned_src={tuned_plan.plan_source};"
             f"candidates_timed={timed}")
