"""Fig 7 analogue: homogeneous vs heterogeneous register blocking.

The paper's 80x80 example needs 10 homogeneous 32x32 microkernels but
only 7 heterogeneous ones.  We sweep ragged output shapes at TPU
granularity and report microkernel counts, utilization, and the planner's
predicted v5e time for both strategies — the planner-level reproduction
of the paper's core optimization.
"""
from benchmarks.common import emit
from repro.core import GemmDescriptor, plan_gemm

SHAPES = [(640, 640), (320, 320), (896, 384), (2048, 272), (160, 1184),
          (80, 80)]
K = 512


def run():
    for m, n in SHAPES:
        d = GemmDescriptor(m=m, n=n, k=K)
        het = plan_gemm(d, heterogeneous=True)
        hom = plan_gemm(d, heterogeneous=False, force_block=(256, 256))
        emit(f"fig7/{m}x{n}", het.predicted_seconds() * 1e6,
             f"het_microkernels={het.num_microkernels};"
             f"hom_microkernels={hom.num_microkernels};"
             f"het_util={het.utilization:.3f};hom_util={hom.utilization:.3f};"
             f"hom_predicted_us={hom.predicted_seconds()*1e6:.1f}")
