"""Mesh-aware expert dispatch: gathered vs distributed step time (§14).

The comm-charged planner (DESIGN.md §14) arbitrates two executions of the
same expert-parallel grouped GEMM on an 8-way model mesh:

  * gathered     — all-gather the expert weights, every shard runs the
                   full expert set over its token slice (XLA moves the
                   weights; the engine issues no collectives);
  * distributed  — keep the weight shards, ``all_to_all`` the activations
                   so each shard runs only its E/s local experts.

This suite times BOTH strategies with pinned plans on two configs — one
where big weight panels make the all-gather (and the E-panel kernel walk)
expensive, one where a large token stream makes the ``all_to_all`` pair
the dominant wire cost — records what the planner chose, and writes the
whole table to ``BENCH_mesh.json`` (step time, per-strategy comm bytes,
collective and kernel launches per shard, cross-strategy max error).

The measurement needs 8 devices, so ``run()`` re-executes this module in
a **subprocess** with ``--xla_force_host_platform_device_count=8`` —
forcing the host platform device count must happen before jax
initialises, and must never leak into the parent process.
"""
import json
import os
import subprocess
import sys

MESH_JSON = "BENCH_mesh.json"
DEVICES = 8

# (label, nt, e, cap, k, n): "weights_heavy" keeps the token stream tiny
# against 8 big k*n expert panels — gathered walks all 8 panels per shard
# while distributed walks one; "tokens_heavy" streams enough rows through
# small panels that the paired all_to_all is the dominant wire cost.
CONFIGS = [
    ("weights_heavy", 8, 8, 16, 256, 256),
    ("tokens_heavy", 64, 8, 64, 64, 64),
]
SMOKE_CONFIGS = [
    ("weights_heavy", 8, 8, 16, 128, 128),
    ("tokens_heavy", 32, 8, 32, 64, 64),
]


def run(smoke: bool = False):
    """Parent entry: re-exec this module on a host-count-forced mesh."""
    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={DEVICES}"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    # The child resolves ``repro``/``benchmarks`` the same way the parent
    # did, wherever it was launched from (check.sh sets PYTHONPATH=src;
    # a bare ``python -m benchmarks.mesh_overlap`` may not have).
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    extra = os.pathsep.join((os.path.join(root, "src"), root))
    env["PYTHONPATH"] = (extra + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else extra)
    cmd = [sys.executable, "-m", "benchmarks.mesh_overlap", "--child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env)
    if proc.returncode:
        raise RuntimeError(
            f"mesh_overlap child failed with code {proc.returncode}")


def _child(smoke: bool) -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core import engine
    from repro.core.blocking import mesh_local_desc, plan_grouped
    from repro.core.descriptor import GroupedGemmDescriptor, MeshSpec
    from repro.launch.mesh import make_test_mesh
    from repro.runtime.shardlib import use_mesh

    ndev = len(jax.devices())
    assert ndev == DEVICES, (
        f"child expected {DEVICES} forced host devices, got {ndev}")

    rng = np.random.default_rng(0)
    iters, warmup = (2, 1) if smoke else (5, 2)
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    out = {"devices": ndev, "mode": "smoke" if smoke else "full",
           "configs": {}}

    with use_mesh(make_test_mesh(1, DEVICES)):
        for label, nt, e, cap, k, n in configs:
            desc = GroupedGemmDescriptor(
                t=nt * e * cap, k=k, n=n, num_experts=e,
                mesh=MeshSpec("model", DEVICES))
            chosen = plan_grouped(desc)
            x4 = jnp.asarray(rng.standard_normal((nt, e, cap, k)),
                             jnp.float32)
            w = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32)

            entry = {"nt": nt, "e": e, "cap": cap, "k": k, "n": n,
                     "planner_choice": chosen.comm}
            ys = {}
            for comm in ("gathered", "distributed"):
                # Pin the strategy: plan the LOCAL sub-problem it executes,
                # then re-attach the global mesh descriptor + strategy tag.
                pin = dataclasses.replace(
                    plan_grouped(mesh_local_desc(desc, comm)),
                    desc=desc, comm=comm)
                f = jax.jit(lambda x4, w, p=pin: engine.dispatch(
                    desc, x4, w, None, plan=p))
                before = {kk: vv for kk, vv in
                          engine.stats().get("grouped_gemm", {}).items()}
                us = time_fn(f, x4, w, iters=iters, warmup=warmup)
                after = engine.stats()["grouped_gemm"]
                ys[comm] = f(x4, w)
                # Trace-time counters: the jit traces the dispatch exactly
                # once across the whole timing loop, so the delta is the
                # per-step traffic of one traced call.
                entry[comm] = {
                    "us": round(us, 1),
                    "comm_bytes": after["comm_bytes"]
                    - before.get("comm_bytes", 0),
                    "collective_launches": after["collective_launches"]
                    - before.get("collective_launches", 0),
                    "launches_per_shard": after["launches"]
                    - before.get("launches", 0),
                }
                emit(f"mesh/{label}/{comm}", us,
                     f"comm_bytes={entry[comm]['comm_bytes']};"
                     f"collective_launches="
                     f"{entry[comm]['collective_launches']};"
                     f"launches_per_shard="
                     f"{entry[comm]['launches_per_shard']}")
            err = float(jnp.max(jnp.abs(ys["gathered"] - ys["distributed"])))
            entry["max_err"] = err
            entry["speedup_distributed"] = round(
                entry["gathered"]["us"] / entry["distributed"]["us"], 3)
            emit(f"mesh/{label}/choice", 0,
                 f"planner={chosen.comm};"
                 f"speedup_distributed={entry['speedup_distributed']};"
                 f"max_err={err:.1e}")
            out["configs"][label] = entry

    with open(MESH_JSON, "w") as fh:
        json.dump(out, fh, indent=1, sort_keys=True)
    emit("mesh/json", 0, f"wrote={MESH_JSON};devices={ndev}")


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child("--smoke" in sys.argv)
    else:
        run("--smoke" in sys.argv)
