"""Fig 4/5 analogue: alignment effects.

The paper shows ZA load/store bandwidth depends on 16/32/64/128-byte
alignment.  The TPU analogue is (8,128)-register-tile alignment of GEMM
operands: aligned shapes hit full-block fast paths, misaligned shapes pay
masked edge blocks ("mask", the predication analogue) or host-side
padding copies ("pad").  We report wall-clock per strategy and the
planner's utilization figure.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import GemmDescriptor, plan_gemm
from repro.kernels.gemm import gemm

CASES = [
    ("aligned", 256, 256),
    ("minus1", 255, 255),
    ("plus1", 257, 257),
    ("odd", 250, 170),
]
K = 256


def run():
    rng = np.random.default_rng(0)
    for name, m, n in CASES:
        a = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, n)), jnp.float32)
        d = GemmDescriptor(m=m, n=n, k=K)
        util = plan_gemm(d).utilization
        # Edge strategies are a property of the multi-launch lowering
        # (the fused path masks inherently, DESIGN.md §8) — pin
        # fused=False so mask-vs-pad compares what it claims to.
        for edge in ("mask", "pad"):
            f = jax.jit(lambda a, b, e=edge: gemm(a, b, edge=e,
                                                  fused=False))
            us = time_fn(f, a, b, iters=3, warmup=1)
            emit(f"fig45/{name}_{edge}", us,
                 f"m={m};n={n};planner_utilization={util:.3f}")
