"""Fig 2/3 analogue: data-movement strategies into the compute unit.

The paper compares direct ZA loads vs two-step (vector-register-staged)
loads and finds staging 2.6x faster.  The TPU analogue: per-element
("direct") access patterns vs block-staged VMEM movement.  On the CPU
host we measure wall-clock bandwidth of (a) a strided gather copy
("direct" anti-pattern), (b) a plain contiguous XLA copy, and (c) the
blocked Pallas transpose/copy kernels that stage through scratch tiles,
across working-set sizes.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.transpose import transpose

SIZES_KB = [64, 1024, 8192]


def run():
    rng = np.random.default_rng(0)
    for kb in SIZES_KB:
        n = kb * 1024 // 4
        side = int(np.sqrt(n))
        x = jnp.asarray(rng.standard_normal((side, side)), jnp.float32)
        nbytes = x.size * 4

        # (a) strided gather ("direct" anti-pattern: element-granular)
        idx = jnp.asarray(rng.permutation(side), jnp.int32)
        ga = jax.jit(lambda x, i: x[i])
        us = time_fn(ga, x, idx)
        emit(f"fig23/gather_rows_{kb}kb", us,
             f"gbps={2*nbytes/us/1e3:.2f}")

        # (b) contiguous copy (the hardware-friendly baseline)
        cp = jax.jit(lambda x: x + 0.0)
        us = time_fn(cp, x)
        emit(f"fig23/contiguous_copy_{kb}kb", us,
             f"gbps={2*nbytes/us/1e3:.2f}")

        # (c) blocked staged movement (pallas, scratch-tile two-step)
        for bt in (64, 256):
            if bt > side:
                continue
            tr = jax.jit(lambda x, bt=bt: transpose(x, bt=bt))
            us = time_fn(tr, x, iters=3, warmup=1)
            emit(f"fig23/staged_transpose_bt{bt}_{kb}kb", us,
                 f"gbps={2*nbytes/us/1e3:.3f};note=interpret_mode")
