"""Scheduled flash attention: fused single-launch vs dense-grid lowering.

The flash analogue of fig89's fused-vs-multi table (DESIGN.md §10): for
each attention shape the suite times the fused scheduled lowering (the
causal-aware tile table — fully-masked k-blocks dropped at plan time)
against the dense-grid lowering (masked tiles branched at run time) of
the *same* (block_q, block_k) plan, records traced launch counts and the
skipped-tile counts, and writes the whole table to
``BENCH_flash_fused.json`` so the perf trajectory is tracked across PRs
alongside ``BENCH_gemm_fused.json`` / ``BENCH_grouped_fused.json``.

``run(smoke=True)`` is the CI end-to-end exercise of the scheduled flash
path (reduced sizes/iterations, same code paths), wired into
``benchmarks/run.py --smoke``.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import FlashDescriptor, FlashPlan, engine, plan_flash
from repro.kernels.flash_attention import flash_attention

FLASH_JSON = "BENCH_flash_fused.json"

# (label, b, h, sq, d, causal, block) — the causal sweep is the
# acceptance series (sq in {128, 512, 2048}); one non-causal point for
# contrast.  Blocks are pinned below the sequence so the causal pruning
# is visible in the skipped-tile column (the planner would otherwise
# cover short sequences with one tile).
CASES = [
    ("causal_128", 2, 4, 128, 64, True, 64),
    ("causal_512", 2, 4, 512, 64, True, 128),
    ("causal_2048", 1, 2, 2048, 64, True, 128),
    ("dense_512", 2, 4, 512, 64, False, 128),
]
SMOKE_CASES = [
    ("causal_128", 1, 2, 128, 32, True, 32),
    ("causal_256", 1, 2, 256, 32, True, 64),
]


def _launches(fn) -> int:
    """Traced pallas_call launches one eager call emits (engine counter)."""
    before = engine.stats().get("flash_attention", {}).get("launches", 0)
    jax.block_until_ready(fn())
    return engine.stats()["flash_attention"]["launches"] - before


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    cases = SMOKE_CASES if smoke else CASES
    iters, warmup = (2, 1) if smoke else (3, 1)
    entries = {}
    for label, b, h, sq, d, causal, block in cases:
        q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
        desc = FlashDescriptor(batch_heads=b * h, sq=sq, sk=sq, d=d,
                               causal=causal)
        auto = plan_flash(desc)
        # pin the tiling so both lowerings walk the same (bq, bk) grid
        bq = bk = block
        sched = FlashPlan(desc, bq, bk).tile_schedule()
        skipped = sched.dense_tiles - sched.num_tiles

        ff = jax.jit(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, block_q=bq, block_k=bk, fused=True))
        fd = jax.jit(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, block_q=bq, block_k=bk, fused=False))
        us_f = time_fn(ff, q, k, v, iters=iters, warmup=warmup)
        us_d = time_fn(fd, q, k, v, iters=iters, warmup=warmup)
        lf = _launches(lambda: flash_attention(
            q, k, v, causal=causal, block_q=bq, block_k=bk, fused=True))
        ld = _launches(lambda: flash_attention(
            q, k, v, causal=causal, block_q=bq, block_k=bk, fused=False))
        err = float(jnp.max(jnp.abs(ff(q, k, v) - fd(q, k, v))))

        entries[label] = {
            "b": b, "h": h, "sq": sq, "d": d, "causal": causal,
            "block_q": bq, "block_k": bk,
            "tiles_walked": sched.num_tiles, "tiles_dense": sched.dense_tiles,
            "tiles_skipped": skipped,
            "fused_us": round(us_f, 1), "dense_us": round(us_d, 1),
            "delta_us": round(us_d - us_f, 1),
            "speedup": round(us_d / us_f, 3) if us_f else None,
            "launches_fused": lf, "launches_dense": ld,
            "plan_fused": auto.fused,
            "agreement_err": err,
        }
        emit(f"flash_fused/{label}", us_f,
             f"dense_us={us_d:.0f};delta_us={us_d - us_f:.0f};"
             f"tiles={sched.num_tiles}/{sched.dense_tiles};"
             f"launches_fused={lf};launches_dense={ld};"
             f"agreement_err={err:.1e}")

    with open(FLASH_JSON, "w") as f:
        json.dump({"mode": "smoke" if smoke else "full",
                   "entries": entries}, f, indent=1, sort_keys=True)
    emit("flash_fused/json", 0, f"wrote={FLASH_JSON};entries={len(entries)}")
