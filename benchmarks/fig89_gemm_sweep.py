"""Fig 8/9 analogue: small-GEMM sweep, engine vs vendor library.

Paper: M=N in [1..512], K=512; generated SME kernels vs Accelerate BLAS,
for B-transposed ("nt", Fig 8) and B-normal ("nn" requiring transposition
handling, Fig 9).  Here: the planned Pallas engine (interpret mode — the
correctness path) and the XLA ``dot_general`` baseline (the "vendor
library"), wall-clock on CPU, plus the planner's modeled v5e time.  For
"nn"-with-strided-B we additionally compare the fused in-kernel transpose
vs the two-pass scratch-panel transpose (§IV-C).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import GemmDescriptor, plan_gemm, matmul, backend
from repro.kernels.gemm import gemm
from repro.kernels.transpose import transpose

SIZES = [16, 64, 80, 128, 250, 512]
K = 512


def run():
    rng = np.random.default_rng(0)
    for layout in ("nt", "nn"):
        for mn in SIZES:
            a = jnp.asarray(rng.standard_normal((mn, K)), jnp.float32)
            bshape = (mn, K) if layout == "nt" else (K, mn)
            b = jnp.asarray(rng.standard_normal(bshape), jnp.float32)
            flops = 2 * mn * mn * K

            fx = jax.jit(lambda a, b, l=layout: matmul(
                a, b, layout=l, backend_override="xla"))
            us_x = time_fn(fx, a, b)

            fp = jax.jit(lambda a, b, l=layout: gemm(a, b, layout=l))
            us_p = time_fn(fp, a, b, iters=3, warmup=1)

            d = GemmDescriptor(m=mn, n=mn, k=K, layout=layout)
            model_us = plan_gemm(d).predicted_seconds() * 1e6
            emit(f"fig89/{layout}_{mn}", us_x,
                 f"xla_gflops={flops/us_x/1e3:.1f};"
                 f"pallas_interpret_us={us_p:.0f};"
                 f"planner_v5e_model_us={model_us:.2f}")

    # §IV-C: fused transpose vs two-pass panel transpose for strided B
    mn = 256
    a = jnp.asarray(rng.standard_normal((mn, K)), jnp.float32)
    b_nt = jnp.asarray(rng.standard_normal((mn, K)), jnp.float32)

    fused = jax.jit(lambda a, b: gemm(a, b, layout="nt"))
    two_pass = jax.jit(lambda a, b: gemm(a, transpose(b, bt=128),
                                         layout="nn"))
    us_f = time_fn(fused, a, b_nt, iters=3, warmup=1)
    us_t = time_fn(two_pass, a, b_nt, iters=3, warmup=1)
    err = float(jnp.max(jnp.abs(fused(a, b_nt) - two_pass(a, b_nt))))
    emit("fig9/fused_transpose_256", us_f, "strategy=in-kernel_contraction")
    emit("fig9/panel_transpose_256", us_t,
         f"strategy=scratch_panel;agreement_err={err:.1e}")
