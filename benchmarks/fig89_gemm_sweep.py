"""Fig 8/9 analogue: small-GEMM sweep, engine vs vendor library.

Paper: M=N in [1..512], K=512; generated SME kernels vs Accelerate BLAS,
for B-transposed ("nt", Fig 8) and B-normal ("nn" requiring transposition
handling, Fig 9).  Here: the planned Pallas engine (interpret mode — the
correctness path) and the XLA ``dot_general`` baseline (the "vendor
library"), wall-clock on CPU, plus the planner's modeled v5e time.  For
"nn"-with-strided-B we additionally compare the fused in-kernel transpose
vs the two-pass scratch-panel transpose (§IV-C).

Since the single-launch rework (DESIGN.md §8) the sweep also times the
fused lowering against the multi-launch lowering of the *same* plan and
reports per-call traced launch counts; the whole fused-vs-multi table is
written to ``BENCH_gemm_fused.json`` so the perf trajectory is tracked
across PRs.  ``run(smoke=True)`` is the CI end-to-end exercise of the
fused path (reduced sizes/iterations, same code paths).

Since the offline-refit loop (DESIGN.md §15) the sweep additionally:

  * writes every fused/multi winner into ``BENCH_tuning_cache.json`` —
    a real engine tuning cache, so CI can drive ``tools/tune.py refit``
    end-to-end on measured smoke data;
  * regresses the measured timings back onto the machine model
    (``repro.core.refit.fit_records``) and reports the analytical
    tier's fused-vs-multi **misrank count before vs after** the refit —
    the number the offline loop exists to reduce.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import GemmDescriptor, engine, plan_gemm, matmul, backend
from repro.core import refit as refit_lib
from repro.core.autotune import TuningCache
from repro.core.config import get_config as get_engine_config
from repro.kernels.gemm import gemm
from repro.kernels.transpose import transpose

SIZES = [16, 64, 80, 128, 250, 512]
SMOKE_SIZES = [16, 80]
K = 512
FUSED_JSON = "BENCH_gemm_fused.json"
TUNING_JSON = "BENCH_tuning_cache.json"


def _launches(fn) -> int:
    """Traced pallas_call launches one eager call emits (engine counter)."""
    before = engine.stats().get("gemm", {}).get("launches", 0)
    jax.block_until_ready(fn())
    return engine.stats()["gemm"]["launches"] - before


def _fused_vs_multi(label, plan, a, b, layout, iters, warmup, entries,
                    measured=None):
    """Time the fused vs multi-launch lowering of one plan; record both
    the wall-clock delta and the traced launch counts (DESIGN.md §8).
    ``measured`` collects ``(plan_variant, us)`` pairs for the refit
    stanza (DESIGN.md §15)."""
    ff = jax.jit(lambda a, b: gemm(a, b, layout=layout, plan=plan,
                                   fused=True))
    fm = jax.jit(lambda a, b: gemm(a, b, layout=layout, plan=plan,
                                   fused=False))
    us_f = time_fn(ff, a, b, iters=iters, warmup=warmup)
    us_m = time_fn(fm, a, b, iters=iters, warmup=warmup)
    if measured is not None:
        measured.append((dataclasses.replace(plan, fused=True), us_f))
        measured.append((dataclasses.replace(plan, fused=False), us_m))
    lf = _launches(lambda: gemm(a, b, layout=layout, plan=plan, fused=True))
    lm = _launches(lambda: gemm(a, b, layout=layout, plan=plan, fused=False))
    d = plan.desc
    entries[label] = {
        "m": d.m, "n": d.n, "k": d.k, "layout": layout,
        "fused_us": round(us_f, 1), "multi_us": round(us_m, 1),
        "delta_us": round(us_m - us_f, 1),
        "speedup": round(us_m / us_f, 3) if us_f else None,
        "launches_fused": lf, "launches_multi": lm,
        "regions": len(plan.regions),
        # The analytical planner's lowering choice for this shape — the
        # --smoke regression gate fails entries where the planner chose
        # fused but the measurement says multi wins by > 10%.
        "chosen_fused": bool(plan.fused),
    }
    emit(f"fig89_fused/{label}", us_f,
         f"multi_launch_us={us_m:.0f};delta_us={us_m - us_f:.0f};"
         f"regions={len(plan.regions)};"
         f"launches_fused={lf};launches_multi={lm}")


def _pairs(measured):
    """(fused_plan, multi_plan, fused_us, multi_us) per benchmark shape —
    ``measured`` interleaves the two lowerings of each plan."""
    for i in range(0, len(measured) - 1, 2):
        (pf, uf), (pm, um) = measured[i], measured[i + 1]
        yield pf, pm, uf, um


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    sizes = SMOKE_SIZES if smoke else SIZES
    iters, warmup = (2, 1) if smoke else (3, 1)
    fused_entries = {}
    measured = []
    for layout in ("nt", "nn"):
        for mn in sizes:
            a = jnp.asarray(rng.standard_normal((mn, K)), jnp.float32)
            bshape = (mn, K) if layout == "nt" else (K, mn)
            b = jnp.asarray(rng.standard_normal(bshape), jnp.float32)
            flops = 2 * mn * mn * K

            fx = jax.jit(lambda a, b, l=layout: matmul(
                a, b, layout=l, backend_override="xla"))
            us_x = time_fn(fx, a, b)

            fp = jax.jit(lambda a, b, l=layout: gemm(a, b, layout=l))
            us_p = time_fn(fp, a, b, iters=iters, warmup=warmup)

            d = GemmDescriptor(m=mn, n=mn, k=K, layout=layout)
            plan = plan_gemm(d)
            model_us = plan.predicted_seconds() * 1e6
            emit(f"fig89/{layout}_{mn}", us_x,
                 f"xla_gflops={flops/us_x/1e3:.1f};"
                 f"pallas_interpret_us={us_p:.0f};"
                 f"planner_v5e_model_us={model_us:.2f}")

            # Fused single-launch vs multi-launch lowering of the same
            # plan (DESIGN.md §8): wall-clock + traced launch counts.
            _fused_vs_multi(f"{layout}_{mn}", plan, a, b, layout,
                            iters, warmup, fused_entries, measured)

    # A genuinely multi-region plan (Fig 7 geometry scaled to the MXU):
    # the fused path collapses its per-region launches to exactly one.
    mn_h = 640
    plan = plan_gemm(GemmDescriptor(m=mn_h, n=mn_h, k=K),
                     force_block=(256, 256))
    assert len(plan.regions) > 1, "hetero benchmark point must be multi-region"
    a = jnp.asarray(rng.standard_normal((mn_h, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, mn_h)), jnp.float32)
    _fused_vs_multi(f"hetero_{mn_h}", plan, a, b, "nn",
                    iters, warmup, fused_entries, measured)

    # Measured winners -> a real tuning-cache file, so the CI smoke run
    # can exercise ``tools/tune.py refit`` on genuine timing data.
    machine = get_engine_config().machine
    if os.path.exists(TUNING_JSON):
        os.unlink(TUNING_JSON)  # a cache instance lazy-loads: start clean
    tcache = TuningCache(TUNING_JSON)
    for plan_f, plan_m, us_f, us_m in _pairs(measured):
        win, us = (plan_f, us_f) if us_f <= us_m else (plan_m, us_m)
        tcache.store(machine.tuning_key, win.desc, win, us, interpret=True)
    emit("fig89_refit/cache", 0,
         f"wrote={TUNING_JSON};entries={len(measured) // 2}")

    # Refit stanza (DESIGN.md §15): fit the model on BOTH lowerings'
    # measured times per shape, then score fused-vs-multi ranking before
    # vs after.  Reported, not hard-gated — wall-clock ranking on a
    # loaded CI host is noisy; the deterministic round-trip is asserted
    # in tests/test_warmstart.py instead.
    fit = refit_lib.fit_records(measured, machine)
    refit_machine = refit_lib.apply_fit(machine, {
        **fit, "fingerprint": "fig89-local"})
    rank_pairs = [(pf, pm, uf, um) for pf, pm, uf, um in _pairs(measured)]
    bad0, considered = refit_lib.count_misranks(rank_pairs, machine)
    bad1, _ = refit_lib.count_misranks(rank_pairs, refit_machine)
    refit_entry = {
        "entries_fit": fit["entries"],
        "fitted": fit["fitted"],
        "residual_us": fit["residual_us"],
        "misranks_before": bad0,
        "misranks_after": bad1,
        "pairs_considered": considered,
    }
    emit("fig89_refit/misranks", 0,
         f"before={bad0};after={bad1};considered={considered};"
         f"residual_before_us={fit['residual_us']['before']};"
         f"residual_after_us={fit['residual_us']['after']}")

    with open(FUSED_JSON, "w") as f:
        json.dump({"k": K, "mode": "smoke" if smoke else "full",
                   "entries": fused_entries, "refit": refit_entry},
                  f, indent=1, sort_keys=True)
    emit("fig89_fused/json", 0, f"wrote={FUSED_JSON};"
         f"entries={len(fused_entries)}")

    if smoke:
        return

    # §IV-C: fused transpose vs two-pass panel transpose for strided B
    mn = 256
    a = jnp.asarray(rng.standard_normal((mn, K)), jnp.float32)
    b_nt = jnp.asarray(rng.standard_normal((mn, K)), jnp.float32)

    fused = jax.jit(lambda a, b: gemm(a, b, layout="nt"))
    two_pass = jax.jit(lambda a, b: gemm(a, transpose(b, bt=128),
                                         layout="nn"))
    us_f = time_fn(fused, a, b_nt, iters=3, warmup=1)
    us_t = time_fn(two_pass, a, b_nt, iters=3, warmup=1)
    err = float(jnp.max(jnp.abs(fused(a, b_nt) - two_pass(a, b_nt))))
    emit("fig9/fused_transpose_256", us_f, "strategy=in-kernel_contraction")
    emit("fig9/panel_transpose_256", us_t,
         f"strategy=scratch_panel;agreement_err={err:.1e}")
