"""Train-step benchmark: fused-VJP vs reference-path autodiff (§11).

One optimization step (forward + backward + SGD update) of a small
causal LM whose mixers ARE the engine's scheduled families — flash
attention for sequence mixing, grouped GEMM for a static-routed expert
MLP — timed twice: once with the families' custom VJPs on the fused
path (each backward is ONE scheduled ``pallas_call``) and once under
``fused="off"`` (reference forward + reference-path autodiff).  Dense
projections are plain XLA in both variants so the delta isolates the
scheduled kernels.  Per-family gradient timings ride along, including
the SSD chunked scan — whose interpret-mode reverse walk loses to the
compiled ``lax.scan`` reference on CPU and is recorded honestly (the
fused win there is the launch-count / no-staged-state-materialization
story, not an interpret-mode wall-clock one).

Asserts the acceptance contract on the way through: every family
gradient is exactly one traced backward launch, and (at full size) the
causal flash backward walks strictly fewer tiles than the dense dKdV
grid.  Writes ``BENCH_train.json``; ``run(smoke=True)`` is the CI
variant (reduced sizes, same code paths), wired into
``benchmarks/run.py --smoke``.
"""
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import FlashBwdDescriptor, FlashDescriptor, engine, \
    plan_flash_bwd
from repro.core.config import use
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_gemm import grouped_gemm
from repro.kernels.ssd_chunk import ssd_chunk_scan

TRAIN_JSON = "BENCH_train.json"
VOCAB = 512
LR = 1e-2

# (seq, heads, head_dim, experts, d_ff, layers) — seq stays >= 1024 even
# in smoke: below that the reference path's rematerialized score /
# gathered-weight tensors still fit in cache and there is nothing for
# the schedule to win.  Full size is 2048 so the causal planner actually
# prunes (at <= 1024 one tile covers the whole walk).
LM_FULL = (2048, 2, 64, 8, 256, 1)
LM_SMOKE = (1024, 2, 64, 4, 256, 1)


# ---------------------------------------------------------------------------
# the model: embed -> [flash mixer + grouped-GEMM expert MLP] x L -> unembed
# ---------------------------------------------------------------------------

def _init_params(rng, seq, h, hd, e, dff, layers):
    dm = h * hd

    def g(*shape, scale=1.0):
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    blocks = [{"wqkv": g(dm, 3 * dm, scale=dm ** -0.5),
               "wo": g(dm, dm, scale=dm ** -0.5),
               "w_up": g(e, dm, dff, scale=dm ** -0.5),
               "w_dn": g(e, dff, dm, scale=dff ** -0.5)}
              for _ in range(layers)]
    return {"embed": g(VOCAB, dm, scale=1.0),
            "unembed": g(dm, VOCAB, scale=dm ** -0.5),
            "blocks": blocks}


def _forward(params, tokens, *, h, hd, group_sizes):
    seq = tokens.shape[0]
    dm = h * hd
    x = params["embed"][tokens]
    for blk in params["blocks"]:
        qkv = (x @ blk["wqkv"]).reshape(1, seq, 3, h, hd)
        q, k, v = (qkv[:, :, i] for i in range(3))
        a = flash_attention(q, k, v, causal=True)
        x = x + a.reshape(seq, dm) @ blk["wo"]
        # Static routing: tokens arrive pre-sorted by expert, so the MLP
        # is two scheduled grouped GEMMs over contiguous equal groups.
        mid = grouped_gemm(x, blk["w_up"], group_sizes, epilogue="gelu")
        x = x + grouped_gemm(mid, blk["w_dn"], group_sizes)
    return x @ params["unembed"]


def _loss(params, tokens, labels, *, h, hd, group_sizes):
    logits = _forward(params, tokens, h=h, hd=hd, group_sizes=group_sizes)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return jnp.mean(logz - gold)


def _make_step(h, hd, group_sizes):
    loss_fn = functools.partial(_loss, h=h, hd=hd, group_sizes=group_sizes)

    def step(params, tokens, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        new = jax.tree.map(lambda p, g: p - LR * g, params, grads)
        return loss, new

    return step


# ---------------------------------------------------------------------------
# per-family gradient micro-timings (fused VJP vs reference autodiff)
# ---------------------------------------------------------------------------

def _grad_pair(make_grad, args, family, iters, warmup):
    """(fused_us, ref_us, launches_bwd): times the same gradient under the
    default (fused) config and under fused="off", and counts the traced
    backward launches one fused gradient emits.  ``make_grad`` builds a
    FRESH function per variant — jax caches traces on function identity,
    and the config is read at trace time, so reusing one callable would
    silently time the fused executable twice."""
    before = engine.stats().get(family, {}).get("launches_bwd", 0)
    jax.block_until_ready(make_grad()(*args))
    launches_bwd = engine.stats()[family]["launches_bwd"] - before
    us_fused = time_fn(jax.jit(make_grad()), *args, iters=iters,
                       warmup=warmup)
    with use(fused="off"):
        us_ref = time_fn(jax.jit(make_grad()), *args, iters=iters,
                         warmup=warmup)
    return us_fused, us_ref, launches_bwd


def _family_cases(rng, smoke):
    sq, h, d = (1024, 2, 64) if smoke else (2048, 2, 64)
    t, k, n, e = (1024, 256, 256, 4) if smoke else (1024, 256, 256, 8)
    g_, nc, q_, n_, p_ = (2, 3, 32, 16, 32) if smoke else (2, 4, 64, 32, 64)

    def r(*shape, scale=1.0, dtype=jnp.float32):
        return jnp.asarray(rng.standard_normal(shape) * scale, dtype)

    qkv = [r(1, sq, h, d) for _ in range(3)]

    def flash_grad():
        return jax.grad(
            lambda q, k, v: jnp.sum(
                flash_attention(q, k, v, causal=True) ** 2),
            argnums=(0, 1, 2))

    gs = jnp.full((e,), t // e, jnp.int32)
    gx, gw = r(t, k), r(e, k, n, scale=0.3)

    def grouped_grad():
        return jax.grad(
            lambda x, w: jnp.sum(grouped_gemm(x, w, gs) ** 2),
            argnums=(0, 1))

    ssd_ops = (r(g_, nc, q_, n_, scale=0.5), r(g_, nc, q_, n_, scale=0.5),
               jnp.asarray(np.tril(np.exp(-np.abs(
                   rng.standard_normal((g_, nc, q_, q_))))), jnp.float32),
               r(g_, nc, q_, p_, scale=0.5),
               jnp.asarray(np.exp(-np.abs(
                   rng.standard_normal((g_, nc, q_)))), jnp.float32),
               jnp.asarray(np.exp(-np.abs(
                   rng.standard_normal((g_, nc, q_)))), jnp.float32),
               r(g_, p_, n_, scale=0.3))
    def ssd_grad():
        return jax.grad(
            lambda *o: jnp.sum(ssd_chunk_scan(*o)[0] ** 2),
            argnums=tuple(range(7)))

    return [
        ("grad_flash", "flash_attention", flash_grad, tuple(qkv),
         {"sq": sq, "h": h, "d": d}),
        ("grad_grouped", "grouped_gemm", grouped_grad, (gx, gw),
         {"tokens": t, "k": k, "n": n, "experts": e}),
        ("grad_ssd", "ssd_chunk", ssd_grad, ssd_ops,
         {"groups": g_, "chunks": nc, "q": q_, "n": n_, "p": p_}),
    ]


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    iters, warmup = (2, 1) if smoke else (3, 1)
    entries = {}

    # -- the train step ------------------------------------------------
    seq, h, hd, e, dff, layers = LM_SMOKE if smoke else LM_FULL
    group_sizes = jnp.full((e,), seq // e, jnp.int32)
    params = _init_params(rng, seq, h, hd, e, dff, layers)
    tokens = jnp.asarray(rng.integers(0, VOCAB, (seq,)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, VOCAB, (seq,)), jnp.int32)

    # acceptance: one eager step -> every family gradient is exactly ONE
    # traced backward launch per call site
    engine.reset_stats(entries=False)
    jax.block_until_ready(_make_step(h, hd, group_sizes)(
        params, tokens, labels))
    stats = engine.stats()
    assert stats["flash_attention"]["launches_bwd"] == layers, stats
    assert stats["grouped_gemm"]["launches_bwd"] == 2 * layers, stats

    # acceptance: the causal backward walk prunes the dense dKdV grid
    sched = plan_flash_bwd(FlashBwdDescriptor.from_forward(
        FlashDescriptor(batch_heads=h, sq=seq, sk=seq, d=hd,
                        causal=True))).tile_schedule()
    if not smoke:  # smoke seqs fit one tile; nothing to prune
        assert sched.num_tiles < sched.dense_tiles, \
            (sched.num_tiles, sched.dense_tiles)

    # fresh closure per variant — see _grad_pair on trace caching
    us_fused = time_fn(jax.jit(_make_step(h, hd, group_sizes)),
                       params, tokens, labels, iters=iters, warmup=warmup)
    with use(fused="off"):
        us_ref = time_fn(jax.jit(_make_step(h, hd, group_sizes)),
                         params, tokens, labels, iters=iters, warmup=warmup)
    entries["train_step"] = {
        "seq": seq, "d_model": h * hd, "heads": h, "experts": e,
        "d_ff": dff, "layers": layers, "vocab": VOCAB,
        "fused_us": round(us_fused, 1), "ref_us": round(us_ref, 1),
        "delta_us": round(us_ref - us_fused, 1),
        "speedup": round(us_ref / us_fused, 3) if us_fused else None,
        "launches_bwd_flash": stats["flash_attention"]["launches_bwd"],
        "launches_bwd_grouped": stats["grouped_gemm"]["launches_bwd"],
        "bwd_tiles_walked": sched.num_tiles,
        "bwd_tiles_dense": sched.dense_tiles,
    }
    emit("train_step/step", us_fused,
         f"ref_us={us_ref:.0f};speedup={us_ref / us_fused:.2f};"
         f"launches_bwd=flash:{stats['flash_attention']['launches_bwd']},"
         f"grouped:{stats['grouped_gemm']['launches_bwd']};"
         f"bwd_tiles={sched.num_tiles}/{sched.dense_tiles}")

    # -- per-family gradients ------------------------------------------
    for label, family, grad_fn, args, shape in _family_cases(rng, smoke):
        us_f, us_r, launches_bwd = _grad_pair(grad_fn, args, family,
                                              iters, warmup)
        entries[label] = {
            **shape, "fused_us": round(us_f, 1), "ref_us": round(us_r, 1),
            "delta_us": round(us_r - us_f, 1),
            "speedup": round(us_r / us_f, 3) if us_f else None,
            "launches_bwd": launches_bwd,
        }
        assert launches_bwd == 1, (label, launches_bwd)
        emit(f"train_step/{label}", us_f,
             f"ref_us={us_r:.0f};speedup={us_r / us_f:.2f};"
             f"launches_bwd={launches_bwd}")

    with open(TRAIN_JSON, "w") as f:
        json.dump({"mode": "smoke" if smoke else "full",
                   "entries": entries}, f, indent=1, sort_keys=True)
    emit("train_step/json", 0, f"wrote={TRAIN_JSON};entries={len(entries)}")


if __name__ == "__main__":
    run()
