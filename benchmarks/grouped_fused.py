"""Scheduled grouped GEMM: fused single-launch vs pad/scatter lowering.

The grouped-GEMM analogue of fig89's fused-vs-multi table (DESIGN.md §9):
for each MoE-shaped ragged dispatch the suite times the fused scheduled
lowering (runtime tile tables, direct ragged stores) against the
pad/scatter lowering (pad-to-``t_padded`` intermediate + gather-back) of
the *same* plan, records traced launch counts, and writes the whole table
to ``BENCH_grouped_fused.json`` so the perf trajectory is tracked across
PRs alongside ``BENCH_gemm_fused.json``.

``run(smoke=True)`` is the CI end-to-end exercise of the scheduled
grouped path (reduced sizes/iterations, same code paths), wired into
``benchmarks/run.py --smoke``.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import GroupedGemmDescriptor, engine, plan_grouped
from repro.kernels.grouped_gemm import grouped_gemm

GROUPED_JSON = "BENCH_grouped_fused.json"

# (label, group_sizes, extra rows past sum, K, N) — ragged MoE dispatch
# populations: balanced, skewed, zero-size experts, sum < T.
CASES = [
    ("balanced_8x64", [64] * 8, 0, 256, 512),
    ("skewed", [300, 5, 0, 150, 25, 32], 0, 256, 512),
    ("ragged_tail", [37, 0, 201, 70], 52, 192, 320),
]
SMOKE_CASES = [
    ("skewed", [60, 5, 0, 30], 0, 96, 128),
    ("ragged_tail", [17, 0, 41], 14, 96, 128),
]


def _launches(fn) -> int:
    """Traced pallas_call launches one eager call emits (engine counter)."""
    before = engine.stats().get("grouped_gemm", {}).get("launches", 0)
    jax.block_until_ready(fn())
    return engine.stats()["grouped_gemm"]["launches"] - before


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    cases = SMOKE_CASES if smoke else CASES
    iters, warmup = (2, 1) if smoke else (3, 1)
    entries = {}
    for label, sizes, t_extra, kdim, n in cases:
        sizes_a = jnp.asarray(sizes, jnp.int32)
        e = len(sizes)
        t = int(sizes_a.sum()) + t_extra
        x = jnp.asarray(rng.standard_normal((t, kdim)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((e, kdim, n)), jnp.float32)
        desc = GroupedGemmDescriptor(t=t, k=kdim, n=n, num_experts=e)
        plan = plan_grouped(desc)

        ff = jax.jit(lambda x, w, s: grouped_gemm(x, w, s, fused=True))
        fp = jax.jit(lambda x, w, s: grouped_gemm(x, w, s, fused=False))
        us_f = time_fn(ff, x, w, sizes_a, iters=iters, warmup=warmup)
        us_p = time_fn(fp, x, w, sizes_a, iters=iters, warmup=warmup)
        lf = _launches(lambda: grouped_gemm(x, w, sizes_a, fused=True))
        lp = _launches(lambda: grouped_gemm(x, w, sizes_a, fused=False))
        err = float(jnp.max(jnp.abs(ff(x, w, sizes_a) - fp(x, w, sizes_a))))

        entries[label] = {
            "t": t, "k": kdim, "n": n, "num_experts": e,
            "group_sizes": list(map(int, sizes)),
            "fused_us": round(us_f, 1), "padscatter_us": round(us_p, 1),
            "delta_us": round(us_p - us_f, 1),
            "speedup": round(us_p / us_f, 3) if us_f else None,
            "launches_fused": lf, "launches_padscatter": lp,
            "plan_fused": plan.fused,
            "agreement_err": err,
        }
        emit(f"grouped_fused/{label}", us_f,
             f"padscatter_us={us_p:.0f};delta_us={us_p - us_f:.0f};"
             f"launches_fused={lf};launches_padscatter={lp};"
             f"agreement_err={err:.1e}")

    with open(GROUPED_JSON, "w") as f:
        json.dump({"mode": "smoke" if smoke else "full",
                   "entries": entries}, f, indent=1, sort_keys=True)
    emit("grouped_fused/json", 0, f"wrote={GROUPED_JSON};"
         f"entries={len(entries)}")
