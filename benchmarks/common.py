"""Shared benchmark utilities: wall-clock timing + CSV emission."""
import time

import jax
import numpy as np


def time_fn(fn, *args, iters=5, warmup=2):
    """Median wall-clock microseconds per call (jit-compiled callable)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
