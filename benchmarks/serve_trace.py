"""Continuous-batching serving trace (DESIGN.md §12).

Drives the paged serving runtime (``repro.runtime.batching``) over a
fixed-seed Poisson request trace and records the serving headline
numbers: tokens/s, p50/p99 per-token latency, eviction count, and the
``engine.stats()`` proof that decode launches stay flat while the batch
churns (admissions, early finishes, evict/re-admit — all data, never a
retrace).  Two phases:

  * ``xla``    — the gather-formulation baseline (dense decode math on
                 the paged layout);
  * ``pallas`` — the engine's ``flash_decode`` family: ONE interpreted
                 ``pallas_call`` per decode step trace, walking the
                 runtime :class:`~repro.core.schedule.DecodeTileSchedule`
                 tables over live pages only.

Both phases check per-request greedy outputs token-identical to the
static-batch ``launch.serve.generate`` path before recording anything —
a wrong number is worse than no number.  Each phase also records the
scheduler's per-phase wall-clock breakdown (admission / prefill /
decode / eviction).

A third stanza measures **cold vs warm startup** (DESIGN.md §15): the
descriptor population seen by the main phases is saved as a manifest,
then first-token latency is timed on a fresh engine once cold and once
after ``ContinuousBatchingEngine.warmup`` — asserting (in smoke too)
that the warm serving phase performs zero autotune timings and zero
plan-cache misses.  Timings are recorded, only the invariants are
gated — wall-clock comparisons are machine-dependent.

Writes ``BENCH_serve.json``; ``run(smoke=True)`` is the CI variant
(smaller trace, same code paths), wired into ``benchmarks/run.py
--smoke``.
"""
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced_config
from repro.core import engine
from repro.core.config import use
from repro.launch.serve import generate
from repro.models import LanguageModel
from repro.models.attention import PageSpec
from repro.runtime.batching import (ContinuousBatchingEngine, Request,
                                    poisson_trace)

SERVE_JSON = "BENCH_serve.json"

# (num_requests, rate, prompt_lens, max_new, num_slots, pages, page, blocks)
TRACE_FULL = (10, 0.6, (8, 16), (4, 12), 4, 48, 8, 8)
TRACE_SMOKE = (4, 0.5, (6, 10), (3, 6), 3, 24, 8, 6)


def _run_phase(cfg, params, backend, trace_args, seed):
    n_req, rate, plens, mnew, slots, pages, psize, blocks = trace_args
    reqs = poisson_trace(num_requests=n_req, rate=rate, prompt_lens=plens,
                         max_new=mnew, vocab_size=cfg.vocab_size,
                         seed=seed)
    with use(backend=backend):
        engine.reset_stats(entries=False)
        serving = ContinuousBatchingEngine(
            cfg, params, num_slots=slots,
            spec=PageSpec(pages, psize, blocks))
        result = serving.run(reqs)
        # oracle gate: never record numbers for wrong tokens
        for r in reqs:
            want = np.asarray(generate(
                cfg, params, jnp.asarray(r.prompt)[None, :],
                r.max_new)["tokens"][0])
            assert np.array_equal(want, result["outputs"][r.rid]), \
                f"{backend}: rid={r.rid} diverged from the static path"
        st = engine.stats().get("flash_decode", {})
    m = result["metrics"]
    if backend == "pallas":
        # launches count traces, not executions: flat under churn
        assert 0 < st.get("launches", 0) <= 4, st
    return {
        "requests": m["requests"],
        "total_tokens": m["total_tokens"],
        "decode_steps": m["decode_steps"],
        "evictions": m["evictions"],
        "tokens_per_s": round(m["tokens_per_s"], 1),
        "p50_token_latency_ms": round(m["p50_token_latency_s"] * 1e3, 2),
        "p99_token_latency_ms": round(m["p99_token_latency_s"] * 1e3, 2),
        "flash_decode_launches": m["flash_decode_launches"],
        "phase_ms": {k: round(v * 1e3, 2)
                     for k, v in m["phase_seconds"].items()},
        "token_identical": True,
    }


def _startup_phase(cfg, params, trace_args, seed, manifest):
    """Cold-vs-warm first-token latency on a fresh serving engine.

    Cold: plan/kernel caches dropped, first request pays every trace and
    build.  Warm: same drop, then ``warmup`` over the manifest — the
    gated invariant is that the warm serving phase dispatches with ZERO
    autotune timings and ZERO plan-cache misses (DESIGN.md §15)."""
    _, _, plens, _, slots, pages, psize, blocks = trace_args
    rng = np.random.default_rng(seed + 7)
    L = int(np.atleast_1d(plens)[0])
    prompt = rng.integers(0, cfg.vocab_size, size=L).astype(np.int32)
    out = {}
    with use(backend="pallas"):
        for mode in ("cold", "warm"):
            engine.reset_stats(entries=True)
            serving = ContinuousBatchingEngine(
                cfg, params, num_slots=slots,
                spec=PageSpec(pages, psize, blocks))
            warm_s = 0.0
            if mode == "warm":
                w = serving.warmup(prompt_lens=[L], manifest=manifest)
                warm_s = w["seconds"]
                engine.reset_stats(entries=False)
            t0 = time.time()
            serving.submit(Request(rid=0, prompt=prompt, max_new=2))
            guard = 0
            while not serving.token_latencies and guard < 50:
                serving.step()
                guard += 1
            first = time.time() - t0
            stats = engine.stats()
            out[mode] = {
                "first_token_ms": round(first * 1e3, 2),
                "warmup_s": round(warm_s, 3),
                "autotune_timings": sum(
                    v for b in stats.values() for k, v in b.items()
                    if k.startswith("autotune_timings")),
                "plan_misses": sum(
                    v for b in stats.values() for k, v in b.items()
                    if k.startswith("plan_misses")),
            }
    assert out["warm"]["autotune_timings"] == 0, out
    assert out["warm"]["plan_misses"] == 0, out
    return out


def run(smoke: bool = False, seed: int = 0):
    trace = TRACE_SMOKE if smoke else TRACE_FULL
    cfg = reduced_config(get_config("qwen3-0.6b"))
    params = LanguageModel.init(jax.random.PRNGKey(0), cfg)

    entries = {"trace": {"num_requests": trace[0], "rate": trace[1],
                         "prompt_lens": [int(x)
                                         for x in np.atleast_1d(trace[2])],
                         "max_new": [int(x)
                                     for x in np.atleast_1d(trace[3])],
                         "num_slots": trace[4], "pages": trace[5],
                         "page_size": trace[6], "max_blocks": trace[7],
                         "seed": seed, "arch": cfg.name}}
    for backend in ("xla", "pallas"):
        r = _run_phase(cfg, params, backend, trace, seed)
        entries[backend] = r
        ph = r["phase_ms"]
        emit(f"serve_trace/{backend}", 0,
             f"tok_s={r['tokens_per_s']};p50_ms={r['p50_token_latency_ms']};"
             f"p99_ms={r['p99_token_latency_ms']};"
             f"evictions={r['evictions']};"
             f"decode_steps={r['decode_steps']};"
             f"launches={r['flash_decode_launches']};identical=1;"
             f"adm_ms={ph['admission']};pf_ms={ph['prefill']};"
             f"dec_ms={ph['decode']};evict_ms={ph['eviction']}")

    # Cold vs warm startup — AFTER the main phases so the descriptor
    # population they dispatched is the manifest (and so the launch-count
    # asserts above saw genuinely cold engines).
    fd, manifest = tempfile.mkstemp(suffix=".manifest.json")
    os.close(fd)
    try:
        engine.save_manifest(manifest)
        s = _startup_phase(cfg, params, trace, seed, manifest)
        entries["startup"] = s
        emit("serve_trace/startup", 0,
             f"cold_ms={s['cold']['first_token_ms']};"
             f"warm_ms={s['warm']['first_token_ms']};"
             f"warmup_s={s['warm']['warmup_s']};"
             f"warm_autotune={s['warm']['autotune_timings']};"
             f"warm_plan_misses={s['warm']['plan_misses']}")
    finally:
        os.unlink(manifest)

    with open(SERVE_JSON, "w") as f:
        json.dump({"mode": "smoke" if smoke else "full",
                   "entries": entries}, f, indent=1, sort_keys=True)
    emit("serve_trace/json", 0, f"wrote={SERVE_JSON};entries={len(entries)}")


if __name__ == "__main__":
    run()
