"""Data pipeline determinism + checkpoint fault-tolerance semantics."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLMDataset
from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


class TestData:
    def test_deterministic_across_instances(self):
        a = SyntheticLMDataset(1000, 32, 4, seed=7)
        b = SyntheticLMDataset(1000, 32, 4, seed=7)
        np.testing.assert_array_equal(a.host_batch(5)["tokens"],
                                      b.host_batch(5)["tokens"])

    def test_steps_differ(self):
        ds = SyntheticLMDataset(1000, 32, 4)
        assert not np.array_equal(ds.host_batch(0)["tokens"],
                                  ds.host_batch(1)["tokens"])

    def test_labels_are_shifted_continuation(self):
        ds = SyntheticLMDataset(1000, 32, 4)
        b = ds.host_batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_shard_slices_match_global(self):
        """Row-range materialization == slicing the full batch (the
        multi-host contract of make_global_batch)."""
        ds = SyntheticLMDataset(1000, 16, 8)
        full = ds._sample_rows(3, 0, 8)
        part = ds._sample_rows(3, 2, 3)
        np.testing.assert_array_equal(full[2:5], part)

    def test_bigram_structure_is_learnable(self):
        """Next token is always one of `branching` successors — entropy
        floor log(branching), far below log(vocab)."""
        ds = SyntheticLMDataset(1000, 64, 2, seed=1, branching=4)
        b = ds.host_batch(0)
        succ = ds._succ
        toks, labels = b["tokens"], b["labels"]
        ok = np.isin(labels.reshape(-1),
                     succ[toks.reshape(-1)].reshape(-1))
        # per-position membership: label[t] in successors of tokens[t]
        for i in range(toks.shape[0]):
            for t in range(toks.shape[1]):
                assert labels[i, t] in succ[toks[i, t]]


class TestCheckpoint:
    def make_tree(self, scale=1.0):
        return {"layer": {"w": jnp.full((4, 4), scale),
                          "b": jnp.arange(4, dtype=jnp.float32)},
                "step_scalars": [jnp.ones(()), jnp.zeros((2,))]}

    def test_roundtrip(self, tmp_path):
        tree = self.make_tree(2.0)
        save_checkpoint(str(tmp_path), 10, tree,
                        meta={"data_step": 10}, async_write=False)
        assert latest_step(str(tmp_path)) == 10
        restored, meta = restore_checkpoint(str(tmp_path), 10, tree)
        assert meta["data_step"] == 10
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(a, b)

    def test_retention(self, tmp_path):
        tree = self.make_tree()
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, tree, max_to_keep=2,
                            async_write=False)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert steps == [4, 5]

    def test_atomic_commit_no_tmp_left(self, tmp_path):
        save_checkpoint(str(tmp_path), 3, self.make_tree(),
                        async_write=False)
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))

    def test_manager_periodic_and_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=5, max_to_keep=2)
        tree = self.make_tree()
        saved = [s for s in range(12) if mgr.maybe_save(s, tree,
                                                        {"data_step": s})]
        mgr.wait()
        assert saved == [0, 5, 10]
        restored, meta = mgr.restore_latest(tree)
        assert meta["data_step"] == 10

    def test_restore_casts_dtype(self, tmp_path):
        tree = self.make_tree()
        save_checkpoint(str(tmp_path), 1, tree, async_write=False)
        target = jax.tree.map(lambda x: x.astype(jnp.bfloat16), tree)
        restored, _ = restore_checkpoint(str(tmp_path), 1, target)
        assert jax.tree.leaves(restored)[0].dtype == jnp.bfloat16
