"""Mesh-aware planning (DESIGN.md §14): comm-charged arbitration,
provenance, and 8-device expert-parallel execution.

In-process tests cover the pure model: MeshSpec validation and cache-key
participation, the per-shard local-descriptor / comm-event algebra, the
calibrated-vs-uncalibrated ``collective_seconds`` split with its ``+net``
fingerprint provenance, gathered-vs-distributed arbitration flips (with
config and with mesh size), the fused-ranking regressions the fig89
sweep caught, tuned-record round-trips carrying the strategy tag, the
``tuning_cache_preload`` warm-start tier, and the fleet-merge CLI.

The ``_MULTIDEV`` subprocess test runs the real thing: an 8-device mesh
(``--xla_force_host_platform_device_count=8`` must be set before jax
initialises, hence the subprocess) where gathered and distributed
lowerings of the same expert-parallel grouped GEMM must agree bit-for-bit
— including on ragged (partially-filled capacity) inputs — with engine
comm counters non-zero ONLY on the distributed path, gradients flowing
through the EP entry, and the MoE layer exact against the XLA oracle.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GemmDescriptor, GroupedGemmDescriptor,
                        MESH_STRATEGIES, MeshSpec, autotune, candidate_plans,
                        engine, matmul, mesh_comm_events, mesh_comm_seconds,
                        mesh_local_desc, plan_gemm, plan_grouped, use)
from repro.core.machine import CPU_HOST, TPU_V5E, MachineModel
from repro.core.microbench import (probe_all_gather, probe_all_to_all,
                                   probe_collective_latency, probe_psum)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RNG = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def fresh_engine():
    engine.reset_stats()
    yield
    engine.reset_stats()


# ---------------------------------------------------------------------------
# MeshSpec: validation + cache-key participation
# ---------------------------------------------------------------------------

def test_meshspec_validates():
    with pytest.raises(ValueError):
        MeshSpec(axis="", size=2)
    with pytest.raises(ValueError):
        MeshSpec(axis="model", size=0)


def test_descriptor_mesh_divisibility():
    with pytest.raises(ValueError):
        GemmDescriptor(m=8, n=100, k=8, mesh=MeshSpec("model", 8))
    with pytest.raises(ValueError):
        GroupedGemmDescriptor(t=64, k=8, n=8, num_experts=6,
                              mesh=MeshSpec("model", 4))
    with pytest.raises(ValueError):
        GroupedGemmDescriptor(t=66, k=8, n=8, num_experts=8,
                              mesh=MeshSpec("model", 4))


def test_mesh_participates_in_cache_key():
    base = GroupedGemmDescriptor(t=64, k=8, n=8, num_experts=8)
    m4 = dataclasses.replace(base, mesh=MeshSpec("model", 4))
    m8 = dataclasses.replace(base, mesh=MeshSpec("model", 8))
    keys = {base.cache_key(), m4.cache_key(), m8.cache_key()}
    assert len(keys) == 3, "mesh must key plans and kernels"


# ---------------------------------------------------------------------------
# Local-descriptor / comm-event algebra
# ---------------------------------------------------------------------------

def test_mesh_local_desc_grouped():
    d = GroupedGemmDescriptor(t=1024, k=64, n=32, num_experts=8,
                              mesh=MeshSpec("model", 4))
    g = mesh_local_desc(d, "gathered")
    assert (g.t, g.num_experts, g.mesh) == (256, 8, None)
    dd = mesh_local_desc(d, "distributed")
    assert (dd.t, dd.num_experts, dd.mesh) == (256, 2, None)
    with pytest.raises(ValueError):
        mesh_local_desc(d, "telepathy")


def test_mesh_local_desc_gemm():
    d = GemmDescriptor(m=64, n=256, k=32, mesh=MeshSpec("model", 4))
    assert mesh_local_desc(d, "gathered").n == 256
    assert mesh_local_desc(d, "distributed").n == 64
    assert mesh_local_desc(d, "gathered").mesh is None


def test_mesh_comm_events_bytes():
    s, e, t, k, n = 4, 8, 1024, 64, 32
    d = GroupedGemmDescriptor(t=t, k=k, n=n, num_experts=e,
                              mesh=MeshSpec("model", s))
    frac = (s - 1) / s
    (cg, bg), = mesh_comm_events(d, "gathered")
    assert cg == "all_gather" and bg == int(frac * e * k * n * 4)
    ev = mesh_comm_events(d, "distributed")
    assert [c for c, _ in ev] == ["all_to_all", "all_to_all"]
    assert ev[0][1] == int(frac * (t // s) * k * 4)
    assert ev[1][1] == int(frac * (t // s) * n * 4)
    # degenerate mesh: no wire traffic at all
    d1 = dataclasses.replace(d, mesh=MeshSpec("model", 1))
    assert mesh_comm_events(d1, "gathered") == ()


# ---------------------------------------------------------------------------
# Calibrated network model + provenance
# ---------------------------------------------------------------------------

def test_collective_seconds_uses_calibration():
    cal = dataclasses.replace(
        TPU_V5E, ici_bandwidth_gbps=100.0, collective_launch_s=2e-6,
        collective_efficiency={"all_gather": 1.0, "all_to_all": 0.5})
    nbytes = 1e8
    ag = cal.collective_seconds(nbytes, collective="all_gather")
    assert ag == pytest.approx(2e-6 + nbytes / 100e9)
    a2a = cal.collective_seconds(nbytes, collective="all_to_all")
    assert a2a == pytest.approx(2e-6 + nbytes / 50e9)
    # uncalibrated: pinned per-link napkin math, still finite + ranked
    un = TPU_V5E.collective_seconds(nbytes)
    assert un > 0 and TPU_V5E.network_calibrated is False


def test_net_provenance_in_fingerprint_and_tuning_key():
    cal = dataclasses.replace(CPU_HOST, ici_bandwidth_gbps=10.0)
    assert cal.fingerprint.endswith("+net")
    assert cal.tuning_key == CPU_HOST.name + "+net"
    assert not CPU_HOST.fingerprint.endswith("+net")
    assert CPU_HOST.tuning_key == CPU_HOST.name


def test_one_device_probes_report_uncalibrated():
    """On a 1-device host every interconnect probe must return an
    EXPLICIT 0.0 "(uncalibrated)" result — never be silently skipped —
    and ``from_probes`` must leave the network fields ``None``."""
    import jax
    if len(jax.devices()) > 1:
        pytest.skip("host unexpectedly multi-device")
    probes = {p.name: p for p in (probe_all_gather(), probe_all_to_all(),
                                  probe_psum(), probe_collective_latency())}
    assert set(probes) == {"all_gather_bw", "all_to_all_bw", "psum_bw",
                           "collective_latency"}
    for p in probes.values():
        assert p.value == 0.0 and "uncalibrated" in p.unit
    m = MachineModel.from_probes(probes, base=CPU_HOST, name="one_dev")
    assert m.ici_bandwidth_gbps is None and not m.network_calibrated
    assert m.tuning_key == "one_dev"


# ---------------------------------------------------------------------------
# Comm-charged arbitration (the §14 planner decision itself)
# ---------------------------------------------------------------------------

def _grouped_desc(nt, e, cap, k, n, s):
    return GroupedGemmDescriptor(t=nt * e * cap, k=k, n=n, num_experts=e,
                                 mesh=MeshSpec("model", s))


def test_arbitration_flips_with_config():
    # Big weight panels, few tokens: all-gathering E panels (and walking
    # all of them per shard) loses to the paired all_to_all.
    heavy_w = _grouped_desc(8, 8, 16, 512, 512, 8)
    assert plan_grouped(heavy_w).comm == "distributed"
    # Tiny panels, heavy token stream: moving activations twice costs
    # more wire time than one small weight all-gather.
    heavy_t = _grouped_desc(64, 8, 64, 64, 64, 8)
    assert plan_grouped(heavy_t).comm == "gathered"


def test_arbitration_flips_with_mesh_size():
    # Same global problem: a 2-way mesh gathers (the all_to_all payload
    # ~t/s dominates), an 8-way mesh distributes (payload shrinks 1/s^2
    # while the weight all-gather stays constant).
    small = _grouped_desc(64, 8, 16, 256, 256, 2)
    large = _grouped_desc(16, 8, 16, 256, 256, 8)
    assert plan_grouped(small).comm == "gathered"
    assert plan_grouped(large).comm == "distributed"


def test_plan_charges_comm_seconds():
    d = _grouped_desc(8, 8, 16, 256, 256, 8)
    for comm in MESH_STRATEGIES:
        pin = dataclasses.replace(plan_grouped(mesh_local_desc(d, comm)),
                                  desc=d, comm=comm)
        local = plan_grouped(mesh_local_desc(d, comm))
        assert pin.predicted_seconds() == pytest.approx(
            local.predicted_seconds() + mesh_comm_seconds(d, TPU_V5E, comm))


def test_candidate_plans_mesh_strategies():
    d = _grouped_desc(8, 8, 16, 256, 256, 8)
    cands = candidate_plans(d)
    assert [p.comm for p in cands] == list(MESH_STRATEGIES) or \
        {p.comm for p in cands} == set(MESH_STRATEGIES)
    assert len(cands) == 2
    # cheapest-first agrees with the family planner
    best = min(cands, key=lambda p: p.predicted_seconds())
    assert best.comm == plan_grouped(d).comm


def test_gemm_mesh_arbitration():
    # B column-sharded: gathered moves k*n weight bytes once, distributed
    # computes n/s locally and all-gathers the m*n output.  Tall-skinny
    # output (m << k) favors distributed; short-fat favors gathered.
    tall = GemmDescriptor(m=8, n=1024, k=4096, mesh=MeshSpec("model", 8))
    fat = GemmDescriptor(m=4096, n=1024, k=8, mesh=MeshSpec("model", 8))
    pt, pf = plan_gemm(tall), plan_gemm(fat)
    assert {pt.comm, pf.comm} == set(MESH_STRATEGIES)
    assert pt.comm == "distributed" and pf.comm == "gathered"


# ---------------------------------------------------------------------------
# Fused-ranking regressions (the fig89 smoke-gate shapes)
# ---------------------------------------------------------------------------

def test_multi_region_plans_rank_fused_vs_multi():
    """hetero_640 measured fused/multi = 0.85x: a multi-region cover's
    stitched fused walk must lose to per-region launches under the model
    too, while single-region fused keeps the paper's stance."""
    hetero = plan_gemm(GemmDescriptor(m=640, n=640, k=512),
                       force_block=(256, 256))
    assert len(hetero.regions) > 1 and hetero.fused is False
    multi = dataclasses.replace(hetero, fused=True)
    assert hetero.predicted_seconds() < multi.predicted_seconds()
    single = plan_gemm(GemmDescriptor(m=80, n=80, k=512))
    assert len(single.regions) == 1 and single.fused is True


# ---------------------------------------------------------------------------
# Tuned records + preload warm-start + fleet merge CLI
# ---------------------------------------------------------------------------

def test_plan_record_roundtrips_comm():
    d = _grouped_desc(8, 8, 16, 256, 256, 8)
    plan = plan_grouped(d)
    assert plan.comm in MESH_STRATEGIES
    rec = autotune.plan_to_record(plan)
    assert rec["comm"] == plan.comm
    back = autotune.plan_from_record(d, rec)
    assert back.comm == plan.comm
    assert (back.bm, back.bk, back.bn) == (plan.bm, plan.bk, plan.bn)


def test_tuning_cache_preload_serves_tier1(tmp_path):
    """A fleet-merged cache preloaded read-only must satisfy plans with
    zero autotune timings — the serving warm-start path (§14)."""
    path = str(tmp_path / "fleet.json")
    d = GemmDescriptor(m=80, n=80, k=64)
    pinned = plan_gemm(d, force_block=(8, 128), heterogeneous=False)
    autotune.TuningCache(path).store(TPU_V5E.tuning_key, d, pinned, 1.0,
                                     interpret=True)
    a = jnp.asarray(RNG.standard_normal((80, 64)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((64, 80)), jnp.float32)
    with use(backend="pallas", tuning_cache_preload=path):
        out = matmul(a, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)
    s = engine.stats()["gemm"]
    assert s["plan_source_tuned_cache"] == 1
    assert s["autotune_timings"] == 0


def test_tune_cli_merge_newest_wins(tmp_path):
    key = "v5e+net|compiled|('gemm',)"
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    a.write_text(json.dumps({"version": 1, "entries": {
        key: {"us": 10.0, "ts": 100.0},
        "v5e|compiled|('gemm', 2)": {"us": 5.0, "ts": 100.0}}}))
    b.write_text(json.dumps({"version": 1, "entries": {
        key: {"us": 8.0, "ts": 200.0}}}))
    out = tmp_path / "merged.json"
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "tune.py"),
         "merge", str(out), str(a), str(b)], capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    merged = json.loads(out.read_text())["entries"]
    assert len(merged) == 2 and merged[key]["us"] == 8.0
    # export filters by machine tuning-key prefix (+net kept separate)
    only = tmp_path / "net.json"
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "tune.py"), "export",
         str(out), str(only), "--machine", "v5e+net"],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert list(json.loads(only.read_text())["entries"]) == [key]


# ---------------------------------------------------------------------------
# 8-device execution (subprocess: forced host device count)
# ---------------------------------------------------------------------------

_MULTIDEV = r"""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GroupedGemmDescriptor, MeshSpec, engine,
                        mesh_local_desc, plan_grouped, use)
from repro.kernels.grouped_gemm import expert_parallel_grouped_gemm
from repro.kernels.grouped_gemm.ops import _ref_ep
from repro.launch.mesh import make_test_mesh
from repro.runtime.shardlib import use_mesh

assert len(jax.devices()) == 8, jax.devices()
rng = np.random.default_rng(0)
nt, e, cap, k, f = 8, 8, 16, 64, 96
x4 = jnp.asarray(rng.standard_normal((nt, e, cap, k)), jnp.float32)
# ragged occupancy: expert j fills only j+1 of its cap slots (zeros feed
# the kernel for the empty tail exactly like real dropped-token routing)
occ = (jnp.arange(cap)[None, :] <= jnp.arange(e)[:, None]).astype(jnp.float32)
x4 = x4 * occ[None, :, :, None]
w = jnp.asarray(rng.standard_normal((e, k, f)), jnp.float32)
desc = GroupedGemmDescriptor(t=nt * e * cap, k=k, n=f, num_experts=e,
                             mesh=MeshSpec("model", 8))
ref = _ref_ep(None, x4, w)

with use(backend="pallas", interpret=True), \
     use_mesh(make_test_mesh(1, 8)):
    # --- both pinned strategies bit-exact on the ragged input ----------
    for comm in ("gathered", "distributed"):
        pin = dataclasses.replace(plan_grouped(mesh_local_desc(desc, comm)),
                                  desc=desc, comm=comm)
        engine.reset_stats()
        y = engine.dispatch(desc, x4, w, None, plan=pin)
        err = float(jnp.max(jnp.abs(y - ref)))
        assert err == 0.0, (comm, err)
        s = engine.stats()["grouped_gemm"]
        assert s["launches"] == 1, (comm, s)  # fused single launch/shard
        if comm == "distributed":
            assert s["comm_bytes"] > 0 and s["collective_launches"] == 2, s
        else:
            assert s["comm_bytes"] == 0 and s["collective_launches"] == 0, s

    # --- planner selection flips across configs on THIS mesh -----------
    heavy_w = GroupedGemmDescriptor(t=8 * 8 * 16, k=512, n=512,
                                    num_experts=8, mesh=MeshSpec("model", 8))
    heavy_t = GroupedGemmDescriptor(t=64 * 8 * 64, k=64, n=64,
                                    num_experts=8, mesh=MeshSpec("model", 8))
    assert plan_grouped(heavy_w).comm == "distributed"
    assert plan_grouped(heavy_t).comm == "gathered"

    # --- EP entry point: autodiff flows (custom VJP over the oracle) ---
    def loss(w):
        return jnp.sum(expert_parallel_grouped_gemm(x4, w, axis="model"))
    g = jax.grad(loss)(w)
    g_ref = jax.grad(lambda w: jnp.sum(_ref_ep(None, x4, w)))(w)
    assert float(jnp.max(jnp.abs(g - g_ref))) < 1e-4

    # --- flagship consumer: MoE layer exact vs the XLA oracle ----------
    from repro.configs import get_config as model_config, reduced_config
    from repro.models.moe import moe_apply, moe_init
    cfg = reduced_config(model_config("phi3.5-moe-42b"), num_experts=8)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((8, 32, cfg.d_model)), jnp.float32)
    engine.reset_stats()
    y_mesh, aux_mesh = moe_apply(params, cfg, x)
    s = engine.stats()["grouped_gemm"]
    assert s["comm_bytes"] > 0 and s["collective_launches"] > 0, s
    assert s["launches"] == 3, s  # up/gate/down, one fused launch each

with use(backend="xla"):
    y_ref, aux_ref = moe_apply(params, cfg, x)
err = float(jnp.max(jnp.abs(y_mesh - y_ref)))
assert err < 1e-4, err
assert abs(float(aux_mesh) - float(aux_ref)) < 1e-5
print("MULTIDEV-OK")
"""


def test_eight_device_mesh_execution(tmp_path):
    script = tmp_path / "multidev.py"
    script.write_text(_MULTIDEV)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "MULTIDEV-OK" in r.stdout
