"""End-to-end system behaviour: training converges on structured data,
checkpoint/restart resumes exactly, fault injection is survived, and
serving generates coherently from a trained model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.data import SyntheticLMDataset
from repro.optim import adamw
from repro.runtime.steps import make_train_step, model_for
from repro.runtime.train_loop import (TrainLoopConfig, run_with_restarts,
                                      train)


def setup_job(tmp_path, arch="qwen3-0.6b", steps=30, vocab=128, seq=32,
              batch=8):
    cfg = reduced_config(get_config(arch), vocab_size=vocab)
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(5e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLMDataset(vocab, seq, batch, seed=5, branching=4)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in ds.host_batch(step).items()}

    loop = TrainLoopConfig(total_steps=steps, ckpt_dir=str(tmp_path),
                           save_every=10, log_every=1000)
    return cfg, params, opt_state, step_fn, batch_fn, loop, ds


def test_training_reduces_loss_toward_structure_floor(tmp_path):
    _, params, opt_state, step_fn, batch_fn, loop, ds = setup_job(
        tmp_path, steps=40)
    out = train(step_fn, params, opt_state, batch_fn, loop)
    first = out["metrics"][0]["nll"]
    last = out["metrics"][-1]["nll"]
    uniform = np.log(128)
    assert first > 0.8 * uniform  # starts near random
    assert last < first - 0.5     # clearly learning the bigram structure


def test_resume_from_checkpoint_is_exact(tmp_path):
    """Train 20 straight vs 10 + resume 10 — identical final params."""
    _, params, opt_state, step_fn, batch_fn, loop, _ = setup_job(
        tmp_path / "a", steps=20)
    loop.save_every = 100
    ref = train(step_fn, params, opt_state, batch_fn, loop)

    _, params2, opt2, step_fn2, batch_fn2, loop2, _ = setup_job(
        tmp_path / "b", steps=10)
    loop2.save_every = 10
    mid = train(step_fn2, params2, opt2, batch_fn2, loop2)
    loop3 = TrainLoopConfig(total_steps=20, ckpt_dir=str(tmp_path / "b"),
                            save_every=100)
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "b"))
    restored, meta = mgr.restore_latest(
        {"params": mid["params"], "opt_state": mid["opt_state"]})
    assert meta["data_step"] == 10
    out = train(step_fn2, restored["params"], restored["opt_state"],
                batch_fn2, loop3, start_step=10)

    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_supervisor_survives_fault_injection(tmp_path):
    """A simulated node failure at step 17 is survived via checkpoint
    restart, and training still completes all 30 steps."""
    _, params, opt_state, step_fn, batch_fn, loop, _ = setup_job(
        tmp_path, steps=30)
    fired = {"n": 0}

    def injector(step):
        if step == 17 and fired["n"] == 0:
            fired["n"] = 1
            raise RuntimeError("simulated node failure")

    out = run_with_restarts(lambda: (params, opt_state), step_fn, batch_fn,
                            loop, fault_injector=injector)
    assert out["final_step"] == 30
    assert out["restarts"] == 1
    assert fired["n"] == 1


def test_serving_generates_from_trained_model(tmp_path):
    """After training on the bigram stream, greedy decode emits tokens
    that are valid bigram successors far above chance."""
    cfg, params, opt_state, step_fn, batch_fn, loop, ds = setup_job(
        tmp_path, steps=60)
    out = train(step_fn, params, opt_state, batch_fn, loop)
    from repro.launch.serve import generate
    prompts = jnp.asarray(ds.host_batch(999)["tokens"][:4, :16])
    tokens = generate(cfg, out["params"], prompts, gen_steps=8)["tokens"]
    succ = ds._succ
    prev = np.asarray(prompts[:, -1])
    hits = total = 0
    toks = np.asarray(tokens)
    for i in range(toks.shape[0]):
        p = prev[i]
        for t in range(toks.shape[1]):
            hits += int(toks[i, t] in succ[p])
            total += 1
            p = toks[i, t]
    assert hits / total > 0.5  # chance level is branching/vocab = 3%
