"""Prefill + decode must reproduce the full forward pass exactly — the
serving-correctness invariant, across every stateful block family."""
import dataclasses
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.models import LanguageModel

CASES = [
    ("qwen3-0.6b", {}),                      # GQA + qk-norm + RoPE, tied
    ("starcoder2-15b", {}),                  # layernorm + bias
    ("recurrentgemma-9b", {}),               # RG-LRU + local attention
    ("mamba2-130m", {"ssm_chunk": 4, "d_model": 48, "ssm_head_dim": 8}),
    ("grok-1-314b", {"capacity_factor": 8.0}),   # MoE no-drop + softcaps
    ("phi3.5-moe-42b", {"capacity_factor": 8.0}),
    ("internvl2-1b", {}),                    # vision prefix
]


@pytest.mark.parametrize("arch,overrides", CASES)
def test_prefill_decode_matches_full(arch, overrides):
    cfg = reduced_config(get_config(arch), **overrides)
    params = LanguageModel.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 17  # deliberately not chunk-aligned
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    feats = None
    n_mod = 0
    if cfg.modality == "vision":
        n_mod = cfg.num_modality_tokens
        feats = jax.random.normal(jax.random.PRNGKey(2),
                                  (b, n_mod, cfg.modality_dim))
    full, _, _ = LanguageModel.apply(params, cfg, tokens,
                                     modality_feats=feats)
    cache = LanguageModel.init_cache(cfg, b, capacity=s + n_mod)
    pre, cache, _ = LanguageModel.apply(
        params, cfg, tokens[:, :-1], positions=jnp.arange(s - 1 + n_mod),
        cache=cache, modality_feats=feats)
    dec, cache, _ = LanguageModel.apply(
        params, cfg, tokens[:, -1:], positions=jnp.array([s - 1 + n_mod]),
        cache=cache)
    assert float(jnp.max(jnp.abs(full[:, :-1] - pre))) < 2e-4
    assert float(jnp.max(jnp.abs(full[:, -1:] - dec))) < 2e-4


def test_multi_token_decode_chain():
    """Token-by-token decode for 8 steps == teacher-forced forward."""
    cfg = reduced_config(get_config("qwen3-0.6b"))
    params = LanguageModel.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    full, _, _ = LanguageModel.apply(params, cfg, tokens)
    cache = LanguageModel.init_cache(cfg, b, capacity=s)
    prefix = 4
    _, cache, _ = LanguageModel.apply(params, cfg, tokens[:, :prefix],
                                      positions=jnp.arange(prefix),
                                      cache=cache)
    outs = []
    for t in range(prefix, s):
        logit, cache, _ = LanguageModel.apply(
            params, cfg, tokens[:, t:t + 1], positions=jnp.array([t]),
            cache=cache)
        outs.append(logit)
    got = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full[:, prefix:] - got))) < 2e-4


def test_ring_buffer_window_cache():
    """Local-attention cache is a ring buffer: capacity < sequence works
    and matches full forward (window semantics)."""
    cfg = reduced_config(get_config("recurrentgemma-9b"))
    assert cfg.attn_window == 16
    params = LanguageModel.init(jax.random.PRNGKey(0), cfg)
    b, s = 1, 40  # longer than window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    full, _, _ = LanguageModel.apply(params, cfg, tokens)
    cache = LanguageModel.init_cache(cfg, b, capacity=s)  # local capped at window
    _, cache, _ = LanguageModel.apply(params, cfg, tokens[:, :-1],
                                      positions=jnp.arange(s - 1), cache=cache)
    dec, _, _ = LanguageModel.apply(params, cfg, tokens[:, -1:],
                                    positions=jnp.array([s - 1]), cache=cache)
    assert float(jnp.max(jnp.abs(full[:, -1:] - dec))) < 2e-4
