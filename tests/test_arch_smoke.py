"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward and one train step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced_config
from repro.optim import adamw
from repro.runtime.steps import make_train_step, make_loss_fn, forward

ARCHS = list_configs()


def tiny_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.modality == "vision":
        batch["modality_feats"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_modality_tokens, cfg.modality_dim)),
            jnp.float32)
    elif cfg.encoder_decoder:
        batch["modality_feats"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.modality_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch))
    from repro.runtime.steps import model_for
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(cfg)
    logits, _, aux = forward(cfg, params, batch)
    b, s = batch["tokens"].shape
    exp_s = s + (cfg.num_modality_tokens if cfg.modality == "vision" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = reduced_config(get_config(arch))
    from repro.runtime.steps import model_for
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    batch = tiny_batch(cfg)
    new_params, new_opt, metrics = step_fn(params, opt_state, batch,
                                           jnp.zeros((), jnp.int32))
    assert float(metrics["loss"]) > 0
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_microbatched_step_matches_loss(arch):
    """mb=2 produces finite metrics and a loss close to mb=1 (same data)."""
    cfg = reduced_config(get_config(arch))
    from repro.runtime.steps import model_for
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(0.0)  # lr 0: isolate grads path
    opt_state = opt.init(params)
    batch = tiny_batch(cfg, b=4)
    s1 = jax.jit(make_train_step(cfg, opt))
    s2 = jax.jit(make_train_step(cfg, opt, microbatches=2))
    _, _, m1 = s1(params, opt_state, batch, jnp.zeros((), jnp.int32))
    _, _, m2 = s2(params, opt_state, batch, jnp.zeros((), jnp.int32))
    assert np.isfinite(float(m2["loss"]))
    # microbatch metrics come from the last microbatch; grad norms of the
    # mean grad should be in the same ballpark
    assert float(m2["grad_norm"]) < 10 * float(m1["grad_norm"]) + 1.0
