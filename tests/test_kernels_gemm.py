"""Pallas GEMM kernel vs pure-jnp oracle: shape/dtype/layout sweeps,
plus the fused-vs-multi-launch parity matrix (DESIGN.md §8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GemmDescriptor, engine, plan_gemm, backend, matmul
from repro.kernels.gemm import gemm, ref_gemm

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def tol_for(dtype):
    return 2e-2 if jnp.dtype(dtype) == jnp.bfloat16 else 1e-4


SHAPES = [
    (128, 128, 128),   # single aligned block
    (256, 256, 512),
    (80, 80, 512),     # paper Fig 7 shape
    (1, 128, 512),     # single-row GEMV-ish
    (7, 33, 100),      # fully ragged
    (513, 129, 257),   # off-by-one everywhere
    (512, 512, 64),    # shallow K
    (64, 1024, 128),
]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("layout", ["nn", "nt"])
def test_gemm_matches_oracle(m, n, k, layout):
    a = rand((m, k))
    b = rand((k, n) if layout == "nn" else (n, k))
    out = gemm(a, b, layout=layout)
    ref = ref_gemm(a, b, layout=layout)
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_dtypes(dtype):
    a, b = rand((96, 160), dtype), rand((160, 224), dtype)
    out = gemm(a, b)
    ref = ref_gemm(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol_for(dtype), rtol=tol_for(dtype))


@pytest.mark.parametrize("edge", ["mask", "pad"])
def test_edge_strategies_agree(edge):
    """Predication (mask) vs copy-based padding — identical results (§IV-B)."""
    a, b = rand((70, 90)), rand((90, 110))
    out = gemm(a, b, edge=edge)
    np.testing.assert_allclose(out, ref_gemm(a, b), atol=1e-4, rtol=1e-4)


def test_accumulate_beta1():
    """C += A@B semantics (the paper's GEMM form)."""
    a, b, c = rand((100, 64)), rand((64, 72)), rand((100, 72))
    out = gemm(a, b, c=c)
    np.testing.assert_allclose(out, ref_gemm(a, b, c=c), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("epilogue", ["bias", "gelu", "silu", "relu",
                                      "bias_gelu", "bias_silu"])
def test_epilogues(epilogue):
    a, b = rand((64, 96)), rand((96, 128))
    bias = rand((128,)) if "bias" in epilogue else None
    out = gemm(a, b, epilogue=epilogue, bias=bias)
    ref = ref_gemm(a, b, epilogue=epilogue, bias=bias)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_batched():
    a, b = rand((3, 40, 50)), rand((3, 50, 60))
    out = gemm(a, b)
    ref = ref_gemm(a, b)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_region_plan_execution_matches_fig7():
    """An 640x640 heterogeneous plan executes region-by-region and still
    produces the exact product."""
    d = GemmDescriptor(m=640, n=640, k=512)
    plan = plan_gemm(d, force_block=(256, 256))
    assert len(plan.regions) >= 3  # interior + strips (+ corner)
    a, b = rand((640, 512)), rand((512, 640))
    out = gemm(a, b, plan=plan)
    np.testing.assert_allclose(out, ref_gemm(a, b), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Fused single-launch execution (DESIGN.md §8): the fused path must be
# bit-identical to the multi-launch path — same bk chunking, same fp32
# accumulation order, masking instead of stitching.
# ---------------------------------------------------------------------------

PARITY_SHAPES = [
    (128, 128, 128),   # fully aligned
    (80, 80, 512),     # paper Fig 7 shape
    (70, 90, 130),     # M/N/K tails everywhere
    (128, 128, 100),   # K tail only
    (7, 33, 100),      # sub-register-tile
    (513, 129, 257),   # off-by-one everywhere
]


def assert_bit_identical(fused, multi):
    assert fused.dtype == multi.dtype and fused.shape == multi.shape
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(multi))


@pytest.mark.parametrize("m,n,k", PARITY_SHAPES)
@pytest.mark.parametrize("layout", ["nn", "nt"])
def test_fused_matches_multilaunch_bitwise(m, n, k, layout):
    a = rand((m, k))
    b = rand((k, n) if layout == "nn" else (n, k))
    fused = gemm(a, b, layout=layout, fused=True)
    multi = gemm(a, b, layout=layout, fused=False)
    assert_bit_identical(fused, multi)
    np.testing.assert_allclose(fused, ref_gemm(a, b, layout=layout),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("epilogue", [None, "bias", "gelu", "silu", "relu",
                                      "bias_gelu", "bias_silu"])
@pytest.mark.parametrize("accumulate", [False, True])
def test_fused_parity_epilogues(epilogue, accumulate):
    m, n, k = 70, 90, 130  # tails on every dim
    a, b = rand((m, k)), rand((k, n))
    c = rand((m, n)) if accumulate else None
    bias = rand((n,)) if epilogue and "bias" in epilogue else None
    fused = gemm(a, b, c=c, epilogue=epilogue, bias=bias, fused=True)
    multi = gemm(a, b, c=c, epilogue=epilogue, bias=bias, fused=False)
    assert_bit_identical(fused, multi)
    ref = ref_gemm(a, b, c=c, epilogue=epilogue, bias=bias)
    np.testing.assert_allclose(fused, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("layout", ["nn", "nt"])
@pytest.mark.parametrize("accumulate", [False, True])
def test_fused_parity_batched(layout, accumulate):
    """desc.batch rides as a leading grid dimension, not a vmap."""
    nb, m, n, k = 3, 40, 70, 50
    a = rand((nb, m, k))
    b = rand((nb, k, n) if layout == "nn" else (nb, n, k))
    c = rand((nb, m, n)) if accumulate else None
    fused = gemm(a, b, c=c, layout=layout, fused=True)
    multi = gemm(a, b, c=c, layout=layout, fused=False)
    assert_bit_identical(fused, multi)
    np.testing.assert_allclose(fused, ref_gemm(a, b, c=c, layout=layout),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_parity_dtypes(dtype):
    a, b = rand((96, 160), dtype), rand((160, 224), dtype)
    assert_bit_identical(gemm(a, b, fused=True), gemm(a, b, fused=False))


def test_multiregion_plan_is_single_launch():
    """Acceptance: a multi-region descriptor resolves to exactly ONE
    pallas_call on the fused path (engine.stats launch counter), and the
    result is bit-identical to the multi-launch lowering.  Since the
    fused-ranking fix (DESIGN.md §14) the planner itself prices the
    stitched fused walk against per-region launches and comes out
    ``fused=False`` on this cover — the measured fused/multi speedup here
    is < 1 — so the fused path is exercised by forcing the bit."""
    engine.reset_stats()
    d = GemmDescriptor(m=640, n=640, k=512)
    plan = plan_gemm(d, force_block=(256, 256))
    assert len(plan.regions) >= 3 and not plan.fused
    a, b = rand((640, 512)), rand((512, 640))
    fused = gemm(a, b, plan=plan, fused=True)
    assert engine.stats()["gemm"]["launches"] == 1
    multi = gemm(a, b, plan=plan, fused=False)
    assert engine.stats()["gemm"]["launches"] == 1 + len(plan.regions)
    assert_bit_identical(fused, multi)


def test_fused_schedule_matches_plan_regions():
    """The flattened schedule covers C exactly once and its windows stay
    inside the operand buffers (clamped two-step load/store)."""
    d = GemmDescriptor(m=513, n=129, k=257)
    sched = plan_gemm(d, force_block=(256, 128)).tile_schedule()
    sched.validate()
    assert sched.bk <= d.k
    assert sched.num_tiles >= len(plan_gemm(d, force_block=(256, 128)).regions)


def test_dispatcher_backends_agree():
    a, b = rand((64, 64)), rand((64, 64))
    with backend("xla"):
        x1 = matmul(a, b)
    with backend("pallas"):
        x2 = matmul(a, b)
    np.testing.assert_allclose(x1, x2, atol=1e-4, rtol=1e-4)


def test_jit_cache_hits():
    from repro.core import GLOBAL_KERNEL_CACHE
    GLOBAL_KERNEL_CACHE.clear()
    a, b = rand((32, 32)), rand((32, 32))
    gemm(a, b)
    h0, m0, _ = GLOBAL_KERNEL_CACHE.stats()
    gemm(a, b)  # same descriptor -> cache hit, no rebuild
    h1, m1, _ = GLOBAL_KERNEL_CACHE.stats()
    assert m1 == m0 and h1 > h0


def test_gradients_flow_through_xla_backend():
    a, b = rand((32, 48)), rand((48, 16))

    def f(a, b):
        with backend("xla"):
            return jnp.sum(matmul(a, b) ** 2)

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    ga_ref, gb_ref = jax.grad(
        lambda a, b: jnp.sum((a @ b) ** 2), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga, ga_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(gb, gb_ref, atol=1e-3, rtol=1e-3)
