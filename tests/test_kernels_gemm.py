"""Pallas GEMM kernel vs pure-jnp oracle: shape/dtype/layout sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GemmDescriptor, plan_gemm, backend, matmul
from repro.kernels.gemm import gemm, ref_gemm

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def tol_for(dtype):
    return 2e-2 if jnp.dtype(dtype) == jnp.bfloat16 else 1e-4


SHAPES = [
    (128, 128, 128),   # single aligned block
    (256, 256, 512),
    (80, 80, 512),     # paper Fig 7 shape
    (1, 128, 512),     # single-row GEMV-ish
    (7, 33, 100),      # fully ragged
    (513, 129, 257),   # off-by-one everywhere
    (512, 512, 64),    # shallow K
    (64, 1024, 128),
]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("layout", ["nn", "nt"])
def test_gemm_matches_oracle(m, n, k, layout):
    a = rand((m, k))
    b = rand((k, n) if layout == "nn" else (n, k))
    out = gemm(a, b, layout=layout)
    ref = ref_gemm(a, b, layout=layout)
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_dtypes(dtype):
    a, b = rand((96, 160), dtype), rand((160, 224), dtype)
    out = gemm(a, b)
    ref = ref_gemm(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol_for(dtype), rtol=tol_for(dtype))


@pytest.mark.parametrize("edge", ["mask", "pad"])
def test_edge_strategies_agree(edge):
    """Predication (mask) vs copy-based padding — identical results (§IV-B)."""
    a, b = rand((70, 90)), rand((90, 110))
    out = gemm(a, b, edge=edge)
    np.testing.assert_allclose(out, ref_gemm(a, b), atol=1e-4, rtol=1e-4)


def test_accumulate_beta1():
    """C += A@B semantics (the paper's GEMM form)."""
    a, b, c = rand((100, 64)), rand((64, 72)), rand((100, 72))
    out = gemm(a, b, c=c)
    np.testing.assert_allclose(out, ref_gemm(a, b, c=c), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("epilogue", ["bias", "gelu", "silu", "relu",
                                      "bias_gelu", "bias_silu"])
def test_epilogues(epilogue):
    a, b = rand((64, 96)), rand((96, 128))
    bias = rand((128,)) if "bias" in epilogue else None
    out = gemm(a, b, epilogue=epilogue, bias=bias)
    ref = ref_gemm(a, b, epilogue=epilogue, bias=bias)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_batched():
    a, b = rand((3, 40, 50)), rand((3, 50, 60))
    out = gemm(a, b)
    ref = ref_gemm(a, b)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_region_plan_execution_matches_fig7():
    """An 640x640 heterogeneous plan executes region-by-region and still
    produces the exact product."""
    d = GemmDescriptor(m=640, n=640, k=512)
    plan = plan_gemm(d, force_block=(256, 256))
    assert len(plan.regions) >= 3  # interior + strips (+ corner)
    a, b = rand((640, 512)), rand((512, 640))
    out = gemm(a, b, plan=plan)
    np.testing.assert_allclose(out, ref_gemm(a, b), atol=1e-3, rtol=1e-3)


def test_dispatcher_backends_agree():
    a, b = rand((64, 64)), rand((64, 64))
    with backend("xla"):
        x1 = matmul(a, b)
    with backend("pallas"):
        x2 = matmul(a, b)
    np.testing.assert_allclose(x1, x2, atol=1e-4, rtol=1e-4)


def test_jit_cache_hits():
    from repro.core import GLOBAL_KERNEL_CACHE
    GLOBAL_KERNEL_CACHE.clear()
    a, b = rand((32, 32)), rand((32, 32))
    gemm(a, b)
    h0, m0, _ = GLOBAL_KERNEL_CACHE.stats()
    gemm(a, b)  # same descriptor -> cache hit, no rebuild
    h1, m1, _ = GLOBAL_KERNEL_CACHE.stats()
    assert m1 == m0 and h1 > h0


def test_gradients_flow_through_xla_backend():
    a, b = rand((32, 48)), rand((48, 16))

    def f(a, b):
        with backend("xla"):
            return jnp.sum(matmul(a, b) ** 2)

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    ga_ref, gb_ref = jax.grad(
        lambda a, b: jnp.sum((a @ b) ** 2), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga, ga_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(gb, gb_ref, atol=1e-3, rtol=1e-3)
