"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, scalable_adamw, warmup_cosine
from repro.optim.adamw import clip_by_global_norm, global_norm
from repro.optim.compression import (error_feedback_compress,
                                     compressed_psum, _quantize_int8,
                                     _dequantize_int8)


def quadratic_loss(params):
    return sum(jnp.sum(jnp.square(p - 3.0)) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(0.1),
    lambda: scalable_adamw(0.1),
    lambda: scalable_adamw(0.1, use_momentum=False),
])
def test_optimizer_converges_on_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.zeros((256, 256)), "b": jnp.zeros((256,))}
    state = opt.init(params)
    loss0 = float(quadratic_loss(params))
    for step in range(60):
        grads = jax.grad(quadratic_loss)(params)
        params, state, _ = opt.update(grads, state, params,
                                      jnp.asarray(step))
    assert float(quadratic_loss(params)) < 0.2 * loss0


def test_scalable_adamw_factored_state_is_small():
    opt = scalable_adamw(1e-3, use_momentum=False)
    params = {"w": jnp.zeros((512, 1024))}
    state = opt.init(params)
    v = state["v"]["w"]
    assert set(v) == {"r", "c"}
    assert v["r"].shape == (512,) and v["c"].shape == (1024,)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state < 0.01 * params["w"].size


def test_clip_preserves_dtype_and_norm():
    grads = {"a": jnp.full((8,), 100.0, jnp.bfloat16)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert clipped["a"].dtype == jnp.bfloat16
    assert abs(float(global_norm(clipped)) - 1.0) < 0.05


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 100, 1000)
    assert float(lr(jnp.asarray(0))) < 1e-4
    assert abs(float(lr(jnp.asarray(100))) - 1e-3) < 1e-4
    assert float(lr(jnp.asarray(999))) < 2.1e-4


def test_int8_quantization_roundtrip_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, scale = _quantize_int8(x)
    deq = _dequantize_int8(q, scale, x.shape)
    # block-symmetric int8: error bounded by scale/2 per block
    err = np.abs(np.asarray(deq - x))
    bound = np.repeat(np.asarray(scale)[:, 0], 256)[:1000] * 0.51
    assert (err <= bound + 1e-6).all()


def test_error_feedback_residual_corrects():
    """Error feedback: sum of applied grads converges to sum of true grads
    (residual stays bounded)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(512),
                          jnp.float32)}
    res = None
    applied = jnp.zeros(512)
    for _ in range(20):
        out, res = error_feedback_compress(g, res)
        applied = applied + out["w"]
    total_true = 20 * g["w"]
    rel = float(jnp.linalg.norm(applied - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 0.02


def test_compressed_psum_single_device():
    from jax.sharding import Mesh
    import jax
    mesh_devices = np.array(jax.devices()[:1])
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = Mesh(mesh_devices, ("pod",))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((8, 16)),
                    jnp.float32)

    def f(x):
        return compressed_psum(x, "pod")

    out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(x)
    np.testing.assert_allclose(out, x, atol=0.05, rtol=0.05)
