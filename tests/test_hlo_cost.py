"""Trip-count-aware HLO cost walker: validated against analytic FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze, parse_module, _trip_count


def test_scan_flops_exact():
    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.ones((128, 256), jnp.float32)
    ws = jnp.ones((7, 256, 256), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    rec = analyze(compiled.as_text())
    assert rec["flops"] == 7 * 2 * 128 * 256 * 256


def test_nested_scan_flops_exact():
    def f(x, ws):
        def outer(c, _):
            def inner(c2, w):
                return jnp.dot(c2, w), None
            c2, _ = jax.lax.scan(inner, c, ws)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jnp.ones((64, 64), jnp.float32)
    ws = jnp.ones((5, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    rec = analyze(compiled.as_text())
    assert rec["flops"] == 3 * 5 * 2 * 64 * 64 * 64


def test_unrolled_matches_module_cost_analysis():
    def g(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    x = jnp.ones((32, 32), jnp.float32)
    w = jnp.ones((32, 32), jnp.float32)
    compiled = jax.jit(g).lower(x, w).compile()
    rec = analyze(compiled.as_text())
    ca = compiled.cost_analysis()
    # older jax returns a one-element list of per-device dicts
    xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    # dots dominate; walker counts only dots, XLA adds elementwise
    assert rec["flops"] <= xla
    assert rec["flops"] >= 4 * 2 * 32 * 32 * 32


def test_collectives_counted_with_multiplier():
    """Collective inside a scan counts trip-count times."""
    import os
    # This test runs on 1 device: use psum over a trivial axis via pjit is
    # not available; instead verify the parser on a synthetic HLO snippet.
    hlo = """
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4] get-tuple-element(%p), index=1
  %ar = f32[4]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4]) tuple(%ni, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[4]) -> (s32[], f32[4]) {
  %x = f32[4] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%z, %x)
  ROOT %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
}
"""
    rec = analyze(hlo)
    assert rec["collectives"]["all-reduce"]["count"] == 6
    assert rec["collectives"]["all-reduce"]["bytes"] == 6 * 16


def test_trip_count_parse():
    comps, entry = parse_module("""
HloModule m

%c (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(28)
  ROOT %r = pred[] compare(%i, %k), direction=LT
}

ENTRY %e (x: f32[2]) -> f32[2] {
  ROOT %x = f32[2] parameter(0)
}
""")
    assert _trip_count(comps["c"]) == 28
