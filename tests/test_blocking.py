"""Planner unit + property tests (§IV-B reproduction invariants).

``hypothesis`` is an optional test extra (see pyproject.toml): when
absent, the property tests degrade to a small deterministic case sweep
instead of erroring at collection.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import GemmDescriptor, fused_legal, plan_gemm, palette
from repro.core.blocking import Region, ceil_div
from repro.core.machine import TPU_V5E


def desc(m, n, k, **kw):
    return GemmDescriptor(m=m, n=n, k=k, **kw)


class TestPalette:
    def test_full_budget_shapes_mirror_paper(self):
        """The full-budget palette is {square, wide, tall} — the 32x32 /
        16x64 / 64x16 analogue."""
        full = [(bm, bn) for bm, bn in palette() if bm * bn == 256 * 256]
        assert (256, 256) in full
        assert (128, 512) in full
        assert (512, 128) in full

    def test_alignment(self):
        sub, lane = TPU_V5E.reg_tile("float32")
        for bm, bn in palette():
            assert bm % sub == 0 and bn % lane == 0

    def test_square_has_best_reuse(self):
        """Paper's loads-per-update argument: among equal-budget blockings
        the square one loads fewest inputs per accumulator update."""
        full = [(bm, bn) for bm, bn in palette() if bm * bn == 256 * 256]
        best = min(full, key=lambda s: s[0] + s[1])
        assert best == (256, 256)


class TestPlans:
    def test_aligned_problem_is_homogeneous(self):
        plan = plan_gemm(desc(1024, 1024, 1024))
        assert len(plan.regions) == 1
        assert plan.utilization == 1.0

    def test_ragged_problem_covers_exactly(self):
        plan = plan_gemm(desc(300, 500, 128))
        plan.validate()

    def test_heterogeneous_beats_homogeneous_on_fig7_shape(self):
        """80x80-style shape (scaled to TPU granularity: 640x640 with
        256-blocks) needs fewer microkernels heterogeneously."""
        d = desc(640, 640, 512)
        het = plan_gemm(d, heterogeneous=True)
        hom = plan_gemm(d, heterogeneous=False, force_block=(256, 256))
        assert het.num_microkernels <= hom.num_microkernels
        assert het.utilization >= hom.utilization

    def test_force_block(self):
        plan = plan_gemm(desc(512, 512, 512), force_block=(128, 512),
                         heterogeneous=False)
        assert plan.regions[0].bm == 128 and plan.regions[0].bn == 512

    def test_tiny_problem(self):
        plan = plan_gemm(desc(1, 1, 1))
        plan.validate()
        assert plan.num_microkernels == 1

    def test_bk_fits_vmem(self):
        plan = plan_gemm(desc(4096, 4096, 8192))
        for r in plan.regions:
            acc = r.bm * r.bn * 4
            inputs = 2 * 4 * plan.bk * (r.bm + r.bn)
            assert acc + inputs <= TPU_V5E.vmem_bytes


class TestTileSchedule:
    """Flattened fused-execution schedules (DESIGN.md §8)."""

    def test_heterogeneous_schedule_covers_exactly_once(self):
        plan = plan_gemm(GemmDescriptor(m=640, n=640, k=512),
                         force_block=(256, 256))
        assert len(plan.regions) >= 3
        sched = plan.tile_schedule()
        sched.validate()  # exact cover + in-bounds clamped windows
        assert len(sched.blocks) >= 2  # heterogeneous geometry survives

    def test_blocks_clamped_to_matrix(self):
        """A region block larger than the matrix clamps so its fixed-shape
        window fits the real operand buffers."""
        d = GemmDescriptor(m=7, n=33, k=100)
        sched = plan_gemm(d, force_block=(512, 1024),
                          heterogeneous=False).tile_schedule()
        sched.validate()
        assert all(bm <= 7 and bn <= 33 for bm, bn in sched.blocks)

    def test_bk_clamped_to_k(self):
        d = GemmDescriptor(m=128, n=128, k=100)
        sched = plan_gemm(d).tile_schedule()
        assert sched.bk <= 100
        assert sched.k_steps == ceil_div(100, sched.bk)

    def test_aligned_single_region_single_tile(self):
        sched = plan_gemm(GemmDescriptor(m=256, n=256, k=256),
                          force_block=(256, 256),
                          heterogeneous=False).tile_schedule()
        assert sched.num_tiles == 1 and sched.blocks == ((256, 256),)

    def test_fused_legality_gates_plan_bit(self):
        small = GemmDescriptor(m=128, n=128, k=128)
        assert fused_legal(small, TPU_V5E)
        assert plan_gemm(small).fused
        huge = GemmDescriptor(m=8192, n=8192, k=8192)
        assert not fused_legal(huge, TPU_V5E)  # operands exceed VMEM
        assert not plan_gemm(huge).fused

    @pytest.mark.parametrize("m,n,k,force", [
        (128, 128, 512, None),         # BENCH_gemm_fused nn_128: 0.79x fused
        (640, 640, 512, (256, 256)),   # BENCH_gemm_fused hetero_640: 0.82x
    ])
    def test_cost_model_ranks_multi_first_on_measured_loss_shapes(
            self, m, n, k, force):
        """Regression for the analytical-tier fused misranking: on the
        BENCH_gemm_fused.json shapes where fused measured *slower* (nn_128
        at 0.79x, hetero_640 at 0.82x) the recalibrated cost model — fused
        pays per-step tile-table decode plus the RMW output re-read; the
        multi-launch dispatch/stitch charges are discounted to measured
        levels — must rank the multi-launch lowering first.  The planner's
        ``fused`` bit stays legality-gated (see
        test_fused_legality_gates_plan_bit); only the candidate ranking
        changes."""
        import dataclasses
        plan = plan_gemm(GemmDescriptor(m=m, n=n, k=k), force_block=force)
        multi = dataclasses.replace(plan, fused=False)
        fused = dataclasses.replace(plan, fused=True)
        assert multi.predicted_seconds() < fused.predicted_seconds()


# Deterministic fallback cases exercised when hypothesis is unavailable —
# chosen to cover the planner's branch space (aligned, ragged, strip-only,
# tiny, deep-K).
_FALLBACK_MNK = [(1, 1, 1), (7, 33, 100), (128, 128, 128), (300, 500, 128),
                 (513, 129, 257), (2048, 1024, 4096), (80, 80, 512),
                 (1, 2048, 64), (2048, 1, 64)]


def _check_plan_cover(m, n, k):
    """Property: every plan covers C exactly once with in-bounds regions,
    positive utilization, and microkernel count >= ceil-div lower bound."""
    plan = plan_gemm(desc(m, n, k))
    plan.validate()
    assert 0.0 < plan.utilization <= 1.0
    lower = ceil_div(m, 512) * ceil_div(n, 1024)
    assert plan.num_microkernels >= 1
    assert plan.num_microkernels >= lower


def _check_heterogeneous_never_worse(m, n):
    d = desc(m, n, 512)
    het = plan_gemm(d, heterogeneous=True)
    hom = plan_gemm(d, heterogeneous=False)
    assert het.predicted_seconds() <= hom.predicted_seconds() * 1.0001


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(m=st.integers(1, 2048), n=st.integers(1, 2048),
           k=st.integers(1, 4096))
    def test_plan_cover_properties(m, n, k):
        _check_plan_cover(m, n, k)

    @settings(max_examples=100, deadline=None)
    @given(m=st.integers(1, 1024), n=st.integers(1, 1024))
    def test_heterogeneous_never_worse_predicted(m, n):
        _check_heterogeneous_never_worse(m, n)
else:
    @pytest.mark.parametrize("m,n,k", _FALLBACK_MNK)
    def test_plan_cover_properties(m, n, k):
        _check_plan_cover(m, n, k)

    @pytest.mark.parametrize("m,n", [(mm, nn) for mm, nn, _ in _FALLBACK_MNK])
    def test_heterogeneous_never_worse_predicted(m, n):
        _check_heterogeneous_never_worse(m, n)


def test_hetero_640_multi_region_prefers_multi_launch():
    """Guard for the fig89 ``hetero_640`` benchmark point (DESIGN.md §15):
    the forced 256x256 blocking of a 640x640x512 GEMM must stay genuinely
    multi-region, and on the default v5e model the planner must keep
    choosing the multi-launch lowering for it — the fused variant pays
    per-tile decode over 4 regions that the model prices above the extra
    launches.  If a machine-model change flips this ranking, the
    benchmark's misrank baseline moves and this fails loudly."""
    import dataclasses

    plan = plan_gemm(GemmDescriptor(m=640, n=640, k=512),
                     force_block=(256, 256))
    assert len(plan.regions) > 1
    assert plan.fused is False
    fused_s = dataclasses.replace(plan, fused=True).predicted_seconds()
    multi_s = dataclasses.replace(plan, fused=False).predicted_seconds()
    assert fused_s > multi_s
