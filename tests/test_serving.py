"""Continuous-batching serving oracle (DESIGN.md §12): the churning
paged-cache runtime must emit exactly the greedy tokens the static-batch
path emits, per sequence — across staggered arrivals, early finishes and
evict/re-admit cycles — and its fault paths must queue, free, and no-op
instead of crashing.  Mirrors tests/test_decode_consistency.py at the
request level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import engine
from repro.core.config import use
from repro.launch.serve import generate
from repro.models import LanguageModel
from repro.models.attention import PageSpec
from repro.runtime.batching import (ContinuousBatchingEngine, Request,
                                    poisson_trace)

# One arch per stateful block family (MoE archs excluded: expert routing
# is batch-composition-dependent, so per-sequence token identity is not
# a property they promise).
CASES = [
    ("qwen3-0.6b", {}),        # GQA + qk-norm + RoPE (paged KV)
    ("recurrentgemma-9b", {}),  # RG-LRU + local ring + paged KV
    ("mamba2-130m", {"ssm_chunk": 4, "d_model": 48, "ssm_head_dim": 8}),
]


def _setup(arch, overrides):
    cfg = reduced_config(get_config(arch), **overrides)
    params = LanguageModel.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _static_tokens(cfg, params, req):
    out = generate(cfg, params, jnp.asarray(req.prompt)[None, :],
                   req.max_new)
    return np.asarray(out["tokens"][0])


def _assert_identical(cfg, params, reqs, result):
    for r in reqs:
        want = _static_tokens(cfg, params, r)
        got = result["outputs"][r.rid]
        assert np.array_equal(want, got), (
            f"rid={r.rid} diverged: static={want.tolist()} "
            f"continuous={got.tolist()}")


@pytest.mark.parametrize("arch,overrides", CASES)
def test_continuous_matches_static_staggered(arch, overrides):
    """Poisson-staggered arrivals with uneven max_new (early finishes):
    every request's greedy stream equals its solo static-path run."""
    cfg, params = _setup(arch, overrides)
    reqs = poisson_trace(num_requests=5, rate=0.5, prompt_lens=(6, 12),
                         max_new=(2, 7), vocab_size=cfg.vocab_size, seed=3)
    serving = ContinuousBatchingEngine(
        cfg, params, num_slots=3, spec=PageSpec(24, 8, 6))
    result = serving.run(reqs)
    assert result["metrics"]["requests"] == len(reqs)
    _assert_identical(cfg, params, reqs, result)


def test_evict_readmit_identical():
    """A pool too small for the full batch forces evict → requeue →
    re-prefill; greedy streams still match the uninterrupted path."""
    cfg, params = _setup(*CASES[0])
    reqs = poisson_trace(num_requests=4, rate=2.0, prompt_lens=10,
                         max_new=8, vocab_size=cfg.vocab_size, seed=1)
    serving = ContinuousBatchingEngine(
        cfg, params, num_slots=3, spec=PageSpec(9, 4, 8))
    result = serving.run(reqs)
    assert result["metrics"]["evictions"] > 0, \
        "case must exercise the eviction path"
    serving.pool.check_invariants([0] * serving.num_slots)
    _assert_identical(cfg, params, reqs, result)


def test_admission_beyond_capacity_queues():
    """More concurrent requests than slots/pages: late arrivals wait in
    the queue (head-of-line FIFO) instead of crashing, and all finish."""
    cfg, params = _setup(*CASES[0])
    reqs = [Request(rid=i,
                    prompt=np.full(8, 7 + i, np.int32),
                    max_new=4, arrival=0.0) for i in range(6)]
    serving = ContinuousBatchingEngine(
        cfg, params, num_slots=2, spec=PageSpec(6, 4, 3))
    result = serving.run(reqs)
    assert sorted(result["outputs"]) == [r.rid for r in reqs]
    assert all(len(t) == 4 for t in result["outputs"].values())
    # everything drained: all pages back on the free list
    assert serving.pool.free_pages == 6
    _assert_identical(cfg, params, reqs, result)


def test_finished_at_admission_is_noop():
    """max_new=1 finishes on the prefill argmax: the decode step must
    never run for it — zero decode launches in engine.stats()."""
    cfg, params = _setup(*CASES[0])
    req = Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                  max_new=1, arrival=0.0)
    with use(backend="pallas"):
        engine.reset_stats()
        serving = ContinuousBatchingEngine(
            cfg, params, num_slots=2, spec=PageSpec(8, 4, 4))
        result = serving.run([req])
        launches = engine.stats().get("flash_decode",
                                      {}).get("launches", 0)
    assert result["metrics"]["decode_steps"] == 0
    assert launches == 0, "finished sequence must not launch decode"
    assert len(result["outputs"][0]) == 1
    assert serving.pool.free_pages == 8  # retirement freed all its pages


def test_launch_count_flat_under_churn():
    """The headline single-launch property: a churning batch (staggered
    arrivals, early finishes, slot reuse) re-enters ONE compiled decode
    step — flash_decode launch count stays at the first trace's value."""
    cfg, params = _setup(*CASES[0])
    reqs = poisson_trace(num_requests=4, rate=0.5, prompt_lens=(6, 10),
                         max_new=(3, 6), vocab_size=cfg.vocab_size, seed=0)
    with use(backend="pallas"):
        engine.reset_stats()
        serving = ContinuousBatchingEngine(
            cfg, params, num_slots=3, spec=PageSpec(24, 8, 6))
        result = serving.run(reqs)
        st = engine.stats()["flash_decode"]
        m = result["metrics"]
        assert m["decode_steps"] > 1 and m["requests"] == len(reqs)
        # one trace of the step == one counted launch, however often the
        # compiled step re-ran with a different batch composition
        n_attn = sum(1 for k in cfg.block_pattern if k == "attn")
        assert st["launches"] <= n_attn * 2, st
        assert st["launches"] == m["flash_decode_launches"]
        # same-backend oracle: static path also runs under pallas
        _assert_identical(cfg, params, reqs, result)


def test_lone_sequence_pool_exhaustion_raises():
    """When ONE sequence outgrows the whole pool there is no victim to
    evict — the runtime must fail loudly, not corrupt pages."""
    from repro.runtime.pages import OutOfPages
    cfg, params = _setup(*CASES[0])
    req = Request(rid=0, prompt=np.arange(1, 8, dtype=np.int32),
                  max_new=16, arrival=0.0)
    serving = ContinuousBatchingEngine(
        cfg, params, num_slots=2, spec=PageSpec(2, 4, 8))
    with pytest.raises(OutOfPages):
        serving.run([req])


def test_serve_step_carries_position():
    """The static-path decode step returns pos+1 so loops never rebuild
    the position scalar host-side (the serve-loop fix this PR)."""
    from repro.runtime.steps import make_serve_step
    cfg, params = _setup(*CASES[0])
    cache = LanguageModel.init_cache(cfg, 1, capacity=8)
    step = jax.jit(make_serve_step(cfg))
    tok = jnp.asarray([[3]], jnp.int32)
    logits, cache, pos = step(params, cache, tok, jnp.asarray(0, jnp.int32))
    assert int(pos) == 1
    _, _, pos = step(params, cache, tok, pos)
    assert int(pos) == 2


def test_generate_reports_engine_stats():
    """generate() snapshots engine.stats() into its result, mirroring
    launch.train's provenance reporting."""
    cfg, params = _setup(*CASES[0])
    prompts = jnp.asarray(np.arange(12, dtype=np.int32)[None, :] % 7)
    out = generate(cfg, params, prompts, 3)
    assert out["tokens"].shape == (1, 3)
    assert "engine_stats" in out and isinstance(out["engine_stats"], dict)
