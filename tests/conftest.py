import os

# Tests must see the real (single-CPU) device topology; only dryrun.py
# forces 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
