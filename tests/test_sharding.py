"""Sharding policy rules: divisibility sanitation, param/opt/cache specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_test_mesh
from repro.runtime import sharding as shd
from repro.runtime.steps import param_shapes, cache_shapes


@pytest.fixture(scope="module")
def mesh():
    # 1 device, but with named axes of size 1 — rules exercise name paths.
    return make_test_mesh(1, 1)


class FakeMesh:
    """Shape-only mesh stand-in to test divisibility logic at 16x16."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


FM = FakeMesh(data=16, model=16)


def test_sanitize_drops_nondividing_axis():
    assert shd.sanitize(FM, ("data", "model"), (48, 512)) == P("data", "model")
    assert shd.sanitize(FM, ("data", "model"), (7, 512)) == P(None, "model")
    assert shd.sanitize(FM, ("data", "model"), (48, 9)) == P("data", None)


def test_sanitize_left_pads_stacked_dims():
    # stacked (groups, d, f) with a trailing-2-dim rule
    assert shd.sanitize(FM, ("data", "model"), (12, 64, 128)) == \
        P(None, "data", "model")


def test_sanitize_composite_fallback():
    fm = FakeMesh(pod=2, data=16, model=16)
    # 32 divides pod*data? 32 % 32 == 0 -> keep composite
    assert shd.sanitize(fm, (("pod", "data"), None), (32, 8)) == \
        P(("pod", "data"), None)
    # 16 doesn't divide 32 -> falls back to a single axis that divides
    spec = shd.sanitize(fm, (("pod", "data"), None), (16, 8))
    assert spec in (P("data", None), P("pod", None))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "grok-1-314b",
                                  "recurrentgemma-9b", "mamba2-130m",
                                  "seamless-m4t-large-v2"])
def test_param_specs_cover_all_leaves(arch):
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    specs = shd.param_pspecs(shapes, cfg, FM)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for s, spec in zip(flat_shapes, flat_specs):
        assert len(spec) <= len(s.shape)
        for dim, axis in zip(s.shape[-len(spec):] if spec else (), spec):
            if axis is not None:
                size = 1
                for a in ([axis] if isinstance(axis, str) else axis):
                    size *= FM.shape[a]
                assert dim % size == 0, (arch, s.shape, spec)


def test_expert_parallel_vs_tp_fallback():
    cfg16 = get_config("phi3.5-moe-42b")  # E=16 == model -> EP
    shapes = param_shapes(cfg16)
    specs = shd.param_pspecs(shapes, cfg16, FM)
    up = specs["blocks"]["groups"]["b0"]["ff"]["w_up"]["w"]
    assert up[-3] == "model"  # experts on model axis

    cfg8 = get_config("grok-1-314b")  # E=8 < 16 -> TP-f fallback
    shapes8 = param_shapes(cfg8)
    specs8 = shd.param_pspecs(shapes8, cfg8, FM)
    up8 = specs8["blocks"]["groups"]["b0"]["ff"]["w_up"]["w"]
    assert up8[-3] is None
    assert up8[-1] == "model"


def test_cache_specs_seq_shard_fallback_for_gqa():
    cfg = get_config("grok-1-314b")  # kv=8 < 16 -> sequence-sharded cache
    cshapes = cache_shapes(cfg, batch=128, capacity=32768)
    cspecs = shd.cache_pspecs(cshapes, cfg, FM)
    kv = cspecs["groups"]["b0"]
    assert kv.k[2] == "model"  # S dim

    cfg2 = get_config("phi3-mini-3.8b")  # kv=32 divisible -> heads sharded
    cshapes2 = cache_shapes(cfg2, batch=128, capacity=32768)
    cspecs2 = shd.cache_pspecs(cshapes2, cfg2, FM)
    assert cspecs2["groups"]["b0"].k[3] == "model"


def test_opt_specs_mirror_params_and_factored(mesh):
    from repro.optim import scalable_adamw
    cfg = reduced_config(get_config("qwen3-0.6b"))
    shapes = param_shapes(cfg)
    opt = scalable_adamw(1e-3)
    oshapes = jax.eval_shape(opt.init, shapes)
    ospecs = shd.opt_pspecs(oshapes, shapes, cfg, FM)
    assert "m" in ospecs and "v" in ospecs


def test_batch_specs(mesh):
    specs = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    out = shd.batch_pspecs(specs, FM)
    assert out["tokens"] == P("data", None)
    assert out["pos"] == P()
