"""Engine-wide differential conformance suite (DESIGN.md §15).

One fuzzer shape per kernel family: random-but-seeded operands run
through the engine's *pairs of lowerings* (fused single-launch vs
multi-launch / XLA fallback) and are checked against the family's pure
``ref.py`` oracle.  The axes the engine can get wrong are the axes the
fuzz draws from: fused × unfused, epilogues, quantization specs, dtype
tails (bf16, odd non-tile-aligned sizes), zero-length groups/slots.

Every assertion carries a **minimal repro snippet** — the exact seeded
operand construction + call — so a failure pasted into an issue is
runnable as-is.

Property-based when ``hypothesis`` is installed (same convention as
tests/test_schedule.py); the deterministic seeded cases below always
run, so CI coverage does not depend on an optional package.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import engine, use
from repro.core.machine import HAS_FP8
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import paged_decode_attention
from repro.kernels.flash_attention.ref import (ref_attention,
                                               ref_paged_decode_attention)
from repro.kernels.gemm import gemm
from repro.kernels.gemm.ref import ref_gemm
from repro.kernels.grouped_gemm import grouped_gemm
from repro.kernels.grouped_gemm.ref import ref_grouped_gemm
from repro.kernels.ssd_chunk import ssd_chunk_diag, ssd_chunk_scan
from repro.kernels.ssd_chunk.ref import (ref_ssd_chunk_diag,
                                         ref_ssd_chunk_scan)
from repro.kernels.transpose import transpose
from repro.kernels.transpose.ref import ref_transpose


@pytest.fixture(autouse=True)
def fresh_engine():
    engine.reset_stats()
    yield
    engine.reset_stats()


def _tol(dtype):
    return 2e-2 if jnp.dtype(dtype) == jnp.bfloat16 else 1e-4


def _close(got, want, tol, repro):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    err = float(np.max(np.abs(got - want))) if got.size else 0.0
    np.testing.assert_allclose(
        got, want, rtol=tol, atol=tol,
        err_msg=(f"\ndifferential mismatch, max|delta|={err:.3e}\n"
                 f"minimal repro (PYTHONPATH=src python - <<'EOF' ... EOF):\n"
                 f"{repro}"))


# ---------------------------------------------------------------------------
# GEMM: fused + multi-launch vs the jnp oracle, across epilogues/dtypes
# ---------------------------------------------------------------------------

def _check_gemm(seed, m, n, k, layout, epilogue, dtype):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    bshape = (k, n) if layout == "nn" else (n, k)
    b = jnp.asarray(rng.standard_normal(bshape), dtype)
    bias = (jnp.asarray(rng.standard_normal((n,)), jnp.float32)
            if epilogue and epilogue.startswith("bias") else None)
    repro = (
        f"import numpy as np, jax.numpy as jnp\n"
        f"from repro.core import use\n"
        f"from repro.kernels.gemm import gemm\n"
        f"from repro.kernels.gemm.ref import ref_gemm\n"
        f"rng = np.random.default_rng({seed})\n"
        f"a = jnp.asarray(rng.standard_normal(({m}, {k})), '{dtype}')\n"
        f"b = jnp.asarray(rng.standard_normal({bshape}), '{dtype}')\n"
        f"bias = "
        + (f"jnp.asarray(rng.standard_normal(({n},)), jnp.float32)\n"
           if bias is not None else "None\n")
        + f"with use(backend='pallas'):\n"
        f"    out = gemm(a, b, layout={layout!r}, epilogue={epilogue!r},"
        f" bias=bias, fused=<FUSED>)\n"
        f"print(abs(out - ref_gemm(a, b, layout={layout!r},"
        f" epilogue={epilogue!r}, bias=bias)).max())")
    want = ref_gemm(a, b, layout=layout, epilogue=epilogue, bias=bias)
    with use(backend="pallas"):
        for fused in (True, False):
            got = gemm(a, b, layout=layout, epilogue=epilogue, bias=bias,
                       fused=fused)
            _close(got, want, _tol(dtype),
                   repro.replace("<FUSED>", str(fused)))


GEMM_CASES = [
    # seed, m, n, k, layout, epilogue, dtype — odd tails on purpose
    (0, 33, 129, 65, "nn", None, jnp.float32),
    (1, 128, 128, 128, "nt", None, jnp.float32),
    (2, 7, 250, 512, "nn", "gelu", jnp.float32),
    (3, 80, 80, 64, "nt", "bias", jnp.float32),
    (4, 65, 33, 100, "nn", "bias_silu", jnp.float32),
    (5, 1, 513, 129, "nn", "relu", jnp.float32),
    (6, 48, 96, 72, "nn", None, jnp.bfloat16),
    (7, 31, 17, 127, "nt", "silu", jnp.bfloat16),
]


@pytest.mark.parametrize("seed,m,n,k,layout,epilogue,dtype", GEMM_CASES)
def test_gemm_differential(seed, m, n, k, layout, epilogue, dtype):
    _check_gemm(seed, m, n, k, layout, epilogue, dtype)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16),
           m=st.integers(1, 160), n=st.integers(1, 160),
           k=st.integers(1, 256),
           layout=st.sampled_from(["nn", "nt"]),
           epilogue=st.sampled_from([None, "relu", "gelu", "silu",
                                     "bias", "bias_gelu", "bias_silu"]))
    def test_gemm_differential_fuzz(seed, m, n, k, layout, epilogue):
        _check_gemm(seed, m, n, k, layout, epilogue, jnp.float32)


# ---------------------------------------------------------------------------
# Quantized GEMM: fused in-kernel dequant vs the XLA dequant formulation
# ---------------------------------------------------------------------------

QUANT_SPECS = ["int8", "w8a16"] + (["fp8"] if HAS_FP8 else [])


def _check_gemm_quant(seed, m, n, k, spec):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    repro = (
        f"import numpy as np, jax.numpy as jnp\n"
        f"from repro.core import use\n"
        f"from repro.kernels.gemm import gemm\n"
        f"rng = np.random.default_rng({seed})\n"
        f"a = jnp.asarray(rng.standard_normal(({m}, {k})), jnp.float32)\n"
        f"b = jnp.asarray(rng.standard_normal(({k}, {n})), jnp.float32)\n"
        f"with use(backend='pallas'):\n"
        f"    f = gemm(a, b, quant={spec!r}, fused=True)\n"
        f"    x = gemm(a, b, quant={spec!r}, fused=False)\n"
        f"print(abs(f - x).max())")
    with use(backend="pallas"):
        # Both paths quantize the identical wide operands at dispatch, so
        # they compute on identical wire values: the comparison isolates
        # the kernel's dequant-epilogue algebra, not quantization error.
        got_f = gemm(a, b, quant=spec, fused=True)
        got_x = gemm(a, b, quant=spec, fused=False)
    _close(got_f, got_x, 1e-3, repro)
    # and both must still approximate the wide oracle within quant error
    want = ref_gemm(a, b)
    err = float(np.max(np.abs(np.asarray(got_f) - np.asarray(want))))
    scale = float(np.max(np.abs(np.asarray(want)))) + 1e-9
    assert err / scale < 0.1, \
        f"quantized GEMM drifted {err / scale:.3f} from the wide oracle"


@pytest.mark.parametrize("spec", QUANT_SPECS)
@pytest.mark.parametrize("seed,m,n,k", [(10, 32, 64, 48), (11, 33, 96, 80)])
def test_gemm_quant_differential(seed, m, n, k, spec):
    _check_gemm_quant(seed, m, n, k, spec)


# ---------------------------------------------------------------------------
# Flash attention: both lowerings vs plain softmax, causal x non-causal
# ---------------------------------------------------------------------------

def _check_flash(seed, s_q, s_k, causal, dtype):
    rng = np.random.default_rng(seed)
    b, h, d = 1, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s_q, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s_k, h, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s_k, h, d)), dtype)
    repro = (
        f"import numpy as np, jax.numpy as jnp\n"
        f"from repro.core import use\n"
        f"from repro.kernels.flash_attention import flash_attention\n"
        f"from repro.kernels.flash_attention.ref import ref_attention\n"
        f"rng = np.random.default_rng({seed})\n"
        f"q = jnp.asarray(rng.standard_normal((1, {s_q}, 2, 16)), "
        f"'{dtype}')\n"
        f"k = jnp.asarray(rng.standard_normal((1, {s_k}, 2, 16)), "
        f"'{dtype}')\n"
        f"v = jnp.asarray(rng.standard_normal((1, {s_k}, 2, 16)), "
        f"'{dtype}')\n"
        f"with use(backend='pallas'):\n"
        f"    out = flash_attention(q, k, v, causal={causal},"
        f" fused=<FUSED>)\n"
        f"print(abs(out - ref_attention(q, k, v, causal={causal}))"
        f".max())")
    want = ref_attention(q, k, v, causal=causal)
    with use(backend="pallas"):
        for fused in (True, False):
            got = flash_attention(q, k, v, causal=causal, fused=fused)
            _close(got, want, _tol(dtype),
                   repro.replace("<FUSED>", str(fused)))


@pytest.mark.parametrize("seed,s_q,s_k,causal,dtype", [
    (20, 5, 5, True, jnp.float32),
    (21, 17, 17, True, jnp.float32),
    (22, 64, 64, False, jnp.float32),
    (23, 33, 64, False, jnp.float32),   # cross-attention tail
    (24, 16, 16, True, jnp.bfloat16),
])
def test_flash_differential(seed, s_q, s_k, causal, dtype):
    _check_flash(seed, s_q, s_k, causal, dtype)


def test_flash_decode_differential():
    """Paged decode vs the gather oracle — live, short and ZERO-length
    slots in one batch, GQA heads, non-trivial block tables."""
    seed = 30
    rng = np.random.default_rng(seed)
    s, pages, psize, maxb, h, hkv, hd = 4, 16, 8, 4, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((s, h, hd)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((pages, psize, hkv, hd)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((pages, psize, hkv, hd)),
                         jnp.float32)
    tables = jnp.asarray(
        rng.permutation(pages)[:s * maxb].reshape(s, maxb), jnp.int32)
    lengths = jnp.asarray([psize * maxb, 5, 0, 17], jnp.int32)
    repro = (
        f"seed={seed}: shapes q({s},{h},{hd}) pool({pages},{psize},"
        f"{hkv},{hd}) tables=rng.permutation({pages})[:{s * maxb}]"
        f".reshape({s},{maxb}) lengths={list(np.asarray(lengths))}\n"
        f"paged_decode_attention(q, k_pool, v_pool, tables, lengths) vs "
        f"ref_paged_decode_attention(same)")
    want = ref_paged_decode_attention(q, k_pool, v_pool, tables, lengths)
    with use(backend="pallas"):
        got = paged_decode_attention(q, k_pool, v_pool, tables, lengths)
    _close(got, want, 1e-4, repro)


# ---------------------------------------------------------------------------
# Grouped GEMM: ragged groups (incl. empty + tail rows) x lowerings
# ---------------------------------------------------------------------------

def _check_grouped(seed, t, k, n, sizes, epilogue):
    rng = np.random.default_rng(seed)
    e = len(sizes)
    x = jnp.asarray(rng.standard_normal((t, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    bias = (jnp.asarray(rng.standard_normal((e, n)), jnp.float32)
            if epilogue and epilogue.startswith("bias") else None)
    repro = (
        f"import numpy as np, jax.numpy as jnp\n"
        f"from repro.core import use\n"
        f"from repro.kernels.grouped_gemm import grouped_gemm\n"
        f"from repro.kernels.grouped_gemm.ref import ref_grouped_gemm\n"
        f"rng = np.random.default_rng({seed})\n"
        f"x = jnp.asarray(rng.standard_normal(({t}, {k})), jnp.float32)\n"
        f"w = jnp.asarray(rng.standard_normal(({e}, {k}, {n})), "
        f"jnp.float32)\n"
        f"gs = jnp.asarray({sizes}, jnp.int32)\n"
        + (f"bias = jnp.asarray(rng.standard_normal(({e}, {n})), "
           f"jnp.float32)\n" if bias is not None else "bias = None\n")
        + f"with use(backend='pallas'):\n"
        f"    a = grouped_gemm(x, w, gs, epilogue={epilogue!r}, "
        f"bias=bias, fused=True)\n"
        f"    b = grouped_gemm(x, w, gs, epilogue={epilogue!r}, "
        f"bias=bias, fused=False)")
    with use(backend="pallas"):
        got_f = grouped_gemm(x, w, gs, epilogue=epilogue, bias=bias,
                             fused=True)
        got_m = grouped_gemm(x, w, gs, epilogue=epilogue, bias=bias,
                             fused=False)
    # the two lowerings must agree exactly-ish with each other...
    _close(got_f, got_m, 1e-4, repro)
    if epilogue is None:
        # ...and with the pure oracle where one exists
        _close(got_f, ref_grouped_gemm(x, w, gs), 1e-4, repro)


@pytest.mark.parametrize("seed,t,k,n,sizes,epilogue", [
    (40, 24, 16, 32, [8, 8, 8], None),
    (41, 30, 24, 16, [10, 0, 17], None),      # empty group + tail rows
    (42, 33, 16, 48, [1, 31, 1], "bias"),
    (43, 40, 32, 32, [13, 27, 0], "bias_silu"),
    (44, 17, 8, 24, [17, 0], "gelu"),
])
def test_grouped_differential(seed, t, k, n, sizes, epilogue):
    _check_grouped(seed, t, k, n, sizes, epilogue)


@pytest.mark.parametrize("spec", QUANT_SPECS)
def test_grouped_quant_differential(spec):
    """Quantized grouped GEMM: both lowerings agree on identical wire
    values and stay within quant error of the wide oracle."""
    seed = 50
    rng = np.random.default_rng(seed)
    t, k, n, e = 24, 16, 32, 3
    x = jnp.asarray(rng.standard_normal((t, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32)
    gs = jnp.asarray([8, 8, 8], jnp.int32)
    repro = (f"seed={seed}: grouped_gemm(x({t},{k}), w({e},{k},{n}), "
             f"gs=[8,8,8], quant={spec!r}, fused=True/False)")
    with use(backend="pallas"):
        got_f = grouped_gemm(x, w, gs, quant=spec, fused=True)
        got_m = grouped_gemm(x, w, gs, quant=spec, fused=False)
    _close(got_f, got_m, 1e-3, repro)
    want = np.asarray(ref_grouped_gemm(x, w, gs))
    err = float(np.max(np.abs(np.asarray(got_f) - want)))
    scale = float(np.max(np.abs(want))) + 1e-9
    assert err / scale < 0.1, repro


# ---------------------------------------------------------------------------
# SSD chunk scan: diag kernel + carried-state scan vs sequential oracle
# ---------------------------------------------------------------------------

def _ssd_operands(seed, g, nc, q, n, p):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((g, nc, q, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, nc, q, n)), jnp.float32)
    l = jnp.asarray(np.tril(rng.standard_normal((g, nc, q, q))),
                    jnp.float32)
    xdt = jnp.asarray(rng.standard_normal((g, nc, q, p)), jnp.float32)
    decay_in = jnp.asarray(rng.uniform(0.2, 1.0, (g, nc, q)), jnp.float32)
    decay_out = jnp.asarray(rng.uniform(0.2, 1.0, (g, nc, q)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((g, p, n)), jnp.float32)
    return c, b, l, xdt, decay_in, decay_out, s0


def test_ssd_diag_differential():
    seed, g, q, n, p = 60, 3, 16, 8, 12
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((g, q, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, q, n)), jnp.float32)
    l = jnp.asarray(np.tril(rng.standard_normal((g, q, q))), jnp.float32)
    xdt = jnp.asarray(rng.standard_normal((g, q, p)), jnp.float32)
    repro = (f"seed={seed}: ssd_chunk_diag(c({g},{q},{n}), b, tril l, "
             f"xdt({g},{q},{p})) vs ref_ssd_chunk_diag(same)")
    with use(backend="pallas"):
        got = ssd_chunk_diag(c, b, l, xdt)
    _close(got, ref_ssd_chunk_diag(c, b, l, xdt), 1e-4, repro)


@pytest.mark.parametrize("seed,g,nc,q,n,p", [
    (61, 2, 3, 8, 8, 8),
    (62, 1, 5, 16, 8, 12),   # odd chunk count, wider state
])
def test_ssd_scan_differential(seed, g, nc, q, n, p):
    ops = _ssd_operands(seed, g, nc, q, n, p)
    repro = (f"seed={seed}: ssd_chunk_scan over (g={g}, nc={nc}, q={q}, "
             f"n={n}, p={p}) vs ref_ssd_chunk_scan(same operands)")
    want_y, want_s = ref_ssd_chunk_scan(*ops)
    with use(backend="pallas"):
        got_y, got_s = ssd_chunk_scan(*ops)
    _close(got_y, want_y, 1e-4, repro + " [y]")
    _close(got_s, want_s, 1e-4, repro + " [state]")


# ---------------------------------------------------------------------------
# Transpose: odd tails + batch vs the trivial oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,shape", [
    (70, (33, 129)),
    (71, (128, 128)),
    (72, (2, 65, 31)),   # batched, odd tail
])
def test_transpose_differential(seed, shape):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    repro = (f"seed={seed}: transpose(x{shape}) vs ref_transpose — "
             f"rng.standard_normal({shape})")
    with use(backend="pallas"):
        got = transpose(x)
    _close(got, ref_transpose(x), 0.0 + 1e-6, repro)
