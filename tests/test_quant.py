"""Low-precision axis (DESIGN.md §13): quantization codec round-trips,
fused-dequant-epilogue parity, single-launch accounting, tuned-cache
dtype keying, W8A16 model plumbing, and KV-int8 decode consistency."""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GemmDescriptor, engine, plan_gemm, use
from repro.core.descriptor import QuantSpec, resolve_quant
from repro.core.machine import HAS_FP8
from repro.core.schedule import QUANT_TILE
from repro.kernels.gemm import gemm
from repro.kernels.gemm.ops import _xla_quant_gemm
from repro.kernels.grouped_gemm import grouped_gemm
from repro.optim.compression import (QuantizedTensor, dequantize,
                                     expand_scale, quantize,
                                     quantize_model, quantize_operand)

RNG = np.random.default_rng(1234)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def rel_err(got, want):
    denom = float(jnp.max(jnp.abs(want))) or 1.0
    return float(jnp.max(jnp.abs(got - want))) / denom


# ---------------------------------------------------------------------------
# Codec round-trips (optim/compression.py)
# ---------------------------------------------------------------------------

SCHEMES = ["per_tensor", "per_channel", "per_tile"]


@pytest.mark.parametrize("scheme", SCHEMES)
def test_roundtrip_error_bound(scheme):
    """Symmetric int8: round-trip error <= scale/2 per element, i.e.
    <= amax/254 of the quantization group's absmax."""
    x = rand((200, 96))
    qt = quantize(x, QuantSpec("int8", scheme), axis=-1)
    back = dequantize(qt)
    scale = expand_scale(qt.scale, qt.spec, x.shape[-1])
    # per-element bound: half a quantization step of the group's scale
    bound = jnp.broadcast_to(scale * 0.5 + 1e-7, x.shape)
    assert bool(jnp.all(jnp.abs(back - x) <= bound))


@pytest.mark.parametrize("scheme", SCHEMES)
def test_roundtrip_tail_not_multiple_of_tile(scheme):
    """Lengths not divisible by QUANT_TILE still round-trip (ragged
    last tile)."""
    n = QUANT_TILE + 37
    x = rand((5, n))
    qt = quantize(x, QuantSpec("int8", scheme), axis=-1)
    back = dequantize(qt)
    assert back.shape == x.shape
    assert rel_err(back, x) < 1e-2


def test_roundtrip_zero_size():
    x = jnp.zeros((0, 64), jnp.float32)
    qt = quantize(x, "int8", axis=-1)
    assert dequantize(qt).shape == (0, 64)
    # all-zero input must not divide by zero and must decode to zeros
    z = jnp.zeros((8, 64), jnp.float32)
    back = dequantize(quantize(z, "int8", axis=-1))
    assert bool(jnp.all(back == 0))


def test_expand_scale_shapes():
    spec_t = QuantSpec("int8", "per_tensor")
    spec_c = QuantSpec("int8", "per_channel")
    spec_b = QuantSpec("int8", "per_tile")
    n = QUANT_TILE * 2 + 9
    assert expand_scale(jnp.ones(()), spec_t, n).shape == (n,)
    assert expand_scale(jnp.ones((n,)), spec_c, n).shape == (n,)
    assert expand_scale(jnp.ones((3,)), spec_b, n).shape == (n,)


def test_quantized_tensor_is_pytree():
    qt = quantize(rand((16, 32)), "w8a16", axis=-1)
    leaves, treedef = jax.tree.flatten(qt)
    assert len(leaves) == 2  # q + scale; spec/axis/orig_dtype are aux
    qt2 = jax.tree.unflatten(treedef, leaves)
    assert qt2.spec == qt.spec and qt2.axis == qt.axis


# ---------------------------------------------------------------------------
# Spec resolution + descriptor constraints
# ---------------------------------------------------------------------------

def test_resolve_quant_aliases():
    assert resolve_quant(None) is None
    assert resolve_quant(False) is None
    assert resolve_quant("int8") == QuantSpec("int8", "per_channel")
    assert resolve_quant("w8a16").weight_only
    spec = QuantSpec("int8", "per_channel")
    assert resolve_quant(spec) is spec


def test_quant_descriptor_constraints():
    with pytest.raises(ValueError):
        GemmDescriptor(m=8, n=8, k=8, accumulate=True,
                       quant=resolve_quant("int8"))
    if not HAS_FP8:
        with pytest.raises(ValueError):
            QuantSpec("float8_e4m3")


def test_cache_key_separates_quant():
    d0 = GemmDescriptor(m=64, n=64, k=64)
    d1 = GemmDescriptor(m=64, n=64, k=64, quant=resolve_quant("int8"))
    d2 = GemmDescriptor(m=64, n=64, k=64, quant=resolve_quant("w8a16"))
    assert len({d0.cache_key(), d1.cache_key(), d2.cache_key()}) == 3


# ---------------------------------------------------------------------------
# Quantized GEMM: parity vs dequant reference + launch accounting
# ---------------------------------------------------------------------------

GEMM_SHAPES = [(80, 96, 160), (128, 128, 128), (33, 70, 100)]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
@pytest.mark.parametrize("mode", ["int8", "w8a16"])
def test_quant_gemm_parity(m, k, n, mode):
    """Quantized GEMM vs pure-jnp dequantize-then-matmul reference: the
    only error is the quantization itself, so comparing against the
    dequantized operands must be tight."""
    a, b = rand((m, k)), rand((k, n))
    spec = resolve_quant(mode)
    bq, sb = quantize_operand(b, spec, axis=1)
    bd = bq.astype(jnp.float32) * sb[None, :]
    if spec.weight_only:
        ref = a @ bd
    else:
        aq, sa = quantize_operand(a, spec, axis=0)
        ref = (aq.astype(jnp.float32) * sa[:, None]) @ bd
    with use(backend="pallas"):
        out = gemm(a, b, quant=mode)
    assert rel_err(out, ref) < 1e-5
    # and the end-to-end error vs the wide product is the quant error only
    assert rel_err(out, a @ b) < 5e-2


def test_quant_gemm_single_launch():
    a, b = rand((80, 96)), rand((96, 160))
    with use(backend="pallas"):
        engine.reset_stats()
        gemm(a, b, quant="int8")
        s = engine.stats()["gemm"]
    assert s["launches"] == 1
    assert s["plan_source_model"] + s["plan_source_autotuned"] \
        + s["plan_source_tuned_cache"] == 1


@pytest.mark.parametrize("epilogue,bias,exact", [
    (None, False, True), ("relu", False, True),
    ("bias", True, False), ("bias_gelu", True, False),
    ("silu", False, False),
])
def test_fused_dequant_epilogue_parity(epilogue, bias, exact):
    """Fused single-launch lowering vs the XLA dequant-then-epilogue
    formulation sharing apply_epilogue: bit-identical when the epilogue
    is multiply-only (int32 accumulation is exact under any tiling and
    the dequant products round identically).  ``bias`` adds after the
    dequant multiply, which XLA may contract into an FMA in one context
    but not the other, and the transcendental activations differ by
    ULPs across fusion contexts — those get a tight float tolerance."""
    a, b = rand((80, 96)), rand((96, 160))
    spec = resolve_quant("int8")
    aq, sa = quantize_operand(a, spec, axis=0)
    bq, sb = quantize_operand(b, spec, axis=1)
    bv = rand((160,)) if bias else None
    desc = GemmDescriptor.from_operands(aq, bq, epilogue=epilogue,
                                        quant=spec)
    ref = _xla_quant_gemm(desc, aq, bq, bv, sa, sb)
    with use(backend="pallas"):
        out = gemm(a, b, quant="int8", epilogue=epilogue, bias=bv,
                   fused=True)
    if exact:
        assert bool(jnp.all(out == ref)), \
            f"fused {epilogue} not bit-identical to dequant reference"
    else:
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_quant_fused_vs_unfused():
    """fused=False routes through the XLA formulation (0 launches);
    for int8 it matches the fused kernel bit for bit."""
    a, b = rand((70, 90)), rand((90, 110))
    with use(backend="pallas"):
        engine.reset_stats()
        fused = gemm(a, b, quant="int8", fused=True)
        assert engine.stats()["gemm"]["launches"] == 1
        engine.reset_stats()
        unfused = gemm(a, b, quant="int8", fused=False)
        assert engine.stats()["gemm"]["launches"] == 0
    assert bool(jnp.all(fused == unfused))


def test_quant_per_schemes_gemm():
    a, b = rand((64, QUANT_TILE + 32)), rand((QUANT_TILE + 32, 96))
    for scheme in SCHEMES:
        spec = QuantSpec("int8", scheme)
        with use(backend="pallas"):
            out = gemm(a, b, quant=spec)
        assert rel_err(out, a @ b) < 5e-2, scheme


def test_ambient_config_quant_and_opt_out():
    a, b = rand((48, 64)), rand((64, 80))
    wide = gemm(a, b)
    with use(backend="pallas", quant="int8"):
        q = gemm(a, b)          # picks up ambient spec
        opt_out = gemm(a, b, quant=False)
    assert rel_err(q, wide) > 1e-6      # actually quantized
    np.testing.assert_allclose(opt_out, wide, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Grouped GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "w8a16"])
def test_quant_grouped_parity(mode):
    E, T, K, N = 4, 96, 64, 128
    x = rand((T, K))
    w = rand((E, K, N))
    gs = jnp.asarray([40, 0, 30, 26], jnp.int32)
    grp = jnp.repeat(jnp.arange(E), np.asarray(gs))
    spec = resolve_quant(mode)
    wq, sw = jax.vmap(
        lambda wi: quantize_operand(wi, spec, axis=1))(w)
    wd = wq.astype(jnp.float32) * sw[:, None, :]
    if spec.weight_only:
        ref = jnp.einsum("tk,tkn->tn", x, wd[grp])
    else:
        xq, sx = quantize_operand(x, spec, axis=0)
        ref = jnp.einsum("tk,tkn->tn",
                         xq.astype(jnp.float32) * sx[:, None], wd[grp])
    with use(backend="pallas"):
        engine.reset_stats()
        out = grouped_gemm(x, w, gs, quant=mode)
        assert engine.stats()["grouped_gemm"]["launches"] == 1
    assert rel_err(out, ref) < 1e-4
    assert rel_err(out, jnp.einsum("tk,tkn->tn", x, w[grp])) < 5e-2


def test_quant_grouped_epilogue():
    E, T, K, N = 3, 64, 48, 96
    x, w = rand((T, K)), rand((E, K, N))
    bias = rand((E, N))
    gs = jnp.asarray([20, 24, 20], jnp.int32)
    grp = jnp.repeat(jnp.arange(E), np.asarray(gs))
    ref = jax.nn.silu(jnp.einsum("tk,tkn->tn", x, w[grp]) + bias[grp])
    with use(backend="pallas"):
        out = grouped_gemm(x, w, gs, quant="int8", epilogue="bias_silu",
                           bias=bias)
    assert rel_err(out, ref) < 5e-2


def test_quant_grouped_fused_vs_unfused():
    E, T, K, N = 3, 48, 32, 64
    x, w = rand((T, K)), rand((E, K, N))
    gs = jnp.asarray([16, 16, 16], jnp.int32)
    with use(backend="pallas"):
        engine.reset_stats()
        fused = grouped_gemm(x, w, gs, quant="int8", fused=True)
        assert engine.stats()["grouped_gemm"]["launches"] == 1
        engine.reset_stats()
        unfused = grouped_gemm(x, w, gs, quant="int8", fused=False)
        assert engine.stats()["grouped_gemm"]["launches"] == 0
    assert bool(jnp.all(fused == unfused))


# ---------------------------------------------------------------------------
# Tuned-cache keying (satellite: full-dtype record fingerprints)
# ---------------------------------------------------------------------------

def test_tuning_record_carries_dtypes():
    from repro.core.autotune import (_desc_dtypes, plan_from_record,
                                     plan_to_record)
    d_wide = GemmDescriptor(m=80, n=80, k=128)
    d_q = GemmDescriptor(m=80, n=80, k=128, quant=resolve_quant("int8"))
    rec = plan_to_record(plan_gemm(d_wide))
    assert rec["dtypes"] == _desc_dtypes(d_wide)
    # a wide record must never replay onto the quantized descriptor
    assert plan_from_record(d_q, rec) is None
    assert plan_from_record(d_wide, rec) is not None
    rec_q = plan_to_record(plan_gemm(d_q))
    assert rec_q["dtypes"] != rec["dtypes"]
    assert plan_from_record(d_q, rec_q) is not None


# ---------------------------------------------------------------------------
# Model plumbing: quantize_model / linear / tree_cast
# ---------------------------------------------------------------------------

def test_quantize_model_and_linear():
    from repro.models.common import linear, tree_cast
    w = rand((64, 48))
    params = {"w": w, "b": jnp.zeros((48,), jnp.float32)}
    qp = quantize_model(params, "w8a16")
    assert isinstance(qp["w"], QuantizedTensor)
    assert not isinstance(qp["b"], QuantizedTensor)
    x = rand((16, 64))
    ref = x @ dequantize(qp["w"])
    for backend in ("xla", "pallas"):
        with use(backend=backend):
            out = linear(qp, x)
        assert rel_err(out, ref) < 1e-5, backend
    # tree_cast must pass quantized leaves through untouched
    qp16 = tree_cast(qp, jnp.bfloat16)
    assert isinstance(qp16["w"], QuantizedTensor)
    assert qp16["b"].dtype == jnp.bfloat16


def test_quantize_model_min_size():
    params = {"a": {"w": rand((8, 8))}, "b": {"w": rand((64, 64))}}
    qp = quantize_model(params, "w8a16", min_size=1024)
    assert not isinstance(qp["a"]["w"], QuantizedTensor)
    assert isinstance(qp["b"]["w"], QuantizedTensor)


# ---------------------------------------------------------------------------
# KV-int8 paged decode
# ---------------------------------------------------------------------------

def test_kv_int8_decode_consistency():
    """int8 KV pools vs wide pools over a multi-step decode: the pallas
    and XLA quantized paths must agree with each other to float noise,
    and with the wide path to within int8 quantization error."""
    from repro.models.attention import (PageSpec, _paged_decode,
                                        init_paged_kv_cache)
    B, H, HKV, HD, P = 3, 4, 2, 64, 16
    cfg = types.SimpleNamespace(attn_logit_softcap=0.0)
    spec_w = PageSpec(num_pages=8, page_size=P, max_blocks=2)
    spec_q = PageSpec(num_pages=8, page_size=P, max_blocks=2,
                      kv_quant="int8")

    def run(spec, backend):
        rng = np.random.default_rng(7)
        cache = init_paged_kv_cache(B, spec, HKV, HD, jnp.float32)
        assert (cache.k.dtype == jnp.int8) == (spec.kv_quant == "int8")
        cache = cache._replace(
            tables=jnp.asarray([[0, 1], [2, 3], [4, 5]], jnp.int32))
        base = jnp.asarray([0, 3, 1], jnp.int32)
        out = None
        with use(backend=backend):
            for step in range(10):
                qkv = [jnp.asarray(rng.standard_normal((B, 1, h, HD)),
                                   jnp.float32) * 0.3
                       for h in (H, HKV, HKV)]
                cache, out = _paged_decode(cfg, cache, *qkv,
                                           (base + step)[:, None],
                                           jnp.float32, H // HKV)
        return out

    wide = run(spec_w, "xla")
    q_xla = run(spec_q, "xla")
    q_pl = run(spec_q, "pallas")
    assert rel_err(q_pl, q_xla) < 1e-5      # same quantized math
    assert rel_err(q_xla, wide) < 5e-2      # only int8 error vs wide
    assert rel_err(q_pl, wide) < 5e-2


def test_kv_int8_write_prefill_roundtrip():
    """runtime/pages.write_prefill quantizes into int8 pools and
    refresh_tables keeps the scale fields."""
    from repro.models.attention import KVCache, PagedKVCache, PageSpec
    from repro.models.attention import init_paged_kv_cache
    from repro.runtime.pages import refresh_tables, _write_one
    P, HKV, HD, L = 16, 2, 32, 21
    spec = PageSpec(num_pages=6, page_size=P, max_blocks=3,
                    kv_quant="int8")
    sv = init_paged_kv_cache(2, spec, HKV, HD, jnp.float32)
    dense = KVCache(k=rand((1, L, HKV, HD)), v=rand((1, L, HKV, HD)),
                    pos=jnp.arange(L, dtype=jnp.int32)[None])
    out = _write_one(sv, dense, slot=0, length=L, page_ids=[4, 2],
                     page_size=P)
    assert out.k.dtype == jnp.int8 and out.k_scale is not None
    # dequantized page rows must match the dense prefill rows
    deq = (out.k[4].astype(jnp.float32)
           * out.k_scale[4][:, None, None])
    np.testing.assert_allclose(deq, dense.k[0, :P], atol=2e-2, rtol=2e-2)
    out2 = refresh_tables(out, np.ones((2, 3), np.int32))
    assert out2.k_scale is not None and bool(jnp.all(out2.tables == 1))


# ---------------------------------------------------------------------------
# fp8 (gated on backend support)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not HAS_FP8, reason="no float8_e4m3 in this jax")
def test_fp8_gemm_parity():
    a, b = rand((64, 96), scale=0.5), rand((96, 64), scale=0.5)
    spec = resolve_quant("fp8")
    bq, sb = quantize_operand(b, spec, axis=1)
    aq, sa = quantize_operand(a, spec, axis=0)
    ref = ((aq.astype(jnp.float32) * sa[:, None])
           @ (bq.astype(jnp.float32) * sb[None, :]))
    with use(backend="pallas"):
        out = gemm(a, b, quant="fp8")
    # fp8 accumulates in f32: looser than int8's exact int32 path
    assert rel_err(out, ref) < 1e-3
    assert rel_err(out, a @ b) < 1e-1
