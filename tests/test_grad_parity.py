"""Gradient parity: scheduled single-launch backward vs reference autodiff.

DESIGN.md §11 acceptance: training gradients of the three fused families
— flash attention, grouped GEMM, the SSD chunked scan — flow through the
families' custom VJPs onto ONE scheduled ``pallas_call`` each, match
reference-path autodiff across dtypes / ragged tails / degenerate group
sizes, and fall back to the reference when forced off the fused path.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.config import use
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import _ref_flat
from repro.kernels.grouped_gemm import grouped_gemm
from repro.kernels.grouped_gemm.ops import _ref_grouped
from repro.kernels.ssd_chunk import ref_ssd_chunk_scan, ssd_chunk_scan

RNG = np.random.default_rng(11)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def assert_grads_close(got, want, dtype):
    tol = dict(atol=2e-4, rtol=2e-3) if dtype == jnp.float32 \
        else dict(atol=1e-1, rtol=1e-1)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **tol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _ref_attention_kernel_convention(q, k, v, causal):
    """(b, s, h, d) reference sharing the kernels' causal convention
    (kpos <= qpos, no diagonal offset) via the VJP's own flat oracle."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    flat = [t.transpose(0, 2, 1, 3).reshape(b * h, t.shape[1], d)
            for t in (q, k, v)]
    out = _ref_flat(causal, *flat)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,h,sq,sk,d,causal,dtype", [
    (1, 2, 128, 128, 32, True, jnp.float32),
    (1, 1, 96, 80, 24, True, jnp.float32),    # tails on every dim + sq != sk
    (1, 2, 100, 128, 64, False, jnp.float32),  # sq tail, non-causal
    (1, 2, 128, 128, 32, True, jnp.bfloat16),
])
def test_flash_grad_parity(b, h, sq, sk, d, causal, dtype):
    q, k, v = rand((b, sq, h, d), dtype), rand((b, sk, h, d), dtype), \
        rand((b, sk, h, d), dtype)
    w = rand((b, sq, h, d))

    def loss(f):
        return lambda q, k, v: jnp.sum(f(q, k, v).astype(jnp.float32) * w)

    got = jax.grad(loss(functools.partial(flash_attention, causal=causal)),
                   argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(
        loss(lambda q, k, v: _ref_attention_kernel_convention(
            q, k, v, causal)), argnums=(0, 1, 2))(q, k, v)
    assert_grads_close(got, want, dtype)


def test_flash_bwd_single_launch_fewer_tiles():
    """Acceptance (DESIGN.md §11): a causal gradient is exactly ONE
    backward pallas_call walking strictly fewer tiles than the dense
    dKdV grid — the masked k-blocks never enter the backward table."""
    from repro.core import (FlashBwdDescriptor, FlashDescriptor,
                            plan_flash_bwd)
    engine.reset_stats()
    q = rand((1, 2048, 2, 64))
    jax.grad(lambda q, k, v: jnp.sum(flash_attention(q, k, v, causal=True)),
             argnums=(0, 1, 2))(q, q, q)
    s = engine.stats()["flash_attention"]
    assert s["launches_bwd"] == 1
    assert s["plan_source_model_bwd"] == 1
    # the backward plan reuses the forward's causal-pruned schedule
    desc = FlashDescriptor(batch_heads=2, sq=2048, sk=2048, d=64, causal=True)
    sched = plan_flash_bwd(
        FlashBwdDescriptor.from_forward(desc)).tile_schedule()
    assert sched.num_tiles < sched.dense_tiles


def test_flash_grad_fallback_matches_fused():
    """fused="off" routes the backward down reference autodiff; the
    gradients agree with the scheduled walk."""
    q, k, v = (rand((1, 64, 2, 32)) for _ in range(3))

    def loss(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True) ** 2)

    engine.reset_stats()
    got = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    assert engine.stats()["flash_attention"]["launches_bwd"] == 1
    with use(fused="off"):
        want = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    # the fallback never reaches the backward family
    assert engine.stats()["flash_attention"]["launches_bwd"] == 1
    assert_grads_close(got, want, jnp.float32)


# ---------------------------------------------------------------------------
# dense matmul front door (pallas primal, reference backward)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("epilogue", [None, "bias_gelu"])
def test_matmul_pallas_backend_grad_parity(epilogue):
    """The engine GEMM path is differentiable (pallas forward, reference
    backward) so ``backend="pallas"`` trains end to end; gradients match
    the XLA backend."""
    from repro.core.matmul import matmul
    a, b = rand((48, 40)), rand((40, 56))
    bias = rand((56,), scale=0.2) if epilogue else None
    w = rand((48, 56))

    def loss(a, b, bias):
        return jnp.sum(matmul(a, b, epilogue=epilogue, bias=bias)
                       .astype(jnp.float32) * w)

    argnums = (0, 1, 2) if epilogue else (0, 1)
    with use(backend="pallas", interpret=True):
        got = jax.grad(loss, argnums=argnums)(a, b, bias)
    with use(backend="xla"):
        want = jax.grad(loss, argnums=argnums)(a, b, bias)
    assert_grads_close(got, want, jnp.float32)


# ---------------------------------------------------------------------------
# grouped GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,k,n,sizes,epilogue,dtype", [
    (64, 32, 48, [20, 0, 30], None, jnp.float32),   # zero-size expert + tail
    (96, 40, 56, [96, 0, 0], None, jnp.float32),    # one expert owns all rows
    (80, 48, 64, [10, 30, 25], "bias", jnp.float32),
    (80, 48, 64, [10, 30, 25], "bias_gelu", jnp.float32),
    (80, 48, 64, [10, 30, 25], "silu", jnp.float32),
    (64, 32, 48, [20, 0, 30], None, jnp.bfloat16),
])
def test_grouped_grad_parity(t, k, n, sizes, epilogue, dtype):
    x = rand((t, k), dtype)
    w = rand((len(sizes), k, n), dtype, scale=0.3)
    gs = jnp.asarray(sizes, jnp.int32)
    biased = epilogue is not None and epilogue.startswith("bias")
    bias = rand((len(sizes), n), dtype, scale=0.2) if biased else None
    wy = rand((t, n))

    def loss(f):
        def inner(x, w, b):
            out = f(x, w, gs, epilogue=epilogue, bias=b) if f is grouped_gemm \
                else _ref_grouped(epilogue, x, w, gs, b)
            return jnp.sum(out.astype(jnp.float32) * wy)
        return inner

    argnums = (0, 1, 2) if biased else (0, 1)
    args = (x, w, bias) if biased else (x, w)
    if biased:
        got = jax.grad(loss(grouped_gemm), argnums=argnums)(*args, )
        want = jax.grad(loss(None), argnums=argnums)(*args)
    else:
        got = jax.grad(lambda x, w: loss(grouped_gemm)(x, w, None),
                       argnums=argnums)(*args)
        want = jax.grad(lambda x, w: loss(None)(x, w, None),
                        argnums=argnums)(*args)
    assert_grads_close(got, want, dtype)


def test_grouped_bwd_single_launch():
    """dgrad AND wgrad ride ONE backward pallas_call over the runtime
    tile tables — the pad/scatter path is never taken (DESIGN.md §11)."""
    engine.reset_stats()
    x, w = rand((64, 32)), rand((4, 32, 48), scale=0.3)
    gs = jnp.asarray([16, 0, 40, 8], jnp.int32)
    jax.grad(lambda x, w: jnp.sum(grouped_gemm(x, w, gs) ** 2),
             argnums=(0, 1))(x, w)
    s = engine.stats()["grouped_gemm"]
    assert s["launches_bwd"] == 1
    assert s["plan_source_model_bwd"] == 1


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

def _ssd_grad_case(g, nc, q, n, p, dtype=jnp.float32):
    c = rand((g, nc, q, n), dtype, scale=0.5)
    b = rand((g, nc, q, n), dtype, scale=0.5)
    l = jnp.asarray(np.tril(np.exp(
        -np.abs(RNG.standard_normal((g, nc, q, q))))), dtype)
    x = rand((g, nc, q, p), dtype, scale=0.5)
    di = jnp.asarray(np.exp(-np.abs(RNG.standard_normal((g, nc, q)))),
                     jnp.float32)
    do = jnp.asarray(np.exp(-np.abs(RNG.standard_normal((g, nc, q)))),
                     jnp.float32)
    s0 = rand((g, p, n), jnp.float32, scale=0.3)
    return c, b, l, x, di, do, s0


@pytest.mark.parametrize("g,nc,q,n,p,dtype", [
    (2, 4, 16, 8, 12, jnp.float32),
    (1, 1, 8, 8, 8, jnp.float32),     # single chunk: recurrence is s0 only
    (2, 3, 16, 8, 8, jnp.bfloat16),
])
def test_ssd_grad_parity(g, nc, q, n, p, dtype):
    ops = _ssd_grad_case(g, nc, q, n, p, dtype)
    wy, ws = rand((g, nc, q, p)), rand((g, p, n))

    def loss(f):
        def inner(*ops):
            y, sf = f(*ops)
            return jnp.sum(y.astype(jnp.float32) * wy) + jnp.sum(sf * ws)
        return inner

    got = jax.grad(loss(ssd_chunk_scan), argnums=tuple(range(7)))(*ops)
    want = jax.grad(loss(ref_ssd_chunk_scan), argnums=tuple(range(7)))(*ops)
    assert_grads_close(got, want, dtype)


def test_ssd_grad_carried_state_tail():
    """Gradients across a carried-state seam: differentiating a scan
    split in two (state handed across the cut, cotangent handed back
    through ``ds0``/``dsf``) matches differentiating the unsplit scan."""
    ops = _ssd_grad_case(2, 4, 16, 8, 12)
    wy = rand((2, 4, 16, 12))
    cut = 2

    def loss_full(c, b, l, x, di, do, s0):
        y, _ = ssd_chunk_scan(c, b, l, x, di, do, s0)
        return jnp.sum(y.astype(jnp.float32) * wy)

    def loss_split(c, b, l, x, di, do, s0):
        head = [t[:, :cut] for t in (c, b, l, x, di, do)]
        tail = [t[:, cut:] for t in (c, b, l, x, di, do)]
        y1, s_mid = ssd_chunk_scan(*head, s0)
        y2, _ = ssd_chunk_scan(*tail, s_mid)
        y = jnp.concatenate([y1, y2], axis=1)
        return jnp.sum(y.astype(jnp.float32) * wy)

    argnums = tuple(range(7))
    got = jax.grad(loss_split, argnums=argnums)(*ops)
    want = jax.grad(loss_full, argnums=argnums)(*ops)
    assert_grads_close(got, want, jnp.float32)


def test_ssd_bwd_single_launch():
    """The whole reverse walk — every chunk's cotangent ladder AND the
    carried state cotangent — is exactly ONE backward pallas_call."""
    engine.reset_stats()
    ops = _ssd_grad_case(2, 5, 16, 8, 8)
    jax.grad(lambda *ops: jnp.sum(ssd_chunk_scan(*ops)[0] ** 2),
             argnums=tuple(range(7)))(*ops)
    s = engine.stats()["ssd_chunk"]
    assert s["launches_bwd"] == 1
    assert s["plan_source_model_bwd"] == 1
