"""Calibration + autotuning subsystem (DESIGN.md §7).

Covers: probe-calibrated machine models, candidate enumeration, plan
record round-trips, the persistent tuning cache (including corrupt-file
degradation), and the engine's three-tier plan resolution — asserting
``plan_source`` provenance for every tier and the warm-start guarantee
(a populated cache file means zero autotune timings after a "restart").
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FlashDescriptor, GemmDescriptor,
                        GroupedGemmDescriptor, SsdChunkDescriptor,
                        TransposeDescriptor, autotune, candidate_plans,
                        engine, matmul, plan_flash, plan_gemm, plan_ssd,
                        plan_transpose, use)
from repro.core.jit_cache import GLOBAL_KERNEL_CACHE
from repro.core.machine import CPU_HOST, MachineModel, TPU_V5E
from repro.core.microbench import ProbeResult

RNG = np.random.default_rng(7)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.fixture(autouse=True)
def fresh_engine():
    engine.reset_stats()
    yield
    engine.reset_stats()


# ---------------------------------------------------------------------------
# Microbench-calibrated machine models
# ---------------------------------------------------------------------------

PROBES = {
    "matmul_float32": ProbeResult("matmul_float32", 50.0, "GFLOP/s"),
    "copy_bw": ProbeResult("copy_bw", 12.5, "GB/s"),
    "dispatch_latency": ProbeResult("dispatch_latency", 3.0, "us"),
    "target_peak_float32": ProbeResult("target_peak_float32", 98500.0,
                                       "GFLOP/s"),  # echo entry: ignored
}


def test_from_probes_overrides_measured_constants():
    m = MachineModel.from_probes(PROBES, base=CPU_HOST, name="cal")
    assert m.name == "cal"
    assert m.peak("float32") == pytest.approx(50e9)
    assert m.hbm_bw == pytest.approx(12.5e9)
    assert m.step_overhead_s == pytest.approx(3e-6)
    # unprobed constants come from the base
    assert m.vmem_bytes == CPU_HOST.vmem_bytes
    assert m.peak("bfloat16") == CPU_HOST.peak("bfloat16")


def test_from_probes_partial_and_iterable():
    m = MachineModel.from_probes([ProbeResult("copy_bw", 100.0, "GB/s")])
    assert m.hbm_bw == pytest.approx(100e9)
    assert m.step_overhead_s == CPU_HOST.step_overhead_s  # default base


def test_calibrated_overhead_feeds_cost_model():
    slow = dataclasses.replace(TPU_V5E, step_overhead_s=1e-3)
    d = GemmDescriptor(m=640, n=640, k=512)
    plan = plan_gemm(d)
    assert plan.predicted_seconds(slow) > plan.predicted_seconds(TPU_V5E)


def test_same_name_different_constants_plan_separately():
    """Two calibrations of one host share a name but not plans: the plan
    cache keys on the constants fingerprint, not the name alone."""
    m1 = MachineModel.from_probes(
        [ProbeResult("matmul_float32", 50.0, "GFLOP/s")], base=TPU_V5E)
    m2 = MachineModel.from_probes(
        [ProbeResult("matmul_float32", 500.0, "GFLOP/s")], base=TPU_V5E)
    assert m1.name == m2.name and m1.fingerprint != m2.fingerprint
    d = GemmDescriptor(m=640, n=640, k=512)
    engine.plan_for(d, machine=m1)
    engine.plan_for(d, machine=m2)
    assert engine.stats()["gemm"]["planner_calls"] == 2
    # and the identical model IS a cache hit
    engine.plan_for(d, machine=m1)
    assert engine.stats()["gemm"]["planner_calls"] == 2


def test_calibrate_smoke():
    from repro.core.microbench import calibrate
    m = calibrate(size=64, mbytes=1)
    assert m.name == "calibrated_host"
    assert m.peak("float32") > 0 and m.hbm_bw > 0
    assert m.step_overhead_s > 0


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def test_gemm_candidates_ranked_and_agree_with_planner():
    d = GemmDescriptor(m=300, n=500, k=128)
    cands = candidate_plans(d, top_k=6)
    assert 1 <= len(cands) <= 6
    times = [p.predicted_seconds(TPU_V5E) for p in cands]
    assert times == sorted(times)
    # The cheapest candidate is at least as good as the planner's pick:
    # the planner's fused bit is legality-gated while the calibrated cost
    # model may rank the multi-launch lowering of the same cover first
    # (see test_blocking's measured-loss-shapes regression), so the two
    # need not be the *same* plan.
    assert (cands[0].predicted_seconds(TPU_V5E)
            <= plan_gemm(d).predicted_seconds(TPU_V5E) * (1 + 1e-9))
    for p in cands:
        p.validate()  # every candidate covers C exactly once
    # knob-level dedup: fused and multi-launch lowerings of one region
    # cover are distinct candidates (DESIGN.md §8)
    knobs = [(p.regions, p.bk, p.fused) for p in cands]
    assert len(set(knobs)) == len(knobs)
    # Both lowerings are enumerated (the calibrated model ranks fused
    # behind multi-launch on this shape, so check the full search space).
    full = candidate_plans(d, top_k=256)
    assert any(p.fused for p in full) and any(not p.fused for p in full)


def test_flash_and_transpose_candidates():
    fd = FlashDescriptor(batch_heads=4, sq=256, sk=256, d=64)
    fc = candidate_plans(fd, top_k=4)
    assert fc[0].block_q == plan_flash(fd).block_q
    assert fc[0].block_k == plan_flash(fd).block_k
    td = TransposeDescriptor(rows=200, cols=300)
    tc = candidate_plans(td, top_k=3)
    assert tc[0].bt == plan_transpose(td).bt


def test_ssd_has_single_candidate():
    d = SsdChunkDescriptor(groups=4, q=64, n=32, p=64)
    cands = candidate_plans(d, top_k=8)
    assert len(cands) == 1
    assert cands[0] == plan_ssd(d)


def test_unknown_family_candidates_rejected():
    class FakeDesc:
        family = "conv"
    with pytest.raises(KeyError, match="candidate enumerator"):
        candidate_plans(FakeDesc())


# ---------------------------------------------------------------------------
# Plan <-> record round-trips
# ---------------------------------------------------------------------------

ROUNDTRIP_CASES = [
    plan_gemm(GemmDescriptor(m=300, n=500, k=128)),
    plan_flash(FlashDescriptor(batch_heads=4, sq=256, sk=128, d=64)),
    plan_transpose(TransposeDescriptor(rows=100, cols=300)),
    plan_ssd(SsdChunkDescriptor(groups=4, q=64, n=32, p=64)),
]


@pytest.mark.parametrize("plan", ROUNDTRIP_CASES,
                         ids=lambda p: p.desc.family)
def test_plan_record_roundtrip(plan):
    record = autotune.plan_to_record(plan)
    assert json.loads(json.dumps(record)) == record  # JSON-stable
    back = autotune.plan_from_record(plan.desc, record)
    assert back is not None
    assert back.plan_source == "autotuned"
    assert dataclasses.replace(back, plan_source=plan.plan_source) == plan


def test_forced_fused_mode_filters_candidates(tmp_path):
    """A config.fused override makes the executor ignore candidate fused
    bits, so search must only time (and persist) matching candidates —
    never record an untimed lowering (DESIGN.md §8)."""
    path = str(tmp_path / "tune.json")
    a, b = rand((48, 64)), rand((64, 80))
    with use(backend="pallas", autotune=True, autotune_budget=6,
             tuning_cache=path, fused="off"):
        matmul(a, b)
    entries = json.load(open(path))["entries"]
    assert entries and all(rec["fused"] is False
                           for rec in entries.values())


def test_plan_from_record_degrades_to_none():
    d = GemmDescriptor(m=64, n=64, k=64)
    assert autotune.plan_from_record(d, {"family": "transpose", "bt": 64}) \
        is None  # family mismatch
    assert autotune.plan_from_record(d, {"family": "gemm"}) is None  # knobs
    assert autotune.plan_from_record(d, {}) is None


# ---------------------------------------------------------------------------
# Tuning cache persistence
# ---------------------------------------------------------------------------

def test_tuning_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    d = GemmDescriptor(m=80, n=80, k=64)
    plan = plan_gemm(d)
    cache = autotune.TuningCache(path)
    assert len(cache) == 0
    assert cache.lookup(TPU_V5E.name, d, interpret=True) is None
    cache.store(TPU_V5E.name, d, plan, 123.4, interpret=True)
    # a fresh mirror (new process) reads the same winner back
    reread = autotune.TuningCache(path)
    record = reread.lookup(TPU_V5E.name, d, interpret=True)
    assert record is not None and record["us"] == pytest.approx(123.4)
    rebuilt = autotune.plan_from_record(d, record)
    assert rebuilt.regions == plan.regions and rebuilt.bk == plan.bk
    # keyed by machine and by execution mode: an interpret-timed winner
    # says nothing about compiled runs
    assert reread.lookup(CPU_HOST.name, d, interpret=True) is None
    assert reread.lookup(TPU_V5E.name, d, interpret=False) is None


def test_tuning_cache_corrupt_file_degrades(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("{not json")
    with pytest.warns(UserWarning, match="corrupt tuning cache"):
        cache = autotune.TuningCache(str(path))
    assert len(cache) == 0
    # storing heals the file
    d = GemmDescriptor(m=80, n=80, k=64)
    cache.store(TPU_V5E.name, d, plan_gemm(d), 1.0, interpret=True)
    assert len(autotune.TuningCache(str(path))) == 1


def test_tuning_cache_wrong_schema_degrades(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text(json.dumps([1, 2, 3]))
    with pytest.warns(UserWarning, match="corrupt tuning cache"):
        assert len(autotune.TuningCache(str(path))) == 0


# ---------------------------------------------------------------------------
# Three-tier dispatch policy (the acceptance criterion)
# ---------------------------------------------------------------------------

def _gemm_operands(m=80, n=80, k=64):
    return rand((m, k)), rand((k, n))


def test_tier_model_default(tmp_path):
    a, b = _gemm_operands()
    with use(backend="pallas"):
        matmul(a, b)
    s = engine.stats()["gemm"]
    assert s["plan_source_model"] == 1
    assert s["plan_source_autotuned"] == 0
    assert s["plan_source_tuned_cache"] == 0
    assert s["autotune_timings"] == 0
    assert engine.plan_for(GemmDescriptor(m=80, n=80, k=64)
                           ).plan_source == "model"


def test_tier_autotune_then_tuned_cache_warm_start(tmp_path):
    path = str(tmp_path / "tune.json")
    a, b = _gemm_operands()
    ref = np.asarray(a) @ np.asarray(b)

    # --- "process 1": cold cache, autotune tier fires -------------------
    with use(backend="pallas", autotune=True, tuning_cache=path,
             autotune_budget=3):
        out = matmul(a, b)
        out2 = matmul(a, b)  # plan-cache hit: no second search
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out2), ref, rtol=1e-4, atol=1e-4)
    s = engine.stats()["gemm"]
    assert s["plan_source_autotuned"] == 1
    assert s["plan_source_tuned_cache"] == 0
    assert 0 < s["autotune_timings"] <= 3
    data = json.load(open(path))
    assert data["version"] == autotune.TUNING_CACHE_VERSION
    assert len(data["entries"]) == 1
    (record,) = data["entries"].values()
    assert record["family"] == "gemm" and record["us"] > 0

    # --- "process 2": restart (drop all in-memory state, keep the file);
    # the warm cache must satisfy the plan with ZERO autotune timings ----
    engine.reset_stats()
    with use(backend="pallas", autotune=True, tuning_cache=path,
             autotune_budget=3):
        out3 = matmul(a, b)
    np.testing.assert_allclose(np.asarray(out3), ref, rtol=1e-4, atol=1e-4)
    s = engine.stats()["gemm"]
    assert s["plan_source_tuned_cache"] == 1
    assert s["plan_source_autotuned"] == 0
    assert s["autotune_timings"] == 0, \
        "a populated tuning cache must not re-time candidates"


def test_tier_order_tuned_cache_preempts_autotune(tmp_path):
    """A cache entry stored out-of-band wins over a fresh search."""
    path = str(tmp_path / "tune.json")
    d = GemmDescriptor(m=80, n=80, k=64)
    pinned = plan_gemm(d, force_block=(8, 128), heterogeneous=False)
    autotune.TuningCache(path).store(TPU_V5E.name, d, pinned, 1.0,
                                     interpret=True)
    engine.reset_stats()
    a, b = _gemm_operands()
    with use(backend="pallas", autotune=True, tuning_cache=path):
        matmul(a, b)
    s = engine.stats()["gemm"]
    assert s["plan_source_tuned_cache"] == 1 and s["autotune_timings"] == 0
    with use(backend="pallas", autotune=True, tuning_cache=path):
        plan = engine.plan_for(d)
    assert plan.plan_source == "autotuned"
    assert plan.regions == pinned.regions


def test_corrupt_cache_falls_back_to_model(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("][ definitely not json")
    a, b = _gemm_operands()
    with pytest.warns(UserWarning, match="corrupt tuning cache"):
        with use(backend="pallas", tuning_cache=str(path)):
            out = matmul(a, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)
    s = engine.stats()["gemm"]
    assert s["plan_source_model"] == 1 and s["plan_source_tuned_cache"] == 0


def test_autotuned_winner_overwrites_stale_traced_plan(tmp_path):
    """A jit trace that resolves before the tuning cache is populated
    caches a model plan on the tuned-tier key; a later eager autotune
    must overwrite it, not serve it for the rest of the process."""
    path = str(tmp_path / "tune.json")
    a, b = _gemm_operands()
    d = GemmDescriptor(m=80, n=80, k=64)
    with use(backend="pallas", autotune=True, tuning_cache=path,
             autotune_budget=3):
        jax.jit(matmul)(a, b)  # tracers: tuned tier misses, model plan cached
        assert engine.plan_for(d).plan_source == "model"
        matmul(a, b)           # concrete: autotunes + propagates the winner
        assert engine.plan_for(d).plan_source == "autotuned"


def test_env_budget_malformed_falls_back(monkeypatch):
    """A bad REPRO_AUTOTUNE_BUDGET must not take down `import repro`."""
    from repro.core import config
    monkeypatch.setenv("REPRO_AUTOTUNE_BUDGET", "abc")
    with pytest.warns(UserWarning, match="REPRO_AUTOTUNE_BUDGET"):
        assert config._env_default().autotune_budget == 8
    monkeypatch.setenv("REPRO_AUTOTUNE_BUDGET", "0")
    with pytest.warns(UserWarning, match="REPRO_AUTOTUNE_BUDGET"):
        assert config._env_default().autotune_budget == 8
    monkeypatch.setenv("REPRO_AUTOTUNE_BUDGET", "5")
    assert config._env_default().autotune_budget == 5


def test_search_short_circuits_single_candidate():
    """One candidate (ssd_chunk has no free knobs) means nothing to
    choose: no executions are timed and the model tier serves the plan."""
    d = SsdChunkDescriptor(groups=2, q=32, n=16, p=32)
    executed = []
    plan, timed = autotune.search(
        lambda *a, **k: executed.append(1), d, TPU_V5E, (), {},
        interpret=True, budget=8)
    assert plan is None and timed == 0 and not executed


def test_autotune_skipped_under_jit_tracing(tmp_path):
    """Tracers can't be timed: inside jit the policy resolves via the
    analytical model and performs zero timings."""
    path = str(tmp_path / "tune.json")
    # A shape no other test jits: jax caches traces by (function, avals),
    # and a cache hit would skip dispatch entirely.
    a, b = _gemm_operands(m=56, n=88, k=48)
    with use(backend="pallas", autotune=True, tuning_cache=path):
        out = jax.jit(matmul)(a, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)
    s = engine.stats()["gemm"]
    assert s["plan_source_model"] == 1 and s["autotune_timings"] == 0


def test_autotune_other_families(tmp_path):
    """The policy is family-agnostic: transpose autotunes and warm-starts
    through the same cache file as gemm."""
    path = str(tmp_path / "tune.json")
    from repro.kernels.transpose import transpose
    x = rand((72, 136))
    with use(backend="pallas", autotune=True, tuning_cache=path,
             autotune_budget=2):
        out = transpose(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x).T)
    s = engine.stats()["transpose"]
    assert s["plan_source_autotuned"] == 1 and s["autotune_timings"] > 0
    engine.reset_stats()
    with use(backend="pallas", autotune=True, tuning_cache=path):
        transpose(x)
    s = engine.stats()["transpose"]
    assert s["plan_source_tuned_cache"] == 1 and s["autotune_timings"] == 0


# ---------------------------------------------------------------------------
# Per-phase stats reset (benchmarks/run.py contract)
# ---------------------------------------------------------------------------

def test_reset_stats_keeps_entries_for_phase_boundaries():
    a, b = _gemm_operands()
    with use(backend="pallas"):
        matmul(a, b)
    kernels_built = len(GLOBAL_KERNEL_CACHE)
    assert kernels_built > 0
    engine.reset_stats(entries=False)
    s = engine.stats()
    assert all(v == 0 for fam in s.values() for v in fam.values())
    # next "phase" reuses the warm caches: hits, no rebuilds
    with use(backend="pallas"):
        matmul(a, b)
    s = engine.stats()["gemm"]
    assert s["plan_hits"] == 1 and s["plan_misses"] == 0
    assert s["kernel_misses"] == 0 and s["kernel_hits"] >= 1
    assert len(GLOBAL_KERNEL_CACHE) == kernels_built
