"""Transpose / grouped-GEMM / flash-attention kernels vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels.transpose import transpose, ref_transpose
from repro.kernels.grouped_gemm import grouped_gemm, ref_grouped_gemm
from repro.kernels.flash_attention import flash_attention, ref_attention

RNG = np.random.default_rng(7)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("rows,cols,bt", [
    (256, 512, 128), (100, 300, 64), (7, 1000, 256), (128, 128, 128),
    (1, 5, 8),
])
def test_transpose(rows, cols, bt):
    x = rand((rows, cols))
    np.testing.assert_array_equal(transpose(x, bt=bt), ref_transpose(x))


def test_transpose_batched():
    x = rand((3, 64, 96))
    np.testing.assert_array_equal(transpose(x, bt=32), ref_transpose(x))


def test_transpose_batched_is_single_launch():
    """Batch walks as a grid dimension (DESIGN.md §9): a batched transpose
    is ONE pallas_call, visible to the launch counter — not B vmap'd
    launches it can't see."""
    from repro.core import engine
    engine.reset_stats()
    x = rand((7, 40, 56))
    out = transpose(x, bt=32)
    np.testing.assert_array_equal(out, ref_transpose(x))
    assert engine.stats()["transpose"]["launches"] == 1


@pytest.mark.parametrize("sizes,bm", [
    ([37, 0, 201, 70], 32), ([128, 64, 0, 64], 64), ([5, 3, 2, 1], 8),
    ([300], 128), ([0, 0, 17], 16),
])
def test_grouped_gemm(sizes, bm):
    sizes_a = jnp.array(sizes, jnp.int32)
    e, kdim, n = len(sizes), 96, 160
    t = int(sizes_a.sum()) + 4
    x, w = rand((t, kdim)), rand((e, kdim, n))
    out = grouped_gemm(x, w, sizes_a, bm=bm, bk=64, bn=64)
    ref = ref_grouped_gemm(x, w, sizes_a)
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


def _check_grouped_gemm(sizes):
    sizes_a = jnp.array(sizes, jnp.int32)
    e, kdim, n = len(sizes), 32, 48
    t = max(1, int(sizes_a.sum()))
    x, w = rand((t, kdim)), rand((e, kdim, n))
    out = grouped_gemm(x, w, sizes_a, bm=16, bk=32, bn=48)
    ref = ref_grouped_gemm(x, w, sizes_a)
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.integers(0, 60), min_size=1, max_size=5))
    def test_grouped_gemm_property(sizes):
        _check_grouped_gemm(sizes)
else:
    # Deterministic fallback: empty / single / ragged / all-empty groups.
    @pytest.mark.parametrize("sizes", [[0], [1], [60], [0, 0, 0],
                                       [17, 0, 42, 3], [60, 60, 60, 60, 60]])
    def test_grouped_gemm_property(sizes):
        _check_grouped_gemm(sizes)


# ---------------------------------------------------------------------------
# Grouped GEMM scheduled single-launch path (DESIGN.md §9): the fused
# lowering must be bit-identical to the pad/scatter lowering (same bk
# chunking, same fp32 accumulation order — masking instead of padding)
# and match the oracle across every ragged case.
# ---------------------------------------------------------------------------

# (group_sizes, extra rows past sum) — zero-size experts, sum < T, a
# single expert owning all rows, and M/K/N-tail-inducing shapes.
GROUPED_RAGGED_CASES = [
    ([37, 0, 201, 70], 4),
    ([0, 0, 0], 5),        # all experts empty: output all zeros
    ([300], 0),            # one expert owns every row
    ([5, 3, 2, 1], 0),
    ([0, 0, 17], 10),
    ([60, 60, 60], 33),    # sum < T with aligned groups
]


def _grouped_case(sizes, t_extra, kdim=100, n=70):
    sizes_a = jnp.array(sizes, jnp.int32)
    t = max(1, int(sizes_a.sum()) + t_extra)
    x = rand((t, kdim))
    w = rand((len(sizes), kdim, n))
    return sizes_a, x, w


@pytest.mark.parametrize("sizes,t_extra", GROUPED_RAGGED_CASES)
def test_grouped_fused_matches_padscatter_bitwise(sizes, t_extra):
    sizes_a, x, w = _grouped_case(sizes, t_extra)
    # bm=16/bk=64/bn=32 force M, K and N tails on every case above
    kw = dict(bm=16, bk=64, bn=32)
    fused = grouped_gemm(x, w, sizes_a, fused=True, **kw)
    padded = grouped_gemm(x, w, sizes_a, fused=False, **kw)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(padded))
    ref = ref_grouped_gemm(x, w, sizes_a)
    np.testing.assert_allclose(fused, ref, atol=1e-3, rtol=1e-3)


def test_grouped_fused_matches_ref_bitwise_single_k_panel():
    """With one K panel the fused kernel's accumulation order matches the
    oracle einsum exactly — bit-identical, not just close."""
    sizes_a, x, w = _grouped_case([37, 0, 201, 70], 4, kdim=96, n=160)
    out = grouped_gemm(x, w, sizes_a, fused=True)
    ref = ref_grouped_gemm(x, w, sizes_a)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("epilogue", ["bias", "gelu", "silu", "relu",
                                      "bias_gelu", "bias_silu"])
def test_grouped_epilogues_fused_vs_padscatter(epilogue):
    """Per-expert bias + activation epilogues lower identically on both
    paths (shared kernels/epilogue.py on the fp32 accumulator)."""
    sizes_a, x, w = _grouped_case([13, 0, 40, 7], 5)
    bias = rand((4, 70)) if "bias" in epilogue else None
    kw = dict(bm=16, bk=64, bn=32, epilogue=epilogue, bias=bias)
    fused = grouped_gemm(x, w, sizes_a, fused=True, **kw)
    padded = grouped_gemm(x, w, sizes_a, fused=False, **kw)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(padded))
    # against the oracle: epilogue applied per-expert on valid rows only
    ref = ref_grouped_gemm(x, w, sizes_a)
    if "bias" in epilogue:
        offsets = np.concatenate([[0], np.cumsum(np.asarray(sizes_a))])
        expert = np.clip(np.searchsorted(offsets, np.arange(x.shape[0]),
                                         side="right") - 1, 0, 3)
        ref = ref + np.asarray(bias)[expert]
    if epilogue in ("gelu", "bias_gelu"):
        ref = jax.nn.gelu(ref)
    elif epilogue in ("silu", "bias_silu"):
        ref = jax.nn.silu(ref)
    elif epilogue == "relu":
        ref = jnp.maximum(ref, 0)
    total = int(np.asarray(sizes_a).sum())
    valid = (np.arange(x.shape[0]) < total)[:, None]
    ref = jnp.where(valid, ref, 0.0)
    np.testing.assert_allclose(fused, ref, atol=1e-4, rtol=1e-4)


def test_grouped_bias_epilogue_requires_bias():
    sizes_a, x, w = _grouped_case([8, 8], 0, kdim=16, n=16)
    with pytest.raises(ValueError, match="bias"):
        grouped_gemm(x, w, sizes_a, epilogue="bias")


def test_grouped_multi_expert_dispatch_is_single_launch():
    """Acceptance (DESIGN.md §9): a multi-expert ragged dispatch executes
    as exactly ONE pallas_call when fused, with no pad/scatter host ops —
    mirroring tests/test_kernels_gemm.py's GEMM assertion."""
    from repro.core import engine
    engine.reset_stats()
    sizes_a, x, w = _grouped_case([37, 0, 201, 70], 4)
    fused = grouped_gemm(x, w, sizes_a, fused=True)
    assert engine.stats()["grouped_gemm"]["launches"] == 1
    padded = grouped_gemm(x, w, sizes_a, fused=False)
    # the pad/scatter lowering is also one launch — it pays in scatter/
    # gather traffic, not dispatches
    assert engine.stats()["grouped_gemm"]["launches"] == 2
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(padded))


def test_grouped_fused_under_jit():
    """group_sizes is runtime data: the scheduled path must trace (tables
    are jnp ops on the traced operand, static shapes throughout)."""
    sizes_a, x, w = _grouped_case([13, 0, 40, 7], 5)
    f = jax.jit(lambda x, w, s: grouped_gemm(x, w, s, fused=True))
    np.testing.assert_allclose(f(x, w, sizes_a),
                               ref_grouped_gemm(x, w, sizes_a),
                               atol=1e-3, rtol=1e-3)


def test_grouped_plan_defaults_to_fused():
    """The analytical planner takes the paper's one-kernel stance when
    the staged operands fit VMEM."""
    from repro.core import (GroupedGemmDescriptor, grouped_fused_legal,
                            plan_grouped)
    d = GroupedGemmDescriptor(t=256, k=96, n=160, num_experts=4)
    assert grouped_fused_legal(d)
    assert plan_grouped(d).fused
    huge = GroupedGemmDescriptor(t=1 << 20, k=4096, n=4096, num_experts=64)
    assert not grouped_fused_legal(huge)
    assert not plan_grouped(huge).fused


@pytest.mark.parametrize("b,s,h,d,causal,bq,bk", [
    (2, 256, 4, 64, True, 128, 128),
    (1, 384, 2, 128, True, 128, 128),
    (2, 128, 3, 64, False, 64, 64),
    (1, 96, 1, 64, True, 64, 64),  # ragged seq vs block
])
def test_flash_attention(b, s, h, d, causal, bq, bk):
    q, k, v = rand((b, s, h, d)), rand((b, s, h, d)), rand((b, s, h, d))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=2e-3)


def test_flash_attention_bf16():
    q = rand((2, 128, 2, 64), jnp.bfloat16)
    k = rand((2, 128, 2, 64), jnp.bfloat16)
    v = rand((2, 128, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2,
                               rtol=3e-2)


# ---------------------------------------------------------------------------
# Flash attention scheduled single-launch path (DESIGN.md §10): the fused
# causal-aware tile-table lowering must be bit-identical to the dense-grid
# pre-schedule lowering (same per-tile online-softmax math; dropped causal
# tiles were exact no-ops) and match the oracle.
# ---------------------------------------------------------------------------

# (b, h, sq, sk, d, bq, bk) — sq/sk/d tails vs the block sizes, ragged
# sq != sk (non-causal), multi-head batches folded into the supergrid.
FLASH_PARITY_CASES = [
    (2, 4, 256, 256, 64, 128, 128),   # aligned, multi-head
    (1, 2, 96, 96, 64, 64, 64),       # sq/sk tails (96 % 64)
    (2, 3, 100, 100, 48, 64, 32),     # ragged everything incl. d=48
    (1, 1, 130, 70, 32, 64, 32),      # sq != sk
    (3, 2, 33, 257, 16, 32, 128),     # long-k, tiny blocks
]


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,sq,sk,d,bq,bk", FLASH_PARITY_CASES)
def test_flash_fused_matches_dense_grid_bitwise(b, h, sq, sk, d, bq, bk,
                                                causal, dtype):
    q = rand((b, sq, h, d), dtype)
    k = rand((b, sk, h, d), dtype)
    v = rand((b, sk, h, d), dtype)
    kw = dict(causal=causal, block_q=bq, block_k=bk)
    fused = flash_attention(q, k, v, fused=True, **kw)
    dense = flash_attention(q, k, v, fused=False, **kw)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(dense))
    if causal and sq != sk:
        # the kernels' causal diagonal is start-aligned (kpos <= qpos);
        # the oracle end-aligns it — only the lowerings are comparable
        return
    ref = ref_attention(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(fused, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_fused_causal_is_single_launch_fewer_tiles():
    """Acceptance (DESIGN.md §10): a causal dispatch with fused legal is
    exactly ONE pallas_call and walks fewer tiles than the dense (q, k)
    grid — the masked k-blocks never enter the table."""
    from repro.core import FlashDescriptor, FlashPlan, engine, plan_flash
    desc = FlashDescriptor(batch_heads=4, sq=512, sk=512, d=64, causal=True)
    assert plan_flash(desc).fused  # the planner takes the one-kernel stance
    # pin 128x128 blocks: a 4x4 (q, k) grid whose upper triangle the
    # table drops — 10 tiles instead of 16
    plan = FlashPlan(desc, 128, 128, fused=True)
    sched = plan.tile_schedule()
    assert sched.dense_tiles == 16 and sched.num_tiles == 10
    engine.reset_stats()
    q = rand((2, 512, 2, 64))
    out = flash_attention(q, q, q, causal=True, block_q=128, block_k=128)
    assert engine.stats()["flash_attention"]["launches"] == 1
    np.testing.assert_allclose(out, ref_attention(q, q, q, causal=True),
                               atol=2e-3, rtol=2e-3)
    # the dense-grid fallback is also one pallas_call — it pays in grid
    # steps for masked tiles, not dispatches
    flash_attention(q, q, q, causal=True, block_q=128, block_k=128,
                    fused=False)
    assert engine.stats()["flash_attention"]["launches"] == 2


def test_flash_plan_defaults_to_fused():
    """Fused whenever one batch-head slice of q/k/v + out stages in VMEM;
    VMEM-oversized problems fall back to the dense grid."""
    from repro.core import FlashDescriptor, flash_fused_legal, plan_flash
    d = FlashDescriptor(batch_heads=8, sq=2048, sk=2048, d=64)
    assert flash_fused_legal(d)
    assert plan_flash(d).fused
    huge = FlashDescriptor(batch_heads=8, sq=1 << 20, sk=1 << 20, d=128)
    assert not flash_fused_legal(huge)
    assert not plan_flash(huge).fused


# ---------------------------------------------------------------------------
# SSD intra-chunk kernel (the small-GEMM ladder in its Mamba-2 habitat)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,q,n,p", [(6, 64, 32, 64), (2, 128, 128, 64),
                                     (1, 32, 16, 16)])
def test_ssd_chunk_kernel(g, q, n, p):
    from repro.kernels.ssd_chunk import ssd_chunk_diag, ref_ssd_chunk_diag
    c = rand((g, q, n))
    b = rand((g, q, n))
    x = rand((g, q, p))
    l = jnp.tril(jnp.exp(rand((g, q, q)) * 0.1))
    out = ssd_chunk_diag(c, b, l, x)
    ref = ref_ssd_chunk_diag(c, b, l, x)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=2e-3)


def test_ssd_chunk_matches_model_ladder():
    """The kernel reproduces the y_diag term of the model's chunked SSD."""
    from repro.kernels.ssd_chunk import ssd_chunk_diag
    from repro.models.ssd import _segsum
    b_, nc, q, h, p, n = 1, 2, 8, 2, 4, 3
    x = rand((b_, nc, q, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b_, nc, q, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = rand((b_, nc, q, 1, n))
    C = rand((b_, nc, q, 1, n))
    da = dt * a[None, None, None, :]
    L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (b, nc, h, q, q)
    xdt = x * dt[..., None]
    # flatten (b, nc, h) into kernel groups
    cg = jnp.broadcast_to(C.transpose(0, 1, 3, 2, 4), (b_, nc, h, q, n)) \
        .reshape(-1, q, n)
    bg = jnp.broadcast_to(B.transpose(0, 1, 3, 2, 4), (b_, nc, h, q, n)) \
        .reshape(-1, q, n)
    lg = L.reshape(-1, q, q)
    xg = xdt.transpose(0, 1, 3, 2, 4).reshape(-1, q, p)
    y_kernel = ssd_chunk_diag(cg, bg, lg, xg).reshape(b_, nc, h, q, p)

    cb = jnp.einsum("bnqgd,bnkgd->bngqk", C, B)
    cb = jnp.repeat(cb, h, axis=2)
    w = cb * L
    y_ref = jnp.einsum("bnhqk,bnkhp->bnqhp", w.astype(x.dtype), xdt)
    np.testing.assert_allclose(y_kernel.transpose(0, 1, 3, 2, 4), y_ref,
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# SSD carried-state scan (DESIGN.md §10): the fused single-launch lowering
# (state carried across the sequential chunk grid dimension) vs the diag
# kernel + XLA associative-scan fallback vs the sequential oracle.
# ---------------------------------------------------------------------------

def _ssd_scan_case(g, nc, q, n, p, seed=11):
    r = np.random.default_rng(seed)
    arr = lambda s: jnp.asarray(r.standard_normal(s), jnp.float32)
    c, b = arr((g, nc, q, n)), arr((g, nc, q, n))
    l = jnp.tril(jnp.exp(arr((g, nc, q, q)) * 0.1))
    x = arr((g, nc, q, p))
    # physical decays: da negative, so decay_in = exp(da_cs) in (0, 1]
    # with decay_in[-1] the whole-chunk decay the state update reads
    da_cs = -jnp.cumsum(jnp.abs(arr((g, nc, q))) * 0.1, axis=-1)
    di = jnp.exp(da_cs)
    do = jnp.exp(da_cs[..., -1:] - da_cs)
    s0 = arr((g, p, n))
    return c, b, l, x, di, do, s0


@pytest.mark.parametrize("g,nc,q,n,p", [
    (2, 3, 16, 8, 12),    # odd little everything
    (1, 1, 8, 4, 4),      # single chunk: recurrence degenerates to s0
    (4, 7, 32, 16, 8),    # longer carried-state walk
])
def test_ssd_scan_fused_matches_fallback(g, nc, q, n, p):
    from repro.core import engine
    from repro.kernels.ssd_chunk import ssd_chunk_scan, ref_ssd_chunk_scan
    ops = _ssd_scan_case(g, nc, q, n, p)
    engine.reset_stats()
    from repro.core.config import use
    y_f, s_f = ssd_chunk_scan(*ops)
    # fused: the whole scan — intra ladder AND inter-chunk recurrence —
    # is exactly ONE pallas_call
    assert engine.stats()["ssd_chunk"]["launches"] == 1
    with use(fused="off"):
        y_m, s_m = ssd_chunk_scan(*ops)
    np.testing.assert_allclose(y_f, y_m, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s_f, s_m, atol=2e-3, rtol=2e-3)
    y_r, s_r = ref_ssd_chunk_scan(*ops)
    np.testing.assert_allclose(y_f, y_r, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s_f, s_r, atol=2e-3, rtol=2e-3)


def test_ssd_scan_carried_state_tail():
    """Carried-state tails: a scan split in two with the intermediate
    state handed across the seam equals the unsplit scan — the property
    decode warm-starts (s0 != 0) rely on."""
    from repro.kernels.ssd_chunk import ssd_chunk_scan
    c, b, l, x, di, do, s0 = _ssd_scan_case(2, 4, 16, 8, 12)
    y_full, s_full = ssd_chunk_scan(c, b, l, x, di, do, s0)
    cut = 2
    y1, s_mid = ssd_chunk_scan(c[:, :cut], b[:, :cut], l[:, :cut],
                               x[:, :cut], di[:, :cut], do[:, :cut], s0)
    y2, s_end = ssd_chunk_scan(c[:, cut:], b[:, cut:], l[:, cut:],
                               x[:, cut:], di[:, cut:], do[:, cut:], s_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), y_full,
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s_end, s_full, atol=2e-3, rtol=2e-3)


def test_ssd_scan_under_jit():
    """The scan form must trace: static shapes, carried scratch, two
    outputs."""
    from repro.kernels.ssd_chunk import ssd_chunk_scan, ref_ssd_chunk_scan
    ops = _ssd_scan_case(2, 3, 8, 4, 4)
    y_j, s_j = jax.jit(ssd_chunk_scan)(*ops)
    y_r, s_r = ref_ssd_chunk_scan(*ops)
    np.testing.assert_allclose(y_j, y_r, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s_j, s_r, atol=2e-3, rtol=2e-3)


def test_ssd_model_routes_through_scan():
    """models/ssd.py under the pallas backend: one ssd_chunk launch for
    the whole chunked forward, bit-for-bit state/output parity with the
    XLA formulation within tolerance."""
    from repro.core import engine
    from repro.core.config import use
    from repro.models.ssd import _ssd_chunked
    r = np.random.default_rng(3)
    b, s, h, p, g, n, chunk = 2, 20, 4, 8, 2, 6, 8  # ragged s: pad to 24
    x = jnp.asarray(r.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = -jnp.asarray(r.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = jnp.asarray(r.standard_normal((b, s, g, n)), jnp.float32)
    C = jnp.asarray(r.standard_normal((b, s, g, n)), jnp.float32)
    s0 = jnp.asarray(r.standard_normal((b, h, p, n)), jnp.float32)
    y_x, f_x = _ssd_chunked(x, dt, a, B, C, chunk, s0)
    engine.reset_stats()
    with use(backend="pallas"):
        y_p, f_p = _ssd_chunked(x, dt, a, B, C, chunk, s0)
    assert engine.stats()["ssd_chunk"]["launches"] == 1
    np.testing.assert_allclose(y_x, y_p, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(f_x, f_p, atol=2e-3, rtol=2e-3)
