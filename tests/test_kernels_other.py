"""Transpose / grouped-GEMM / flash-attention kernels vs oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels.transpose import transpose, ref_transpose
from repro.kernels.grouped_gemm import grouped_gemm, ref_grouped_gemm
from repro.kernels.flash_attention import flash_attention, ref_attention

RNG = np.random.default_rng(7)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("rows,cols,bt", [
    (256, 512, 128), (100, 300, 64), (7, 1000, 256), (128, 128, 128),
    (1, 5, 8),
])
def test_transpose(rows, cols, bt):
    x = rand((rows, cols))
    np.testing.assert_array_equal(transpose(x, bt=bt), ref_transpose(x))


def test_transpose_batched():
    x = rand((3, 64, 96))
    np.testing.assert_array_equal(transpose(x, bt=32), ref_transpose(x))


@pytest.mark.parametrize("sizes,bm", [
    ([37, 0, 201, 70], 32), ([128, 64, 0, 64], 64), ([5, 3, 2, 1], 8),
    ([300], 128), ([0, 0, 17], 16),
])
def test_grouped_gemm(sizes, bm):
    sizes_a = jnp.array(sizes, jnp.int32)
    e, kdim, n = len(sizes), 96, 160
    t = int(sizes_a.sum()) + 4
    x, w = rand((t, kdim)), rand((e, kdim, n))
    out = grouped_gemm(x, w, sizes_a, bm=bm, bk=64, bn=64)
    ref = ref_grouped_gemm(x, w, sizes_a)
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


def _check_grouped_gemm(sizes):
    sizes_a = jnp.array(sizes, jnp.int32)
    e, kdim, n = len(sizes), 32, 48
    t = max(1, int(sizes_a.sum()))
    x, w = rand((t, kdim)), rand((e, kdim, n))
    out = grouped_gemm(x, w, sizes_a, bm=16, bk=32, bn=48)
    ref = ref_grouped_gemm(x, w, sizes_a)
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.integers(0, 60), min_size=1, max_size=5))
    def test_grouped_gemm_property(sizes):
        _check_grouped_gemm(sizes)
else:
    # Deterministic fallback: empty / single / ragged / all-empty groups.
    @pytest.mark.parametrize("sizes", [[0], [1], [60], [0, 0, 0],
                                       [17, 0, 42, 3], [60, 60, 60, 60, 60]])
    def test_grouped_gemm_property(sizes):
        _check_grouped_gemm(sizes)


@pytest.mark.parametrize("b,s,h,d,causal,bq,bk", [
    (2, 256, 4, 64, True, 128, 128),
    (1, 384, 2, 128, True, 128, 128),
    (2, 128, 3, 64, False, 64, 64),
    (1, 96, 1, 64, True, 64, 64),  # ragged seq vs block
])
def test_flash_attention(b, s, h, d, causal, bq, bk):
    q, k, v = rand((b, s, h, d)), rand((b, s, h, d)), rand((b, s, h, d))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=2e-3)


def test_flash_attention_bf16():
    q = rand((2, 128, 2, 64), jnp.bfloat16)
    k = rand((2, 128, 2, 64), jnp.bfloat16)
    v = rand((2, 128, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2,
                               rtol=3e-2)


# ---------------------------------------------------------------------------
# SSD intra-chunk kernel (the small-GEMM ladder in its Mamba-2 habitat)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,q,n,p", [(6, 64, 32, 64), (2, 128, 128, 64),
                                     (1, 32, 16, 16)])
def test_ssd_chunk_kernel(g, q, n, p):
    from repro.kernels.ssd_chunk import ssd_chunk_diag, ref_ssd_chunk_diag
    c = rand((g, q, n))
    b = rand((g, q, n))
    x = rand((g, q, p))
    l = jnp.tril(jnp.exp(rand((g, q, q)) * 0.1))
    out = ssd_chunk_diag(c, b, l, x)
    ref = ref_ssd_chunk_diag(c, b, l, x)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=2e-3)


def test_ssd_chunk_matches_model_ladder():
    """The kernel reproduces the y_diag term of the model's chunked SSD."""
    from repro.kernels.ssd_chunk import ssd_chunk_diag
    from repro.models.ssd import _segsum
    b_, nc, q, h, p, n = 1, 2, 8, 2, 4, 3
    x = rand((b_, nc, q, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b_, nc, q, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = rand((b_, nc, q, 1, n))
    C = rand((b_, nc, q, 1, n))
    da = dt * a[None, None, None, :]
    L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (b, nc, h, q, q)
    xdt = x * dt[..., None]
    # flatten (b, nc, h) into kernel groups
    cg = jnp.broadcast_to(C.transpose(0, 1, 3, 2, 4), (b_, nc, h, q, n)) \
        .reshape(-1, q, n)
    bg = jnp.broadcast_to(B.transpose(0, 1, 3, 2, 4), (b_, nc, h, q, n)) \
        .reshape(-1, q, n)
    lg = L.reshape(-1, q, q)
    xg = xdt.transpose(0, 1, 3, 2, 4).reshape(-1, q, p)
    y_kernel = ssd_chunk_diag(cg, bg, lg, xg).reshape(b_, nc, h, q, p)

    cb = jnp.einsum("bnqgd,bnkgd->bngqk", C, B)
    cb = jnp.repeat(cb, h, axis=2)
    w = cb * L
    y_ref = jnp.einsum("bnhqk,bnkhp->bnqhp", w.astype(x.dtype), xdt)
    np.testing.assert_allclose(y_kernel.transpose(0, 1, 3, 2, 4), y_ref,
                               atol=2e-3, rtol=2e-3)
