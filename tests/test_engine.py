"""Engine layer: dispatch, plan/kernel caches, descriptors, config."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, matmul, use
from repro.core.config import get_config
from repro.core.descriptor import (FlashDescriptor, GemmDescriptor,
                                   GroupedGemmDescriptor, SsdChunkDescriptor,
                                   TransposeDescriptor)
from repro.core.jit_cache import LruCache

RNG = np.random.default_rng(3)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.fixture(autouse=True)
def fresh_engine():
    engine.reset_stats()
    yield
    engine.reset_stats()


# ---------------------------------------------------------------------------
# Descriptor round-trips — all five families
# ---------------------------------------------------------------------------

DESCRIPTORS = [
    GemmDescriptor(m=64, n=96, k=32, layout="nt", epilogue="gelu"),
    FlashDescriptor(batch_heads=8, sq=256, sk=256, d=64, causal=True),
    GroupedGemmDescriptor(t=300, k=96, n=160, num_experts=4),
    SsdChunkDescriptor(groups=12, q=64, n=32, p=64),
    TransposeDescriptor(rows=100, cols=300),
]


@pytest.mark.parametrize("desc", DESCRIPTORS, ids=lambda d: d.family)
def test_descriptor_hash_equality_roundtrip(desc):
    clone = dataclasses.replace(desc)
    assert clone == desc and hash(clone) == hash(desc)
    assert clone.cache_key() == desc.cache_key()
    assert desc.cache_key()[0] == desc.family
    # a changed field breaks equality (take the first int field)
    field = next(f.name for f in dataclasses.fields(desc)
                 if isinstance(getattr(desc, f.name), int))
    other = dataclasses.replace(desc, **{field: getattr(desc, field) + 1})
    assert other != desc and other.cache_key() != desc.cache_key()
    # usable as a dict key
    assert {desc: 1, other: 2}[clone] == 1


@pytest.mark.parametrize("desc", DESCRIPTORS, ids=lambda d: d.family)
def test_descriptor_accounting_positive(desc):
    assert desc.flops >= 0
    assert desc.in_bytes > 0 and desc.out_bytes > 0
    assert desc.arithmetic_intensity >= 0.0


def test_descriptor_costing_hooks():
    from repro.launch.hlo_cost import descriptor_cost
    from repro.launch.roofline import kernel_roofline
    for desc in DESCRIPTORS:
        r = kernel_roofline(desc)
        assert r["dominant"] in ("compute", "memory")
        c = descriptor_cost(desc)
        assert c["flops"] == float(desc.flops)
        assert set(c) >= {"flops", "bytes", "collectives", "collective_bytes"}


# ---------------------------------------------------------------------------
# Plan cache: repeated same-shape matmul plans once
# ---------------------------------------------------------------------------

def test_matmul_plan_cache_hit_on_repeat():
    a, b = rand((48, 64)), rand((64, 80))
    with use(backend="pallas"):
        out1 = matmul(a, b)
        out2 = matmul(a, b)
    np.testing.assert_allclose(out1, out2, atol=0, rtol=0)
    s = engine.stats()["gemm"]
    assert s["planner_calls"] == 1, "second call must not re-plan"
    assert s["plan_misses"] == 1
    assert s["plan_hits"] >= 1
    assert s["kernel_misses"] >= 1 and s["kernel_hits"] >= 1


def test_stats_expose_plan_source_counters():
    """Every stats bucket carries three-tier provenance (DESIGN.md §7);
    the default policy resolves via the analytical model."""
    a, b = rand((48, 64)), rand((64, 80))
    with use(backend="pallas"):
        matmul(a, b)
    s = engine.stats()["gemm"]
    assert s["plan_source_model"] == 1
    assert s["plan_source_autotuned"] == 0
    assert s["plan_source_tuned_cache"] == 0
    assert s["autotune_timings"] == 0
    assert s["launches"] >= 1  # traced pallas_call launches (DESIGN.md §8)


def test_different_shapes_plan_separately():
    with use(backend="pallas"):
        matmul(rand((32, 32)), rand((32, 32)))
        matmul(rand((32, 48)), rand((48, 32)))
    s = engine.stats()["gemm"]
    assert s["planner_calls"] == 2 and s["plan_misses"] == 2


def test_per_family_stats_buckets():
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.transpose import transpose
    q = rand((1, 64, 1, 64))
    flash_attention(q, q, q)
    transpose(rand((40, 56)))
    s = engine.stats()
    assert s["flash_attention"]["planner_calls"] == 1
    assert s["transpose"]["planner_calls"] == 1
    assert s["flash_attention"]["kernel_misses"] == 1
    assert s["transpose"]["kernel_misses"] == 1
    # buckets are independent
    assert "gemm" not in s or s["gemm"]["planner_calls"] == 0


# ---------------------------------------------------------------------------
# LRU cache mechanics
# ---------------------------------------------------------------------------

def test_lru_eviction_order():
    c = LruCache(max_entries=2)
    c.get_or_build(("f", 1), lambda: "a")
    c.get_or_build(("f", 2), lambda: "b")
    c.get_or_build(("f", 1), lambda: "a")   # refresh 1 -> 2 is now LRU
    c.get_or_build(("f", 3), lambda: "c")   # evicts 2, not 1
    assert c.keys() == [("f", 1), ("f", 3)]
    assert c.evictions == 1
    # rebuilding the evicted key is a miss; the refreshed key is a hit
    calls = []
    c.get_or_build(("f", 2), lambda: calls.append(1) or "b")
    assert calls == [1]


def test_lru_put_overwrites_and_evicts():
    c = LruCache(max_entries=2)
    c.get_or_build(("f", 1), lambda: "a")
    c.put(("f", 1), "A")  # overwrite in place, no growth
    assert c.get_or_build(("f", 1), lambda: "x") == "A"
    c.put(("f", 2), "b")
    c.put(("f", 3), "c")  # over capacity: evicts the LRU entry ("f", 1)
    assert c.keys() == [("f", 2), ("f", 3)]
    assert c.evictions == 1


def test_lru_family_stats():
    c = LruCache(max_entries=1)
    c.get_or_build(("gemm", 1), lambda: 1)
    c.get_or_build(("gemm", 1), lambda: 1)
    c.get_or_build(("transpose", 1), lambda: 2)  # evicts the gemm entry
    st = c.family_stats()
    assert st["gemm"] == {"hits": 1, "misses": 1, "evictions": 1}
    assert st["transpose"] == {"hits": 0, "misses": 1, "evictions": 0}


# ---------------------------------------------------------------------------
# Config + error paths
# ---------------------------------------------------------------------------

def test_config_nesting_restores():
    assert get_config().backend == "xla"
    with use(backend="pallas", interpret=True):
        assert get_config().backend == "pallas"
        with use(backend="xla"):
            assert get_config().backend == "xla"
        assert get_config().backend == "pallas"
    assert get_config().backend == "xla"


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        with use(backend="cuda"):
            pass


def test_bias_epilogue_requires_bias_xla():
    a, b = rand((16, 16)), rand((16, 16))
    with pytest.raises(ValueError, match="bias"):
        matmul(a, b, epilogue="bias")  # xla path


def test_bias_epilogue_requires_bias_pallas():
    from repro.kernels.gemm import gemm
    a, b = rand((16, 16)), rand((16, 16))
    with pytest.raises(ValueError, match="bias"):
        with use(backend="pallas"):
            matmul(a, b, epilogue="bias_gelu")
    with pytest.raises(ValueError, match="bias"):
        gemm(a, b, epilogue="bias_silu")


def test_unknown_family_rejected():
    with pytest.raises(KeyError, match="unknown kernel family"):
        engine.get_family("conv")


# ---------------------------------------------------------------------------
# Planner sanity for the non-GEMM families
# ---------------------------------------------------------------------------

def test_planned_tiles_respect_problem_and_machine():
    from repro.core import (plan_flash, plan_grouped, plan_ssd,
                            plan_transpose)
    from repro.core.machine import TPU_V5E
    fp = plan_flash(FlashDescriptor(batch_heads=4, sq=384, sk=384, d=64))
    assert fp.block_q >= 8 and fp.block_k >= 8
    gp = plan_grouped(GroupedGemmDescriptor(t=4096, k=512, n=1024,
                                            num_experts=8))
    vmem = gp.bm * gp.bn * 4 + 2 * (gp.bm * gp.bk + gp.bk * gp.bn) * 4
    assert vmem <= TPU_V5E.vmem_bytes // 2
    assert gp.t_padded >= 4096
    tp = plan_transpose(TransposeDescriptor(rows=1000, cols=3000))
    assert 2 * tp.bt * tp.bt * 4 <= TPU_V5E.vmem_bytes // 2
    sp = plan_ssd(SsdChunkDescriptor(groups=16, q=128, n=128, p=64))
    assert sp.fits_vmem


def test_plan_cache_key_includes_machine():
    from repro.core.machine import CPU_HOST
    d = GemmDescriptor(m=64, n=64, k=64)
    p1 = engine.plan_for(d)
    p2 = engine.plan_for(d, machine=CPU_HOST)
    assert engine.stats()["gemm"]["planner_calls"] == 2
    assert p1 is engine.plan_for(d)  # cached
