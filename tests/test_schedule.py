"""The schedule layer (DESIGN.md §9/§10): property-style validation of
the dense, grouped and flash tile schedules, table packing, and launch
accounting."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (GemmDescriptor, GroupedGemmDescriptor,
                        GroupedTileSchedule, plan_gemm, plan_grouped)
from repro.core.schedule import (QUANT_TILE, TILE_COMPUTE, TILE_SKIP,
                                 TILE_ZERO, ceil_div, flash_tile_schedule,
                                 flatten_regions, pack_table,
                                 plan_launches)


# ---------------------------------------------------------------------------
# Dense (GEMM) schedules
# ---------------------------------------------------------------------------

def _check_gemm_schedule(m, n, k):
    """Every C cell owned by exactly one tile; windows in bounds; the
    packed scalar-prefetch table is int32."""
    plan = plan_gemm(GemmDescriptor(m=m, n=n, k=k))
    sched = plan.tile_schedule()
    sched.validate()  # exact ownership + in-bounds clamped windows
    assert sched.bk <= k and sched.k_steps == ceil_div(k, sched.bk)
    # cell-exact ownership (validate() checks areas; this checks cells)
    owned = np.zeros((m, n), dtype=np.int64)
    for row0, col0, row_end, col_end, rs, cs, bid, sidx in sched.tiles:
        owned[row0:row_end, col0:col_end] += 1
        assert sidx == rs // QUANT_TILE
    assert (owned == 1).all()
    table = pack_table(sched.tiles)
    assert table.dtype == np.int32 and table.shape == (sched.num_tiles, 8)


_GEMM_CASES = [(1, 1, 1), (7, 33, 100), (128, 128, 128), (300, 500, 128),
               (513, 129, 257), (80, 80, 512), (1, 2048, 64), (640, 640, 512)]


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(m=st.integers(1, 1024), n=st.integers(1, 1024),
           k=st.integers(1, 2048))
    def test_gemm_schedule_ownership(m, n, k):
        _check_gemm_schedule(m, n, k)
else:
    @pytest.mark.parametrize("m,n,k", _GEMM_CASES)
    def test_gemm_schedule_ownership(m, n, k):
        _check_gemm_schedule(m, n, k)


def test_flatten_regions_matches_plan_tile_schedule():
    """BlockingPlan.tile_schedule delegates to the schedule layer."""
    plan = plan_gemm(GemmDescriptor(m=640, n=640, k=512),
                     force_block=(256, 256))
    d = plan.desc
    assert plan.tile_schedule() == flatten_regions(d.m, d.n, d.k, plan.bk,
                                                   plan.regions)


def test_pack_table_rejects_flat_rows():
    with pytest.raises(AssertionError):
        pack_table([1, 2, 3])


# ---------------------------------------------------------------------------
# Grouped (ragged) schedules
# ---------------------------------------------------------------------------

def _check_grouped_tables(sizes, t_extra, bm=16):
    """Runtime tables from group_sizes: every output row owned exactly
    once (compute rows by their expert, tail rows by zero-fill tiles),
    windows in bounds, int32 packing."""
    sizes = np.asarray(sizes, dtype=np.int32)
    t = max(1, int(sizes.sum()) + t_extra)
    sched = GroupedTileSchedule(t=t, k=32, n=48, num_experts=len(sizes),
                                bm=min(bm, t), bk=32, bn=48)
    import jax.numpy as jnp
    table = np.asarray(sched.tables(jnp.asarray(sizes)))
    assert table.dtype == np.int32
    sched.validate_tables(table, sizes)
    # State accounting: zero tiles iff rows are left over.
    states = table[:, 4]
    assert ((states == TILE_ZERO).any()) == (int(sizes.sum()) < t)
    assert (states != TILE_SKIP).sum() <= sched.max_tiles


_GROUPED_CASES = [
    ([37, 0, 201, 70], 4),   # ragged + zero-size expert + tail rows
    ([0, 0, 0], 5),          # all experts empty: pure zero-fill
    ([300], 0),              # single expert owns all rows
    ([5, 3, 2, 1], 0),       # sub-block groups, no tail
    ([0, 0, 17], 10),        # leading empties + tail
    ([1], 0),                # minimal
]


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(sizes=st.lists(st.integers(0, 70), min_size=1, max_size=6),
           t_extra=st.integers(0, 20))
    def test_grouped_tables_ownership(sizes, t_extra):
        _check_grouped_tables(sizes, t_extra)
else:
    @pytest.mark.parametrize("sizes,t_extra", _GROUPED_CASES)
    def test_grouped_tables_ownership(sizes, t_extra):
        _check_grouped_tables(sizes, t_extra)


def test_grouped_schedule_static_bounds():
    """max_tiles is a static bound: every expert may add one partial
    block plus the zero-fill tail — never exceeded, even adversarially."""
    sched = GroupedTileSchedule(t=100, k=32, n=32, num_experts=4,
                                bm=16, bk=32, bn=32)
    assert sched.max_tiles == ceil_div(100, 16) + 4 + 1
    import jax.numpy as jnp
    worst = jnp.asarray([1, 1, 1, 97], jnp.int32)  # max partial blocks
    table = np.asarray(sched.tables(worst))
    assert (table[:, 4] != TILE_SKIP).sum() <= sched.max_tiles
    sched.validate_tables(table, np.asarray(worst))


def test_grouped_plan_tile_schedule_clamps_blocks():
    """Plan blocks larger than the problem clamp so windows fit."""
    desc = GroupedGemmDescriptor(t=7, k=9, n=11, num_experts=2)
    plan = plan_grouped(desc)
    sched = plan.tile_schedule()
    assert sched.bm <= 7 and sched.bk <= 9 and sched.bn <= 11


def test_grouped_compute_tiles_never_cross_experts():
    """A compute tile's owned rows all belong to one expert — the
    property that lets the kernel pull a single weight panel per tile."""
    import jax.numpy as jnp
    sizes = np.asarray([13, 7, 0, 21], np.int32)
    sched = GroupedTileSchedule(t=50, k=16, n=16, num_experts=4,
                                bm=8, bk=16, bn=16)
    table = np.asarray(sched.tables(jnp.asarray(sizes)))
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    for row0, row_end, rs, expert, state in table:
        if state != TILE_COMPUTE:
            continue
        assert offsets[expert] <= row0 and row_end <= offsets[expert + 1]


# ---------------------------------------------------------------------------
# Flash (causal-aware) schedules
# ---------------------------------------------------------------------------

def _check_flash_schedule(sq, sk, bq, bk, causal):
    """Every query row drained exactly once; causal k-blocks above the
    diagonal dropped at plan time; every kept (q, k) pair that the dense
    grid would compute is covered by exactly one tile's [k0, k_end)."""
    sched = flash_tile_schedule(sq, sk, bq, bk, causal)
    sched.validate()
    assert sched.num_tiles <= sched.dense_tiles
    # column coverage per q-block: union of [k0, k_end) over its tiles
    # equals the visible prefix of [0, sk)
    cover = {}
    for q0, q_end, qs, k0, k_end, ks, first, last in sched.tiles:
        cover.setdefault((q0, q_end), []).append((k0, k_end))
    for (q0, q_end), spans in cover.items():
        hit = np.zeros(sk, np.int64)
        for k0, k_end in spans:
            hit[k0:k_end] += 1
        if causal:
            # every column visible to the last owned row is covered once
            visible = min(sk, q_end)
            assert (hit[:visible] == 1).all()
            assert (hit[min(sk, ceil_div(q_end, sched.bk) * sched.bk):]
                    == 0).all()
        else:
            assert (hit == 1).all()


_FLASH_CASES = [
    (256, 256, 128, 128, True), (96, 96, 64, 64, True),
    (100, 100, 64, 32, True), (130, 70, 64, 32, False),
    (1, 1, 64, 64, True), (7, 300, 8, 128, True), (512, 512, 128, 64, False),
]


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(sq=st.integers(1, 600), sk=st.integers(1, 600),
           bq=st.sampled_from([8, 32, 64, 128]),
           bk=st.sampled_from([8, 32, 64, 128]),
           causal=st.booleans())
    def test_flash_schedule_coverage(sq, sk, bq, bk, causal):
        _check_flash_schedule(sq, sk, bq, bk, causal)
else:
    @pytest.mark.parametrize("sq,sk,bq,bk,causal", _FLASH_CASES)
    def test_flash_schedule_coverage(sq, sk, bq, bk, causal):
        _check_flash_schedule(sq, sk, bq, bk, causal)


def test_flash_schedule_causal_drops_tiles():
    """The causal triangle drops ~half the dense grid at plan time — the
    acceptance property the launch/step savings rest on."""
    sched = flash_tile_schedule(2048, 2048, 128, 128, causal=True)
    assert sched.num_tiles < sched.dense_tiles
    # 16x16 grid: lower triangle = 136 of 256
    assert sched.dense_tiles == 256 and sched.num_tiles == 136
    dense = flash_tile_schedule(2048, 2048, 128, 128, causal=False)
    assert dense.num_tiles == dense.dense_tiles == 256
    table = pack_table(sched.tiles)
    assert table.dtype == np.int32 and table.shape == (136, 8)


# ---------------------------------------------------------------------------
# Decode (paged) schedules + the page allocator (DESIGN.md §12)
# ---------------------------------------------------------------------------

from repro.core.schedule import DecodeTileSchedule
from repro.models.attention import PageSpec
from repro.runtime.pages import OutOfPages, PagePool, pages_for


def _pool_for(lengths, page_size, extra_pages=0):
    """A pool sized to hold ``lengths``, with every slot grown to its
    length — the allocator state one scheduler tick would produce."""
    need = [pages_for(L, page_size) for L in lengths]
    spec = PageSpec(num_pages=max(1, sum(need) + extra_pages),
                    page_size=page_size,
                    max_blocks=max(1, max(need, default=1)))
    pool = PagePool(spec, len(lengths))
    for i, L in enumerate(lengths):
        pool.grow(i, L)
    return pool, spec


def _check_decode_tables(lengths, page_size, extra_pages):
    """Rows visit each live page exactly once in block-table order, tail
    k_lens are exact, carries bracket, inactive tail rows are inert —
    and the allocator's invariants hold after building the state."""
    pool, spec = _pool_for(lengths, page_size, extra_pages)
    pool.check_invariants(list(lengths))
    sched = DecodeTileSchedule(num_seqs=len(lengths), pages=spec.num_pages,
                               page_size=page_size,
                               max_blocks=spec.max_blocks)
    import jax.numpy as jnp
    table = np.asarray(sched.tables(jnp.asarray(pool.tables),
                                    jnp.asarray(lengths, jnp.int32)))
    assert table.dtype == np.int32
    sched.validate_tables(table, pool.tables, np.asarray(lengths))


_DECODE_CASES = [
    ([0, 0, 0], 4, 2),       # all slots idle: one dummy row each
    ([5], 4, 0),             # single seq, ragged tail
    ([16, 16], 16, 0),       # exact page multiples
    ([1, 33, 0, 7], 8, 3),   # mixed live/idle, multi-page walk
    ([9, 9, 9, 9, 9], 3, 0), # every seq spans several pages
    ([100], 8, 5),           # long single sequence
]


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(lengths=st.lists(st.integers(0, 70), min_size=1, max_size=6),
           page_size=st.sampled_from([1, 4, 8, 16]),
           extra_pages=st.integers(0, 8))
    def test_decode_tables_coverage(lengths, page_size, extra_pages):
        _check_decode_tables(lengths, page_size, extra_pages)
else:
    @pytest.mark.parametrize("lengths,page_size,extra_pages", _DECODE_CASES)
    def test_decode_tables_coverage(lengths, page_size, extra_pages):
        _check_decode_tables(lengths, page_size, extra_pages)


def test_decode_schedule_static_bounds():
    """max_tiles caps the walk pool-wide: live pages are exclusively
    owned, so compute tiles can never exceed min(S*B, pages), and every
    slot adds at most one dummy row."""
    sched = DecodeTileSchedule(num_seqs=3, pages=5, page_size=4,
                               max_blocks=4)
    assert sched.max_tiles == 5 + 3
    assert sched.max_len == 16
    import jax.numpy as jnp
    bt = jnp.asarray([[0, 1, 0, 0], [2, 3, 4, 0], [0, 0, 0, 0]], jnp.int32)
    lengths = np.asarray([8, 12, 0])
    table = np.asarray(sched.tables(bt, jnp.asarray(lengths)))
    sched.validate_tables(table, np.asarray(bt), lengths)
    # 2 + 3 live pages + 1 dummy for the idle slot = 6 active rows
    active = (table[:, 3] | table[:, 4] | (table[:, 2] > 0)).sum()
    assert active == 6 <= sched.max_tiles


def _check_pool_ops(ops, page_size, num_pages, num_slots):
    """Allocator conservation under an arbitrary grow/release trace: no
    page double-owned, free list + live pages exactly partition the
    pool, block tables cover exactly ceil(len/page) pages per slot."""
    spec = PageSpec(num_pages=num_pages, page_size=page_size,
                    max_blocks=num_pages)
    pool = PagePool(spec, num_slots)
    lengths = [0] * num_slots
    for kind, slot, length in ops:
        slot %= num_slots
        if kind == "grow":
            try:
                pool.grow(slot, length)
                lengths[slot] = max(lengths[slot], length)
            except (OutOfPages, ValueError):
                pass  # rejected (queue / unmappable) — state untouched
        else:
            pool.release(slot)
            lengths[slot] = 0
        pool.check_invariants(lengths)
        for i in range(num_slots):
            assert pool.slot_blocks(i) == pages_for(lengths[i], page_size)
    assert pool.free_pages == num_pages - sum(
        pages_for(L, page_size) for L in lengths)


_POOL_CASES = [
    ([("grow", 0, 9), ("grow", 1, 5), ("release", 0, 0),
      ("grow", 2, 12), ("release", 1, 0), ("grow", 0, 3)], 4, 6, 3),
    ([("grow", 0, 50)], 4, 2, 1),  # oversized growth only queues
    ([("grow", 0, 8), ("grow", 0, 8), ("release", 0, 0),
      ("release", 0, 0)], 8, 2, 1),  # idempotent re-release
]


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["grow", "release"]),
                  st.integers(0, 5), st.integers(0, 40)),
        min_size=1, max_size=20),
        page_size=st.sampled_from([2, 4, 8]),
        num_pages=st.integers(1, 12),
        num_slots=st.integers(1, 4))
    def test_page_pool_conservation(ops, page_size, num_pages, num_slots):
        _check_pool_ops(ops, page_size, num_pages, num_slots)
else:
    @pytest.mark.parametrize("ops,page_size,num_pages,num_slots",
                             _POOL_CASES)
    def test_page_pool_conservation(ops, page_size, num_pages, num_slots):
        _check_pool_ops(ops, page_size, num_pages, num_slots)


def test_page_pool_faults():
    """OutOfPages when the free list runs dry; ValueError when a length
    can never be mapped; release frees exactly the victim's pages."""
    pool = PagePool(PageSpec(num_pages=4, page_size=4, max_blocks=3), 2)
    pool.grow(0, 12)  # 3 pages
    assert pool.free_pages == 1
    with pytest.raises(OutOfPages):
        pool.grow(1, 8)  # needs 2, only 1 free
    assert pool.free_pages == 1  # failed growth must not leak
    with pytest.raises(ValueError):
        pool.grow(1, 13)  # 4 pages > max_blocks
    assert pool.release(0) == 3
    assert pool.free_pages == 4
    assert pool.grow(1, 8) and pool.slot_blocks(1) == 2
    pool.check_invariants([0, 8])


def test_pages_for():
    assert [pages_for(L, 4) for L in (0, 1, 4, 5, 8)] == [0, 1, 1, 2, 2]


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------

def test_plan_launches():
    gemm_plan = plan_gemm(GemmDescriptor(m=640, n=640, k=512),
                          force_block=(256, 256))
    assert len(gemm_plan.regions) >= 3
    assert plan_launches(gemm_plan, fused=True) == 1
    assert plan_launches(gemm_plan, fused=False) == len(gemm_plan.regions)
    grouped = plan_grouped(GroupedGemmDescriptor(t=64, k=32, n=32,
                                                 num_experts=2))
    # both grouped lowerings are single pallas_calls (pad/scatter pays in
    # stitch traffic, not launches)
    assert plan_launches(grouped, fused=True) == 1
    assert plan_launches(grouped, fused=False) == 1
