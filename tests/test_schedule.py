"""The schedule layer (DESIGN.md §9/§10): property-style validation of
the dense, grouped and flash tile schedules, table packing, and launch
accounting."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (GemmDescriptor, GroupedGemmDescriptor,
                        GroupedTileSchedule, plan_gemm, plan_grouped)
from repro.core.schedule import (TILE_COMPUTE, TILE_SKIP, TILE_ZERO,
                                 ceil_div, flash_tile_schedule,
                                 flatten_regions, pack_table,
                                 plan_launches)


# ---------------------------------------------------------------------------
# Dense (GEMM) schedules
# ---------------------------------------------------------------------------

def _check_gemm_schedule(m, n, k):
    """Every C cell owned by exactly one tile; windows in bounds; the
    packed scalar-prefetch table is int32."""
    plan = plan_gemm(GemmDescriptor(m=m, n=n, k=k))
    sched = plan.tile_schedule()
    sched.validate()  # exact ownership + in-bounds clamped windows
    assert sched.bk <= k and sched.k_steps == ceil_div(k, sched.bk)
    # cell-exact ownership (validate() checks areas; this checks cells)
    owned = np.zeros((m, n), dtype=np.int64)
    for row0, col0, row_end, col_end, rs, cs, bid in sched.tiles:
        owned[row0:row_end, col0:col_end] += 1
    assert (owned == 1).all()
    table = pack_table(sched.tiles)
    assert table.dtype == np.int32 and table.shape == (sched.num_tiles, 7)


_GEMM_CASES = [(1, 1, 1), (7, 33, 100), (128, 128, 128), (300, 500, 128),
               (513, 129, 257), (80, 80, 512), (1, 2048, 64), (640, 640, 512)]


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(m=st.integers(1, 1024), n=st.integers(1, 1024),
           k=st.integers(1, 2048))
    def test_gemm_schedule_ownership(m, n, k):
        _check_gemm_schedule(m, n, k)
else:
    @pytest.mark.parametrize("m,n,k", _GEMM_CASES)
    def test_gemm_schedule_ownership(m, n, k):
        _check_gemm_schedule(m, n, k)


def test_flatten_regions_matches_plan_tile_schedule():
    """BlockingPlan.tile_schedule delegates to the schedule layer."""
    plan = plan_gemm(GemmDescriptor(m=640, n=640, k=512),
                     force_block=(256, 256))
    d = plan.desc
    assert plan.tile_schedule() == flatten_regions(d.m, d.n, d.k, plan.bk,
                                                   plan.regions)


def test_pack_table_rejects_flat_rows():
    with pytest.raises(AssertionError):
        pack_table([1, 2, 3])


# ---------------------------------------------------------------------------
# Grouped (ragged) schedules
# ---------------------------------------------------------------------------

def _check_grouped_tables(sizes, t_extra, bm=16):
    """Runtime tables from group_sizes: every output row owned exactly
    once (compute rows by their expert, tail rows by zero-fill tiles),
    windows in bounds, int32 packing."""
    sizes = np.asarray(sizes, dtype=np.int32)
    t = max(1, int(sizes.sum()) + t_extra)
    sched = GroupedTileSchedule(t=t, k=32, n=48, num_experts=len(sizes),
                                bm=min(bm, t), bk=32, bn=48)
    import jax.numpy as jnp
    table = np.asarray(sched.tables(jnp.asarray(sizes)))
    assert table.dtype == np.int32
    sched.validate_tables(table, sizes)
    # State accounting: zero tiles iff rows are left over.
    states = table[:, 4]
    assert ((states == TILE_ZERO).any()) == (int(sizes.sum()) < t)
    assert (states != TILE_SKIP).sum() <= sched.max_tiles


_GROUPED_CASES = [
    ([37, 0, 201, 70], 4),   # ragged + zero-size expert + tail rows
    ([0, 0, 0], 5),          # all experts empty: pure zero-fill
    ([300], 0),              # single expert owns all rows
    ([5, 3, 2, 1], 0),       # sub-block groups, no tail
    ([0, 0, 17], 10),        # leading empties + tail
    ([1], 0),                # minimal
]


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(sizes=st.lists(st.integers(0, 70), min_size=1, max_size=6),
           t_extra=st.integers(0, 20))
    def test_grouped_tables_ownership(sizes, t_extra):
        _check_grouped_tables(sizes, t_extra)
else:
    @pytest.mark.parametrize("sizes,t_extra", _GROUPED_CASES)
    def test_grouped_tables_ownership(sizes, t_extra):
        _check_grouped_tables(sizes, t_extra)


def test_grouped_schedule_static_bounds():
    """max_tiles is a static bound: every expert may add one partial
    block plus the zero-fill tail — never exceeded, even adversarially."""
    sched = GroupedTileSchedule(t=100, k=32, n=32, num_experts=4,
                                bm=16, bk=32, bn=32)
    assert sched.max_tiles == ceil_div(100, 16) + 4 + 1
    import jax.numpy as jnp
    worst = jnp.asarray([1, 1, 1, 97], jnp.int32)  # max partial blocks
    table = np.asarray(sched.tables(worst))
    assert (table[:, 4] != TILE_SKIP).sum() <= sched.max_tiles
    sched.validate_tables(table, np.asarray(worst))


def test_grouped_plan_tile_schedule_clamps_blocks():
    """Plan blocks larger than the problem clamp so windows fit."""
    desc = GroupedGemmDescriptor(t=7, k=9, n=11, num_experts=2)
    plan = plan_grouped(desc)
    sched = plan.tile_schedule()
    assert sched.bm <= 7 and sched.bk <= 9 and sched.bn <= 11


def test_grouped_compute_tiles_never_cross_experts():
    """A compute tile's owned rows all belong to one expert — the
    property that lets the kernel pull a single weight panel per tile."""
    import jax.numpy as jnp
    sizes = np.asarray([13, 7, 0, 21], np.int32)
    sched = GroupedTileSchedule(t=50, k=16, n=16, num_experts=4,
                                bm=8, bk=16, bn=16)
    table = np.asarray(sched.tables(jnp.asarray(sizes)))
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    for row0, row_end, rs, expert, state in table:
        if state != TILE_COMPUTE:
            continue
        assert offsets[expert] <= row0 and row_end <= offsets[expert + 1]


# ---------------------------------------------------------------------------
# Flash (causal-aware) schedules
# ---------------------------------------------------------------------------

def _check_flash_schedule(sq, sk, bq, bk, causal):
    """Every query row drained exactly once; causal k-blocks above the
    diagonal dropped at plan time; every kept (q, k) pair that the dense
    grid would compute is covered by exactly one tile's [k0, k_end)."""
    sched = flash_tile_schedule(sq, sk, bq, bk, causal)
    sched.validate()
    assert sched.num_tiles <= sched.dense_tiles
    # column coverage per q-block: union of [k0, k_end) over its tiles
    # equals the visible prefix of [0, sk)
    cover = {}
    for q0, q_end, qs, k0, k_end, ks, first, last in sched.tiles:
        cover.setdefault((q0, q_end), []).append((k0, k_end))
    for (q0, q_end), spans in cover.items():
        hit = np.zeros(sk, np.int64)
        for k0, k_end in spans:
            hit[k0:k_end] += 1
        if causal:
            # every column visible to the last owned row is covered once
            visible = min(sk, q_end)
            assert (hit[:visible] == 1).all()
            assert (hit[min(sk, ceil_div(q_end, sched.bk) * sched.bk):]
                    == 0).all()
        else:
            assert (hit == 1).all()


_FLASH_CASES = [
    (256, 256, 128, 128, True), (96, 96, 64, 64, True),
    (100, 100, 64, 32, True), (130, 70, 64, 32, False),
    (1, 1, 64, 64, True), (7, 300, 8, 128, True), (512, 512, 128, 64, False),
]


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(sq=st.integers(1, 600), sk=st.integers(1, 600),
           bq=st.sampled_from([8, 32, 64, 128]),
           bk=st.sampled_from([8, 32, 64, 128]),
           causal=st.booleans())
    def test_flash_schedule_coverage(sq, sk, bq, bk, causal):
        _check_flash_schedule(sq, sk, bq, bk, causal)
else:
    @pytest.mark.parametrize("sq,sk,bq,bk,causal", _FLASH_CASES)
    def test_flash_schedule_coverage(sq, sk, bq, bk, causal):
        _check_flash_schedule(sq, sk, bq, bk, causal)


def test_flash_schedule_causal_drops_tiles():
    """The causal triangle drops ~half the dense grid at plan time — the
    acceptance property the launch/step savings rest on."""
    sched = flash_tile_schedule(2048, 2048, 128, 128, causal=True)
    assert sched.num_tiles < sched.dense_tiles
    # 16x16 grid: lower triangle = 136 of 256
    assert sched.dense_tiles == 256 and sched.num_tiles == 136
    dense = flash_tile_schedule(2048, 2048, 128, 128, causal=False)
    assert dense.num_tiles == dense.dense_tiles == 256
    table = pack_table(sched.tiles)
    assert table.dtype == np.int32 and table.shape == (136, 8)


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------

def test_plan_launches():
    gemm_plan = plan_gemm(GemmDescriptor(m=640, n=640, k=512),
                          force_block=(256, 256))
    assert len(gemm_plan.regions) >= 3
    assert plan_launches(gemm_plan, fused=True) == 1
    assert plan_launches(gemm_plan, fused=False) == len(gemm_plan.regions)
    grouped = plan_grouped(GroupedGemmDescriptor(t=64, k=32, n=32,
                                                 num_experts=2))
    # both grouped lowerings are single pallas_calls (pad/scatter pays in
    # stitch traffic, not launches)
    assert plan_launches(grouped, fused=True) == 1
    assert plan_launches(grouped, fused=False) == 1
