"""Layer-level correctness: norms, rope, MoE invariants, RG-LRU and SSD
against naive step-by-step recurrence oracles."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import common
from repro.models.rotary import apply_rope
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rglru_apply, rglru_init, _rglru_scan
from repro.models.ssd import ssd_apply, ssd_init, _ssd_chunked

RNG = np.random.default_rng(3)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def test_rmsnorm_matches_manual():
    x = rand((2, 5, 16))
    p = common.rmsnorm_init(16)
    got = common.rmsnorm(p, x, eps=1e-6)
    ref = x / np.sqrt(np.mean(np.square(np.asarray(x)), -1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(got, ref, atol=1e-5)


def test_layernorm_zero_mean_unit_var():
    x = rand((4, 32))
    p = common.layernorm_init(32)
    y = np.asarray(common.layernorm(p, x, eps=1e-6))
    np.testing.assert_allclose(y.mean(-1), 0, atol=1e-5)
    np.testing.assert_allclose(y.var(-1), 1, atol=1e-3)


def test_rope_preserves_norm_and_relativity():
    hd = 32
    x = rand((1, 6, 2, hd))
    pos = jnp.arange(6)
    y = apply_rope(x, pos[None, :], theta=10000.0)
    # rotation preserves per-pair norms
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-4)
    # relative property: <R(p)q, R(k)k'> depends only on p-k
    q, k = rand((1, 1, 1, hd)), rand((1, 1, 1, hd))
    def dot_at(pq, pk):
        rq = apply_rope(q, jnp.array([[pq]]), 10000.0)
        rk = apply_rope(k, jnp.array([[pk]]), 10000.0)
        return float(jnp.sum(rq * rk))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_cfg(**kw):
    return reduced_config(get_config("phi3.5-moe-42b"), **kw)


def test_moe_no_drop_equals_dense_mixture():
    """With capacity so large nothing drops, output == sum of gated expert
    FFNs computed naively."""
    cfg = moe_cfg(capacity_factor=16.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = rand((2, 8, cfg.d_model))
    y, aux = moe_apply(params, cfg, x)

    # naive dense reference
    t = x.reshape(-1, cfg.d_model)
    logits = t @ params["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    vals = vals / vals.sum(-1, keepdims=True)
    ref = jnp.zeros_like(t)
    for e in range(cfg.num_experts):
        up = t @ params["w_up"]["w"][e]
        gate = jax.nn.silu(t @ params["w_gate"]["w"][e])
        out_e = (gate * up) @ params["w_down"]["w"][e]
        w_e = jnp.sum(jnp.where(idx == e, vals, 0.0), -1, keepdims=True)
        ref = ref + w_e * out_e
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), ref,
                               atol=2e-3, rtol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    # enough tokens that the per-group capacity (floored at 8) binds
    cfg = moe_cfg(capacity_factor=0.25)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = rand((2, 512, cfg.d_model))
    y, _ = moe_apply(params, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
    # dropped tokens produce strictly zero output rows somewhere
    norms = jnp.linalg.norm(y.reshape(-1, cfg.d_model), axis=-1)
    assert float(jnp.min(norms)) == 0.0


def test_moe_aux_loss_uniform_router_is_one():
    """With perfectly uniform routing probabilities the GShard aux loss
    equals 1 (E * E * (1/E) * (1/E))."""
    cfg = moe_cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    x = rand((2, 32, cfg.d_model))
    _, aux = moe_apply(params, cfg, x)
    assert abs(float(aux) - 1.0) < 0.05


# ---------------------------------------------------------------------------
# RG-LRU vs naive loop
# ---------------------------------------------------------------------------

def test_rglru_scan_matches_loop():
    b, s, w = 2, 11, 8
    a = jnp.asarray(RNG.uniform(0.5, 0.99, (b, s, w)), jnp.float32)
    xs = rand((b, s, w))
    h0 = rand((b, w))
    got = _rglru_scan(xs, jnp.log(a), h0)
    h = h0
    refs = []
    for t in range(s):
        h = a[:, t] * h + xs[:, t]
        refs.append(h)
    ref = jnp.stack(refs, axis=1)
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_rglru_state_continuity():
    cfg = reduced_config(get_config("recurrentgemma-9b"))
    params = rglru_init(jax.random.PRNGKey(0), cfg)
    x = rand((2, 12, cfg.d_model))
    from repro.models.rglru import init_recurrent_state
    st0 = init_recurrent_state(2, cfg)
    y_full, _ = rglru_apply(params, cfg, x, state=st0)
    y1, st = rglru_apply(params, cfg, x[:, :7], state=st0)
    y2, _ = rglru_apply(params, cfg, x[:, 7:], state=st)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# SSD vs naive recurrence
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_naive_recurrence():
    b, s, h, p, n, chunk = 1, 12, 2, 4, 3, 4
    x = rand((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = rand((b, s, 1, n))
    C = rand((b, s, 1, n))
    y, final = _ssd_chunked(x, dt, a, B, C, chunk)

    S = np.zeros((b, h, p, n), np.float32)
    ys = []
    for t in range(s):
        da = np.exp(np.asarray(dt[:, t]) * np.asarray(a))  # (b, h)
        bx = np.einsum("bn,bhp->bhpn", np.asarray(B[:, t, 0]),
                       np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None])
        S = S * da[..., None, None] + bx
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(C[:, t, 0]), S))
    ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), S, atol=1e-3, rtol=1e-3)


def test_ssd_chunked_initial_state():
    b, s, h, p, n, chunk = 1, 8, 2, 4, 3, 4
    x = rand((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 1.5, (h,)), jnp.float32)
    B, C = rand((b, s, 1, n)), rand((b, s, 1, n))
    y_full, f_full = _ssd_chunked(x, dt, a, B, C, chunk)
    y1, st = _ssd_chunked(x[:, :4], dt[:, :4], a, B[:, :4], C[:, :4], chunk)
    y2, f2 = _ssd_chunked(x[:, 4:], dt[:, 4:], a, B[:, 4:], C[:, 4:], chunk,
                          s0=st)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(f2, f_full, atol=1e-3, rtol=1e-3)
