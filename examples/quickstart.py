"""Quickstart: the JIT small-GEMM engine (the paper's contribution).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (GemmDescriptor, plan_gemm, matmul, backend,
                        GLOBAL_KERNEL_CACHE)
from repro.kernels.gemm import ref_gemm

# --- 1. describe a small, ragged GEMM (the paper's Fig 7 shape) ---------
desc = GemmDescriptor(m=80, n=80, k=512, layout="nn")
plan = plan_gemm(desc)
print(f"plan for C[{desc.m},{desc.n}] += A·B (K={desc.k}):")
for r in plan.regions:
    print(f"  region @({r.row0},{r.col0}) {r.rows}x{r.cols} "
          f"blocked {r.bm}x{r.bn} -> {r.num_microkernels} microkernel(s)")
print(f"  microkernels={plan.num_microkernels} "
      f"utilization={plan.utilization:.2f} "
      f"predicted v5e time={plan.predicted_seconds()*1e6:.2f}us")

# --- 2. run it through the engine (Pallas interpret on CPU) -------------
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((80, 512)), jnp.float32)
b = jnp.asarray(rng.standard_normal((512, 80)), jnp.float32)
with backend("pallas"):
    out = matmul(a, b)
err = float(jnp.max(jnp.abs(out - ref_gemm(a, b))))
print(f"engine vs oracle max err: {err:.2e}")

# --- 3. the JIT cache serves repeat shapes (LIBXSMM dispatch) ------------
with backend("pallas"):
    matmul(a, b)
hits, misses, size = GLOBAL_KERNEL_CACHE.stats()
print(f"kernel cache: hits={hits} misses={misses} entries={size}")

# --- 4. transposed-B (the paper's §IV-C case) ----------------------------
bt = jnp.asarray(rng.standard_normal((80, 512)), jnp.float32)  # B stored (N,K)
with backend("pallas"):
    out_nt = matmul(a, bt, layout="nt")
err = float(jnp.max(jnp.abs(out_nt - ref_gemm(a, bt, layout="nt"))))
print(f"nt-layout (fused transpose) max err: {err:.2e}")
