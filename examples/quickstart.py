"""Quickstart: the descriptor-driven kernel engine (the paper's pipeline).

    PYTHONPATH=src python examples/quickstart.py

Walks the four engine stages on a ragged GEMM — descriptor → plan →
build → dispatch (DESIGN.md §1) — then shows the schedule layer's fused
single-launch execution and the engine's cache/launch counters.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (GemmDescriptor, engine, matmul, plan_gemm, use)
from repro.kernels.gemm import ref_gemm

# --- 1. describe + plan a small, ragged GEMM (the paper's Fig 7 shape) --
desc = GemmDescriptor(m=80, n=80, k=512, layout="nn")
plan = plan_gemm(desc)
print(f"plan for C[{desc.m},{desc.n}] += A·B (K={desc.k}):")
for r in plan.regions:
    print(f"  region @({r.row0},{r.col0}) {r.rows}x{r.cols} "
          f"blocked {r.bm}x{r.bn} -> {r.num_microkernels} microkernel(s)")
print(f"  microkernels={plan.num_microkernels} "
      f"utilization={plan.utilization:.2f} "
      f"fused={plan.fused} "
      f"predicted v5e time={plan.predicted_seconds()*1e6:.2f}us")

# --- 2. dispatch through the engine (Pallas interpret on CPU) -----------
# `use(backend="pallas")` routes matmul through engine.dispatch: plan
# cache -> kernel cache -> the generated pallas_call (DESIGN.md §1).
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((80, 512)), jnp.float32)
b = jnp.asarray(rng.standard_normal((512, 80)), jnp.float32)
engine.reset_stats()
with use(backend="pallas"):
    out = matmul(a, b)
err = float(jnp.max(jnp.abs(out - ref_gemm(a, b))))
print(f"engine vs oracle max err: {err:.2e}")

# --- 3. repeat shapes hit both engine caches (LIBXSMM dispatch) ----------
with use(backend="pallas"):
    matmul(a, b)
s = engine.stats()["gemm"]
print(f"gemm stats: plan_hits={s['plan_hits']} "
      f"plan_misses={s['plan_misses']} kernel_hits={s['kernel_hits']} "
      f"kernel_misses={s['kernel_misses']} launches={s['launches']}")
assert s["plan_hits"] >= 1 and s["kernel_hits"] >= 1

# --- 4. the schedule layer: a fused plan is ONE pallas_call --------------
# The whole region cover executes as a single launch walking the
# flattened tile schedule (DESIGN.md §8-§10); `launches` proves it.
engine.reset_stats()
with use(backend="pallas", fused="on"):
    matmul(a, b)
print(f"fused dispatch launches: {engine.stats()['gemm']['launches']}")
assert engine.stats()["gemm"]["launches"] == 1

# --- 5. transposed-B (the paper's §IV-C case) ----------------------------
bt = jnp.asarray(rng.standard_normal((80, 512)), jnp.float32)  # B as (N,K)
with use(backend="pallas"):
    out_nt = matmul(a, bt, layout="nt")
err = float(jnp.max(jnp.abs(out_nt - ref_gemm(a, bt, layout="nt"))))
print(f"nt-layout (fused transpose) max err: {err:.2e}")

# --- 6. the low-precision axis: int8 with a fused dequant epilogue -------
# `quant="int8"` quantizes both operands to int8 wire dtype, accumulates
# exactly in int32, and folds the dequant multiply into the epilogue —
# still ONE pallas_call (DESIGN.md §13).  The same spec can be set
# ambiently with `configure(quant=...)` / `use(quant=...)` or the
# REPRO_QUANT env var; `quant=False` opts a single call back out.
from repro.kernels.gemm import gemm
from repro.optim.compression import quantize_operand

engine.reset_stats()
with use(backend="pallas"):
    out_q = gemm(a, b, quant="int8")
print(f"quantized dispatch launches: {engine.stats()['gemm']['launches']}")
assert engine.stats()["gemm"]["launches"] == 1

# parity vs the dequantize-then-matmul reference: the only error left
# is the int8 rounding itself (int32 accumulation is exact).
from repro.core.descriptor import resolve_quant
spec = resolve_quant("int8")
aq, sa = quantize_operand(a, spec, axis=0)
bq, sb = quantize_operand(b, spec, axis=1)
ref_q = (aq.astype(jnp.float32) * sa[:, None]) \
    @ (bq.astype(jnp.float32) * sb[None, :])
err = float(jnp.max(jnp.abs(out_q - ref_q)))
print(f"int8 vs dequant reference max err: {err:.2e}")
assert err < 1e-3
