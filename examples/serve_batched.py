"""Batched serving: prefill a batch of prompts, then decode with KV/state
caches — across three architecture families (attention / hybrid / SSM).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.launch.serve import generate
from repro.runtime.steps import model_for

ARCHS = ["qwen3-0.6b", "recurrentgemma-9b", "mamba2-130m"]


def main():
    b, prompt_len, gen_steps = 8, 64, 24
    for arch in ARCHS:
        cfg = reduced_config(get_config(arch))
        model = model_for(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (b, prompt_len), 0, cfg.vocab_size)
        tokens, t_p, t_d = generate(cfg, params, prompts, gen_steps)
        print(f"{arch:20s} out={tuple(tokens.shape)} "
              f"prefill {b*prompt_len/t_p:7.0f} tok/s | "
              f"decode {b*(gen_steps-1)/max(t_d,1e-9):7.0f} tok/s")


if __name__ == "__main__":
    main()
