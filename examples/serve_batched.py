"""Batched serving: prefill a batch of prompts, then decode with KV/state
caches — across three architecture families (attention / hybrid / SSM).

    PYTHONPATH=src python examples/serve_batched.py [--backend pallas]

The backend is ambient engine configuration (``repro.core.configure``,
DESIGN.md §3), not a per-call kwarg: ``--backend pallas`` routes every
attention / SSD / matmul hot path through ``engine.dispatch`` (interpret
mode on CPU) and prints the per-family launch counters afterwards —
e.g. mamba2's whole chunked forward is ONE ssd_chunk launch per layer
call (DESIGN.md §10).  The default XLA backend is the vendor-BLAS
baseline the paper benchmarks against.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core import configure, engine
from repro.launch.serve import generate
from repro.runtime.steps import model_for

ARCHS = ["qwen3-0.6b", "recurrentgemma-9b", "mamba2-130m"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["xla", "pallas"], default="xla",
                    help="engine backend (pallas = interpret mode on CPU)")
    args = ap.parse_args()
    configure(backend=args.backend)

    b, prompt_len, gen_steps = 8, 64, 24
    for arch in ARCHS:
        cfg = reduced_config(get_config(arch))
        model = model_for(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (b, prompt_len), 0, cfg.vocab_size)
        engine.reset_stats()
        res = generate(cfg, params, prompts, gen_steps)
        tokens, t_p, t_d = (res["tokens"], res["prefill_seconds"],
                            res["decode_seconds"])
        print(f"{arch:20s} out={tuple(tokens.shape)} "
              f"prefill {b*prompt_len/t_p:7.0f} tok/s | "
              f"decode {b*(gen_steps-1)/max(t_d,1e-9):7.0f} tok/s")
        if args.backend == "pallas":
            for fam, c in sorted(engine.stats().items()):
                print(f"  engine/{fam}: launches={c['launches']} "
                      f"plan_misses={c['plan_misses']} "
                      f"kernel_misses={c['kernel_misses']}")


if __name__ == "__main__":
    main()
