"""End-to-end training driver: a ~100M-parameter qwen3-family model on the
synthetic bigram stream, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 300        # full
    PYTHONPATH=src python examples/train_lm.py --steps 30 --small # quick

Loss should fall from ~log(vocab) toward the bigram structure floor
log(branching) ≈ 2.08 nats.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data import SyntheticLMDataset
from repro.optim import adamw, warmup_cosine
from repro.runtime.steps import make_train_step, model_for
from repro.runtime.train_loop import TrainLoopConfig, run_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="tiny model for smoke runs")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("qwen3-0.6b")
    if args.small:
        cfg = reduced_config(base, vocab_size=512)
    else:
        # ~128M params: 12 layers, d=768, head_dim 64, tied 32k vocab
        cfg = reduced_config(
            base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=3072, vocab_size=32768, moe_group=1024)
    model = model_for(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}-reduced, {n/1e6:.1f}M params")

    opt = adamw(warmup_cosine(1e-3, max(10, args.steps // 10), args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch,
                            seed=11, branching=8)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in ds.host_batch(step).items()}

    loop = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                           save_every=max(10, args.steps // 4), log_every=10)

    def log(step, m):
        print(f"step {step:4d} nll={m['nll']:.3f} "
              f"gnorm={m['grad_norm']:.2f} dt={m['step_seconds']*1e3:.0f}ms")

    out = run_with_restarts(lambda: (params, opt_state), step_fn, batch_fn,
                            loop, log_fn=log)
    nll0, nll1 = out["metrics"][0]["nll"], out["metrics"][-1]["nll"]
    print(f"\nnll {nll0:.3f} -> {nll1:.3f} | uniform={jnp.log(cfg.vocab_size):.3f} "
          f"structure floor={ds.unigram_floor_nats():.3f} | "
          f"stragglers={out['stragglers']} restarts={out['restarts']}")
    assert nll1 < nll0, "training failed to reduce loss"


if __name__ == "__main__":
    main()
