"""MoE expert compute as ragged grouped small GEMMs — the paper's
technique in its natural framework habitat.

Routes a token batch with a real top-2 router, sorts tokens by expert,
runs the scalar-prefetch grouped-GEMM Pallas kernel, and cross-checks
against the per-expert dense loop.

    PYTHONPATH=src python examples/moe_grouped_gemm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.grouped_gemm import grouped_gemm, ref_grouped_gemm


def main():
    rng = np.random.default_rng(0)
    t, d, f, e, topk = 512, 128, 256, 8, 2
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w_router = jnp.asarray(rng.standard_normal((d, e)) * 0.1, jnp.float32)
    w_up = jnp.asarray(rng.standard_normal((e, d, f)) * 0.05, jnp.float32)

    # --- route and sort ----------------------------------------------------
    probs = jax.nn.softmax(x @ w_router, -1)
    gate, idx = jax.lax.top_k(probs, topk)  # (t, k)
    flat_expert = idx.reshape(-1)           # (t*k,)
    order = jnp.argsort(flat_expert)
    x_expanded = jnp.repeat(x, topk, axis=0)[order]
    sizes = jnp.bincount(flat_expert, length=e)
    print("tokens per expert:", np.asarray(sizes))

    # --- the paper's engine: one ragged grouped GEMM ------------------------
    out_sorted = grouped_gemm(x_expanded, w_up, sizes, bm=64, bk=128, bn=128)
    ref = ref_grouped_gemm(x_expanded, w_up, sizes)
    err = float(jnp.max(jnp.abs(out_sorted - ref)))
    print(f"grouped kernel vs per-expert loop: max err {err:.2e}")

    # --- unsort + combine ----------------------------------------------------
    unsort = jnp.argsort(order)
    out = out_sorted[unsort].reshape(t, topk, f)
    gate = gate / gate.sum(-1, keepdims=True)
    y = jnp.einsum("tkf,tk->tf", out, gate)
    print(f"combined MoE output: {tuple(y.shape)}, "
          f"finite={bool(jnp.isfinite(y).all())}")


if __name__ == "__main__":
    main()
